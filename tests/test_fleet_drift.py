"""Fleet drift detection: fused kernel sweeps against the oracle, and
FleetDriftDetector parity with the scalar per-stream DriftDetector —
bit-identical scores on the exact path, bit-identical trigger decisions
(and triggered-stream scores) under every kernel dispatch mode."""
import jax
import numpy as np
import pytest

from repro.core.drift import (DriftDetector, FleetDriftDetector,
                              batch_token_histogram, js_divergence,
                              js_divergence_rows, token_histogram)
from repro.kernels import ops

ALL_IMPLS = ["exact", "pallas", "interpret", "xla", "ref"]
KERNEL_IMPLS = ["pallas", "interpret", "xla", "ref"]


def _skip_off_tpu(impl):
    if impl == "pallas" and jax.default_backend() != "tpu":
        pytest.skip("pallas compiled mode needs a TPU")


# ---------------------------------------------------------------------------
# kernel sweep: fused histogram + JS vs the materialized oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("N,T,B,vocab", [
    (1, 32, 64, 64),       # single stream
    (5, 64, 64, 64),
    (33, 48, 64, 64),      # pad over tile fraction
    (100, 16, 128, 256),   # vocab > buckets
    (17, 64, 64, 0),       # modulo-hash path (no vocab)
])
@pytest.mark.parametrize("impl", ["interpret", "xla"])
def test_fleet_drift_kernel_sweep(N, T, B, vocab, impl):
    rng = np.random.default_rng(0)
    hi = (vocab or B) + 1           # include token == vocab boundary
    toks = rng.integers(0, hi, size=(N, T))
    ref = rng.random((N, B)).astype(np.float32)
    ref[0] = 0.0                    # zero-sum reference histogram
    got_s, got_h = map(np.asarray, ops.fleet_drift(
        toks, ref, buckets=B, vocab=vocab, impl=impl))
    want_s, want_h = map(np.asarray, ops.fleet_drift(
        toks, ref, buckets=B, vocab=vocab, impl="ref"))
    assert got_s.shape == (N,) and got_h.shape == (N, B)
    assert np.isfinite(got_s).all()
    np.testing.assert_allclose(got_s, want_s, atol=1e-5, rtol=0)
    np.testing.assert_allclose(got_h, want_h, atol=1e-6, rtol=0)


@pytest.mark.parametrize("impl", ["interpret", "xla", "ref"])
def test_fleet_drift_empty_fleet(impl):
    s, h = ops.fleet_drift(np.zeros((0, 8), np.int64),
                           np.zeros((0, 64), np.float32),
                           buckets=64, vocab=64, impl=impl)
    assert np.asarray(s).shape == (0,)
    assert np.asarray(h).shape == (0, 64)


@pytest.mark.parametrize("impl", ["interpret", "xla", "ref"])
def test_fleet_drift_matches_scalar_js(impl):
    """Fused kernel row i == js_divergence(token_histogram(row i), ref i)
    to fp32 accuracy, including the token == vocab clipping edge."""
    rng = np.random.default_rng(1)
    N, T, B, V = 9, 40, 64, 64
    toks = rng.integers(0, V + 1, size=(N, T))
    ref = rng.random((N, B))
    got = np.asarray(ops.fleet_drift(toks, ref.astype(np.float32),
                                     buckets=B, vocab=V, impl=impl)[0])
    for i in range(N):
        want = js_divergence(token_histogram(toks[i], B, V), ref[i])
        assert abs(got[i] - want) < 1e-5


# ---------------------------------------------------------------------------
# exact vectorized primitives: bit-identical to the scalar loop
# ---------------------------------------------------------------------------
def test_batch_token_histogram_bit_identical():
    rng = np.random.default_rng(2)
    toks = rng.integers(0, 65, size=(13, 4, 16))     # includes 64 == vocab
    for vocab in (64, None):
        got = batch_token_histogram(toks, 32, vocab)
        for i in range(13):
            want = token_histogram(toks[i], 32, vocab)
            assert (got[i] == want).all()
    # zero-sum row: no tokens -> unnormalized zeros, same as scalar
    empty = batch_token_histogram(np.zeros((2, 0), np.int64), 16, 64)
    assert (empty == token_histogram([], 16, 64)).all()


def test_js_divergence_rows_bit_identical():
    rng = np.random.default_rng(3)
    p = rng.random((50, 64))
    q = rng.random((50, 64))
    q[7] = 0.0                                       # zero-sum histogram
    got = js_divergence_rows(p, q)
    want = np.array([js_divergence(p[i], q[i]) for i in range(50)])
    assert (got == want).all()


# ---------------------------------------------------------------------------
# FleetDriftDetector parity with per-stream DriftDetector
# ---------------------------------------------------------------------------
def _fleet_windows(seed=0, n=6, windows=4, batch=8, seq=32, vocab=64):
    """Deterministic multi-window token streams with a drift event."""
    from repro.data.streams import make_fleet
    _, streams = make_fleet(vocab=vocab, regions=2, streams_per_region=n // 2,
                            dim=4, switch_times=(10.0,), seed=seed)
    ids = [s.stream_id for s in streams]
    wins = [np.stack([s.sample(10.0 * w, batch, seq) for s in streams])
            for w in range(windows)]
    return ids, wins


@pytest.mark.parametrize("impl", ALL_IMPLS)
def test_fleet_detector_matches_scalar(impl):
    """The batched detector reproduces the scalar per-stream loop:
    identical trigger decisions every window in every dispatch mode,
    bit-identical scores on the exact path, and bit-identical scores
    for every potentially-triggered stream on the kernel paths (the
    float64 near-threshold rescore)."""
    _skip_off_tpu(impl)
    vocab, buckets, thr = 64, 64, 0.25
    ids, wins = _fleet_windows(vocab=vocab)
    scalar = {sid: DriftDetector(threshold=thr, buckets=buckets,
                                 vocab=vocab) for sid in ids}
    fleet = FleetDriftDetector(threshold=thr, buckets=buckets,
                               vocab=vocab, impl=impl)
    for sid, toks in zip(ids, wins[0]):
        scalar[sid].set_reference(toks)
    fleet.set_references(ids, wins[0])
    for toks_all in wins:
        want_trig = [sid for sid, toks in zip(ids, toks_all)
                     if scalar[sid].observe(toks)]
        got_trig = fleet.observe(ids, toks_all)
        assert got_trig == want_trig
        for sid, toks in zip(ids, toks_all):
            # live signatures are always exact
            assert (fleet.hist(sid) == scalar[sid].last_hist).all()
            if impl == "exact":
                assert fleet.score(sid) == scalar[sid].last_score
            elif fleet.score(sid) > thr - fleet.band:
                # near/above threshold: rescored in exact float64
                assert fleet.score(sid) == scalar[sid].last_score
            else:
                assert fleet.score(sid) == pytest.approx(
                    scalar[sid].last_score, abs=1e-5)


@pytest.mark.parametrize("impl", ALL_IMPLS)
def test_fleet_detector_vocab_boundary_and_zero_sum(impl):
    """token == vocab clips into the top bucket (not bucket `buckets`)
    and a zero-sum reference histogram scores finitely — identically to
    the scalar detector."""
    _skip_off_tpu(impl)
    vocab, buckets, thr = 64, 64, 0.25
    ids = ["boundary", "zeroref"]
    scalar = {sid: DriftDetector(threshold=thr, buckets=buckets,
                                 vocab=vocab) for sid in ids}
    fleet = FleetDriftDetector(threshold=thr, buckets=buckets,
                               vocab=vocab, impl=impl)
    scalar["boundary"].set_reference(np.arange(vocab))
    fleet.set_reference("boundary", np.arange(vocab))
    scalar["zeroref"].set_reference([])          # zero-sum reference
    fleet.set_reference("zeroref", [])
    toks = np.stack([np.full((4, 8), vocab),     # all tokens == vocab
                     np.arange(32).reshape(4, 8)])
    want = [sid for sid, tk in zip(ids, toks) if scalar[sid].observe(tk)]
    got = fleet.observe(ids, toks)
    assert got == want
    for sid in ids:
        assert np.isfinite(fleet.score(sid))
        if impl == "exact" or fleet.score(sid) > thr - fleet.band:
            assert fleet.score(sid) == scalar[sid].last_score
        else:
            assert fleet.score(sid) == pytest.approx(
                scalar[sid].last_score, abs=1e-5)
        assert (fleet.hist(sid) == scalar[sid].last_hist).all()


def test_fleet_detector_first_observation_sets_reference():
    """Scalar semantics: without a reference, the first window becomes
    the reference and never triggers."""
    fleet = FleetDriftDetector(threshold=0.0, buckets=16, vocab=64)
    scalar = DriftDetector(threshold=0.0, buckets=16, vocab=64)
    toks = np.arange(64).reshape(2, 32)
    assert fleet.observe(["s"], toks[None]) == []
    assert not scalar.observe(toks)
    assert (fleet.reference("s") == scalar.reference).all()
    # second window with different data now triggers both
    toks2 = np.zeros((2, 32), np.int64)
    assert fleet.observe(["s"], toks2[None]) == ["s"]
    assert scalar.observe(toks2)
    assert fleet.score("s") == scalar.last_score


def test_fleet_detector_churn_preserves_rows():
    """Swap-with-last removal must not corrupt surviving streams'
    references, scores, or live histograms."""
    rng = np.random.default_rng(4)
    fleet = FleetDriftDetector(threshold=0.25, buckets=32, vocab=64)
    ids = [f"s{i}" for i in range(5)]
    refs = rng.integers(0, 64, size=(5, 4, 16))
    fleet.set_references(ids, refs)
    live = rng.integers(0, 64, size=(5, 4, 16))
    fleet.observe(ids, live)
    before = {sid: (fleet.reference(sid), fleet.score(sid),
                    fleet.hist(sid)) for sid in ids}
    fleet.remove_stream("s1")                    # middle row: swaps s4 in
    fleet.remove_stream("s1")                    # idempotent
    assert len(fleet) == 4 and "s1" not in fleet
    for sid in ("s0", "s2", "s3", "s4"):
        r, sc, h = before[sid]
        assert (fleet.reference(sid) == r).all()
        assert fleet.score(sid) == sc
        assert (fleet.hist(sid) == h).all()
    # re-adding starts fresh (no stale reference)
    fleet.add_stream("s1")
    assert fleet.reference("s1") is None


def test_controller_drift_impls_agree():
    """ECCOController grouping decisions are independent of the drift
    scoring backend: the kernel path's near-threshold float64 rescue
    keeps window-loop behavior bit-identical to the exact path."""
    import dataclasses
    from repro.configs import smoke_config
    from repro.core.controller import ControllerConfig, ECCOController
    from repro.core.trainer import SharedEngine
    from repro.data.streams import make_fleet

    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=64)
    engine = SharedEngine(cfg)
    histories = {}
    for impl in ("exact", "xla"):
        _, streams = make_fleet(vocab=64, regions=2, streams_per_region=2,
                                dim=4, switch_times=(5.0,), seed=1)
        cc = ControllerConfig(window_micro=4, micro_steps=2,
                              train_batch=8, p_drop=0.5,
                              shared_bandwidth=1e9, drift_impl=impl)
        ctl = ECCOController(engine, streams, cc, seed=0)
        ctl.warmup()
        for _ in range(3):
            ctl.run_window()
        histories[impl] = ([w.groups for w in ctl.history],
                           [e["kind"] + e["stream"]
                            for e in ctl.grouper.events])
    assert any(histories["exact"][0][-1].values())     # groups did form
    assert [sorted(g.values()) for g in histories["exact"][0]] == \
        [sorted(g.values()) for g in histories["xla"][0]]
    assert histories["exact"][1] == histories["xla"][1]
