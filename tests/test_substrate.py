"""Substrate tests: drift detection, data pipeline, checkpointing,
stragglers, gradient compression, optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.drift import DriftDetector, js_divergence, token_histogram
from repro.data.pipeline import GroupPipeline, StreamBuffer
from repro.data.streams import DomainBank, make_fleet
from repro.distributed import checkpoint as ckpt
from repro.distributed.stragglers import StragglerPolicy
from repro.train import compression as comp


# ---------------------------------------------------------------------------
# drift
# ---------------------------------------------------------------------------
def test_drift_triggers_on_domain_switch():
    bank = DomainBank(64, 4, dim=8, seed=0)
    rng = np.random.default_rng(0)
    det = DriftDetector(threshold=0.25, vocab=64)
    det.set_reference(bank.sample(0, rng, 16, 32))
    # same domain: no drift
    assert not det.observe(bank.sample(0, rng, 16, 32))
    # switched domain: drift
    assert det.observe(bank.sample(2, rng, 16, 32))
    # rebase: new domain becomes reference
    det.rebase(bank.sample(2, rng, 16, 32))
    assert not det.observe(bank.sample(2, rng, 16, 32))


def test_token_histogram_clips_token_at_vocab_boundary():
    """Regression: a token equal to `vocab` used to land in bucket
    `buckets`, yielding a length buckets+1 histogram that
    shape-mismatched the reference inside js_divergence."""
    h = token_histogram([0, 5, 64], buckets=64, vocab=64)
    assert h.shape == (64,)
    assert h[63] > 0                      # boundary token clipped into range
    ref = token_histogram(np.arange(64), buckets=64, vocab=64)
    assert np.isfinite(js_divergence(h, ref))
    # detector survives a boundary token in the live window
    det = DriftDetector(threshold=0.25, vocab=64)
    det.set_reference(np.arange(64))
    det.observe(np.array([64, 64, 1, 2]))


def test_js_divergence_properties():
    p = np.array([0.5, 0.5])
    q = np.array([0.9, 0.1])
    assert js_divergence(p, p) == pytest.approx(0.0, abs=1e-9)
    assert js_divergence(p, q) == pytest.approx(js_divergence(q, p))
    assert js_divergence(p, q) > 0


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------
def test_stream_buffer_ring():
    b = StreamBuffer(seq_len=8, capacity=4)
    b.push(np.arange(8 * 6).reshape(6, 8))
    assert len(b) == 4
    assert b.dropped_total == 2
    assert b.delivered_total == 6
    # oldest rows dropped
    assert b.tokens[0, 0] == 16


def test_pipeline_bandwidth_truncation_and_balance():
    p = GroupPipeline(seq_len=8, seed=0)
    p.deliver("a", np.zeros((10, 8), np.int64), bandwidth_tokens=3 * 8)
    p.deliver("b", np.ones((10, 8), np.int64), bandwidth_tokens=10 * 8)
    assert len(p.buffers["a"]) == 3
    assert len(p.buffers["b"]) == 10
    batch = p.group_batch(8)
    # member-balanced: both streams contribute
    vals = set(batch["inputs"][:, 0].tolist())
    assert vals == {0, 1}
    assert batch["inputs"].shape == (8, 8)


def test_pipeline_empty_returns_none():
    p = GroupPipeline(seq_len=8)
    assert p.group_batch(4) is None


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    ckpt.save(str(tmp_path), 3, tree, extra={"note": "x"})
    assert ckpt.latest_step(str(tmp_path)) == 3
    got, extra = ckpt.restore(str(tmp_path), 3, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    assert extra == {"note": "x"}


def test_checkpoint_atomicity_no_tmp_visible(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    names = os.listdir(tmp_path)
    assert all(not n.endswith(".tmp") for n in names)


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 0, {"a": jnp.zeros((2,))})
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 0, {"a": jnp.zeros((2,)),
                                        "b": jnp.zeros((1,))})


def test_async_checkpointer_gc(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(4):
        c.save_async(s, {"a": jnp.full((2,), s)})
    c.wait()
    assert ckpt.list_steps(str(tmp_path)) == [2, 3]
    got, _ = ckpt.restore(str(tmp_path), 3, {"a": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(got["a"]), [3, 3])


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------
def test_straggler_quota_shrinks():
    pol = StragglerPolicy(threshold=2.0, min_quota_frac=0.25)
    for _ in range(8):
        pol.record("fast1", 1.0)
        pol.record("fast2", 1.1)
        pol.record("slow", 5.0)
    assert pol.is_straggler("slow")
    assert not pol.is_straggler("fast1")
    q = pol.quota("slow", base_quota=8)
    assert q < 8 and q >= 2       # shrunk but bounded below
    assert pol.quota("fast1", 8) == 8
    rep = pol.report()
    assert rep["jobs"]["slow"]["straggler"]


def test_straggler_policy_cold_start():
    pol = StragglerPolicy()
    assert pol.quota("new", 8) == 8
    assert not pol.is_straggler("new")


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def test_int8_quantization_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
    q, s = comp.quantize_int8(x)
    back = comp.dequantize_int8(q, s)
    # max error is scale/2
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) / 2 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Sum of compressed grads + final residual == sum of true grads."""
    key = jax.random.PRNGKey(1)
    grads = [jax.random.normal(jax.random.fold_in(key, i), (64,))
             for i in range(10)]

    def compress(x):
        q, s = comp.quantize_int8(x)
        return comp.dequantize_int8(q, s)

    residual = None
    sent = jnp.zeros((64,))
    for g in grads:
        c, residual = comp.with_error_feedback({"g": g}, residual,
                                               compress)
        sent = sent + c["g"]
    true = sum(grads)
    np.testing.assert_allclose(np.asarray(sent + residual["g"]),
                               np.asarray(true), atol=1e-4)


def test_topk_mask_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    m = comp.topk_mask(x, frac=0.4)
    np.testing.assert_allclose(np.asarray(m), [0, -5.0, 0, 3.0, 0])


def test_compressed_psum_single_axis():
    """On a 1-element mesh axis, the compressed mean must equal the input
    up to the int8 quantization bound (scale/2 per element)."""
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("pod",))
    x = jax.random.normal(jax.random.PRNGKey(2), (32,))
    out = comp.pod_mean_compressed({"g": x}, mesh)["g"]
    _, s = comp.quantize_int8(x)
    assert float(jnp.max(jnp.abs(out - x))) <= float(s) / 2 + 1e-6


def test_compressed_psum_noop_without_pod_axis():
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    x = {"g": jnp.ones((4,))}
    out = comp.pod_mean_compressed(x, mesh)
    np.testing.assert_array_equal(np.asarray(out["g"]),
                                  np.asarray(x["g"]))


def test_wire_bytes_saved():
    d = comp.wire_bytes_saved(10**6, pods=2)
    assert d["fp32_bytes"] == 4 * 10**6
    assert d["reduction"] == 4.0


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_decreases_quadratic_loss():
    from repro.configs.base import TrainConfig
    from repro.train import optimizer as opt
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=100,
                       weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init_opt_state(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}    # d/dw ||w||^2
        params, state, m = opt.adamw_update(tcfg, params, grads, state)
    assert float(jnp.sum(params["w"] ** 2)) < 0.1
    assert float(m["grad_norm"]) >= 0


def test_grad_clip():
    from repro.train.optimizer import clip_by_global_norm
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-3)
