"""Tier-1 tests for the roofline scheduling cost model
(launch/roofline.py) and the metered allocator/precision policy it
feeds (docs/scheduling.md).

CPU-safe: every compile is a tiny 2-layer smoke model at batch 2,
seq 16, lowered once per (kind, precision) key.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.allocator import ECCOAllocator
from repro.core.grouping import Grouper, Request
from repro.core.trainer import RetrainJob, SharedEngine
from repro.launch.roofline import (Cost, CostTable, DeviceSpec,
                                   RooflineMeter, WindowBudget,
                                   _cost_dict, precision_dtype)
from repro.models import transformer as T

CFG = smoke_config("olmo-1b")      # 2-layer scan-over-layers dense model


@pytest.fixture(scope="module")
def table():
    return CostTable()


# -- scan-body correction ----------------------------------------------------

@pytest.mark.parametrize("kind", ["train", "prefill"])
def test_scan_correction_matches_unrolled(kind, table):
    """The corrected cost of the scan-over-layers compile must match
    the direct cost_analysis of the SAME model fully unrolled (the
    correction exists because XLA counts a scan body once)."""
    corrected = table.cost(CFG, batch=2, seq=16, kind=kind)
    with T.unrolled_scans():
        compiled = CostTable()._base_compiled(
            CFG, 2, 16, kind, jnp.float32)
        direct = _cost_dict(compiled, lambda hlo: {})
    assert direct["flops"] > 0
    assert corrected.flops == pytest.approx(direct["flops"], rel=0.02)
    # bytes shift with buffer reuse across schedules; same ballpark
    assert corrected.bytes == pytest.approx(direct["bytes"], rel=0.5)


def test_corrected_exceeds_single_body_count(table):
    """Sanity: the corrected 2-layer cost must exceed the raw compile's
    once-counted scan body by roughly one more layer of FLOPs."""
    base = _cost_dict(
        CostTable()._base_compiled(CFG, 2, 16, "eval", jnp.float32),
        lambda hlo: {})
    corrected = table.cost(CFG, batch=2, seq=16, kind="eval")
    assert corrected.flops > base["flops"]


# -- CostTable ---------------------------------------------------------------

def test_cost_table_caches(table):
    a = table.cost(CFG, batch=2, seq=16, kind="eval")
    b = table.cost(CFG, batch=2, seq=16, kind="eval")
    assert a is b                      # dict hit, no recompile
    c = table.cost(CFG, batch=2, seq=16, kind="eval", precision="bf16")
    assert c is not a                  # precision is part of the key


def test_cost_table_all_kinds_positive(table):
    for kind in ("train", "eval", "prefill", "decode"):
        c = table.cost(CFG, batch=2, seq=16, kind=kind)
        assert c.flops > 0 and c.bytes > 0, kind
    assert table.seconds(CFG, batch=2, seq=16, kind="train") > 0


def test_cost_table_unknown_kind(table):
    with pytest.raises(ValueError, match="unknown kind"):
        table.cost(CFG, batch=2, seq=16, kind="finetune")


def test_train_costs_more_than_eval(table):
    tr = table.cost(CFG, batch=2, seq=16, kind="train")
    ev = table.cost(CFG, batch=2, seq=16, kind="eval")
    assert tr.flops > 2 * ev.flops     # fwd+bwd vs fwd


# -- DeviceSpec / WindowBudget ----------------------------------------------

def test_device_spec_roofline():
    dev = DeviceSpec(peak_flops_bf16=200.0, peak_flops_fp32=100.0,
                     hbm_bw=10.0)
    compute_bound = Cost(flops=1000.0, bytes=1.0)
    memory_bound = Cost(flops=1.0, bytes=1000.0)
    assert dev.seconds(compute_bound, "fp32") == pytest.approx(10.0)
    assert dev.seconds(compute_bound, "bf16") == pytest.approx(5.0)
    assert dev.seconds(memory_bound, "fp32") == pytest.approx(100.0)
    assert dev.seconds(memory_bound, "bf16") == pytest.approx(100.0)


def test_precision_dtype_rejects_unknown():
    assert precision_dtype("bf16") == jnp.bfloat16
    with pytest.raises(ValueError):
        precision_dtype("fp8")


def test_window_budget_ledger():
    b = WindowBudget(total=10.0)
    assert b.remaining == 10.0 and b.can_afford(10.0)
    b.charge(4.0, "train")
    b.charge(1.5, "eval")
    b.charge(0.5, "eval")
    assert b.remaining == pytest.approx(4.0)
    assert not b.can_afford(4.5)
    rep = b.report()
    assert rep["spent"] == pytest.approx(6.0)
    assert rep["by_kind"]["train"] == pytest.approx(4.0)
    assert rep["by_kind"]["eval"] == pytest.approx(2.0)


# -- RooflineMeter over duck-typed jobs --------------------------------------

class FakeJob:
    """Deterministic allocator fake: accuracy steps through a script,
    advanced by train_micro (same contract as tests/test_allocator)."""

    def __init__(self, jid, accs):
        self.job_id = jid
        self._accs = list(accs)
        self._i = 0
        self.num_members = 1
        self.gpu_time = 0

    def eval(self):
        return self._accs[min(self._i, len(self._accs) - 1)]

    def train_micro(self):
        self._i += 1
        self.gpu_time += 1


def test_meter_fallback_for_fake_jobs(table):
    m = RooflineMeter(table, 10.0, fallback_cost=2.0)
    j = FakeJob("j0", [0.1])
    assert m.train_cost(j) == 2.0
    assert m.eval_cost(j) == 0.0
    assert m.micro_cost(j) == 2.0


def test_meter_prices_real_jobs(table):
    eng = SharedEngine(CFG, batched=False)
    req = Request(stream_id="s0", t=0.0, loc=(0.0, 0.0),
                  subsamples=np.zeros((2, 16), np.int32), acc=0.0)
    job = RetrainJob(eng, req, micro_steps=4, batch=2)
    m = RooflineMeter(table, 10.0, seq_len=16, eval_batch=2)
    tc, ec = m.train_cost(job), m.eval_cost(job)
    assert tc > 0 and ec > 0
    assert m.micro_cost(job) == pytest.approx(tc + 2 * ec)
    job.micro_steps = 8                # linear in micro_steps
    assert m.train_cost(job) == pytest.approx(2 * tc)
    assert m.serve_cost(CFG, queries=3, prompt_len=8, gen_tokens=4) > 0


# -- metered allocator -------------------------------------------------------

def test_metered_window_stops_at_budget(table):
    jobs = [FakeJob(f"j{i}", [0.1 * i, 0.5, 0.9]) for i in range(3)]
    m = RooflineMeter(table, 2.5, fallback_cost=1.0)
    trace = ECCOAllocator().run_window(jobs, 8, meter=m)
    assert sum(trace.gpu_time.values()) == 2      # 2.5s buys 2 micros
    assert any("roofline budget exhausted" in n for n in trace.notes)
    assert trace.budget is not None
    assert trace.budget["spent"] == pytest.approx(2.0)


def test_metered_window_degrades_to_eval_only(table):
    jobs = [FakeJob("j0", [0.3]), FakeJob("j1", [0.6])]
    m = RooflineMeter(table, 0.5, fallback_cost=1.0)
    alloc = ECCOAllocator()
    alloc.last_gains = {"j0": 0.42}
    trace = alloc.run_window(jobs, 8, meter=m)
    assert trace.order == []
    assert sum(trace.gpu_time.values()) == 0
    assert any("eval-only" in n for n in trace.notes)
    # the fleet is still measured once for the metrics consumers
    assert trace.acc["j0"] == [0.3] and trace.acc["j1"] == [0.6]
    # estimate_shares keeps serving the last real window's signal
    assert alloc.last_gains == {"j0": 0.42}


def test_zero_micro_window_degrades_without_meter():
    jobs = [FakeJob("j0", [0.3])]
    trace = ECCOAllocator().run_window(jobs, 0)
    assert trace.order == [] and trace.acc["j0"] == [0.3]
    assert any("window_micro=0" in n for n in trace.notes)
    assert trace.budget is None


def test_unmetered_path_matches_seed_decisions(table):
    def fleet():
        return [FakeJob("a", [0.0, 0.2, 0.4, 0.6]),
                FakeJob("b", [0.1, 0.5, 0.55, 0.6]),
                FakeJob("c", [0.3, 0.31, 0.32, 0.33])]
    seed = ECCOAllocator().run_window(fleet(), 6)
    # a huge budget never constrains; equal fallback costs make
    # gain/cost ordering identical to plain gain ordering
    m = RooflineMeter(table, 1e9, fallback_cost=1.0)
    metered = ECCOAllocator().run_window(fleet(), 6, meter=m)
    assert metered.order == seed.order
    assert metered.acc == seed.acc
    assert metered.shares == seed.shares


# -- precision policy --------------------------------------------------------

def test_job_precision_validation():
    eng = SharedEngine(CFG, batched=False)
    req = Request(stream_id="s0", t=0.0, loc=(0.0, 0.0),
                  subsamples=np.zeros((2, 16), np.int32), acc=0.0)
    with pytest.raises(ValueError, match="precision"):
        RetrainJob(eng, req, precision="fp16")


def test_bf16_screen_and_fp32_rescore_agree_at_smoke_scale():
    """bf16 decision screens run end to end and stay close to the fp32
    master score on a tiny model; the fp32 rescore path reproduces the
    fp32 job's number exactly."""
    eng = SharedEngine(CFG, batched=True)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, CFG.vocab_size, (2, 16), np.int32)
    req = Request(stream_id="s0", t=0.0, loc=(0.0, 0.0),
                  subsamples=toks, acc=0.0)
    job32 = RetrainJob(eng, req, precision="fp32", seed=1)
    job16 = RetrainJob(eng, Request(stream_id="s1", t=0.0, loc=(0.0, 0.0),
                                    subsamples=toks, acc=0.0),
                       precision="bf16", seed=1)
    a32 = job32.eval_on(toks)
    a16 = job16.eval_on(toks)
    assert np.isfinite(a16)
    assert abs(a16 - a32) <= 0.25          # same weights, coarser dtype
    # explicit fp32 rescore of the bf16 job == the fp32 job's score
    assert job16.eval_on(toks, precision="fp32") == a32


def test_params_stack_compute_cast_at_flush():
    eng = SharedEngine(CFG, batched=True)
    req = Request(stream_id="s0", t=0.0, loc=(0.0, 0.0),
                  subsamples=np.zeros((2, 16), np.int32), acc=0.0)
    job = RetrainJob(eng, req, precision="bf16")
    bank = eng.bank
    # fp32 request returns the master stack itself
    assert bank.params_stack_compute(jnp.float32) is bank.params_stack()
    s1 = bank.params_stack_compute(jnp.bfloat16)
    s2 = bank.params_stack_compute(jnp.bfloat16)
    assert s1 is s2                        # one cast per bank version
    import jax
    assert all(x.dtype == jnp.bfloat16
               for x in jax.tree.leaves(s1)
               if jnp.issubdtype(x.dtype, jnp.floating))
    job.state = job.state                  # host write bumps the version
    assert bank.params_stack_compute(jnp.bfloat16) is not s1


class PrecScriptedJob:
    """Grouper fake with a split screen/rescore personality."""

    def __init__(self, jid, bf16_acc, fp32_acc, member):
        self.job_id = jid
        self.precision = "bf16"
        self.members = [member]
        self._bf16, self._fp32 = bf16_acc, fp32_acc

    def eval_on(self, samples, precision=None):
        p = precision if precision is not None else self.precision
        return self._fp32 if p == "fp32" else self._bf16

    def add_member(self, req):
        self.members.append(req)

    def remove_member(self, sid):
        self.members = [m for m in self.members if m.stream_id != sid]


def _member(sid="m0", acc_prev=None):
    return Request(stream_id=sid, t=0.0, loc=(0.0, 0.0),
                   subsamples=np.zeros((2, 16), np.int32), acc=0.5,
                   acc_prev=acc_prev)


def test_grouper_rescores_near_threshold_join():
    req = _member("new")
    req.acc = 0.8
    # screens at 0.5 (fails the join), fp32 truth 0.9 (passes)
    job = PrecScriptedJob("j0", 0.5, 0.9, _member())
    no_rescore = Grouper(new_job_fn=lambda r: PrecScriptedJob(
        "fresh", 0.0, 0.0, r))
    got = no_rescore.group_request([job], req)
    assert got.job_id == "fresh"           # margin 0: screen decides
    job2 = PrecScriptedJob("j0", 0.5, 0.9, _member())
    rescore = Grouper(new_job_fn=lambda r: PrecScriptedJob(
        "fresh", 0.0, 0.0, r), rescore_margin=0.4)
    got = rescore.group_request([job2], req)
    assert got is job2                     # fp32 rescore flips the join


def test_grouper_rescores_near_threshold_evict():
    # screen 0.5 vs EMA 0.9 would evict at p_drop=0.15 (threshold
    # 0.765); the fp32 rescore (0.9) is within margin and cancels it
    m = _member("m0", acc_prev=0.9)
    job = PrecScriptedJob("j0", 0.5, 0.9, m)
    g = Grouper(p_drop=0.15, rescore_margin=0.3,
                new_job_fn=lambda r: PrecScriptedJob("x", 0, 0, r))
    jobs = [job]
    requeued = g.update_grouping(jobs, now=1.0)
    assert requeued == [] and jobs == [job]
    # without the margin the bf16 screen evicts
    m2 = _member("m0", acc_prev=0.9)
    job2 = PrecScriptedJob("j0", 0.5, 0.9, m2)
    g2 = Grouper(p_drop=0.15,
                 new_job_fn=lambda r: PrecScriptedJob("x", 0, 0, r))
    jobs2 = [job2]
    requeued2 = g2.update_grouping(jobs2, now=1.0)
    assert len(requeued2) == 1
