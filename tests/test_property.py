"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# GAIMD: proportional shares hold for arbitrary share vectors
# ---------------------------------------------------------------------------
@given(shares=st.lists(st.floats(0.05, 1.0), min_size=2, max_size=6))
@settings(max_examples=20, deadline=None)
def test_gaimd_proportionality_property(shares):
    from repro.core import gaimd
    p = np.asarray(shares, np.float32)
    p = p / p.sum()
    alpha, beta = gaimd.ecco_params(p, np.ones_like(p))
    caps = np.full(len(p), np.inf, np.float32)
    r = gaimd.steady_state_rates(alpha, beta, caps, shared_cap=100.0,
                                 steps=6000, tail=2000)
    err = gaimd.proportionality_error(r, p)
    assert err < 0.12, (p, r, err)


# ---------------------------------------------------------------------------
# GAIMD: steady state tracks alpha/(1-beta); error metric well-behaved;
# local caps are inviolable
# ---------------------------------------------------------------------------
@given(alphas=st.lists(st.floats(0.1, 1.0), min_size=2, max_size=6),
       beta=st.floats(0.35, 0.65), seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_gaimd_steady_state_tracks_alpha_over_one_minus_beta(alphas, beta,
                                                             seed):
    """Yang & Lam: synchronized-loss GAIMD converges to rates
    proportional to alpha_i / (1 - beta_i). Betas get a small
    heterogeneous jitter; the sawtooth's (1+beta)/2 time-average factor
    then bounds the residual, so the tolerance is loose but the
    proportionality must hold."""
    from repro.core import gaimd
    rng = np.random.default_rng(seed)
    a = np.asarray(alphas, np.float32)
    b = np.clip(beta + rng.uniform(-0.05, 0.05, size=len(a)),
                0.1, 0.9).astype(np.float32)
    caps = np.full(len(a), np.inf, np.float32)       # absent local caps
    r = gaimd.steady_state_rates(a, b, caps, shared_cap=200.0,
                                 steps=8000, tail=3000)
    target = a / (1.0 - b)
    assert gaimd.proportionality_error(r, target) < 0.15, (a, b, r)


@given(rates=st.lists(st.floats(0.0, 100.0), min_size=1, max_size=8),
       targets=st.lists(st.floats(0.0, 100.0), min_size=1, max_size=8))
@settings(max_examples=40, deadline=None)
def test_gaimd_proportionality_error_bounds(rates, targets):
    """proportionality_error is a normalized L1/2 distance between
    distributions: always in [0, 1], and exactly 0 at the target."""
    from repro.core.gaimd import proportionality_error
    n = min(len(rates), len(targets))
    r, t = np.asarray(rates[:n]), np.asarray(targets[:n])
    err = proportionality_error(r, t)
    assert 0.0 <= err <= 1.0
    assert proportionality_error(t, t) == pytest.approx(0.0, abs=1e-12)
    assert proportionality_error(3.0 * t + 0.0, t) == \
        pytest.approx(0.0, abs=1e-9)                 # scale-invariant


@given(n=st.integers(2, 8), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_gaimd_rates_never_exceed_local_caps(n, seed):
    """Every simulated rate trajectory (not just the tail mean) respects
    per-flow local uplink caps."""
    from repro.core import gaimd
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(0.1, 1.5, n).astype(np.float32)
    beta = rng.uniform(0.2, 0.8, n).astype(np.float32)
    caps = rng.uniform(0.5, 20.0, n).astype(np.float32)
    caps[rng.integers(0, n)] = np.inf                # mix in an uncapped flow
    rates, final = gaimd.simulate(alpha, beta, caps,
                                  shared_cap=float(rng.uniform(5, 50)),
                                  steps=500)
    rates = np.asarray(rates)
    assert (rates <= caps[None, :] + 1e-5).all()
    assert (np.asarray(final) <= caps + 1e-5).all()
    tail = gaimd.steady_state_rates(alpha, beta, caps, 25.0, steps=2000,
                                    tail=500)
    assert (tail <= caps + 1e-5).all()


# ---------------------------------------------------------------------------
# MoE dispatch: capacity and slot invariants
# ---------------------------------------------------------------------------
@given(t=st.integers(4, 64), E=st.integers(2, 16), k=st.integers(1, 4),
       cap=st.integers(1, 32), seed=st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_moe_dispatch_invariants(t, E, k, cap, seed):
    from repro.models.moe import _dispatch_slots
    k = min(k, E)
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, E, size=(t, k)))
    slot, keep = _dispatch_slots(ids, E, cap)
    slot, keep, ids = map(np.asarray, (slot, keep, ids))
    # kept slots within capacity
    assert (slot[keep] < cap).all()
    assert (slot >= 0).all()
    # no two kept (token,k) pairs share an (expert, slot) cell
    cells = list(zip(ids[keep].tolist(), slot[keep].tolist()))
    assert len(cells) == len(set(cells))
    # per-expert kept count never exceeds capacity
    for e in range(E):
        assert keep[ids == e].sum() <= cap


# ---------------------------------------------------------------------------
# Allocator: greedy trace conserves budget & tracks argmax gains
# ---------------------------------------------------------------------------
@given(n_jobs=st.integers(1, 5), W=st.integers(1, 20),
       seed=st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_allocator_budget_conservation(n_jobs, W, seed):
    from repro.core.allocator import ECCOAllocator
    rng = np.random.default_rng(seed)

    class J:
        def __init__(self, i):
            self.job_id = f"j{i}"
            self.num_members = int(rng.integers(1, 5))
            self.t = 0.0
            self.r = rng.uniform(0.05, 0.5)

        def eval(self):
            return 1 - np.exp(-self.r * self.t)

        def train_micro(self):
            self.t += 1

    jobs = [J(i) for i in range(n_jobs)]
    trace = ECCOAllocator().run_window(jobs, W)
    assert len(trace.order) == W
    assert sum(trace.gpu_time.values()) == W
    # every job in the initial pass ran (if budget allowed)
    ran = set(trace.order[:n_jobs])
    assert len(ran) == min(n_jobs, W)
    # shares: a probability vector
    assert abs(sum(trace.shares.values()) - 1) < 1e-9


# ---------------------------------------------------------------------------
# Grouping: every stream belongs to at most one job at all times
# ---------------------------------------------------------------------------
@given(n_streams=st.integers(2, 8), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_grouping_partition_invariant(n_streams, seed):
    from repro.core.grouping import Grouper, Request
    rng = np.random.default_rng(seed)

    class J:
        _n = 0

        def __init__(self, req):
            J._n += 1
            self.job_id = f"j{J._n}"
            self.members = [req]
            self.acc = rng.uniform(0.3, 0.9)

        def eval_on(self, s):
            return self.acc + rng.uniform(-0.3, 0.1)

        def add_member(self, r):
            self.members.append(r)

        def remove_member(self, sid):
            self.members = [m for m in self.members if m.stream_id != sid]

    g = Grouper(eps_t=rng.uniform(1, 50), delta_loc=rng.uniform(1, 200),
                p_drop=0.1, new_job_fn=J)
    jobs = []
    for i in range(n_streams):
        r = Request(stream_id=f"s{i}", t=float(rng.uniform(0, 40)),
                    loc=(float(rng.uniform(0, 100)), 0.0),
                    subsamples=object(), acc=float(rng.uniform(0, 0.5)))
        g.group_request(jobs, r)
        seen = [m.stream_id for j in jobs for m in j.members]
        assert len(seen) == len(set(seen))      # partition
        assert f"s{i}" in seen                  # admitted somewhere
    g.update_grouping(jobs, now=100.0)
    seen = [m.stream_id for j in jobs for m in j.members]
    assert len(seen) == len(set(seen))
    assert len(seen) == n_streams               # nobody lost
    assert all(j.members for j in jobs)         # no empty jobs


# ---------------------------------------------------------------------------
# Softmax xent: matches -log p and is invariant to logit shifts
# ---------------------------------------------------------------------------
@given(B=st.integers(1, 3), S=st.integers(2, 8), V=st.integers(2, 32),
       shift=st.floats(-50, 50), seed=st.integers(0, 20))
@settings(max_examples=30, deadline=None)
def test_xent_shift_invariance(B, S, V, shift, seed):
    from repro.train.train_step import softmax_xent
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(B, S, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=(B, S)))
    ce1, _ = softmax_xent(None, logits, labels)
    ce2, _ = softmax_xent(None, logits + shift, labels)
    assert abs(float(ce1) - float(ce2)) < 1e-3
    # matches direct -log softmax
    ref = -np.take_along_axis(
        np.asarray(jax.nn.log_softmax(logits, -1)),
        np.asarray(labels)[..., None], -1).mean()
    assert abs(float(ce1) - float(ref)) < 1e-4


# ---------------------------------------------------------------------------
# int8 compression: error bound holds for arbitrary tensors
# ---------------------------------------------------------------------------
@given(scale=st.floats(1e-3, 1e3), n=st.integers(1, 256),
       seed=st.integers(0, 20))
@settings(max_examples=30, deadline=None)
def test_int8_error_bound_property(scale, n, seed):
    from repro.train.compression import dequantize_int8, quantize_int8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = float(jnp.max(jnp.abs(dequantize_int8(q, s) - x)))
    assert err <= float(s) / 2 * (1 + 1e-3) + 1e-9
