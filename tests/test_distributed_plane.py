"""Sharded fleet decision-plane parity suite.

Contract (docs/distributed_plane.md): with the row/job axis of every
decision plane block-sharded over a fleet mesh, all decisions are
bit-identical to the single-device run; a mid-window device loss
recovers from the window-start checkpoint and re-runs the window to
the SAME decisions. Multi-device tests run in subprocesses with 8
forced host devices (in-process tests must keep seeing 1 device —
tests/conftest.py deliberately sets no XLA_FLAGS).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.allocator import ECCOAllocator
from repro.core.rows import RowRegistry
from repro.core.transmission import (FleetTransmissionPlane, ProfileTable,
                                     SamplingConfig)
from repro.distributed.elastic import DeviceFailure, FleetElastic
from repro.distributed.stragglers import StragglerPolicy


def _run_sub(script, **env_extra):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"),
               **env_extra)
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          timeout=600)


# -- sharded kernels + drift plane (one 8-device subprocess) ---------------

KERNEL_PARITY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    assert jax.device_count() == 8
    from repro.kernels import ops
    from repro.core.drift import FleetDriftDetector
    from repro.launch.mesh import make_fleet_mesh

    mesh = make_fleet_mesh(8)
    rng = np.random.default_rng(0)

    # kernels: row counts deliberately NOT multiples of 8 (padding path)
    for n in (37, 11):
        toks = rng.integers(0, 64, (n, 32))
        ref = rng.random((n, 16)); ref /= ref.sum(1, keepdims=True)
        for impl in ("xla", "interpret"):
            s0, h0 = ops.fleet_drift(toks, ref, buckets=16, vocab=64,
                                     impl=impl)
            s1, h1 = ops.fleet_drift(toks, ref, buckets=16, vocab=64,
                                     impl=impl, mesh=mesh)
            np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
            np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))
    p = rng.random((23, 16)); p /= p.sum(1, keepdims=True)
    q = rng.random((37, 16)); q /= q.sum(1, keepdims=True)
    for impl in ("xla", "interpret"):
        d0 = np.asarray(ops.pairwise_js(p, q, impl=impl))
        for shard in ("rows", "cols"):
            d1 = np.asarray(ops.pairwise_js(p, q, impl=impl, mesh=mesh,
                                            shard=shard))
            np.testing.assert_array_equal(d0, d1)

    # drift plane end-to-end, including churn (remove + re-add streams)
    def drive(det):
        out = []
        ids = [f"s{i}" for i in range(13)]
        for s in ids:
            det.add_stream(s)
        refs = rng0 = np.random.default_rng(1)
        toks = rng0.integers(0, 64, (13, 8, 32))
        det.set_references(ids, toks)
        for rnd in range(4):
            if rnd == 2:
                for s in ("s3", "s7"):
                    det.remove_stream(s)
                    ids.remove(s)
                for s in ("s13", "s14"):
                    det.add_stream(s); ids.append(s)
                det.set_references(["s13", "s14"],
                                   rng0.integers(0, 64, (2, 8, 32)))
            obs = rng0.integers(0, 64, (len(ids), 8, 32))
            trig = det.observe(ids, obs)
            out.append((list(trig),
                        [float(det.score(s)) for s in ids]))
        return out

    a = drive(FleetDriftDetector(threshold=0.1, buckets=16, vocab=64,
                                 impl="exact"))
    b = drive(FleetDriftDetector(threshold=0.1, buckets=16, vocab=64,
                                 impl="exact", mesh=mesh))
    assert a == b, (a, b)
    print("KERNEL_PARITY_OK")
""")


def test_sharded_kernels_and_drift_plane_parity():
    r = _run_sub(KERNEL_PARITY)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "KERNEL_PARITY_OK" in r.stdout


# -- sharded JobBank: batched train/eval + churn (8-device subprocess) -----

BANK_PARITY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np, jax
    assert jax.device_count() == 8
    from repro.configs import smoke_config
    from repro.core.grouping import Request
    from repro.core.trainer import RetrainJob, SharedEngine
    from repro.launch.mesh import make_fleet_mesh

    VOCAB = 64

    def req(sid, toks):
        return Request(stream_id=sid, t=0.0, loc=(0.0, 0.0),
                       subsamples=toks, acc=0.0, train_data=toks)

    def drive(mesh):
        cfg = dataclasses.replace(smoke_config("olmo-1b"),
                                  vocab_size=VOCAB)
        eng = SharedEngine(cfg, batch_min_jobs=2, mesh=mesh)
        rng = np.random.default_rng(0)
        jobs = [RetrainJob(eng, req(f"s{i}",
                                    rng.integers(0, VOCAB, (8, 32))),
                           micro_steps=2, batch=4, seed=i)
                for i in range(6)]
        eng.train_micro_many(jobs)
        # churn: one job dies mid-fleet (swap-compaction), one joins
        jobs[2].release(); del jobs[2]
        jobs.append(RetrainJob(eng, req("s9",
                                        rng.integers(0, VOCAB, (8, 32))),
                               micro_steps=2, batch=4, seed=9))
        eng.train_micro_many(jobs)
        accs = eng.eval_jobs(jobs)
        states = [jax.tree.map(np.asarray, j.state) for j in jobs]
        return accs, states

    a_accs, a_states = drive(None)
    b_accs, b_states = drive(make_fleet_mesh(8))
    assert a_accs == b_accs, (a_accs, b_accs)
    for sa, sb in zip(a_states, b_states):
        for la, lb in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
            np.testing.assert_array_equal(la, lb)
    print("BANK_PARITY_OK")
""")


def test_sharded_bank_train_eval_churn_parity():
    r = _run_sub(BANK_PARITY)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "BANK_PARITY_OK" in r.stdout


# -- elastic mid-window recovery (8-device subprocess) ---------------------

ELASTIC_RECOVERY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np, jax
    assert jax.device_count() == 8
    from repro.configs import smoke_config
    from repro.core import trainer as T
    from repro.core.controller import ControllerConfig, ECCOController
    from repro.core.trainer import SharedEngine
    from repro.data.streams import make_fleet
    from repro.distributed.elastic import FleetElastic
    from repro.launch.mesh import make_fleet_mesh

    VOCAB = 64

    def build(mesh=None, elastic=None):
        T._job_counter.n = 0      # job ids must match across runs
        cfg = dataclasses.replace(smoke_config("olmo-1b"),
                                  vocab_size=VOCAB)
        engine = SharedEngine(cfg)
        bank, streams = make_fleet(vocab=VOCAB, regions=2,
                                   streams_per_region=2, dim=4,
                                   switch_times=(5.0,), seed=1)
        cc = ControllerConfig(window_micro=6, micro_steps=4,
                              train_batch=16, drift_threshold=0.25,
                              p_drop=0.5, shared_bandwidth=1e9)
        return ECCOController(engine, streams, cc, seed=0, mesh=mesh,
                              elastic=elastic)

    # reference: 8-device mesh, no failure
    ctl_a = build(mesh=make_fleet_mesh(8))
    ctl_a.run(3)

    # elastic: 4 of 8 fleet devices die inside window 2's allocator loop
    ckpt_dir = os.environ["CKPT_DIR"]
    el = FleetElastic(ckpt_dir, mesh=make_fleet_mesh(8))
    ctl_b = build(mesh=el.mesh, elastic=el)
    ctl_b.warmup()
    ctl_b.run_window()
    el.schedule_failure(4, after_barriers=4)
    ctl_b.run_window()            # aborts, re-meshes to 4, re-runs
    ctl_b.run_window()
    assert len(el.recoveries) == 1, el.recoveries
    plan = el.recoveries[0]
    assert (plan.old_mesh_shape, plan.new_mesh_shape) == ((8,), (4,))
    assert int(np.asarray(ctl_b.mesh.devices).size) == 4

    assert len(ctl_a.history) == len(ctl_b.history)
    for wa, wb in zip(ctl_a.history, ctl_b.history):
        assert wa.t == wb.t
        assert wa.groups == wb.groups, (wa.groups, wb.groups)
        assert set(wa.per_stream_acc) == set(wb.per_stream_acc)
        for k in wa.per_stream_acc:
            va, vb = wa.per_stream_acc[k], wb.per_stream_acc[k]
            assert (va == vb) or (np.isnan(va) and np.isnan(vb)), \\
                (k, va, vb)
        assert wa.shares == wb.shares
        assert wa.bandwidth == wb.bandwidth
        assert wa.delivered == wb.delivered
    print("ELASTIC_RECOVERY_OK")
""")


def test_elastic_mid_window_recovery_bit_identical(tmp_path):
    r = _run_sub(ELASTIC_RECOVERY, CKPT_DIR=str(tmp_path))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_RECOVERY_OK" in r.stdout


# -- decide_many shard-span parity (pure elementwise; in-process) ----------

def test_decide_many_shard_span_parity():
    """Concatenating decide_many over the registry's per-device row
    spans equals the global call row-for-row — the contract that makes
    the transmission plane's decisions shard-local."""
    table = ProfileTable([SamplingConfig(8, 32), SamplingConfig(4, 32),
                          SamplingConfig(2, 32)])
    plane = FleetTransmissionPlane(table, bytes_per_token=1.0)
    rng = np.random.default_rng(0)
    n = 24
    reg = RowRegistry(align=4)
    reg.reserve(n)
    kw = dict(budget_levels=[0] * n,
              token_budgets=rng.uniform(32, 2048, n),
              p_shares=rng.uniform(0, 1, n),
              n_members=rng.integers(1, 5, n),
              achieved_bw=rng.uniform(0, 64, n),
              window_seconds=10.0)
    full = plane.decide_many(**kw)
    spans = reg.shard_spans(4)
    assert [hi - lo for lo, hi in spans] == [reg.capacity // 4] * 4
    for field in ("rate", "resolution", "scaled_rate", "deliverable",
                  "delivered"):
        parts = []
        for lo, hi in spans:
            lo, hi = min(lo, n), min(hi, n)
            if lo == hi:
                continue
            sub = plane.decide_many(**{
                k: (v if np.isscalar(v) else np.asarray(v)[lo:hi])
                for k, v in kw.items()})
            parts.append(getattr(sub, field))
        np.testing.assert_array_equal(np.concatenate(parts),
                                      getattr(full, field))


# -- straggler quota + window deadline (in-process, fake clock) ------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class _FakeJob:
    """Allocator duck-type whose train_micro advances a fake clock by
    micro_steps * step_time and logs the quota it actually ran."""

    def __init__(self, jid, clock, step_time, gain):
        self.job_id = jid
        self.num_members = 1
        self.micro_steps = 4
        self._clock = clock
        self._step_time = step_time
        self._gain = gain
        self._acc = 0.0
        self.steps_run = []

    def eval(self):
        return self._acc

    def train_micro(self):
        self._clock.t += self.micro_steps * self._step_time
        self.steps_run.append(self.micro_steps)
        self._acc = min(1.0, self._acc + self._gain * self.micro_steps)


def test_straggler_quota_shrinks_micro_windows():
    clock = _Clock()
    fast1 = _FakeJob("fast1", clock, step_time=1.0, gain=0.001)
    fast2 = _FakeJob("fast2", clock, step_time=1.0, gain=0.001)
    # slow job: 10x the step time, juiciest gain (so the greedy loop
    # keeps picking it — the quota must be what reins it in)
    slow = _FakeJob("slow", clock, step_time=10.0, gain=0.05)
    pol = StragglerPolicy(threshold=2.0, min_quota_frac=0.25)
    ECCOAllocator().run_window([fast1, fast2, slow], 8,
                               stragglers=pol, clock=clock)
    assert pol.is_straggler("slow")
    assert not pol.is_straggler("fast1")
    # first micro-window ran at full quota (no timings yet); every
    # later one at the re-normalized quota: 4 * max(0.25, med/mean)
    assert slow.steps_run[0] == 4
    assert len(slow.steps_run) > 1
    assert all(s == 1 for s in slow.steps_run[1:]), slow.steps_run
    assert pol.flagged.get("slow", 0) >= 1
    assert fast1.steps_run == [4] * len(fast1.steps_run)


def test_window_deadline_drops_leftover_budget():
    clock = _Clock()
    jobs = [_FakeJob(f"j{i}", clock, step_time=10.0, gain=0.01)
            for i in range(3)]
    pol = StragglerPolicy()
    # initial pass alone burns 3 * 40s; the 100s deadline leaves no
    # room for greedy micro-windows after it
    trace = ECCOAllocator().run_window(jobs, 10, stragglers=pol,
                                       deadline=100.0, clock=clock)
    assert len(trace.order) == 3, trace.order
    # without a deadline the full budget runs
    clock2 = _Clock()
    jobs2 = [_FakeJob(f"j{i}", clock2, step_time=10.0, gain=0.01)
             for i in range(3)]
    trace2 = ECCOAllocator().run_window(jobs2, 10,
                                        stragglers=StragglerPolicy(),
                                        clock=clock2)
    assert len(trace2.order) == 10


def test_straggler_off_is_seed_identical():
    """stragglers=None must leave the scalar path untouched — same
    order, same accuracies as the seed signature."""
    clock = _Clock()
    jobs = [_FakeJob(f"j{i}", clock, step_time=1.0, gain=0.01 * (i + 1))
            for i in range(3)]
    a = ECCOAllocator().run_window(jobs, 6)
    clock2 = _Clock()
    jobs2 = [_FakeJob(f"j{i}", clock2, step_time=1.0, gain=0.01 * (i + 1))
             for i in range(3)]
    b = ECCOAllocator().run_window(jobs2, 6, stragglers=None,
                                   deadline=None, clock=clock2)
    assert a.order == b.order
    assert a.acc == b.acc
    assert a.gpu_time == b.gpu_time


# -- elastic barrier plumbing (in-process) ---------------------------------

def test_barrier_failure_aborts_allocator_window(tmp_path):
    el = FleetElastic(str(tmp_path))
    el.schedule_failure(1, after_barriers=3)
    clock = _Clock()
    jobs = [_FakeJob(f"j{i}", clock, step_time=1.0, gain=0.01)
            for i in range(2)]
    with pytest.raises(DeviceFailure) as ei:
        ECCOAllocator().run_window(jobs, 8, stragglers=StragglerPolicy(),
                                   clock=clock, barrier=el.barrier)
    assert ei.value.lost == 1
    # the two pre-failure micro-windows ran; the third aborted cleanly
    assert sum(len(j.steps_run) for j in jobs) == 2
