"""Transmission controller (§3.2): sampling-config table lookups, the
f*/n_j member scaling, GPU-proportional bandwidth allocation vs the
equal-share baseline."""
import numpy as np
import pytest

from repro.core import transmission as tx


def _table():
    cfgs = [tx.SamplingConfig(rate=r, resolution=q)
            for r in (2, 4, 8) for q in (16, 32, 64)]
    t = tx.ProfileTable(cfgs)
    # budget level 0: low budget -> prefer low-rate hi-res; level 1: high
    for i, c in enumerate(cfgs):
        t.record(0, i, 0.5 - 0.01 * c.rate + 0.002 * c.resolution)
        t.record(1, i, 0.3 + 0.01 * c.rate + 0.001 * c.resolution)
    return t, cfgs


def test_profile_table_best_respects_budget():
    t, cfgs = _table()
    best = t.best(0, token_budget=128)
    assert best.tokens <= 128
    # and it is the argmax among fitting configs
    fitting = [(t.acc(0, i), c) for i, c in enumerate(cfgs)
               if c.tokens <= 128]
    assert t.acc(0, cfgs.index(best)) == max(a for a, _ in fitting)
    # unprofiled cells read back as None
    assert t.acc(9, 0) is None


def test_profile_table_fallback_sparsest():
    """An unprofiled budget level must degrade conservatively: the
    SPARSEST config that fits (the seed returned the densest, maximally
    violating the budget when nothing fit at all)."""
    t = tx.ProfileTable([tx.SamplingConfig(2, 16), tx.SamplingConfig(4, 32)])
    # no recordings at level 7 -> sparsest fitting config
    assert t.best(7, token_budget=64).tokens == 32
    assert t.best(7, token_budget=1000).tokens == 32
    # over-budget regression: nothing fits token_budget=8 -> still the
    # sparsest overall, NOT the densest
    assert t.best(7, token_budget=8).tokens == 32
    assert t.best(7).tokens == 32


def test_profile_table_empty_configs_no_crash():
    """Regression: best() raised ValueError (max() of empty sequence)
    when the table was built with no configs — both with and without
    profiled accuracies pointing at the budget level."""
    empty = tx.ProfileTable([])
    assert empty.best(0) is None
    assert empty.best(3, token_budget=16) is None
    # decide() degrades to a zero-token transmission, not a crash
    ctrl = tx.TransmissionController(empty)
    d = ctrl.decide(gpu_budget_level=0, token_budget=64, p_share=0.5,
                    n_members=2, achieved_bandwidth=8.0,
                    window_seconds=1.0)
    assert d.delivered_tokens == 0 and d.config.tokens == 0
    # nonempty table where nothing fits still falls back to a config
    # (here the only one)
    t = tx.ProfileTable([tx.SamplingConfig(4, 32)])
    assert t.best(0, token_budget=1).tokens == 128


def test_decision_scales_rate_by_members():
    t, _ = _table()
    ctrl = tx.TransmissionController(t, bytes_per_token=1.0)
    d = ctrl.decide(gpu_budget_level=1, token_budget=512, p_share=0.6,
                    n_members=3, achieved_bandwidth=1e6,
                    window_seconds=1.0)
    assert d.scaled_rate == pytest.approx(d.config.rate / 3)
    assert d.gaimd_alpha == pytest.approx(0.6 / 3)
    assert d.gaimd_beta == 0.5


def test_decision_target_rate_is_proportional_target():
    """target_rate is the alpha/(1-beta) steady-state GAIMD target the
    realized bandwidth is graded against — NOT the achieved bandwidth
    (the seed stored achieved, making proportionality-error reporting
    compare achieved-vs-achieved, i.e. identically zero)."""
    from repro.core.gaimd import proportionality_error
    t, _ = _table()
    ctrl = tx.TransmissionController(t, bytes_per_token=1.0)
    decs = [ctrl.decide(gpu_budget_level=1, token_budget=512, p_share=p,
                        n_members=n, achieved_bandwidth=bw,
                        window_seconds=1.0)
            for p, n, bw in ((0.6, 3, 7.0), (0.4, 1, 3.0))]
    for d, (p, n) in zip(decs, ((0.6, 3), (0.4, 1))):
        assert d.target_rate == pytest.approx((p / n) / (1 - 0.5))
        assert d.target_rate != pytest.approx(7.0) or p != 0.6
    # achieved deviates from target -> nonzero proportionality error
    err = proportionality_error([7.0, 3.0],
                                [d.target_rate for d in decs])
    assert err > 0.0


def test_decision_compresses_to_bandwidth():
    t, _ = _table()
    ctrl = tx.TransmissionController(t, bytes_per_token=2.0)
    d = ctrl.decide(gpu_budget_level=1, token_budget=10**6, p_share=1.0,
                    n_members=1, achieved_bandwidth=64.0,
                    window_seconds=1.0)
    assert d.delivered_tokens <= 64.0 * 1.0 / 2.0


def test_proportional_beats_equal_for_matched_delivery():
    """Table 1 mechanism: GPU-proportional bandwidth lets the high-GPU
    flow deliver matched data volume."""
    p = [0.3, 0.7]
    n = [1, 1]
    caps = [np.inf, np.inf]
    prop = tx.allocate_bandwidth(p, n, caps, shared_cap=3.0)
    eq = tx.equal_share_bandwidth(2, caps, shared_cap=3.0)
    # proportional: flow 1 gets ~70% of bandwidth
    assert prop[1] / prop.sum() == pytest.approx(0.7, abs=0.08)
    # equal: both ~50%, so the high-GPU flow is bandwidth-starved
    assert eq[1] / eq.sum() == pytest.approx(0.5, abs=0.08)
