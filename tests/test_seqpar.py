"""Sequence-parallel mLSTM (shard_map) correctness: state-summary
algebra in-process, full block parity in a subprocess with 8 forced host
devices."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.xlstm import (combine_mlstm_states, mlstm_chunked,
                                mlstm_state_summary)


def _qkvg(key, B, S, H, P):
    ks = jax.random.split(key, 5)
    return (jax.random.normal(ks[0], (B, S, H, P)),
            jax.random.normal(ks[1], (B, S, H, P)),
            jax.random.normal(ks[2], (B, S, H, P)),
            jax.random.normal(ks[3], (B, S, H)) * 2,
            jax.random.normal(ks[4], (B, S, H)) * 2 + 1)


@pytest.mark.parametrize("split", [16, 64, 96])
def test_summary_combine_matches_full(split):
    B, S, H, P = 2, 128, 2, 16
    q, k, v, ig, fg = _qkvg(jax.random.PRNGKey(0), B, S, H, P)
    sa, _ = mlstm_state_summary(k[:, :split], v[:, :split],
                                ig[:, :split], fg[:, :split], chunk=16)
    h_b = mlstm_chunked(q[:, split:], k[:, split:], v[:, split:],
                        ig[:, split:], fg[:, split:], chunk=16,
                        init_state=sa)
    h_a = mlstm_chunked(q[:, :split], k[:, :split], v[:, :split],
                        ig[:, :split], fg[:, :split], chunk=16)
    h_full = mlstm_chunked(q, k, v, ig, fg, chunk=16)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h_a, h_b], 1)),
        np.asarray(h_full), atol=1e-5)


def test_combine_is_associative_on_invariants():
    B, S, H, P = 1, 96, 2, 8
    _, k, v, ig, fg = _qkvg(jax.random.PRNGKey(1), B, S, H, P)
    thirds = [slice(0, 32), slice(32, 64), slice(64, 96)]
    ss = [mlstm_state_summary(k[:, t], v[:, t], ig[:, t], fg[:, t],
                              chunk=16) for t in thirds]
    # ((s0 + s1) + s2) vs (s0 + (s1 combined later))
    left = combine_mlstm_states(
        combine_mlstm_states(ss[0][0], ss[1][1], ss[1][0]),
        ss[2][1], ss[2][0])
    full, _ = mlstm_state_summary(k, v, ig, fg, chunk=16)

    def inv(s):
        C, n, m = s
        return (C * jnp.exp(m)[..., None, None],
                n * jnp.exp(m)[..., None])

    for a, b in zip(inv(left), inv(full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.models.xlstm import (apply_mlstm_block,
                                    apply_mlstm_block_seqpar,
                                    mlstm_block_spec, mlstm_block_states)
    from repro.models import param as P
    cfg = smoke_config('xlstm-350m')
    spec = mlstm_block_spec(cfg)
    params = P.init_params(spec, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (4, 64, cfg.d_model), jnp.float32)
    ref, _ = apply_mlstm_block(cfg, params, x, chunk=16)
    out = apply_mlstm_block_seqpar(cfg, params, x, mesh, chunk=16,
                                   batch_axes=('data',))
    assert float(jnp.max(jnp.abs(ref - out))) < 1e-4
    ref2, cref = mlstm_block_states(cfg, params, x, chunk=16)
    out2, c = apply_mlstm_block_seqpar(cfg, params, x, mesh, chunk=16,
                                       want_state=True)
    assert float(jnp.max(jnp.abs(ref2 - out2))) < 1e-4
    assert float(jnp.max(jnp.abs(cref['conv'] - c['conv']))) < 1e-5
    m1, m2 = cref['m'], c['m']
    M = jnp.maximum(m1, m2)
    a = cref['C'] * jnp.exp(m1 - M)[..., None, None]
    b = c['C'] * jnp.exp(m2 - M)[..., None, None]
    assert float(jnp.max(jnp.abs(a - b))) < 1e-4
    print("SEQPAR_OK")
""")


def test_seqpar_block_parity_subprocess():
    # runs on jax 0.4.x too: the block goes through the
    # kernels._compat.shard_map wrapper (check_rep/check_vma fallback)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SUBPROC],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SEQPAR_OK" in r.stdout


def test_zero_policy_rules_consistent():
    """zero policy must never map two mesh axes onto one logical axis in
    a conflicting way, for every arch."""
    from repro.configs import ARCH_IDS, get_config
    from repro.distributed.sharding import mesh_rules

    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ({"data": 16, "model": 16},
                      {"pod": 2, "data": 16, "model": 16}):
            rules = mesh_rules(FakeMesh(shape), cfg, policy="zero")
            # no TP on heads/mlp under zero
            assert rules["heads"] is None and rules["mlp"] is None
            if rules["fsdp"] == ("data", "model"):
                # 2D param sharding excludes vocab TP (axis conflict on
                # the embedding table) and MoE (experts own the axis)
                assert rules["vocab"] is None
                assert cfg.moe is None
                assert cfg.d_model % (16 * 16) == 0
