"""Golden-trace regression: one fixed-seed scenario run per framework,
asserted equal to the checked-in traces under tests/golden/. Catches
silent behavior drift in drift detection, grouping, allocation, and
transmission control.

After an INTENTIONAL behavior change, regenerate with

    PYTHONPATH=src python -m repro.testing.trace --regen tests/golden

and review the golden diff like code (see docs/scenarios.md).
"""
import copy
import os

import pytest

from repro.testing import trace as T

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def engine():
    return T.make_engine_for(T.golden_scenario())


@pytest.mark.parametrize("framework", T.GOLDEN_FRAMEWORKS)
def test_trace_matches_golden(framework, engine):
    got = T.golden_trace(framework, engine=engine)
    want = T.load_trace(T.golden_path(GOLDEN_DIR, framework))
    diffs = T.compare(got, want)
    assert not diffs, "behavior drifted from golden trace " \
        f"(regenerate only if intentional):\n" + "\n".join(diffs)


# ---------------------------------------------------------------------------
# the comparator itself must catch what it claims to catch
# ---------------------------------------------------------------------------
def _base():
    return {
        "meta": {"scenario": "s", "framework": "ecco", "seed": 0,
                 "scenario_seed": 0, "windows": 1},
        "windows": [{"t": 0.0,
                     "drift": {"a": 0.1, "b": None},
                     "groups": {"g0": ["a", "b"]},
                     "shares": {"g0": 1.0},
                     "bandwidth": {"a": 10.0, "b": 12.0},
                     "acc": {"a": 0.5, "b": None},
                     "events": [{"kind": "new", "stream": "a",
                                 "job": "g0"}]}],
    }


def test_compare_clean_on_equal():
    assert T.compare(_base(), _base()) == []


def test_compare_flags_structural_drift():
    for mutate in [
        lambda tr: tr["windows"][0]["groups"]["g0"].pop(),
        lambda tr: tr["windows"][0]["events"].clear(),
        lambda tr: tr["windows"].clear(),
        lambda tr: tr["meta"].update(seed=1),
        lambda tr: tr["windows"][0]["drift"].update(a=0.4),
        lambda tr: tr["windows"][0]["acc"].update(b=0.9),   # None -> float
        lambda tr: tr["windows"][0]["bandwidth"].update(a=11.0),
    ]:
        bad = copy.deepcopy(_base())
        mutate(bad)
        assert T.compare(bad, _base()), mutate


def test_compare_tolerates_float_wobble():
    near = copy.deepcopy(_base())
    near["windows"][0]["drift"]["a"] += 5e-5
    near["windows"][0]["shares"]["g0"] -= 2e-3
    near["windows"][0]["bandwidth"]["a"] *= 1.001
    near["windows"][0]["acc"]["a"] += 0.03
    assert T.compare(near, _base()) == []


def test_goldens_checked_in():
    for fw in T.GOLDEN_FRAMEWORKS:
        path = T.golden_path(GOLDEN_DIR, fw)
        assert os.path.exists(path), f"missing golden {path}"
        tr = T.load_trace(path)
        assert tr["meta"]["framework"] == fw
        assert len(tr["windows"]) == tr["meta"]["windows"]
