"""Golden-trace regression: one fixed-seed scenario run per framework,
asserted equal to the checked-in traces under tests/golden/. Catches
silent behavior drift in drift detection, grouping, allocation, and
transmission control.

After an INTENTIONAL behavior change, regenerate with

    PYTHONPATH=src python -m repro.testing.trace --regen tests/golden

and review the golden diff like code (see docs/scenarios.md).
"""
import copy
import os

import pytest

from repro.testing import trace as T

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def engine():
    return T.make_engine_for(T.golden_scenario())


@pytest.mark.parametrize("framework", T.GOLDEN_FRAMEWORKS)
def test_trace_matches_golden(framework, engine):
    got = T.golden_trace(framework, engine=engine)
    want = T.load_trace(T.golden_path(GOLDEN_DIR, framework))
    diffs = T.compare(got, want)
    assert not diffs, "behavior drifted from golden trace " \
        f"(regenerate only if intentional):\n" + "\n".join(diffs)


# ---------------------------------------------------------------------------
# hostile scenarios (ROADMAP item 3): golden-pinned at smoke scale,
# with run_scenario's window invariants checked along the way
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("framework", T.GOLDEN_FRAMEWORKS)
@pytest.mark.parametrize("scenario", T.HOSTILE_SCENARIOS)
def test_hostile_trace_matches_golden(scenario, framework, engine):
    got = T.hostile_trace(scenario, framework, engine=engine)
    want = T.load_trace(T.golden_path(GOLDEN_DIR, framework,
                                      scenario=scenario))
    diffs = T.compare(got, want)
    assert not diffs, f"{scenario}/{framework} drifted from golden " \
        "(regenerate only if intentional):\n" + "\n".join(diffs)


def test_hostile_goldens_exercise_their_failure_modes():
    """The pinned trajectories must actually enter the hostile regimes
    they were designed for — a golden of a scenario that never bites
    pins nothing."""
    fc = T.load_trace(T.golden_path(GOLDEN_DIR, "ecco",
                                    scenario="flash_crowd_10k"))
    spec = T.HOSTILE_GOLDEN["flash_crowd_10k"]["scenario"]
    w0, wj = fc["windows"][0], fc["windows"][spec["join_window"]]
    assert len(wj["drift"]) == len(w0["drift"]) + spec["joiners"]
    # the cohort's correlated drift pulls it into groups
    crowd = [s for s in fc["windows"][-1]["drift"] if "crowd" in s]
    grouped = {m for w in fc["windows"] for ms in w["groups"].values()
               for m in ms}
    assert crowd and set(crowd) <= grouped

    sb = T.load_trace(T.golden_path(GOLDEN_DIR, "ecco",
                                    scenario="sensor_blackout"))
    bw = T.HOSTILE_GOLDEN["sensor_blackout"]["scenario"][
        "blackout_window"]
    gone = set(sb["windows"][bw - 1]["drift"]) - \
        set(sb["windows"][bw]["drift"])
    assert gone and all(s.startswith("cam0") for s in gone)
    # the doomed region had grouped before dying
    assert gone <= {m for ms in sb["windows"][bw - 1]["groups"].values()
                    for m in ms}

    od = T.load_trace(T.golden_path(GOLDEN_DIR, "ecco",
                                    scenario="oscillating_drift"))
    evicts = [e for w in od["windows"] for e in w["events"]
              if e["kind"] == "evict"]
    assert evicts            # the flip cadence thrashes regrouping

    bc = T.load_trace(T.golden_path(GOLDEN_DIR, "ecco",
                                    scenario="bandwidth_collapse"))
    cw = T.HOSTILE_GOLDEN["bandwidth_collapse"]["scenario"][
        "collapse_window"]
    pre = sum(v for v in bc["windows"][cw - 1]["bandwidth"].values())
    post = sum(v for v in bc["windows"][cw]["bandwidth"].values())
    assert post < pre / 20   # the collapse actually starves the fleet


# ---------------------------------------------------------------------------
# the comparator itself must catch what it claims to catch
# ---------------------------------------------------------------------------
def _base():
    return {
        "meta": {"scenario": "s", "framework": "ecco", "seed": 0,
                 "scenario_seed": 0, "windows": 1},
        "windows": [{"t": 0.0,
                     "drift": {"a": 0.1, "b": None},
                     "groups": {"g0": ["a", "b"]},
                     "shares": {"g0": 1.0},
                     "bandwidth": {"a": 10.0, "b": 12.0},
                     "acc": {"a": 0.5, "b": None},
                     "events": [{"kind": "new", "stream": "a",
                                 "job": "g0"}]}],
    }


def test_compare_clean_on_equal():
    assert T.compare(_base(), _base()) == []


def test_compare_flags_structural_drift():
    for mutate in [
        lambda tr: tr["windows"][0]["groups"]["g0"].pop(),
        lambda tr: tr["windows"][0]["events"].clear(),
        lambda tr: tr["windows"].clear(),
        lambda tr: tr["meta"].update(seed=1),
        lambda tr: tr["windows"][0]["drift"].update(a=0.4),
        lambda tr: tr["windows"][0]["acc"].update(b=0.9),   # None -> float
        lambda tr: tr["windows"][0]["bandwidth"].update(a=11.0),
    ]:
        bad = copy.deepcopy(_base())
        mutate(bad)
        assert T.compare(bad, _base()), mutate


def test_compare_tolerates_float_wobble():
    near = copy.deepcopy(_base())
    near["windows"][0]["drift"]["a"] += 5e-5
    near["windows"][0]["shares"]["g0"] -= 2e-3
    near["windows"][0]["bandwidth"]["a"] *= 1.001
    near["windows"][0]["acc"]["a"] += 0.03
    assert T.compare(near, _base()) == []


def test_goldens_checked_in():
    runs = [(None, fw) for fw in T.GOLDEN_FRAMEWORKS] + \
        [(sc, fw) for sc in T.HOSTILE_SCENARIOS
         for fw in T.GOLDEN_FRAMEWORKS]
    for sc, fw in runs:
        path = T.golden_path(GOLDEN_DIR, fw, scenario=sc)
        assert os.path.exists(path), f"missing golden {path}"
        tr = T.load_trace(path)
        assert tr["meta"]["framework"] == fw
        if sc is not None:
            assert tr["meta"]["scenario"] == sc
        assert len(tr["windows"]) == tr["meta"]["windows"]
