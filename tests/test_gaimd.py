"""GAIMD fluid-model tests: the steady-state proportionality law the
paper's transmission controller relies on (rate ∝ alpha/(1-beta)), local
cap saturation, and ECCO's GPU-proportional parameterization."""
import numpy as np
import pytest

from repro.core import gaimd


def test_steady_state_proportional_to_alpha():
    """Equal beta: rates should converge ∝ alpha (Yang & Lam Eq. 21)."""
    alpha = np.array([1.0, 2.0, 4.0], np.float32)
    beta = np.full(3, 0.5, np.float32)
    caps = np.full(3, np.inf, np.float32)
    r = gaimd.steady_state_rates(alpha, beta, caps, shared_cap=100.0)
    ratios = r / r[0]
    np.testing.assert_allclose(ratios, [1.0, 2.0, 4.0], rtol=0.15)


def test_beta_raises_share():
    """Higher beta (gentler backoff) -> larger share at equal alpha."""
    alpha = np.array([1.0, 1.0], np.float32)
    beta = np.array([0.5, 0.8], np.float32)
    caps = np.full(2, np.inf, np.float32)
    r = gaimd.steady_state_rates(alpha, beta, caps, shared_cap=50.0)
    assert r[1] > r[0] * 1.5


def test_local_cap_saturates_then_remainder_shared():
    """Paper Fig. 11 (right): a locally-capped flow pins at its cap; the
    others split the remainder in proportion."""
    alpha = np.array([2.0, 1.0, 1.0], np.float32)
    beta = np.full(3, 0.5, np.float32)
    caps = np.array([3.0, np.inf, np.inf], np.float32)
    r = gaimd.steady_state_rates(alpha, beta, caps, shared_cap=30.0)
    # pinned at its cap (time-average sits slightly below: AIMD dips on
    # every shared-bottleneck loss event)
    assert 2.5 <= r[0] <= 3.0
    np.testing.assert_allclose(r[1] / r[2], 1.0, rtol=0.1)
    assert r[1] + r[2] > 0.6 * (30.0 - 3.0)              # uses remainder


def test_ecco_params_gpu_proportional():
    """alpha = p_j/n_j, beta = 0.5 -> per-flow rate ∝ p_j/n_j, so group
    aggregate ∝ p_j (the paper's goal)."""
    # two groups: p = 0.75 / 0.25, sizes 3 and 1
    p_shares = [0.75] * 3 + [0.25]
    n_members = [3] * 3 + [1]
    alpha, beta = gaimd.ecco_params(p_shares, n_members)
    caps = np.full(4, np.inf, np.float32)
    r = gaimd.steady_state_rates(alpha, beta, caps, shared_cap=40.0)
    g1, g2 = r[:3].sum(), r[3]
    np.testing.assert_allclose(g1 / (g1 + g2), 0.75, atol=0.08)


def test_proportionality_error_metric():
    assert gaimd.proportionality_error([1, 1], [1, 1]) == 0.0
    assert gaimd.proportionality_error([1, 0], [0, 1]) == 1.0
    e = gaimd.proportionality_error([3, 1], [1, 1])
    assert 0.2 < e < 0.3


def test_simulate_respects_shared_cap_on_average():
    alpha = np.ones(8, np.float32)
    beta = np.full(8, 0.5, np.float32)
    caps = np.full(8, np.inf, np.float32)
    rates, _ = gaimd.simulate(alpha, beta, caps, shared_cap=20.0,
                              steps=2000)
    tail = np.asarray(rates)[-500:]
    # AIMD oscillates around the cap; time-average must stay below
    # cap * (1 + alpha-step overshoot)
    assert tail.sum(axis=1).mean() < 20.0 * 1.5
    assert tail.sum(axis=1).mean() > 20.0 * 0.5
