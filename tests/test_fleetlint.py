"""fleetlint: paired firing/passing fixtures per rule, pragma
behavior, the src/repro cleanliness meta-test, and the runtime
sanitizer (borrow fingerprinting + transfer guard)."""
import os
import textwrap

import numpy as np
import pytest

from repro.testing.fleetlint import (check_module, default_rules,
                                     module_from_source)
from repro.testing.fleetlint.rules import (BorrowedStackRule,
                                           DeterminismRule, HostSyncRule,
                                           MeshCompatRule,
                                           PerMemberLoopRule,
                                           PragmaReasonRule,
                                           ProfileResolutionRule,
                                           RowsDisciplineRule,
                                           SyncBeforeCaptureRule)
from repro.testing.fleetlint.runtime import (FleetlintRuntimeError,
                                             install, installed, uninstall)

CORE = "src/repro/core/mod.py"


def lint(src, rule, rel=CORE):
    mod = module_from_source(textwrap.dedent(src), rel)
    return check_module(mod, [rule])


def names(findings):
    return [f.rule for f in findings]


# -- rule fixtures: one firing + one passing snippet each -------------------

def test_borrowed_stack_fires_on_attribute_store():
    bad = """
    class C:
        def cache(self):
            self.stack = self.bank.params_stack()
    """
    assert names(lint(bad, BorrowedStackRule())) == ["borrowed-stack"]


def test_borrowed_stack_fires_on_escape_via_return():
    bad = """
    def leak(bank):
        s = bank.params_stack_compute("bf16")
        return s
    """
    assert names(lint(bad, BorrowedStackRule())) == ["borrowed-stack"]


def test_borrowed_stack_passes_local_use_and_snapshots():
    good = """
    class C:
        def use(self):
            s = self.bank.params_stack()
            score(s)
        def keep(self):
            self.snap = self.bank.snapshot_params(0)   # committed copy
    """
    assert lint(good, BorrowedStackRule()) == []


def test_sync_before_capture_fires_without_compact():
    bad = """
    def dispatch(jobs, bank):
        idxs = [j._slot.idx for j in jobs]
        return bank.gather(idxs)
    """
    assert names(lint(bad, SyncBeforeCaptureRule())) \
        == ["sync-before-capture"]


def test_sync_before_capture_conditional_compact_still_fires():
    bad = """
    def dispatch(jobs, bank, maybe):
        if maybe:
            bank.compact()
        return [j._slot.idx for j in jobs]
    """
    assert names(lint(bad, SyncBeforeCaptureRule())) \
        == ["sync-before-capture"]


def test_sync_before_capture_passes_with_compact_first():
    good = """
    def dispatch(jobs, bank):
        bank.compact()
        return bank.gather([j._slot.idx for j in jobs])

    class Handle:
        def own(self):
            return self._slot.idx        # a handle's OWN index: exempt
    """
    assert lint(good, SyncBeforeCaptureRule()) == []


def test_per_member_loop_fires_in_core():
    bad = """
    def score(job, evs):
        return [m.eval_on(evs) for m in job.members]
    """
    assert names(lint(bad, PerMemberLoopRule())) == ["per-member-loop"]


def test_per_member_loop_passes_batched_and_out_of_scope():
    good = """
    def score(eng, jobs, evs):
        return eng.eval_pairs([(j, evs) for j in jobs])
    """
    assert lint(good, PerMemberLoopRule()) == []
    bad = "accs = [m.eval_on(e) for m in job.members]\n"
    # the rule scopes to plane code; test helpers are out of scope
    assert lint(bad, PerMemberLoopRule(), rel="tests/helper.py") == []
    assert names(lint(bad, PerMemberLoopRule(), rel="benchmarks/b.py")) \
        == ["per-member-loop"]


def test_rows_discipline_fires_on_handrolled_growth():
    bad = """
    import numpy as np
    class T:
        def grow(self, pad):
            self._acc = np.concatenate([self._acc, np.zeros(pad)])
    """
    assert names(lint(bad, RowsDisciplineRule())) == ["rows-discipline"]


def test_rows_discipline_passes_registry_sized_growth():
    good = """
    import numpy as np
    class T:
        def grow(self):
            pad = self._rows.capacity - self._acc.shape[0]
            self._acc = np.concatenate([self._acc, np.zeros(pad)])
    """
    assert lint(good, RowsDisciplineRule()) == []
    # core/rows.py itself is the sanctioned implementation
    bad = """
    import numpy as np
    class RowRegistry:
        def grow(self, pad):
            self._ids = np.concatenate([self._ids, np.zeros(pad)])
    """
    assert lint(bad, RowsDisciplineRule(),
                rel="src/repro/core/rows.py") == []


def test_host_sync_fires_on_item_and_jax_casts():
    bad = """
    import jax.numpy as jnp
    def decide(x):
        a = x.item()
        b = float(jnp.mean(x))
        return a + b
    """
    got = names(lint(bad, HostSyncRule(), rel="src/repro/core/trainer.py"))
    assert got == ["host-sync", "host-sync"]


def test_host_sync_passes_host_values_and_other_modules():
    good = """
    import numpy as np
    def decide(xs):
        return float(np.mean(xs))      # host numpy, no device sync
    """
    assert lint(good, HostSyncRule(),
                rel="src/repro/core/trainer.py") == []
    bad = "import jax.numpy as jnp\nb = float(jnp.mean(x))\n"
    # serve/ is not on the decision-plane allowlist
    assert lint(bad, HostSyncRule(), rel="src/repro/serve/plane.py") == []


def test_determinism_fires_on_wallclock_unseeded_and_set_iter():
    bad = """
    import time
    import numpy as np
    def decide(flows):
        t = time.time()
        r = np.random.uniform(0, 1)
        for f in set(flows):
            pass
        return t + r
    """
    got = names(lint(bad, DeterminismRule()))
    assert got == ["determinism"] * 3


def test_determinism_passes_seeded_and_sorted():
    good = """
    import time
    import numpy as np
    def decide(flows, clock=time.monotonic):
        rng = np.random.default_rng(0)
        r = rng.uniform(0, 1)
        for f in sorted(set(flows)):
            pass
        return clock() + r
    """
    assert lint(good, DeterminismRule()) == []


def test_profile_resolution_fires_on_mixed_literal():
    bad = 'spec = {"configs": [[30, 32], [15, 16]], "acc": []}\n'
    assert names(lint(bad, ProfileResolutionRule(), rel="data/s.py")) \
        == ["profile-resolution"]


def test_profile_resolution_passes_uniform_literal():
    good = 'spec = {"configs": [[r, 32] for r in (30, 15, 5)], "acc": []}\n'
    assert lint(good, ProfileResolutionRule(), rel="data/s.py") == []


def test_mesh_compat_fires_outside_compat_module():
    bad = """
    import jax
    from jax.experimental.shard_map import shard_map
    fn = jax.shard_map(f, mesh=m, in_specs=s, out_specs=s)
    """
    got = names(lint(bad, MeshCompatRule(), rel="src/repro/models/m.py"))
    assert got == ["mesh-compat", "mesh-compat"]


def test_mesh_compat_passes_shim_and_compat_module():
    good = """
    from repro.kernels._compat import CompilerParams, shard_map
    fn = shard_map(f, mesh=m, in_specs=s, out_specs=s)
    """
    assert lint(good, MeshCompatRule(), rel="src/repro/kernels/k.py") == []
    bad = "import jax\nfn = jax.shard_map(f, mesh=m, in_specs=s, out_specs=s)\n"
    assert lint(bad, MeshCompatRule(),
                rel="src/repro/kernels/_compat.py") == []


# -- pragma behavior ---------------------------------------------------------

def test_pragma_suppresses_same_line_and_next_code_line():
    src = """
    class C:
        def a(self):
            self.s = self.bank.params_stack()  # fleetlint: disable=borrowed-stack -- test
        def b(self):
            # fleetlint: disable=borrowed-stack -- justification may
            # continue over several comment lines before the code
            self.s = self.bank.params_stack()
    """
    assert lint(src, BorrowedStackRule()) == []


def test_pragma_only_covers_its_line():
    src = """
    class C:
        def a(self):
            self.s = self.bank.params_stack()  # fleetlint: disable=borrowed-stack -- test
            self.t = self.bank.params_stack()
    """
    assert names(lint(src, BorrowedStackRule())) == ["borrowed-stack"]


def test_pragma_disable_file():
    src = """
    # fleetlint: disable-file=borrowed-stack -- fixture file
    class C:
        def a(self):
            self.s = self.bank.params_stack()
        def b(self):
            self.t = self.bank.params_stack()
    """
    assert lint(src, BorrowedStackRule()) == []


def test_pragma_without_reason_or_unknown_rule_is_a_finding():
    src = """
    x = 1  # fleetlint: disable=borrowed-stack
    y = 2  # fleetlint: disable=no-such-rule -- because
    """
    rule = PragmaReasonRule([r.name for r in default_rules()])
    got = names(lint(src, rule))
    assert got == ["pragma-reason", "pragma-reason"]


def test_default_rule_set_has_at_least_eight_contract_rules():
    rules = default_rules()
    contract = [r for r in rules if r.name != "pragma-reason"]
    assert len(contract) >= 8
    assert all(r.contract for r in rules)


# -- meta-test: the real tree is clean ---------------------------------------

def test_src_repro_is_clean_under_default_rules():
    from repro.testing.fleetlint import run
    root = os.path.join(os.path.dirname(__file__), "..")
    paths = [os.path.join(root, "src"), os.path.join(root, "benchmarks"),
             os.path.join(root, "examples")]
    findings = run([p for p in paths if os.path.isdir(p)], default_rules())
    assert findings == [], "\n".join(f.human() for f in findings)


# -- runtime sanitizer -------------------------------------------------------

@pytest.fixture()
def sanitizer():
    install()
    yield
    uninstall()


def _tiny_engine(resident=True):
    import dataclasses

    from repro.configs import smoke_config
    from repro.core.trainer import SharedEngine
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=32,
                              d_model=16, d_ff=32, num_heads=2,
                              num_kv_heads=2, num_layers=1)
    return SharedEngine(cfg, batch_min_jobs=2, resident=resident)


def _jobs(engine, n=2, seq=8):
    from repro.core.grouping import Request
    from repro.core.trainer import RetrainJob
    rng = np.random.default_rng(0)
    jobs = []
    for i in range(n):
        data = rng.integers(0, 32, size=(4, seq)).astype(np.int32)
        req = Request(stream_id=f"s{i}", t=0.0, loc=(0.0, 0.0),
                      subsamples=data, acc=0.0, train_data=data)
        jobs.append(RetrainJob(engine, req, micro_steps=1, batch=2,
                               seed=i))
    return jobs


def test_sanitizer_catches_seeded_borrow_mutation(sanitizer):
    import jax
    eng = _tiny_engine(resident=False)   # host mode: leaves are numpy
    jobs = _jobs(eng)
    stack = eng.bank.params_stack()
    leaf = jax.tree.leaves(stack)[0]
    leaf[...] += 1.0      # mutate the borrowed buffer IN PLACE,
    #                       bypassing the dirty-bit write protocol
    with pytest.raises(FleetlintRuntimeError, match="mutated in place"):
        eng.bank.compact()
    del jobs


def test_sanitizer_allows_legit_borrow_lifecycle(sanitizer):
    eng = _tiny_engine(resident=False)
    jobs = _jobs(eng)
    stack = eng.bank.params_stack()
    # a legitimate write retires the borrow (version bump) — no error
    jobs[0].state = jobs[0].state
    eng.bank.compact()
    del stack, jobs


def test_sanitizer_transfer_guard_catches_host_stack(sanitizer):
    eng = _tiny_engine(resident=True)
    jobs = _jobs(eng)
    eng.bank.compact()
    eng.bank.sync_to_device()
    host_stack = jobs[0].state["params"]           # numpy host copy
    import jax
    stacked = jax.tree.map(
        lambda x: np.broadcast_to(x, (eng.bank.capacity,) + x.shape),
        host_stack)
    toks = np.stack([jobs[0].members[0].subsamples])
    with pytest.raises(FleetlintRuntimeError, match="h2d transfer"):
        # a host params stack fed to a batched decision call on a
        # RESIDENT bank: the per-job h2d the residency contract bans
        eng.batched_accuracy(stacked, toks, [0])
    del jobs


def test_sanitizer_silent_on_clean_batched_paths(sanitizer):
    eng = _tiny_engine(resident=True)
    jobs = _jobs(eng, n=3)
    eng.train_micro_many(jobs)
    pairs = [(j, j.members[0].subsamples) for j in jobs]
    a = eng.eval_pairs(pairs)
    assert len(a) == 3
    # and stats stay quiet across a warm repeat (no per-call crossings)
    before = eng.bank.stats.snapshot()
    b = eng.eval_pairs(pairs)
    after = eng.bank.stats.snapshot()
    assert a == b
    assert after["h2d_syncs"] == before["h2d_syncs"]
    assert after["d2h_syncs"] == before["d2h_syncs"]
    del jobs


def test_sanitizer_install_uninstall_roundtrip():
    from repro.core.trainer import JobBank, SharedEngine
    orig = (JobBank.params_stack, SharedEngine.eval_pairs)
    install()
    assert installed()
    install()                      # idempotent
    uninstall()
    assert not installed()
    assert (JobBank.params_stack, SharedEngine.eval_pairs) == orig


def test_sanitizer_parity_with_unpatched_engine():
    """The hooks change failure modes only, never values."""
    eng = _tiny_engine(resident=True)
    jobs = _jobs(eng, n=2)
    pairs = [(j, j.members[0].subsamples) for j in jobs]
    plain = eng.eval_pairs(pairs)
    install()
    try:
        guarded = eng.eval_pairs(pairs)
    finally:
        uninstall()
    assert plain == guarded
    del jobs


# -- satellite parity: the bench_heterogeneity grading fix -------------------

def test_eval_jobs_precision_override_matches_scalar_loop():
    """The batched fp32 grading pass (bench_heterogeneity) is
    bit-identical to the old per-member eval_on loop, including on a
    bf16-screened fleet."""
    eng = _tiny_engine(resident=True)
    jobs = _jobs(eng, n=2)
    for j in jobs:
        j.precision = "bf16"       # screens bf16; grading forces fp32
    batched = eng.eval_jobs(jobs, precision="fp32")
    # fleetlint: disable=per-member-loop -- the parity REFERENCE loop
    scalar = [float(np.mean([j.eval_on(m.subsamples, precision="fp32")
                             for m in j.members])) for j in jobs]
    assert batched == scalar
    del jobs
