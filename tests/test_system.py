"""End-to-end behaviour tests of the paper's claims, at reduced scale.

Each test maps to a paper result:
  * group retraining >= independent retraining under the same budget on
    correlated streams (Fig. 2c)
  * natural model reuse: a stream joining an ongoing group job starts
    from the group's already-adapted model (Fig. 12)
  * ECCO controller groups correlated streams and adapts to drift
    (Fig. 9 mechanism)
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.controller import ControllerConfig, ECCOController
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob, SharedEngine
from repro.data.streams import DomainBank, make_fleet


VOCAB = 64


@pytest.fixture(scope="module")
def engine():
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=VOCAB)
    return SharedEngine(cfg)


def _req(sid, toks, acc=0.0, t=0.0, loc=(0.0, 0.0)):
    return Request(stream_id=sid, t=t, loc=loc, subsamples=toks, acc=acc,
                   train_data=toks)


def test_group_beats_independent_same_budget(engine):
    """3 correlated streams delivering fresh data every window (the
    paper's continuous-transmission setting); total budget = 12
    micro-windows.

    Group retraining: ONE shared job sees all 3 streams' inflow and all
    12 micro-windows. Independent: three jobs each see their own inflow
    and 4 micro-windows. Group accuracy must win (Fig. 2c): the shared
    model gets 3x the data AND 3x the optimization steps.
    """
    bank = DomainBank(VOCAB, 4, dim=4, seed=0)
    rng = np.random.default_rng(0)
    dom = 0
    evals = {f"s{i}": bank.sample(dom, rng, 16, 32) for i in range(3)}

    def inflow():
        return bank.sample(dom, rng, 4, 32)      # 4 fresh seqs / window

    # group: one job, 6 windows x (3 streams' inflow, 2 micro-windows)
    gjob = RetrainJob(engine, _req("s0", inflow()), micro_steps=4,
                      batch=16, seed=0)
    for s in ("s1", "s2"):
        gjob.add_member(_req(s, inflow()))
    for _ in range(6):
        for _ in range(3):
            gjob.ingest(inflow())
        gjob.train_micro()
        gjob.train_micro()
    group_acc = np.mean([engine.accuracy(gjob.state["params"], evals[s])
                         for s in evals])

    # independent: three jobs, 6 windows x (own inflow, 4/6 micro-window
    # budget -> 4 micro-windows total each, run spread over windows)
    ind_accs = []
    for i, s in enumerate(evals):
        job = RetrainJob(engine, _req(s, inflow()), micro_steps=4,
                         batch=16, seed=0)
        micro_left = 4
        for w in range(6):
            job.ingest(inflow())
            if w % 2 == 0 and micro_left > 0:    # 4 of 6 windows train
                job.train_micro()
                micro_left -= 1
        ind_accs.append(engine.accuracy(job.state["params"], evals[s]))
    ind_acc = np.mean(ind_accs)

    assert group_acc > ind_acc + 0.02, (group_acc, ind_acc)


def test_natural_model_reuse(engine):
    """A stream joining an ongoing group job starts at the group model's
    accuracy — far above a cold-start model (Fig. 12)."""
    bank = DomainBank(VOCAB, 4, dim=4, seed=4)
    rng = np.random.default_rng(1)
    dom = 2
    d0 = bank.sample(dom, rng, 16, 32)
    job = RetrainJob(engine, _req("s0", d0), micro_steps=4, batch=16,
                     seed=0)
    for _ in range(8):
        job.train_micro()

    late_eval = bank.sample(dom, rng, 16, 32)      # the late joiner's data
    reuse_acc = engine.accuracy(job.state["params"], late_eval)
    cold = engine.fresh_state(0)
    cold_acc = engine.accuracy(cold["params"], late_eval)
    assert reuse_acc > cold_acc + 0.15, (reuse_acc, cold_acc)


def test_controller_groups_by_region():
    """Streams of the same region drift together and must land in the
    same job; different regions in different jobs."""
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=VOCAB)
    engine = SharedEngine(cfg)
    bank, streams = make_fleet(vocab=VOCAB, regions=2,
                               streams_per_region=2, dim=4,
                               switch_times=(5.0,), seed=1)
    cc = ControllerConfig(window_micro=6, micro_steps=4, train_batch=16,
                          drift_threshold=0.25, p_drop=0.5,
                          shared_bandwidth=1e9)
    ctl = ECCOController(engine, streams, cc, seed=0)
    ctl.warmup()
    for _ in range(3):
        wm = ctl.run_window()
    # all four streams requested retraining and got grouped
    grouped = {s for g in wm.groups.values() for s in g}
    assert grouped == {s.stream_id for s in streams}
    # groups respect regions
    for members in wm.groups.values():
        regions = {m.split("_")[0] for m in members}
        assert len(regions) == 1, wm.groups


def test_member_signatures_track_recent_window(engine):
    """The regrouping step must refresh each member's drift signature
    along with its subsamples: an evicted member re-enters group_request
    ranked by the distribution it drifted TO, and a stale signature
    would shortlist the old domain's jobs."""
    from repro.core.drift import token_histogram
    bank, streams = make_fleet(vocab=VOCAB, regions=1,
                               streams_per_region=2, dim=4,
                               switch_times=(5.0,), seed=3)
    cc = ControllerConfig(window_micro=4, micro_steps=2, train_batch=8,
                          drift_threshold=0.25, p_drop=0.5,
                          shared_bandwidth=1e9)
    ctl = ECCOController(engine, streams, cc, seed=0)
    ctl.warmup()
    for _ in range(3):
        ctl.run_window()
    members = [m for j in ctl.jobs for m in j.members]
    assert members
    # step 5 derives sig and subsamples from the same window tokens, so
    # after any window the two must agree; a signature frozen at
    # request-creation time diverges on the next window's sample noise
    for m in members:
        np.testing.assert_allclose(
            m.sig, token_histogram(m.subsamples, cc.sig_buckets,
                                   engine.cfg.vocab_size))
        # the index row the shortlist scores against is refreshed too
        row = ctl.sig_index._row[m.stream_id]
        np.testing.assert_allclose(ctl.sig_index._sig[row], m.sig,
                                   atol=1e-6)


def test_remove_stream_purges_request_time(engine):
    """Churn regression: a departed camera must not linger in
    request_time, or response_times() reports response latencies for
    cameras no longer in the fleet."""
    bank, streams = make_fleet(vocab=VOCAB, regions=1,
                               streams_per_region=2, dim=4,
                               switch_times=(5.0,), seed=5)
    cc = ControllerConfig(window_micro=4, micro_steps=2, train_batch=8,
                          drift_threshold=0.25, p_drop=0.5,
                          shared_bandwidth=1e9)
    ctl = ECCOController(engine, streams, cc, seed=0)
    ctl.warmup()
    for _ in range(2):
        ctl.run_window()
    gone = streams[0].stream_id
    assert gone in ctl.request_time        # it did request retraining
    ctl.remove_stream(gone)
    assert gone not in ctl.request_time
    assert gone not in ctl.response_times(threshold=0.0)
    # the survivor's clock is untouched
    assert streams[1].stream_id in ctl.request_time


def test_controller_adapts_accuracy_over_windows():
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=VOCAB)
    engine = SharedEngine(cfg)
    bank, streams = make_fleet(vocab=VOCAB, regions=1,
                               streams_per_region=3, dim=4,
                               switch_times=(5.0,), seed=2)
    cc = ControllerConfig(window_micro=8, micro_steps=4, train_batch=16,
                          drift_threshold=0.25, p_drop=0.5,
                          shared_bandwidth=1e9)
    ctl = ECCOController(engine, streams, cc, seed=0)
    ctl.warmup()
    for _ in range(6):
        ctl.run_window()
    assert ctl.mean_accuracy(last_k=2) > 0.35, \
        [w.per_stream_acc for w in ctl.history]
