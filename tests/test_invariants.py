"""The window-level invariant harness (repro.testing.invariants):

1. the checker must actually FIRE on each violation class (a harness
   that cannot fail pins nothing), asserted against minimal duck-typed
   fleets;
2. `expected_shares` must re-derive ECCOAllocator.estimate_shares
   bit-for-bit (the proportionality law is an independent
   reimplementation, not a tautology);
3. every benign scenario passes under every framework — drift_wave is
   already invariant-checked by the golden suite (run_scenario checks
   by default), the other four sweep here at smoke scale. The hostile
   scenarios are covered by tests/test_golden_traces.py.
"""
import types

import numpy as np
import pytest

from repro.testing import trace as T
from repro.testing.invariants import (InvariantChecker, InvariantViolation,
                                      expected_shares)


# ---------------------------------------------------------------------------
# a minimal duck-typed controller the checker accepts
# ---------------------------------------------------------------------------
class _Bank:
    def __init__(self, live=0):
        self.live = live

    def compact(self):
        pass

    def __len__(self):
        return self.live


def _stream(sid):
    return types.SimpleNamespace(stream_id=sid)

def _job(jid, members, engine):
    return types.SimpleNamespace(
        job_id=jid, members=[_stream(m) for m in members], engine=engine)


def _fake_ctl(*, streams=("a", "b"), groups={"j0": ["a", "b"]},
              local_caps=None, shared_bandwidth=100.0, mode="ecco",
              bank_live=None):
    engine = types.SimpleNamespace(bank=_Bank())
    jobs = [_job(j, ms, engine) for j, ms in groups.items()]
    engine.bank.live = len(jobs) if bank_live is None else bank_live
    members = [m for ms in groups.values() for m in ms]
    return types.SimpleNamespace(
        cc=types.SimpleNamespace(window_seconds=10.0, bytes_per_token=1.0,
                                 local_caps=local_caps,
                                 shared_bandwidth=shared_bandwidth),
        bandwidth_mode=mode,
        allocator=types.SimpleNamespace(last_gains={}),
        streams=[_stream(s) for s in streams],
        jobs=jobs, engine=engine,
        fleet=types.SimpleNamespace(stream_ids=list(streams)),
        tx_plane=types.SimpleNamespace(flow_ids=list(members)),
        sig_index=types.SimpleNamespace(
            state_dict=lambda: {"row": {m: 0 for m in members}}),
        request_time={}, serve_plane=None,
        grouper=types.SimpleNamespace())


def _wm(ctl, *, shares=None, bandwidth={}, delivered={}, groups=None):
    n = len(ctl.jobs)
    return types.SimpleNamespace(
        t=0.0,
        shares=({j.job_id: 1.0 / n for j in ctl.jobs}
                if shares is None else shares),
        bandwidth=bandwidth, delivered=delivered,
        groups=({j.job_id: [m.stream_id for m in j.members]
                 for j in ctl.jobs} if groups is None else groups))


def _run(ctl, wm, events=None, **kw):
    chk = InvariantChecker(**kw)
    chk.before_window(ctl)
    chk.after_window(ctl, wm, events)
    return chk


def test_checker_accepts_a_lawful_window():
    ctl = _fake_ctl()
    chk = _run(ctl, _wm(ctl, bandwidth={"a": 5.0, "b": 5.0},
                        delivered={"a": 50, "b": 49}))
    assert chk.windows_checked == 1


@pytest.mark.parametrize("mutate,msg", [
    (lambda c, w: w.delivered.update(a=51), "bw"),
    (lambda c, w: w.delivered.update(ghost=1), "no bandwidth"),
    (lambda c, w: w.bandwidth.update(a=-1.0), "negative"),
    (lambda c, w: w.bandwidth.update(a=200.0), "shared"),
    (lambda c, w: w.shares.update(j0=0.9), "sum"),
])
def test_checker_flags_bandwidth_and_share_sums(mutate, msg):
    ctl = _fake_ctl()
    wm = _wm(ctl, bandwidth={"a": 5.0, "b": 5.0},
             delivered={"a": 50, "b": 49})
    mutate(ctl, wm)
    with pytest.raises(InvariantViolation):
        _run(ctl, wm)


def test_checker_flags_local_cap_breach():
    ctl = _fake_ctl(local_caps={"a": 2.0})
    with pytest.raises(InvariantViolation, match="local"):
        _run(ctl, _wm(ctl, bandwidth={"a": 3.0}))


def test_checker_flags_disproportional_shares():
    ctl = _fake_ctl(groups={"j0": ["a"], "j1": ["b"]})
    ctl.allocator.last_gains = {"j0": 3.0, "j1": 1.0}
    good = _wm(ctl, shares={"j0": 0.75, "j1": 0.25})
    assert _run(ctl, good).windows_checked == 1
    with pytest.raises(InvariantViolation, match="proportionality"):
        _run(ctl, _wm(ctl, shares={"j0": 0.5, "j1": 0.5}))


def test_checker_flags_group_inconsistencies():
    ctl = _fake_ctl(groups={"j0": ["a"], "j1": ["b"]})
    # a stream in two groups
    with pytest.raises(InvariantViolation, match="both"):
        _run(ctl, _wm(ctl, shares={"j0": 0.5, "j1": 0.5},
                      groups={"j0": ["a", "b"], "j1": ["b"]}))
    # wm.groups out of sync with the live jobs list
    with pytest.raises(InvariantViolation, match="disagrees"):
        _run(ctl, _wm(ctl, shares={"j0": 0.5, "j1": 0.5},
                      groups={"j0": ["a"], "j1": []}))
    # grouped stream that is not in the fleet
    ctl2 = _fake_ctl(streams=("a",), groups={"j0": ["a", "zombie"]})
    ctl2.fleet.stream_ids = ["a"]
    with pytest.raises(InvariantViolation, match="not in the fleet"):
        _run(ctl2, _wm(ctl2))


def test_checker_flags_membership_change_without_event():
    ctl = _fake_ctl(groups={"j0": ["a"], "j1": ["b"]})
    chk = InvariantChecker()
    chk.before_window(ctl)
    # "a" silently moves j0 -> j1 with no grouping event
    ctl.jobs[0].members = []
    ctl.jobs[1].members = [_stream("b"), _stream("a")]
    wm = _wm(ctl, shares={"j0": 0.5, "j1": 0.5},
             groups={"j0": [], "j1": ["b", "a"]})
    with pytest.raises(InvariantViolation, match="no join/new event"):
        chk.after_window(ctl, wm, events=[])
    # the same move WITH its event is lawful
    chk2 = InvariantChecker()
    chk2.before_window(_fake_ctl(groups={"j0": ["a"], "j1": ["b"]}))
    chk2.after_window(ctl, wm, events=[
        {"kind": "evict", "stream": "a", "job": "j0"},
        {"kind": "join", "stream": "a", "job": "j1"}])


def test_checker_flags_evicted_member_still_resident():
    ctl = _fake_ctl(groups={"j0": ["a", "b"]})
    with pytest.raises(InvariantViolation, match="evicted"):
        _run(ctl, _wm(ctl), events=[
            {"kind": "evict", "stream": "a", "job": "j0"},
            {"kind": "join", "stream": "a", "job": "j0"}])


def test_checker_flags_plane_row_leaks():
    ctl = _fake_ctl()
    ctl.tx_plane.flow_ids = ["a", "b", "departed"]
    with pytest.raises(InvariantViolation, match="transmission"):
        _run(ctl, _wm(ctl))
    ctl = _fake_ctl()
    ctl.fleet.stream_ids = ["a"]
    with pytest.raises(InvariantViolation, match="detector"):
        _run(ctl, _wm(ctl))
    ctl = _fake_ctl()
    ctl.request_time = {"departed": 0.0}
    with pytest.raises(InvariantViolation, match="pending"):
        _run(ctl, _wm(ctl))


def test_checker_flags_bank_leaks():
    ctl = _fake_ctl(bank_live=3)        # 1 live job, 3 live slots
    with pytest.raises(InvariantViolation, match="leaked"):
        _run(ctl, _wm(ctl), bank_exact=True)
    # shared-engine mode tolerates pre-existing strangers...
    chk = _run(ctl, _wm(ctl), bank_exact=False)
    # ...but flags NEW strangers appearing mid-run
    ctl.engine.bank.live = 4
    chk.before_window(ctl)
    with pytest.raises(InvariantViolation, match="grew"):
        chk.after_window(ctl, _wm(ctl))
    # fewer slots than live jobs is always broken
    ctl.engine.bank.live = 0
    with pytest.raises(InvariantViolation, match="live slots"):
        _run(ctl, _wm(ctl), bank_exact=False)


def test_checker_flags_serving_store_leak():
    ctl = _fake_ctl()
    ctl.serve_plane = types.SimpleNamespace(
        store=types.SimpleNamespace(group_ids=["j0", "dead"]))
    with pytest.raises(InvariantViolation, match="ServingStore"):
        _run(ctl, _wm(ctl))


def test_violation_message_names_run_and_window():
    ctl = _fake_ctl(bank_live=9)
    with pytest.raises(InvariantViolation,
                       match=r"myscenario/ecco: window 0"):
        _run(ctl, _wm(ctl), label="myscenario/ecco")


# ---------------------------------------------------------------------------
# expected_shares is a faithful reimplementation of estimate_shares
# ---------------------------------------------------------------------------
def test_expected_shares_matches_allocator_bitwise():
    from repro.core.allocator import ECCOAllocator
    rng = np.random.default_rng(0)
    for trial in range(200):
        n = int(rng.integers(1, 7))
        jobs = [types.SimpleNamespace(job_id=f"j{i}") for i in range(n)]
        alloc = ECCOAllocator()
        # random gains: some jobs unknown, some negative, sometimes all
        # nonpositive (the uniform fallback)
        for j in jobs:
            if rng.random() < 0.7:
                g = float(rng.normal())
                if rng.random() < 0.3:
                    g = -abs(g)
                alloc.last_gains[j.job_id] = g
        got = alloc.estimate_shares(jobs)
        want = expected_shares([j.job_id for j in jobs],
                               dict(alloc.last_gains), uniform=False)
        assert got.keys() == want.keys()
        for k in got:
            assert got[k] == want[k], (trial, k, got, want)


def test_expected_shares_uniform_contract():
    assert expected_shares(["a", "b"], {"a": 9.0}, uniform=True) == \
        {"a": 0.5, "b": 0.5}
    assert expected_shares([], {}, uniform=False) == {}


# ---------------------------------------------------------------------------
# benign scenarios x all frameworks pass the invariants at smoke scale
# (drift_wave x all frameworks is covered by the golden suite)
# ---------------------------------------------------------------------------
BENIGN = {
    "diurnal": dict(regions=2, streams_per_region=2, windows=3),
    "camera_churn": dict(regions=1, streams_per_region=2, join_window=1,
                         leave_window=2, windows=3, switch_time=5.0),
    "flash_crowd": dict(regions=2, streams_per_region=2,
                        flash_time=12.0, windows=3),
    "bandwidth_contention": dict(regions=2, streams_per_region=2,
                                 windows=3),
}


@pytest.fixture(scope="module")
def engine():
    return T.make_engine_for(T.golden_scenario())


@pytest.mark.parametrize("framework", T.GOLDEN_FRAMEWORKS)
@pytest.mark.parametrize("name", sorted(BENIGN))
def test_benign_scenarios_pass_invariants(name, framework, engine):
    from repro.data.scenarios import build_scenario
    sc = build_scenario(name, seed=0, **BENIGN[name])
    ctl = T.run_scenario(framework, sc, engine=engine, window_micro=2,
                         micro_steps=1, train_batch=8, p_drop=0.5)
    assert len(ctl.history) == sc.windows


def test_exclusive_engine_run_checks_bank_exactly():
    """run_scenario with its own engine uses the strict JobBank
    residency law (live slots == live jobs, every window)."""
    from repro.data.scenarios import build_scenario
    sc = build_scenario("diurnal", seed=0, regions=1,
                        streams_per_region=2, windows=2)
    ctl = T.run_scenario("ecco", sc, window_micro=2, micro_steps=1,
                         train_batch=8)
    assert len(ctl.history) == 2


def test_run_scenario_invariants_opt_out(monkeypatch, engine):
    """`invariants=False` (the benchmark fast path) must not construct
    a checker at all."""
    from repro.data.scenarios import build_scenario
    calls = []

    class Spy(InvariantChecker):
        def __init__(self, **kw):
            calls.append(kw)
            super().__init__(**kw)

    monkeypatch.setattr(T, "InvariantChecker", Spy)
    sc = build_scenario("diurnal", seed=0, regions=1,
                        streams_per_region=2, windows=1)
    T.run_scenario("ecco", sc, engine=engine, window_micro=2,
                   micro_steps=1, train_batch=8, invariants=False)
    assert calls == []
    T.run_scenario("ecco", sc, engine=engine, window_micro=2,
                   micro_steps=1, train_batch=8)
    assert len(calls) == 1 and calls[0]["bank_exact"] is False
