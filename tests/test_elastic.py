"""Fault-tolerance tests: mesh shrink planning in-process, plus a full
elastic re-mesh + checkpoint-reshard recovery in a subprocess with 8
forced host devices (tests themselves must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.distributed.elastic import MeshSpec, plan_recovery, shrink_mesh


def test_shrink_mesh_drops_data_rows():
    spec = MeshSpec((2, 16, 16), ("pod", "data", "model"))
    new = shrink_mesh(spec, 4)
    assert new.shape == (2, 12, 16)
    assert new.axes == spec.axes


def test_shrink_mesh_exhaustion_raises():
    spec = MeshSpec((4, 2), ("data", "model"))
    with pytest.raises(RuntimeError):
        shrink_mesh(spec, 4)


def test_plan_recovery_scales_batch(tmp_path):
    from repro.distributed import checkpoint as ckpt
    import jax.numpy as jnp
    ckpt.save(str(tmp_path), 7, {"w": jnp.zeros((2,))})
    spec = MeshSpec((8, 2), ("data", "model"))
    plan = plan_recovery(spec, 2, str(tmp_path))
    assert plan.new_mesh_shape == (6, 2)
    assert plan.restore_step == 7
    assert plan.global_batch_scale == pytest.approx(6 / 8)


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.elastic import ElasticRuntime, MeshSpec
    from repro.distributed import checkpoint as ckpt

    ckpt_dir = os.environ["CKPT_DIR"]

    def rules_fn(mesh):
        return {"batch": "data", "mlp": "model"}

    def step_factory(mesh, rules):
        w_shard = NamedSharding(mesh, P(None, "model"))
        x_shard = NamedSharding(mesh, P("data", None))

        def step(w, x):
            return w + 0.1 * jnp.mean(x), None

        shardings = {"w": w_shard}
        return step, shardings

    spec = MeshSpec((4, 2), ("data", "model"))
    rt = ElasticRuntime(spec, step_factory, rules_fn, ckpt_dir)

    # state sharded on the 4x2 mesh
    w = jax.device_put(np.arange(32, dtype=np.float32).reshape(4, 8),
                       rt.state_shardings["w"])
    state = {"w": w}
    ckpt.save(ckpt_dir, 0, state)

    # lose 2 data rows -> 2x2 mesh; restore + reshard
    restored, plan = rt.fail_and_recover(2, state)
    assert plan.new_mesh_shape == (2, 2), plan
    assert rt.mesh.devices.size == 4
    got = np.asarray(jax.device_get(restored["w"]))
    np.testing.assert_array_equal(got,
                                  np.arange(32, dtype=np.float32
                                            ).reshape(4, 8))
    # restored arrays carry the NEW mesh's sharding
    assert restored["w"].sharding.mesh.shape["data"] == 2
    # and the step still runs on the shrunken mesh
    y, _ = jax.jit(rt.step)(restored["w"],
                            jnp.ones((4, 8)))
    assert np.isfinite(np.asarray(y)).all()
    print("ELASTIC_OK")
""")


def test_elastic_recovery_subprocess(tmp_path):
    # runs on jax 0.4.x too: launch.mesh._mesh_compat degrades from
    # jax.make_mesh(axis_types=...) down to a manual Mesh build
    env = dict(os.environ, CKPT_DIR=str(tmp_path),
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout
