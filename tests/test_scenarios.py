"""Scenario library: seeded determinism, churn plumbing end to end, and
the vectorized DomainBank sampler's bit-equivalence with the original
per-timestep searchsorted loop."""
import dataclasses

import numpy as np
import pytest

from repro.data.scenarios import (SCENARIOS, ChurnEvent, build_scenario,
                                  camera_churn)
from repro.data.streams import DomainBank


# ---------------------------------------------------------------------------
# DomainBank.sample vectorization (fixed-seed equivalence)
# ---------------------------------------------------------------------------
def _sample_reference(bank, domain, rng, batch, seq_len, mix_with=None,
                      mix_frac=0.0):
    """The pre-vectorization sampler: per-timestep Python searchsorted."""
    P = bank.P[domain]
    if mix_with is not None and mix_frac > 0:
        P = (1 - mix_frac) * P + mix_frac * bank.P[mix_with]
    out = np.empty((batch, seq_len), np.int64)
    tok = rng.integers(0, bank.vocab, size=batch)
    cum = np.cumsum(P, axis=1)
    for s in range(seq_len):
        out[:, s] = tok
        u = rng.random(batch)
        tok = np.array([np.searchsorted(cum[t], x)
                        for t, x in zip(tok, u)])
        tok = np.minimum(tok, bank.vocab - 1)
    return out


@pytest.mark.parametrize("mix", [None, (2, 0.3)])
def test_domain_bank_sample_matches_reference(mix):
    bank = DomainBank(64, 4, dim=8, seed=0)
    kw = {} if mix is None else {"mix_with": mix[0], "mix_frac": mix[1]}
    got = bank.sample(1, np.random.default_rng(7), 32, 48, **kw)
    want = _sample_reference(bank, 1, np.random.default_rng(7), 32, 48,
                             **kw)
    assert got.dtype == want.dtype
    assert (got == want).all()


# ---------------------------------------------------------------------------
# scenario generators
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_deterministic_and_well_formed(name):
    a = build_scenario(name, seed=3)
    b = build_scenario(name, seed=3)
    c = build_scenario(name, seed=4)
    assert a.name == name and a.windows > 0
    ids = [s.stream_id for s in a.streams]
    assert len(ids) == len(set(ids))
    # same seed -> identical fleet (ids, locations, lags, schedules, caps)
    assert ids == [s.stream_id for s in b.streams]
    for sa, sb in zip(a.streams, b.streams):
        assert sa.loc == sb.loc and sa.lag == sb.lag
        assert sa.region.schedule == sb.region.schedule
    np.testing.assert_array_equal(a.bank.P, b.bank.P)
    assert a.local_caps == b.local_caps
    assert [dataclasses.astuple(e)[:3] for e in a.churn] == \
        [dataclasses.astuple(e)[:3] for e in b.churn]
    # a different seed perturbs the fleet
    assert not np.array_equal(a.bank.P, c.bank.P)
    # every stream samples deterministically
    x = a.streams[0].sample(0.0, 2, 8)
    y = b.streams[0].sample(0.0, 2, 8)
    assert (x == y).all()


def test_scenario_specs():
    wave = build_scenario("drift_wave", seed=0)
    switch = [s.region.schedule[1][0] for s in wave.streams]
    assert sorted(switch) == switch and len(set(switch)) > 1   # staggered
    di = build_scenario("diurnal", seed=0)
    assert all(len(s.region.schedule) >= 4 for s in di.streams)  # recurs
    fc = build_scenario("flash_crowd", seed=0)
    post = {s.region.domain_at(1e9) for s in fc.streams}
    assert len(post) == 1                   # everyone lands on one domain
    pre = {s.region.domain_at(0.0) for s in fc.streams}
    assert len(pre) > 1
    bc = build_scenario("bandwidth_contention", seed=0)
    assert bc.local_caps and set(bc.local_caps) == \
        {s.stream_id for s in bc.streams}
    assert bc.shared_bandwidth < 1e9
    with pytest.raises(KeyError):
        build_scenario("nope")


def test_camera_churn_events():
    sc = camera_churn(seed=0)
    joins = [e for e in sc.churn if e.kind == "join"]
    leaves = [e for e in sc.churn if e.kind == "leave"]
    assert joins and leaves
    initial = {s.stream_id for s in sc.streams}
    for e in joins:
        assert e.stream is not None
        assert e.stream.stream_id == e.stream_id
        assert e.stream_id not in initial       # genuinely new cameras
    for e in leaves:
        assert e.stream_id in initial
    assert sc.events_at(joins[0].window) != []


def test_run_scenario_does_not_consume_the_scenario():
    """run_scenario deep-copies: running one scenario instance twice
    yields identical traces (streams' rng state and churn Stream
    objects must not be mutated by the first run)."""
    from repro.testing import trace as T
    sc = build_scenario("drift_wave", seed=0, regions=2,
                        streams_per_region=2, windows=2)
    engine = T.make_engine_for(sc)
    traces = []
    for _ in range(2):
        tr = {}
        T.run_scenario("ecco", sc, engine=engine, trace=tr,
                       window_micro=2, micro_steps=1, train_batch=8)
        traces.append(tr)
    assert T.compare(traces[0], traces[1]) == []
    assert traces[0] == traces[1]           # byte-identical, not just tol
    # the scenario's own streams still hold their pristine rng state
    fresh = build_scenario("drift_wave", seed=0, regions=2,
                           streams_per_region=2, windows=2)
    a = sc.streams[0].sample(0.0, 2, 8)
    b = fresh.streams[0].sample(0.0, 2, 8)
    assert (a == b).all()


# ---------------------------------------------------------------------------
# churn end to end through the controller
# ---------------------------------------------------------------------------
def test_controller_churn_end_to_end():
    from repro.testing.trace import make_engine_for, run_scenario
    sc = camera_churn(regions=1, streams_per_region=2, join_window=1,
                      leave_window=2, windows=3, switch_time=5.0, seed=0)
    engine = make_engine_for(sc)
    ctl = run_scenario("ecco", sc, engine=engine, window_micro=2,
                       micro_steps=1, train_batch=8)
    live = {s.stream_id for s in ctl.streams}
    joined = {e.stream_id for e in sc.churn if e.kind == "join"}
    left = {e.stream_id for e in sc.churn if e.kind == "leave"}
    assert joined <= live and not (left & live)
    # detector rows track the fleet exactly
    assert set(ctl.fleet.stream_ids) == live
    # no job retains a departed member, and metrics cover the live fleet
    members = {m.stream_id for j in ctl.jobs for m in j.members}
    assert not (members & left)
    assert set(ctl.history[-1].per_stream_acc) == live
    # a departed camera's pooled training data is purged too: the group
    # must not keep doing SGD on a distribution no live member has
    for j in ctl.jobs:
        assert not (set(j._pool_src) & left)


# ---------------------------------------------------------------------------
# hostile scenario generators (ROADMAP item 3)
# ---------------------------------------------------------------------------
def test_hostile_scenario_specs():
    from repro.data.scenarios import HOSTILE_SCENARIOS
    assert set(HOSTILE_SCENARIOS) <= set(SCENARIOS)

    fc = build_scenario("flash_crowd_10k", seed=0)
    joins = [e for e in fc.churn if e.kind == "join"]
    assert len(joins) == 10_000                 # full-scale by default
    assert len({e.stream_id for e in joins}) == len(joins)
    assert all(e.window == joins[0].window for e in joins)
    # the whole cohort drifts together one window after the join
    crowd = joins[0].stream
    w = fc.window_seconds
    t_join, t_next = joins[0].window * w, (joins[0].window + 1) * w
    assert crowd.region.domain_at(t_join) != \
        crowd.region.domain_at(t_next + w)
    small = build_scenario("flash_crowd_10k", seed=0, joiners=5)
    assert len(small.churn) == 5                # smoke-sizable

    sb = build_scenario("sensor_blackout", seed=0)
    leaves = [e for e in sb.churn if e.kind == "leave"]
    assert leaves and all(e.kind == "leave" for e in sb.churn)
    doomed = {e.stream_id for e in leaves}
    regions = {s.region.region_id for s in sb.streams
               if s.stream_id in doomed}
    assert len(regions) == 1                    # one whole region dies
    assert doomed == {s.stream_id for s in sb.streams
                      if s.region.region_id in regions}
    # the doomed region drifts BEFORE the blackout, so it is grouped
    blackout_t = leaves[0].window * sb.window_seconds
    sw = [t for s in sb.streams if s.stream_id in doomed
          for t, _ in s.region.schedule[1:]]
    assert sw and all(t < blackout_t for t in sw)

    od = build_scenario("oscillating_drift", seed=0)
    for s in od.streams:
        doms = [s.region.domain_at(w * 10.0 + 0.5)
                for w in range(od.windows)]
        assert all(a != b for a, b in zip(doms, doms[1:]))  # every window
        assert len(set(doms)) == 2                          # two domains

    bc = build_scenario("bandwidth_collapse", seed=0)
    assert bc.profile and bc.local_caps
    assert bc.bandwidth and bc.bandwidth[0].window > 0
    ev = bc.bandwidth[0]
    assert ev.shared_bandwidth < bc.shared_bandwidth / 50
    for sid, cap in ev.local_caps.items():
        assert cap < bc.local_caps[sid] / 50
    rec = build_scenario("bandwidth_collapse", seed=0, recover_window=4)
    assert rec.bandwidth[-1].shared_bandwidth == rec.shared_bandwidth


def test_bandwidth_events_at():
    from repro.data.scenarios import BandwidthEvent, FleetScenario
    sc = build_scenario("drift_wave", seed=0)
    assert sc.bandwidth_events_at(0) == []
    ev = BandwidthEvent(window=2, shared_bandwidth=1.0)
    sc.bandwidth.append(ev)
    assert sc.bandwidth_events_at(2) == [ev]
    assert sc.bandwidth_events_at(1) == []


# ---------------------------------------------------------------------------
# churn races: join/leave of the SAME id inside one window boundary
# ---------------------------------------------------------------------------
def _race_scenario(order):
    """A tiny drift_wave fleet plus same-window churn races on top."""
    from repro.data.streams import Region, Stream
    sc = build_scenario("drift_wave", seed=0, regions=1,
                        streams_per_region=2, wave_start=5.0, windows=3)
    region = Region("race", [(0.0, 0), (5.0, 1)])
    if order == "join_remove":
        # a camera joins and dies at the same boundary: it must leave
        # zero residue in any plane
        ghost = Stream("ghost", sc.bank, region, (0.0, 0.0), seed=99)
        sc.churn += [ChurnEvent(1, "join", "ghost", ghost),
                     ChurnEvent(1, "leave", "ghost")]
    else:
        # an existing camera is replaced by a NEW stream with the SAME
        # id at one boundary (hardware swap): planes must carry exactly
        # one row for the id, keyed to the new stream's state
        sid = sc.streams[0].stream_id
        fresh = Stream(sid, sc.bank, region, (9.0, 9.0), seed=77)
        sc.churn += [ChurnEvent(1, "leave", sid),
                     ChurnEvent(1, "join", sid, fresh)]
    return sc


@pytest.mark.parametrize("order", ["join_remove", "remove_rejoin"])
def test_controller_churn_race_planes_consistent(order):
    from repro.serve.plane import ServeConfig
    from repro.testing.trace import make_engine_for, run_scenario
    sc = _race_scenario(order)
    engine = make_engine_for(sc)
    # serve plane ON so the race also exercises ServingStore residency;
    # run_scenario's default invariants re-assert all of this per window
    ctl = run_scenario("ecco", sc, engine=engine, window_micro=2,
                       micro_steps=1, train_batch=8,
                       serve=ServeConfig(num_slots=4, capacity=16,
                                         max_new=2, prompt_len=4))
    live = {s.stream_id for s in ctl.streams}
    ids = [s.stream_id for s in ctl.streams]
    assert len(ids) == len(set(ids))            # no duplicate rows
    if order == "join_remove":
        assert "ghost" not in live
        racer = "ghost"
    else:
        racer = sc.streams[0].stream_id
        assert racer in live
        # the surviving row belongs to the REPLACEMENT stream
        kept = [s for s in ctl.streams if s.stream_id == racer]
        assert len(kept) == 1 and kept[0].loc == (9.0, 9.0)
    # drift / transmission / signature / request-clock rows agree
    assert set(ctl.fleet.stream_ids) == live
    assert ctl.fleet.stream_ids.count(racer) <= 1
    assert set(ctl.tx_plane.flow_ids) <= live
    assert set(ctl.sig_index.state_dict()["row"]) <= live
    assert set(ctl.request_time) <= live
    members = [m.stream_id for j in ctl.jobs for m in j.members]
    assert len(members) == len(set(members))
    assert set(members) <= live
    # serving rows only for live groups
    assert set(ctl.serve_plane.store.group_ids) <= \
        {j.job_id for j in ctl.jobs}
    # metrics cover exactly the live fleet
    assert set(ctl.history[-1].per_stream_acc) == live


def test_run_scenario_rejects_duplicate_join():
    """A ChurnEvent joining an id that is already live must fail loudly
    instead of silently overwriting the stream's plane rows."""
    from repro.data.streams import Region, Stream
    from repro.testing.trace import make_engine_for, run_scenario
    sc = build_scenario("drift_wave", seed=0, regions=1,
                        streams_per_region=2, windows=3)
    sid = sc.streams[0].stream_id
    dup = Stream(sid, sc.bank, Region("dup", [(0.0, 0)]), (0.0, 0.0))
    sc.churn.append(ChurnEvent(1, "join", sid, dup))
    engine = make_engine_for(sc)
    with pytest.raises(ValueError, match="already live"):
        run_scenario("ecco", sc, engine=engine, window_micro=2,
                     micro_steps=1, train_batch=8)


def test_controller_add_stream_rejects_duplicate():
    from repro.testing.trace import make_engine_for, run_scenario
    sc = build_scenario("drift_wave", seed=0, regions=1,
                        streams_per_region=2, windows=1)
    engine = make_engine_for(sc)
    ctl = run_scenario("ecco", sc, engine=engine, window_micro=2,
                       micro_steps=1, train_batch=8)
    with pytest.raises(ValueError, match="already live"):
        ctl.add_stream(ctl.streams[0])
