"""Algorithm 1 (GPU allocation) behaviour tests, including the paper's
own motivating example (§3.1): a 4-camera group must not starve a
1-camera group under ECCO's objective, but does under RECL's."""
import numpy as np
import pytest

from repro.core.allocator import (AllocationTrace, ECCOAllocator,
                                  RECLAllocator, UniformAllocator)


class FakeJob:
    """Concave accuracy-vs-GPU-time curve: acc = ceil*(1-exp(-r*t))."""

    def __init__(self, job_id, n, ceil=0.8, rate=0.35, acc0=0.0):
        self.job_id = job_id
        self.num_members = n
        self.ceil = ceil
        self.rate = rate
        self.t = 0.0
        self.acc0 = acc0

    def eval(self):
        return self.acc0 + (self.ceil - self.acc0) * \
            (1 - np.exp(-self.rate * self.t))

    def train_micro(self):
        self.t += 1.0


class ScriptedJob:
    """Deterministic per-micro-window accuracy gains (then flat)."""

    def __init__(self, job_id, gains):
        self.job_id = job_id
        self.num_members = 1
        self.gains = list(gains)
        self.a = 0.0

    def eval(self):
        return self.a

    def train_micro(self):
        self.a += self.gains.pop(0) if self.gains else 0.0


def test_budget_fully_consumed_and_counted():
    jobs = [FakeJob("a", 2), FakeJob("b", 1)]
    trace = ECCOAllocator().run_window(jobs, window_micro=10)
    assert len(trace.order) == 10
    assert sum(trace.gpu_time.values()) == 10
    assert set(trace.gpu_time) == {"a", "b"}


def test_shares_sum_to_one():
    jobs = [FakeJob("a", 3), FakeJob("b", 1), FakeJob("c", 2)]
    trace = ECCOAllocator().run_window(jobs, window_micro=9)
    assert abs(sum(trace.shares.values()) - 1.0) < 1e-9
    assert all(v >= 0 for v in trace.shares.values())


def test_paper_example_no_starvation():
    """§3.1: G1 (4 cams, +10%/unit) vs G2 (1 cam, +15%/unit). RECL-style
    total-accuracy objective starves G2; ECCO's fairness term must not."""
    def mk():
        return [FakeJob("G1", 4, ceil=0.8, rate=0.25, acc0=0.30),
                FakeJob("G2", 1, ceil=0.8, rate=0.40, acc0=0.10)]

    W = 12
    recl = RECLAllocator().run_window(mk(), W)
    ecco = ECCOAllocator(alpha=1.0, beta=0.5).run_window(mk(), W)
    # RECL gives the big group the lion's share
    assert recl.gpu_time["G1"] > recl.gpu_time["G2"]
    # ECCO shifts time toward the starved small group...
    assert ecco.gpu_time["G2"] > recl.gpu_time["G2"], (ecco.gpu_time,
                                                       recl.gpu_time)
    # ...and closes the accuracy gap (paper Fig. 10: "near-synchronous
    # accuracy increase among different groups")
    gap_recl = abs(recl.acc["G1"][-1] - recl.acc["G2"][-1])
    gap_ecco = abs(ecco.acc["G1"][-1] - ecco.acc["G2"][-1])
    assert gap_ecco < 0.5 * gap_recl, (gap_ecco, gap_recl)


def test_fairness_bonus_targets_worst_job():
    alloc = ECCOAllocator(alpha=1.0, beta=0.5)
    jobs = [FakeJob("hi", 1, acc0=0.7, ceil=0.9),
            FakeJob("lo", 1, acc0=0.1, ceil=0.9)]
    acc = {"hi": 0.7, "lo": 0.1}
    gain = {"hi": 0.05, "lo": 0.05}
    g = alloc._objective_gains(jobs, acc, gain)
    assert g["lo"] > g["hi"]      # worst job gets the +AccGain bonus


def test_beta_tempering_reduces_size_bias():
    """beta < 1 shrinks the big group's weight advantage."""
    jobs = [FakeJob("big", 9), FakeJob("small", 1)]
    acc = {"big": 0.5, "small": 0.5}
    gain = {"big": 0.1, "small": 0.1}
    g1 = ECCOAllocator(beta=1.0)._objective_gains(jobs, acc, gain)
    g5 = ECCOAllocator(beta=0.5)._objective_gains(jobs, acc, gain)
    # same-accuracy tie -> fairness bonus irrelevant which; compare the
    # weighted first terms via ratio big/small
    r1 = g1["big"] / max(g1["small"], 1e-12)
    r5 = g5["big"] / max(g5["small"], 1e-12)
    assert r5 < r1


def test_uniform_allocator_round_robin():
    jobs = [FakeJob("a", 1), FakeJob("b", 1)]
    trace = UniformAllocator().run_window(jobs, 8)
    assert trace.gpu_time == {"a": 4, "b": 4}
    assert trace.order[:4] == ["a", "b", "a", "b"]


def test_convergence_shifts_allocation():
    """Once the favored job converges (gain -> 0), the allocator moves
    micro-windows to the other job."""
    jobs = [FakeJob("fast", 1, ceil=0.5, rate=2.0),     # converges fast
            FakeJob("slow", 1, ceil=0.9, rate=0.05)]
    trace = ECCOAllocator().run_window(jobs, 16)
    # the slow-improving job keeps receiving time in the tail
    tail = trace.order[-6:]
    assert tail.count("slow") >= 3


def test_run_window_with_zero_jobs_returns_empty_trace():
    """Regression: update_grouping can drop every job; the allocators
    must hand back an empty trace instead of raising."""
    for alloc in (ECCOAllocator(), RECLAllocator(), UniformAllocator()):
        trace = alloc.run_window([], 8)
        assert isinstance(trace, AllocationTrace)
        assert trace.order == [] and trace.shares == {}
        assert trace.acc == {} and trace.gpu_time == {}


def test_shares_reflect_final_gains_not_initial_pass():
    """Alg. 1 Line 15: the transmission controller consumes shares from
    the window's FINAL gains. A job with a big first-micro gain that
    immediately converges must not keep a stale majority share."""
    early = ScriptedJob("early", [0.5])          # converges instantly
    late = ScriptedJob("late", [0.1] * 20)       # keeps improving
    trace = ECCOAllocator().run_window([early, late], 10)
    assert trace.shares["late"] > trace.shares["early"]
    assert trace.shares["late"] > 0.9


def test_estimate_shares_uses_last_window_gains():
    alloc = ECCOAllocator()
    jobs = [ScriptedJob("a", [0.5]), ScriptedJob("b", [0.1] * 20)]
    # before any window: uniform
    assert alloc.estimate_shares(jobs) == {"a": 0.5, "b": 0.5}
    alloc.run_window(jobs, 10)
    p = alloc.estimate_shares(jobs)
    assert p["b"] > p["a"]
    # a job unseen by the last window gets a non-starving share
    class Fresh:
        job_id = "fresh"
        num_members = 1
    p = alloc.estimate_shares(jobs + [Fresh()])
    assert p["fresh"] > 0
    assert abs(sum(p.values()) - 1.0) < 1e-9


def test_estimate_shares_no_positive_gains_stays_uniform():
    """Regression: when the last window ended with every gain <= 0
    (converged/noisy fleet), the arrival of one fresh job must not hand
    it 100% of the bandwidth and zero the whole existing fleet — shares
    fall back to uniform exactly as they do without the fresh job."""
    alloc = ECCOAllocator()
    jobs = [ScriptedJob("old1", []), ScriptedJob("old2", [-0.05] * 20)]
    alloc.run_window(jobs, 6)
    assert all(v <= 0 for v in alloc.last_gains.values())
    # without a fresh job: uniform fallback
    assert alloc.estimate_shares(jobs) == {"old1": 0.5, "old2": 0.5}

    class Fresh:
        job_id = "fresh"
        num_members = 1
    p = alloc.estimate_shares(jobs + [Fresh()])
    assert p == pytest.approx({"old1": 1 / 3, "old2": 1 / 3,
                               "fresh": 1 / 3})
