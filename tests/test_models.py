"""Per-architecture smoke tests (reduced configs, CPU) + decode parity.

Every assigned arch: one forward and one train step — asserting output
shapes and no NaNs. Causal archs additionally get prefill+decode parity
against the full forward (the KV/ring/recurrent cache paths must emit
the same logits as teacher-forcing the same tokens).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, TrainConfig, get_config, \
    smoke_config
from repro.models.model import build_model
from repro.train.train_step import init_state, make_train_step


def _tiny(arch):
    cfg = smoke_config(arch)
    return dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 64))


def _inputs(cfg, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.embedding_frontend:
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = _tiny(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    x = _inputs(cfg)
    logits, aux = model.apply(params, x, compute_dtype=jnp.float32)
    from repro.models.layers import padded_vocab
    assert logits.shape == (2, 16, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size])))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = _tiny(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(remat="none", warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(model, tcfg))
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    x = _inputs(cfg)
    labels = (jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size))
    state, metrics = step(state, {"inputs": x, "labels": labels})
    assert bool(jnp.isfinite(metrics["loss"])), metrics
    # params actually moved
    leaf = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).causal])
def test_decode_parity(arch):
    """Prefill(S) + decode(k) logits == forward(S+k) logits."""
    cfg = _tiny(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, k = 1, 12, 3
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + k), 0,
                              cfg.vocab_size)
    # high capacity factor so MoE never drops tokens — capacity-based
    # dispatch otherwise (correctly) differs between a 15-token forward
    # and a 1-token decode
    cf = 8.0
    full_logits, _ = model.apply(params, toks, compute_dtype=jnp.float32,
                                 capacity_factor=cf)

    cap = S + k + cfg.meta_tokens
    last, cache, pos = model.prefill(params, toks[:, :S], cap,
                                     compute_dtype=jnp.float32,
                                     cache_dtype=jnp.float32,
                                     capacity_factor=cf)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, S - 1]),
                               atol=2e-3, rtol=2e-3)
    for i in range(k):
        logits, cache = model.decode(params, toks[:, S + i:S + i + 1],
                                     cache, pos,
                                     compute_dtype=jnp.float32,
                                     capacity_factor=cf)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, S + i]),
            atol=2e-3, rtol=2e-3, err_msg=f"{arch} decode step {i}")
        pos = pos + 1


def test_sliding_window_parity_with_meta():
    """hymba-style windowed attention == full attention restricted to the
    window + always-visible meta prefix."""
    cfg = _tiny("hymba-1.5b")
    assert cfg.sliding_window > 0 and cfg.meta_tokens > 0
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                              cfg.vocab_size)
    logits, _ = model.apply(params, toks, compute_dtype=jnp.float32)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size])))


def test_head_padding_semantics():
    """tp-padded GQA model == unpadded model when real heads carry the
    same weights (padded heads are masked)."""
    cfg = _tiny("starcoder2-3b")        # 4 heads, kv=2
    m1 = build_model(cfg, tp=1)
    m2 = build_model(cfg, tp=3)         # pads per-group: G 2 -> 3, H 4 -> 6
    p1 = m1.init(jax.random.PRNGKey(0))
    p2 = m2.init(jax.random.PRNGKey(1))
    G, Gp = 2, 3

    def embed_attn(a1, a2):
        wq2 = np.array(a2["wq"]); wo2 = np.array(a2["wo"])
        for i in range(cfg.num_heads):
            pos = (i // G) * Gp + (i % G)
            wq2[:, :, pos] = np.array(a1["wq"])[:, :, i]
            wo2[:, pos] = np.array(a1["wo"])[:, i]
        return dict(a2, wq=jnp.array(wq2), wo=jnp.array(wo2),
                    wk=a1["wk"], wv=a1["wv"])

    for s1, s2 in zip(p1["segments"], p2["segments"]):
        s2["attn"] = embed_attn(s1["attn"], s2["attn"])
        for key in ("ln1", "ln2", "mlp"):
            s2[key] = s1[key]
    p2["embed"] = p1["embed"]; p2["final_norm"] = p1["final_norm"]
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg.vocab_size)
    l1, _ = m1.apply(p1, toks, compute_dtype=jnp.float32)
    l2, _ = m2.apply(p2, toks, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims_match_assignment(arch):
    """Published dims are exactly the assigned ones."""
    expect = {
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    }[arch]
    cfg = get_config(arch)
    d_ff = cfg.moe.d_ff_expert if cfg.moe else cfg.d_ff
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           d_ff, cfg.vocab_size)
    assert got == expect, (arch, got, expect)


def test_moe_expert_counts():
    q3 = get_config("qwen3-moe-30b-a3b")
    assert (q3.moe.num_experts, q3.moe.top_k) == (128, 8)
    q2 = get_config("qwen2-moe-a2.7b")
    assert (q2.moe.num_experts, q2.moe.top_k) == (60, 4)
    assert q2.moe.num_shared_experts == 4
