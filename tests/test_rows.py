"""RowRegistry: the shared dense-row churn discipline every batched
plane (drift detector, transmission plane) builds on."""
import pytest

from repro.core.rows import RowRegistry


def test_rows_insertion_order_and_lookup():
    r = RowRegistry()
    assert len(r) == 0 and "a" not in r
    assert r.add("a") == (0, True)
    assert r.add("b") == (1, True)
    assert r.add("a") == (0, False)          # idempotent re-add
    assert r.ids == ["a", "b"]
    assert r["b"] == 1 and r.get("c") is None
    with pytest.raises(KeyError):
        r["c"]


def test_rows_amortized_doubling():
    r = RowRegistry(capacity=2)
    for i in range(100):
        r.add(f"s{i}")
    assert r.capacity >= 100
    # doubling, not per-add growth: few distinct capacities were seen
    assert r.capacity in (128, 100) or r.capacity >= 100
    assert r.reserve(1000) >= 1100


def test_rows_swap_remove_reports_move():
    r = RowRegistry()
    for x in "abcd":
        r.add(x)
    assert r.remove("nope") is None
    dst, src = r.remove("b")                 # middle: last swaps in
    assert (dst, src) == (1, 3)
    assert r.ids == ["a", "d", "c"]
    assert r["d"] == 1
    dst, src = r.remove("c")                 # last row: no move needed
    assert dst == src == 2
    assert r.ids == ["a", "d"]
    # fully drain, then refill reuses dense rows from 0
    r.remove("a")
    r.remove("d")
    assert len(r) == 0
    assert r.add("z") == (0, True)
