"""RowRegistry: the shared dense-row churn discipline every batched
plane (drift detector, transmission plane) builds on.

The hypothesis suite at the bottom drives the registry with random
adversarial churn programs (add/remove/reserve/set_align interleaved)
against a shadow model that maintains its own dense array via the
reported (dst, src) moves — the exact contract every owner plane
relies on under hostile scenarios like flash_crowd_10k."""
import pytest

from repro.core.rows import RowRegistry


def test_rows_insertion_order_and_lookup():
    r = RowRegistry()
    assert len(r) == 0 and "a" not in r
    assert r.add("a") == (0, True)
    assert r.add("b") == (1, True)
    assert r.add("a") == (0, False)          # idempotent re-add
    assert r.ids == ["a", "b"]
    assert r["b"] == 1 and r.get("c") is None
    with pytest.raises(KeyError):
        r["c"]


def test_rows_amortized_doubling():
    r = RowRegistry(capacity=2)
    for i in range(100):
        r.add(f"s{i}")
    assert r.capacity >= 100
    # doubling, not per-add growth: few distinct capacities were seen
    assert r.capacity in (128, 100) or r.capacity >= 100
    assert r.reserve(1000) >= 1100


def test_rows_swap_remove_reports_move():
    r = RowRegistry()
    for x in "abcd":
        r.add(x)
    assert r.remove("nope") is None
    dst, src = r.remove("b")                 # middle: last swaps in
    assert (dst, src) == (1, 3)
    assert r.ids == ["a", "d", "c"]
    assert r["d"] == 1
    dst, src = r.remove("c")                 # last row: no move needed
    assert dst == src == 2
    assert r.ids == ["a", "d"]
    # fully drain, then refill reuses dense rows from 0
    r.remove("a")
    r.remove("d")
    assert len(r) == 0
    assert r.add("z") == (0, True)


# ---------------------------------------------------------------------------
# property suite: random adversarial churn vs a shadow model
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    _IDS = st.sampled_from([f"s{i}" for i in range(12)])
    _OPS = st.lists(
        st.one_of(
            st.tuples(st.just("add"), _IDS),
            st.tuples(st.just("remove"), _IDS),
            st.tuples(st.just("reserve"), st.integers(0, 40)),
            st.tuples(st.just("align"), st.integers(1, 8)),
        ),
        max_size=60)


    def _apply(ops):
        """Run a churn program against the registry AND a shadow that
        maintains a dense row->payload array using only the registry's
        reported contract: rows append at len(), removals copy src->dst."""
        reg = RowRegistry(capacity=2)
        arr = {}                    # row -> payload (the owner's array)
        live = {}                   # id -> payload (the ground truth)
        gen = reg.generation
        for op, x in ops:
            if op == "add":
                row, new = reg.add(x)
                assert new == (x not in live)
                if new:
                    assert row == len(reg) - 1     # dense append
                    arr[row] = live[x] = f"payload:{x}"
                    assert reg.generation > gen
                else:
                    assert arr[row] == live[x]     # idempotent: same row
            elif op == "remove":
                mv = reg.remove(x)
                if x not in live:
                    assert mv is None
                else:
                    dst, src = mv
                    assert src == len(reg)         # old last row
                    if dst != src:
                        arr[dst] = arr[src]        # the owner's move
                    arr.pop(src, None)
                    del live[x]
                    assert reg.generation > gen
            elif op == "reserve":
                assert reg.reserve(x) >= len(reg) + x
            elif op == "align":
                cap = reg.set_align(x)
                assert cap == reg.capacity and cap % x == 0
            gen = reg.generation
        return reg, arr, live


    @settings(max_examples=60, deadline=None)
    @given(_OPS)
    def test_rows_churn_preserves_contents(ops):
        reg, arr, live = _apply(ops)
        # the registry and the ground truth agree on membership...
        assert len(reg) == len(live)
        assert set(reg.ids) == set(live)
        # ...and the owner's array, driven only by reported moves, holds
        # every live id's payload at the registry's row for it
        for rid, payload in live.items():
            assert rid in reg
            assert arr[reg[rid]] == payload
        # rows are the dense prefix [0, len)
        assert sorted(reg[r] for r in reg.ids) == list(range(len(reg)))
        assert reg.rows_of(reg.ids) == list(range(len(reg)))
        assert reg.rows_of(list(live) + ["absent"]) is None


    @settings(max_examples=60, deadline=None)
    @given(_OPS, st.integers(1, 8))
    def test_rows_churn_preserves_shard_spans(ops, align):
        reg, _, live = _apply(ops)
        cap = reg.set_align(align)
        spans = reg.shard_spans()
        # equal contiguous blocks tiling [0, capacity) exactly
        assert spans[0][0] == 0 and spans[-1][1] == cap
        blk = cap // align
        assert all(hi - lo == blk for lo, hi in spans)
        assert all(spans[i][1] == spans[i + 1][0]
                   for i in range(len(spans) - 1))
        counts = reg.shard_counts()
        assert sum(counts) == len(reg) == len(live)
        # live rows fill the dense prefix: block loads are maximal-first
        assert counts == sorted(counts, reverse=True)


    @settings(max_examples=60, deadline=None)
    @given(_OPS)
    def test_rows_churn_is_row_order_fast_path(ops):
        reg, _, _ = _apply(ops)
        ids = reg.ids
        assert reg.is_row_order(ids)
        assert reg.is_row_order(tuple(ids))        # any sequence type
        if len(ids) >= 2:
            swapped = list(ids)
            swapped[0], swapped[-1] = swapped[-1], swapped[0]
            if swapped != ids:
                assert not reg.is_row_order(swapped)
            assert not reg.is_row_order(ids[:-1])  # prefix: wrong length
        assert not reg.is_row_order(ids + ["absent"])
except ImportError:                                    # pragma: no cover
    pass
