"""Sharding-policy invariants: every parameter of every arch must be
divisible by its mesh-axis assignment on the production mesh, and
padded_heads must preserve the GQA group structure. Runs against mesh
*rules* without building a 256-device mesh (device-free)."""
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.models.layers import padded_heads
from repro.models.param import Spec, is_spec
from repro.models.transformer import build_spec

import jax

MODEL_N = 16
DATA_N = 16


class FakeMesh:
    """Just enough mesh for mesh_rules (shape dict)."""
    def __init__(self, shape):
        self.shape = shape


def _rules(cfg, multi_pod=False):
    from repro.distributed.sharding import mesh_rules
    shape = ({"pod": 2, "data": DATA_N, "model": MODEL_N} if multi_pod
             else {"data": DATA_N, "model": MODEL_N})
    return mesh_rules(FakeMesh(shape), cfg), shape


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_every_param_divisible(arch, multi_pod):
    cfg = get_config(arch)
    rules, shape = _rules(cfg, multi_pod)
    tp = MODEL_N if rules.get("heads") else 1
    spec = build_spec(cfg, ep=MODEL_N, tp=tp)
    leaves = jax.tree.leaves(spec, is_leaf=is_spec)
    assert leaves, arch
    for s in leaves:
        for dim, ax in zip(s.shape, s.axes):
            if ax is None:
                continue
            mesh_ax = rules.get(ax)
            if mesh_ax is None:
                continue
            axes = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
            ways = math.prod(shape[a] for a in axes)
            assert dim % ways == 0, (
                f"{arch}: dim {dim} (axis {ax}->{mesh_ax}) not divisible "
                f"by {ways} in spec {s.shape}/{s.axes}")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_moe_experts_divisible_by_ep(arch):
    cfg = get_config(arch)
    if cfg.moe is None:
        pytest.skip("dense arch")
    from repro.models.moe import padded_experts
    E = padded_experts(cfg, MODEL_N)
    assert E % MODEL_N == 0
    assert E >= cfg.moe.num_experts
    assert E - cfg.moe.num_experts < MODEL_N    # minimal padding


@given(H=st.integers(1, 128), K=st.integers(1, 32),
       tp=st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=200, deadline=None)
def test_padded_heads_properties(H, K, tp):
    if H % K:
        H = K * max(1, H // K)     # GQA requires K | H
    import dataclasses
    from repro.configs.base import DENSE, ModelConfig
    cfg = ModelConfig(name="x", family=DENSE, num_layers=1, d_model=64,
                      num_heads=H, num_kv_heads=K, d_ff=64, vocab_size=64)
    Hp = padded_heads(cfg, tp)
    assert Hp >= H
    assert Hp % K == 0                          # group structure intact
    assert Hp <= 1.5 * H                        # bounded waste
    if Hp % tp == 0 and Hp != H:
        # padding achieved divisibility with per-group padding
        assert (Hp // K) >= (H // K)
    if H % tp == 0:
        assert Hp == H                          # no-op when divisible


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_rule_covers_dp_axes(arch):
    cfg = get_config(arch)
    rules, _ = _rules(cfg, multi_pod=True)
    assert rules["batch"] == ("pod", "data")


def test_starcoder2_heads_padded_not_replicated():
    cfg = get_config("starcoder2-3b")
    rules, _ = _rules(cfg)
    assert rules["heads"] == "model"            # 24 -> 32 pads fine
    assert padded_heads(cfg, MODEL_N) == 32


def test_hymba_heads_replicated_not_padded():
    cfg = get_config("hymba-1.5b")
    rules, _ = _rules(cfg)
    assert rules["heads"] is None               # 25 -> 80 too wasteful
    assert padded_heads(cfg, MODEL_N) == 25
