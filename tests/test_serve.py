"""Serving tests: slot-pool cache manager, batched decode loop, decode
correctness against teacher forcing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.model import build_model
from repro.serve.kvcache import CacheManager, ServeLoop


def _model(arch="olmo-1b", vocab=64):
    cfg = dataclasses.replace(smoke_config(arch),
                              vocab_size=vocab)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_slot_admission_and_release():
    cfg, model, params = _model()
    mgr = CacheManager(model, num_slots=3, capacity=32)
    a = mgr.admit("r1")
    b = mgr.admit("r2")
    assert a != b
    assert len(mgr.free_slots()) == 1
    assert mgr.utilization() == pytest.approx(2 / 3)
    mgr.release(a)
    assert len(mgr.free_slots()) == 2
    c = mgr.admit("r3")
    assert c == a                      # slot recycled


def test_pool_exhaustion_raises():
    cfg, model, params = _model()
    mgr = CacheManager(model, num_slots=1, capacity=16)
    mgr.admit("r1")
    with pytest.raises(RuntimeError):
        mgr.admit("r2")


def test_serve_loop_matches_single_request_decode():
    """Greedy generation through the slot pool == straight prefill+decode
    on a dedicated cache."""
    cfg, model, params = _model()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=10)
    max_new = 5

    loop = ServeLoop(model, params, num_slots=2, capacity=32,
                     max_new=max_new)
    loop.submit("a", prompt)
    loop.run_until_drained()
    got = loop.outputs["a"]

    # reference: direct greedy decode
    cap = 32 + cfg.meta_tokens
    last, cache, pos = model.prefill(params, jnp.asarray(prompt)[None],
                                     cap)
    tok = int(jnp.argmax(last.astype(jnp.float32), -1)[0])
    want = [tok]
    for _ in range(max_new - 1):
        logits, cache = model.decode(params,
                                     jnp.asarray([[want[-1]]], jnp.int32),
                                     cache, pos)
        want.append(int(jnp.argmax(logits[0, -1].astype(jnp.float32))))
        pos = pos + 1
    assert got == want, (got, want)


def test_serve_loop_batched_requests_drain():
    cfg, model, params = _model()
    rng = np.random.default_rng(1)
    loop = ServeLoop(model, params, num_slots=3, capacity=32, max_new=4)
    for i in range(3):
        loop.submit(f"r{i}", rng.integers(0, cfg.vocab_size, size=8))
    out = loop.run_until_drained()
    assert set(out) == {"r0", "r1", "r2"}
    assert all(len(v) == 4 for v in out.values())
    assert not loop.mgr.active()


def _solo_outputs(model, params, prompt, max_new, capacity=32, eos_id=None):
    """Reference transcript: a dedicated single-slot loop."""
    loop = ServeLoop(model, params, num_slots=1, capacity=capacity,
                     max_new=max_new, eos_id=eos_id)
    loop.submit("solo", prompt)
    loop.run_until_drained()
    return loop.outputs["solo"]


def test_submit_retires_at_max_new_1():
    """The prefill's argmax IS emitted token #1: with max_new == 1 the
    request is complete at submit time. The seed left it active — it
    burned a decode tick and over-emitted a second token."""
    cfg, model, params = _model()
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    loop = ServeLoop(model, params, num_slots=2, capacity=32, max_new=1)
    loop.submit("a", prompt)
    assert not loop.mgr.active()              # retired at submit
    assert len(loop.outputs["a"]) == 1
    assert loop.tick() == {}                  # nothing left to decode
    assert len(loop.outputs["a"]) == 1        # no over-emission
    done = loop.drain()                       # transcript handed over
    assert set(done) == {"a"} and len(done["a"]) == 1
    assert "a" not in loop.outputs


def test_submit_retires_on_eos_prefill_token():
    """EOS on the prefill token must retire the request at submit."""
    cfg, model, params = _model()
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    first = _solo_outputs(model, params, prompt, max_new=4)[0]
    loop = ServeLoop(model, params, num_slots=2, capacity=32, max_new=4,
                     eos_id=first)
    loop.submit("a", prompt)
    assert not loop.mgr.active()
    assert loop.outputs["a"] == [first]
    assert loop.tick() == {}
    assert loop.outputs["a"] == [first]


def test_release_clears_per_slot_decode_state():
    """Retirement must clear `_new_tokens` — the seed kept the dead
    request's last token keyed by the slot, so a recycled slot could
    replay it — and a recycled slot must serve the next request
    bit-identically to a fresh loop."""
    cfg, model, params = _model()
    rng = np.random.default_rng(5)
    p1 = rng.integers(0, cfg.vocab_size, size=8)
    p2 = rng.integers(0, cfg.vocab_size, size=8)
    loop = ServeLoop(model, params, num_slots=1, capacity=32, max_new=3)
    slot1 = loop.submit("a", p1)
    loop.run_until_drained()
    assert loop._new_tokens == {}             # no dead-request residue
    slot2 = loop.submit("b", p2)
    assert slot2 == slot1                     # slot recycled
    loop.run_until_drained()
    assert loop.outputs["b"] == _solo_outputs(model, params, p2, 3)


def test_drain_keeps_outputs_bounded():
    """Continuous serving: finished transcripts leave via drain();
    in-flight requests stay."""
    cfg, model, params = _model()
    rng = np.random.default_rng(6)
    loop = ServeLoop(model, params, num_slots=4, capacity=32, max_new=2)
    for i in range(3):
        loop.submit(f"r{i}", rng.integers(0, cfg.vocab_size, size=8))
    loop.run_until_drained()
    loop.submit("late", rng.integers(0, cfg.vocab_size, size=8))
    done = loop.drain()
    assert set(done) == {"r0", "r1", "r2"}
    assert all(len(v) == 2 for v in done.values())
    assert set(loop.outputs) == {"late"}      # in-flight request kept
    assert loop.drain() == {}                 # idempotent


def test_admission_capacity_check():
    """A prompt needs prompt_len + max_new - 1 <= capacity cache
    positions; the seed prefilled oversized prompts into the slot
    silently. Boundary: the exactly-fitting length admits."""
    cfg, model, params = _model()
    rng = np.random.default_rng(7)
    cap, max_new = 16, 4
    loop = ServeLoop(model, params, num_slots=2, capacity=cap,
                     max_new=max_new)
    fit = cap - max_new + 1
    loop.submit("ok", rng.integers(0, cfg.vocab_size, size=fit))
    loop.run_until_drained()
    assert len(loop.outputs["ok"]) == max_new
    with pytest.raises(ValueError, match="does not fit"):
        loop.submit("big", rng.integers(0, cfg.vocab_size, size=fit + 1))
    with pytest.raises(ValueError, match="max_new"):
        loop.mgr.check_fit(4, 0)
    assert len(loop.mgr.free_slots()) == 2    # nothing was admitted


def test_multi_slot_tick_matches_sequential_decode():
    """Batched-vs-sequential parity: a multi-slot tick over staggered
    requests (different prompt lengths AND different positions) must
    emit bit-identical tokens to decoding each request alone."""
    cfg, model, params = _model()
    rng = np.random.default_rng(8)
    lens = [10, 7, 10, 5]
    prompts = [rng.integers(0, cfg.vocab_size, size=s) for s in lens]
    max_new = 5
    loop = ServeLoop(model, params, num_slots=4, capacity=32,
                     max_new=max_new)
    # staggered admission: positions diverge across slots
    loop.submit("r0", prompts[0])
    loop.tick()
    loop.submit("r1", prompts[1])
    loop.submit("r2", prompts[2])
    loop.tick()
    loop.submit("r3", prompts[3])
    loop.run_until_drained()
    for i, p in enumerate(prompts):
        want = _solo_outputs(model, params, p, max_new)
        assert loop.outputs[f"r{i}"] == want, (i, loop.outputs[f"r{i}"],
                                               want)


def test_serve_loop_isolation_between_requests():
    """A second concurrent request must not change the first one's
    output (cache isolation across slots)."""
    cfg, model, params = _model()
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, size=8)
    p2 = rng.integers(0, cfg.vocab_size, size=8)

    solo = ServeLoop(model, params, num_slots=2, capacity=32, max_new=4)
    solo.submit("a", p1)
    solo.run_until_drained()

    duo = ServeLoop(model, params, num_slots=2, capacity=32, max_new=4)
    duo.submit("a", p1)
    duo.submit("b", p2)
    duo.run_until_drained()
    assert solo.outputs["a"] == duo.outputs["a"]
