"""Serving tests: slot-pool cache manager, batched decode loop, decode
correctness against teacher forcing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.model import build_model
from repro.serve.kvcache import CacheManager, ServeLoop


def _model(arch="olmo-1b", vocab=64):
    cfg = dataclasses.replace(smoke_config(arch),
                              vocab_size=vocab)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_slot_admission_and_release():
    cfg, model, params = _model()
    mgr = CacheManager(model, num_slots=3, capacity=32)
    a = mgr.admit("r1")
    b = mgr.admit("r2")
    assert a != b
    assert len(mgr.free_slots()) == 1
    assert mgr.utilization() == pytest.approx(2 / 3)
    mgr.release(a)
    assert len(mgr.free_slots()) == 2
    c = mgr.admit("r3")
    assert c == a                      # slot recycled


def test_pool_exhaustion_raises():
    cfg, model, params = _model()
    mgr = CacheManager(model, num_slots=1, capacity=16)
    mgr.admit("r1")
    with pytest.raises(RuntimeError):
        mgr.admit("r2")


def test_serve_loop_matches_single_request_decode():
    """Greedy generation through the slot pool == straight prefill+decode
    on a dedicated cache."""
    cfg, model, params = _model()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=10)
    max_new = 5

    loop = ServeLoop(model, params, num_slots=2, capacity=32,
                     max_new=max_new)
    loop.submit("a", prompt)
    loop.run_until_drained()
    got = loop.outputs["a"]

    # reference: direct greedy decode
    cap = 32 + cfg.meta_tokens
    last, cache, pos = model.prefill(params, jnp.asarray(prompt)[None],
                                     cap)
    tok = int(jnp.argmax(last.astype(jnp.float32), -1)[0])
    want = [tok]
    for _ in range(max_new - 1):
        logits, cache = model.decode(params,
                                     jnp.asarray([[want[-1]]], jnp.int32),
                                     cache, pos)
        want.append(int(jnp.argmax(logits[0, -1].astype(jnp.float32))))
        pos = pos + 1
    assert got == want, (got, want)


def test_serve_loop_batched_requests_drain():
    cfg, model, params = _model()
    rng = np.random.default_rng(1)
    loop = ServeLoop(model, params, num_slots=3, capacity=32, max_new=4)
    for i in range(3):
        loop.submit(f"r{i}", rng.integers(0, cfg.vocab_size, size=8))
    out = loop.run_until_drained()
    assert set(out) == {"r0", "r1", "r2"}
    assert all(len(v) == 4 for v in out.values())
    assert not loop.mgr.active()


def test_serve_loop_isolation_between_requests():
    """A second concurrent request must not change the first one's
    output (cache isolation across slots)."""
    cfg, model, params = _model()
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, size=8)
    p2 = rng.integers(0, cfg.vocab_size, size=8)

    solo = ServeLoop(model, params, num_slots=2, capacity=32, max_new=4)
    solo.submit("a", p1)
    solo.run_until_drained()

    duo = ServeLoop(model, params, num_slots=2, capacity=32, max_new=4)
    duo.submit("a", p1)
    duo.submit("b", p2)
    duo.run_until_drained()
    assert solo.outputs["a"] == duo.outputs["a"]
