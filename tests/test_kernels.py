"""Pallas kernel sweeps: every kernel validated against its pure-jnp
oracle (kernels/ref.py) across shapes and dtypes, in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_scan import mlstm_scan
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,T,H,K,hd", [
    (1, 128, 128, 4, 4, 64),      # MHA square
    (2, 64, 64, 4, 2, 32),        # GQA
    (1, 96, 96, 8, 1, 64),        # MQA, ragged S
    (1, 32, 128, 4, 2, 64),       # queries appended at end (decode-ish)
])
@pytest.mark.parametrize("causal,window", [
    (True, 0), (True, 32), (False, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, T, H, K, hd, causal, window, dtype):
    if not causal and S != T:
        pytest.skip("appended-query layout only defined for causal")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=32, kv_block=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_blocksize_invariance():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    outs = [flash_attention(q, k, v, q_block=qb, kv_block=kb,
                            interpret=True)
            for qb, kb in [(32, 32), (64, 32), (32, 64), (128, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,P,chunk", [
    (1, 64, 2, 32, 16),
    (2, 96, 3, 16, 32),     # ragged chunks
    (1, 33, 1, 64, 32),     # pad
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_kernel_sweep(B, S, H, P, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (B, S, H, P), dtype)
    k = jax.random.normal(ks[1], (B, S, H, P), dtype)
    v = jax.random.normal(ks[2], (B, S, H, P), dtype)
    ig = (jax.random.normal(ks[3], (B, S, H)) * 2).astype(dtype)
    fg = (jax.random.normal(ks[4], (B, S, H)) * 2 + 1).astype(dtype)
    out = mlstm_scan(q, k, v, ig, fg, chunk=chunk, interpret=True)
    want = ref.mlstm_recurrent(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_mlstm_xla_chunked_matches_ref():
    from repro.models.xlstm import mlstm_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P = 2, 80, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, P))
    v = jax.random.normal(ks[2], (B, S, H, P))
    ig = jax.random.normal(ks[3], (B, S, H)) * 2
    fg = jax.random.normal(ks[4], (B, S, H)) * 2
    np.testing.assert_allclose(
        np.asarray(mlstm_chunked(q, k, v, ig, fg, chunk=32)),
        np.asarray(ref.mlstm_recurrent(q, k, v, ig, fg)), atol=3e-4)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 32, 16, 16),
    (2, 80, 1, 64, 8, 32),      # pad
    (1, 32, 4, 16, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    D = jnp.ones((H,))
    out = ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    want = ref.ssd_recurrent(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ssd_xla_chunked_matches_ref():
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, S, H, P, N = 2, 48, 2, 16, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    D = jnp.ones((H,))
    np.testing.assert_allclose(
        np.asarray(ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)),
        np.asarray(ref.ssd_recurrent(x, dt, A, Bm, Cm, D)), atol=3e-4)


# ---------------------------------------------------------------------------
# pairwise Jensen-Shannon divergence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("N,M,B", [
    (3, 5, 64),       # N != M
    (1, 7, 64),       # single query stream
    (9, 1, 128),      # single reference stream
    (17, 13, 128),    # odd sizes, both > tile fraction
    (100, 73, 64),    # multiple tiles, ragged
])
@pytest.mark.parametrize("impl", ["interpret", "xla"])
def test_pairwise_js_sweep(N, M, B, impl):
    rng = np.random.default_rng(0)
    p = rng.random((N, B)).astype(np.float32)
    p[0, :] = 0.0                       # all-zero histogram edge case
    q = rng.random((M, B)).astype(np.float32)
    got = np.asarray(ops.pairwise_js(p, q, impl=impl))
    want = np.asarray(ops.pairwise_js(p, q, impl="ref"))
    assert got.shape == (N, M)
    assert not np.isnan(got).any()
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=0)


@pytest.mark.parametrize("impl", ["interpret", "xla", "ref"])
def test_pairwise_js_empty_inputs(impl):
    """Zero streams on either side must yield an empty matrix, not a
    crash (the xla path divided by a zero tile size at M == 0)."""
    p = np.ones((3, 64), np.float32)
    e = np.zeros((0, 64), np.float32)
    assert np.asarray(ops.pairwise_js(p, e, impl=impl)).shape == (3, 0)
    assert np.asarray(ops.pairwise_js(e, p, impl=impl)).shape == (0, 3)
    assert np.asarray(ops.pairwise_js(e, e, impl=impl)).shape == (0, 0)


def test_pairwise_js_matches_scalar_js_divergence():
    """The batched engine agrees with drift.js_divergence per pair."""
    from repro.core.drift import js_divergence
    rng = np.random.default_rng(1)
    p = rng.random((4, 64))
    q = rng.random((6, 64))
    D = np.asarray(ops.pairwise_js(p.astype(np.float32),
                                   q.astype(np.float32), impl="xla"))
    for i in range(4):
        for j in range(6):
            assert abs(D[i, j] - js_divergence(p[i], q[j])) < 1e-5


def test_pairwise_js_identity_and_symmetry():
    rng = np.random.default_rng(2)
    p = rng.random((5, 64)).astype(np.float32)
    D = np.asarray(ops.pairwise_js(p, p, impl="xla"))
    np.testing.assert_allclose(np.diag(D), 0.0, atol=1e-6)
    np.testing.assert_allclose(D, D.T, atol=1e-6)
    assert (D + 1e-6 >= 0).all()


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------
def test_ops_dispatch_consistency():
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    a = ops.attention(q, k, v, impl="interpret", q_block=16, kv_block=16)
    b = ops.attention(q, k, v, impl="ref")
    c = ops.attention(q, k, v, impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(b), np.asarray(c), atol=1e-5)
