"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — tests must see
the real single CPU device; only launch/dryrun.py forces 512 devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny_config(arch: str = "olmo-1b", vocab: int = 64):
    import dataclasses
    from repro.configs import smoke_config
    cfg = smoke_config(arch)
    return dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, vocab))
