"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — tests must see
the real single CPU device; only launch/dryrun.py forces 512 devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


# FLEETLINT_RUNTIME=1: run the suite under the fleetlint runtime
# sanitizer (borrow fingerprinting + transfer guard on the batched
# decision entry points — docs/static_analysis.md). The hooks change
# failure modes only, never values, so any suite that passes plain
# must pass sanitized; CI runs the trainer-bank and transmission-plane
# suites in this mode.
if os.environ.get("FLEETLINT_RUNTIME") == "1":
    def pytest_configure(config):
        from repro.testing.fleetlint.runtime import install
        install()

    def pytest_unconfigure(config):
        from repro.testing.fleetlint.runtime import uninstall
        uninstall()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny_config(arch: str = "olmo-1b", vocab: int = 64):
    import dataclasses
    from repro.configs import smoke_config
    cfg = smoke_config(arch)
    return dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, vocab))
