"""Parity + unit suite for the stacked training plane (JobBank,
TokenRingPool, vmapped SharedEngine executables).

The batched paths must be BIT-IDENTICAL to the seed per-job loop —
same float32 per-member accuracies, same SGD trajectories (same rng
draws per job, same batch order) — so the allocator/grouper decisions
they feed are pinned, not merely close. `SharedEngine(batched=False)`
is the scalar reference twin: same model config and seeds produce the
same initial states, so any divergence is the batched dispatch's.
"""
import dataclasses
import gc

import numpy as np
import pytest

import jax

from repro.configs import smoke_config
from repro.core.allocator import ECCOAllocator, UniformAllocator
from repro.core.grouping import Request
from repro.core.trainer import (JobBank, RetrainJob, SharedEngine,
                                TokenRingPool)

VOCAB = 64
SEQ = 16


@pytest.fixture(scope="module")
def engines():
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=VOCAB)
    return SharedEngine(cfg), SharedEngine(cfg, batched=False)


@pytest.fixture(scope="module")
def host_engine():
    """Batched engine on the HOST-resident bank (PR 3's layout) — the
    residency-parity reference twin."""
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=VOCAB)
    return SharedEngine(cfg, resident=False)


def _req(sid, toks, acc=0.0, t=0.0, loc=(0.0, 0.0)):
    return Request(stream_id=sid, t=t, loc=loc, subsamples=toks, acc=acc,
                   train_data=toks)


def _data(rng, n, seq=SEQ):
    return rng.integers(0, VOCAB, size=(n, seq))


def _make_fleet(engine, *, jobs=3, members=3, batch=4, micro=2, seed0=0):
    """Identically-seeded jobs on `engine`; rebuildable on the twin."""
    out = []
    for j in range(jobs):
        rng = np.random.default_rng(100 + j)
        job = RetrainJob(engine, _req(f"s{j}_0", _data(rng, 8)),
                         micro_steps=micro, batch=batch, seed=seed0 + j)
        for m in range(1, members):
            job.add_member(_req(f"s{j}_{m}", _data(rng, 8)))
        out.append(job)
    return out


def _states_equal(a, b) -> bool:
    eq = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)
    return all(jax.tree.leaves(eq))


# ---------------------------------------------------------------------------
# TokenRingPool: row-budget eviction, ordering, purge
# ---------------------------------------------------------------------------
def test_ring_pool_matches_concat_order_under_capacity():
    rng = np.random.default_rng(0)
    pool = TokenRingPool(capacity_rows=64)
    entries = [rng.integers(0, 9, size=(n, 8)) for n in (3, 1, 5)]
    for i, e in enumerate(entries):
        pool.add(e, f"s{i}")
    np.testing.assert_array_equal(pool.rows(), np.concatenate(entries))
    assert pool.sources() == ["s0"] * 3 + ["s1"] * 1 + ["s2"] * 5


def test_ring_pool_evicts_by_rows_not_entries():
    """The token budget is ROWS: variably-sized entries must not widen
    the memory window. The kept/evicted boundary is exactly the newest
    `capacity` rows — an old entry can survive partially."""
    rng = np.random.default_rng(1)
    pool = TokenRingPool(capacity_rows=8)
    entries = [rng.integers(0, 9, size=(n, 4)) for n in (3, 4, 3)]
    for i, e in enumerate(entries):
        pool.add(e, f"s{i}")
    # 10 rows total, budget 8 -> the 2 oldest rows of entry 0 evicted,
    # its 3rd row kept (partial-entry boundary)
    want = np.concatenate(entries)[-8:]
    np.testing.assert_array_equal(pool.rows(), want)
    assert pool.sources() == ["s0"] + ["s1"] * 4 + ["s2"] * 3
    assert len(pool) == 8


def test_ring_pool_oversized_entry_keeps_newest_rows():
    rng = np.random.default_rng(2)
    pool = TokenRingPool(capacity_rows=4)
    big = rng.integers(0, 9, size=(10, 4))
    pool.add(big, "s0")
    np.testing.assert_array_equal(pool.rows(), big[-4:])
    assert len(pool) == 4


def test_ring_pool_wraparound_stays_ordered():
    pool = TokenRingPool(capacity_rows=5)
    for i in range(7):        # 7 one-row entries through a 5-row ring
        pool.add(np.full((1, 3), i), f"s{i}")
    np.testing.assert_array_equal(pool.rows()[:, 0], [2, 3, 4, 5, 6])
    assert pool.sources() == [f"s{i}" for i in range(2, 7)]


def test_ring_pool_purge_preserves_survivor_order():
    pool = TokenRingPool(capacity_rows=6)
    pool.add(np.full((2, 3), 0), "a")
    pool.add(np.full((2, 3), 1), "b")
    pool.add(np.full((2, 3), 2), "a")
    pool.purge("a")
    np.testing.assert_array_equal(pool.rows()[:, 0], [1, 1])
    assert pool.sources() == ["b", "b"]
    pool.add(np.full((1, 3), 3), "c")      # still usable after purge
    np.testing.assert_array_equal(pool.rows()[:, 0], [1, 1, 3])


def test_ingest_row_budget_boundary(engines):
    """RetrainJob.ingest evicts by total pooled rows (token budget)."""
    engine, _ = engines
    rng = np.random.default_rng(3)
    job = RetrainJob(engine, _req("s0", _data(rng, 2)), pool_rows=6)
    job.ingest(_data(rng, 3), "s1")
    job.ingest(_data(rng, 4), "s2")       # 9 rows -> oldest 3 evicted
    assert len(job.pool) == 6
    assert job._pool_src == ["s1", "s1", "s2", "s2", "s2", "s2"]


# ---------------------------------------------------------------------------
# JobBank: slot lifecycle, deferred free, swap-compaction
# ---------------------------------------------------------------------------
def test_bank_read_write_roundtrip(engines):
    engine, _ = engines
    bank = JobBank(engine)
    s0, s1 = engine.fresh_state(0), engine.fresh_state(1)
    a, b = bank.alloc(s0), bank.alloc(s1)
    assert _states_equal(bank.read(a.idx), s0)
    assert _states_equal(bank.read(b.idx), s1)
    bank.write(a.idx, s1)
    assert _states_equal(bank.read(a.idx), s1)


def test_bank_capacity_doubles(engines):
    engine, _ = engines
    bank = JobBank(engine, capacity=2)
    slots = [bank.alloc(engine.fresh_state(i)) for i in range(5)]
    assert bank.capacity >= 5
    for i, s in enumerate(slots):       # growth preserved every slot
        assert _states_equal(bank.read(s.idx), engine.fresh_state(i))


def test_bank_free_is_deferred_until_compact(engines):
    """free() must not move rows (it runs from GC finalizers at
    arbitrary points while batched callers hold captured indices);
    compact() does the swap."""
    engine, _ = engines
    bank = JobBank(engine)
    states = [engine.fresh_state(i) for i in range(3)]
    slots = [bank.alloc(s) for s in states]
    bank.free(slots[0])
    assert slots[0].dead and slots[0].idx == 0      # queued, row intact
    assert slots[2].idx == 2                        # nothing moved yet
    assert _states_equal(bank.read(slots[2].idx), states[2])
    bank.compact()
    assert slots[0].idx is None
    assert len(bank) == 2
    # swap-compaction moved the LAST slot into the freed row and
    # retargeted its handle
    assert slots[2].idx == 0
    assert _states_equal(bank.read(slots[2].idx), states[2])
    assert _states_equal(bank.read(slots[1].idx), states[1])
    bank.free(slots[0])                             # idempotent
    bank.compact()
    assert len(bank) == 2


def test_mass_churn_compaction_resolves_swap_chains(engines):
    """Several queued deaths compact as ONE batched device move; a
    swap CHAIN (the survivor moved into one hole becomes the move
    source for the next) must resolve to original rows, because the
    batched kernel's gathers all read the pre-update stack."""
    engine, _ = engines
    bank = JobBank(engine)
    states = [engine.fresh_state(i) for i in range(6)]
    slots = [bank.alloc(s) for s in states]
    # round-trip through gather/scatter: every row device-authoritative
    bank.scatter(list(range(6)), bank.gather(list(range(6))))
    assert not bank._host_ok[:6].any()
    # free 0 and 4; compact pops 4 first (row 5 -> 4), then 0
    # (row 4 -> 0) — the second move's source holds row 5's content
    bank.free(slots[0])
    bank.free(slots[4])
    bank.compact()
    assert len(bank) == 4
    assert slots[5].idx == 0 and slots[0].idx is None
    for orig, slot in ((1, slots[1]), (2, slots[2]), (3, slots[3]),
                       (5, slots[5])):
        assert _states_equal(bank.read(slot.idx), states[orig]), orig


def test_use_after_release_raises(engines):
    """numpy would treat a freed slot's idx=None as np.newaxis and
    broadcast a state write across the WHOLE bank; the bank must fail
    loudly instead."""
    engine, _ = engines
    rng = np.random.default_rng(11)
    job = RetrainJob(engine, _req("uar0", _data(rng, 4)))
    keep = job.state
    job.release()
    engine.bank.compact()
    with pytest.raises(ValueError, match="use-after-release"):
        job.state
    with pytest.raises(ValueError, match="use-after-release"):
        job.state = keep
    with pytest.raises(ValueError, match="use-after-release"):
        job.eval_on(_data(rng, 2))


def test_job_handle_gc_returns_slot(engines):
    engine, _ = engines
    rng = np.random.default_rng(4)
    gc.collect()
    engine.bank.compact()        # settle earlier tests' dead handles
    n0 = len(engine.bank)
    job = RetrainJob(engine, _req("gc0", _data(rng, 4)))
    assert len(engine.bank) == n0 + 1
    del job
    gc.collect()
    engine.bank.compact()
    assert len(engine.bank) == n0


# ---------------------------------------------------------------------------
# eval-plane parity: batched_accuracy / eval_pairs / eval_jobs
# ---------------------------------------------------------------------------
def test_batched_accuracy_bit_identical_to_scalar(engines):
    engine, _ = engines
    rng = np.random.default_rng(5)
    jobs = _make_fleet(engine, jobs=3, members=3)
    # include a 1-member job
    solo = RetrainJob(engine, _req("solo", _data(rng, 8)), seed=9)
    jobs.append(solo)
    pairs = [(j, m.subsamples) for j in jobs for m in j.members]
    batched = engine.eval_pairs(pairs)
    scalar = [j.eval_on(s) for j, s in pairs]
    assert batched == scalar                 # exact float equality
    # the (P,)-pairs primitive agrees too
    jids = np.array([j._slot.idx for j, _ in pairs])
    toks = np.stack([np.asarray(s) for _, s in pairs])
    accs = engine.batched_accuracy(engine.bank.params_stack(), toks, jids)
    assert [float(a) for a in accs] == scalar


def test_eval_jobs_matches_scalar_eval(engines):
    engine, scalar_engine = engines
    jobs = _make_fleet(engine, jobs=3, members=2)
    ref = [float(np.mean([j.eval_on(m.subsamples) for m in j.members]))
           for j in jobs]
    assert engine.eval_jobs(jobs) == ref
    assert [j.eval() for j in jobs] == ref
    # the scalar twin produces the same numbers for the same seeds
    twin = _make_fleet(scalar_engine, jobs=3, members=2)
    assert [j.eval() for j in twin] == ref


def test_eval_parity_on_just_compacted_slot(engines):
    engine, _ = engines
    jobs = _make_fleet(engine, jobs=3, members=2, seed0=20)
    ref = {j.job_id: [j.eval_on(m.subsamples) for m in j.members]
           for j in jobs}
    victim = jobs.pop(1)
    victim.release()                 # queued; compacted inside eval_pairs
    pairs = [(j, m.subsamples) for j in jobs for m in j.members]
    got = engine.eval_pairs(pairs)
    want = [a for j in jobs for a in ref[j.job_id]]
    assert got == want


def test_mixed_sample_shapes_batch_per_shape(engines):
    engine, _ = engines
    rng = np.random.default_rng(6)
    jobs = _make_fleet(engine, jobs=2, members=1, seed0=30)
    pairs = [(jobs[0], _data(rng, 8)), (jobs[1], _data(rng, 4)),
             (jobs[0], _data(rng, 4)), (jobs[1], _data(rng, 8))]
    assert engine.eval_pairs(pairs) == [j.eval_on(s) for j, s in pairs]


# ---------------------------------------------------------------------------
# train-plane parity: train_micro_many vs sequential train_micro
# ---------------------------------------------------------------------------
def test_train_micro_many_bit_identical_to_sequential(engines):
    """Identical params after N micro-windows under identical rng:
    full-batch jobs ride the vmapped executable, a straggler (pool <
    batch) exercises the in-dispatch scalar fallback."""
    engine, scalar_engine = engines
    # 4 full-batch jobs: at the default batch_min_jobs=4 they ride the
    # vmapped executable (3 or fewer would all take the scalar path)
    fast = _make_fleet(engine, jobs=4, members=2, batch=4, seed0=40)
    slow = _make_fleet(scalar_engine, jobs=4, members=2, batch=4, seed0=40)
    rng = np.random.default_rng(7)
    straggler_data = _data(rng, 2)          # 2 rows < batch 4
    fast.append(RetrainJob(engine, _req("st", straggler_data),
                           micro_steps=2, batch=4, seed=77))
    slow.append(RetrainJob(scalar_engine, _req("st", straggler_data),
                           micro_steps=2, batch=4, seed=77))
    for _ in range(3):                      # N micro-windows
        engine.train_micro_many(fast)
        for j in slow:
            j.train_micro()
    for f, s in zip(fast, slow):
        assert _states_equal(f.state, s.state), f.job_id
        assert f.gpu_time == s.gpu_time == 3
    # and the post-training accuracies agree exactly
    pairs_f = [(j, m.subsamples) for j in fast for m in j.members]
    pairs_s = [(j, m.subsamples) for j in slow for m in j.members]
    assert engine.eval_pairs(pairs_f) == \
        [j.eval_on(s) for j, s in pairs_s]


def test_train_micro_many_skips_empty_pools(engines):
    engine, _ = engines
    rng = np.random.default_rng(8)
    job = RetrainJob(engine, Request(stream_id="e0", t=0.0, loc=(0, 0),
                                     subsamples=_data(rng, 4), acc=0.0))
    assert len(job.pool) == 0
    before = job.state
    engine.train_micro_many([job])
    assert job.gpu_time == 0                # seed no-op semantics
    assert _states_equal(job.state, before)


def test_mid_window_job_death_leaves_survivors_intact(engines):
    """A job dying mid-window (handle dropped -> finalizer -> deferred
    free -> compaction inside the next fleet call) must not perturb any
    survivor's state or subsequent training."""
    engine, scalar_engine = engines
    fast = _make_fleet(engine, jobs=4, members=2, seed0=60)
    slow = _make_fleet(scalar_engine, jobs=4, members=2, seed0=60)
    engine.train_micro_many(fast)
    for j in slow:
        j.train_micro()
    # job 1 dies mid-window on both engines
    del fast[1], slow[1]
    gc.collect()
    engine.train_micro_many(fast)           # compacts, then trains
    for j in slow:
        j.train_micro()
    for f, s in zip(fast, slow):
        assert _states_equal(f.state, s.state), f.job_id
    pairs = [(j, m.subsamples) for j in fast for m in j.members]
    assert engine.eval_pairs(pairs) == \
        [j.eval_on(m.subsamples) for j in slow for m in j.members]


# ---------------------------------------------------------------------------
# allocator decision parity: batched engine vs scalar twin
# ---------------------------------------------------------------------------
# ---------------------------------------------------------------------------
# residency: device-resident slot cache vs host-resident bank
# ---------------------------------------------------------------------------
def test_residency_parity_across_churn(engines, host_engine):
    """Device- and host-resident banks must produce bit-identical
    eval/train results through alloc/free/compaction churn: a job dies
    mid-window (GC finalizer -> deferred free -> compaction inside the
    next fleet call), a slot is explicitly released, and a new job
    allocates into the recycled row."""
    dev_e, _ = engines
    dev = _make_fleet(dev_e, jobs=5, members=2, seed0=200)
    host = _make_fleet(host_engine, jobs=5, members=2, seed0=200)

    def window(tag):
        dev_e.train_micro_many(dev)
        host_engine.train_micro_many(host)
        pd = [(j, m.subsamples) for j in dev for m in j.members]
        ph = [(j, m.subsamples) for j in host for m in j.members]
        assert dev_e.eval_pairs(pd) == host_engine.eval_pairs(ph), tag

    window("warm")
    # mid-window death on both fleets (handle dropped, GC'd)
    del dev[1], host[1]
    gc.collect()
    window("after-death")
    # explicit release; the next alloc recycles the compacted row
    dev.pop(2).release()
    host.pop(2).release()
    data = _data(np.random.default_rng(9), 8)
    dev.append(RetrainJob(dev_e, _req("rnew", data), micro_steps=2,
                          batch=4, seed=300))
    host.append(RetrainJob(host_engine, _req("rnew", data), micro_steps=2,
                           batch=4, seed=300))
    window("after-recycle")
    for d, h in zip(dev, host):
        assert _states_equal(d.state, h.state)
    # the allocator's measured decisions agree on both banks (its
    # greedy tail also exercises the scalar fallback on each)
    td = ECCOAllocator().run_window(dev, window_micro=9)
    th = ECCOAllocator().run_window(host, window_micro=9)
    dmap = {j.job_id: f"g{i}" for i, j in enumerate(dev)}
    hmap = {j.job_id: f"g{i}" for i, j in enumerate(host)}
    assert [dmap[x] for x in td.order] == [hmap[x] for x in th.order]
    assert {dmap[k]: v for k, v in td.acc.items()} == \
        {hmap[k]: v for k, v in th.acc.items()}
    assert {dmap[k]: v for k, v in td.shares.items()} == \
        {hmap[k]: v for k, v in th.shares.items()}


def test_batched_calls_zero_per_member_transfers(engines):
    """Once the fleet is resident, batched entry points must move NO
    state across the host boundary — not per member, not even per call
    (the PR 3 follow-up the device-resident slot cache closes)."""
    engine, _ = engines
    gc.collect()
    engine.bank.compact()            # settle earlier tests' dead handles
    jobs = _make_fleet(engine, jobs=4, members=3, seed0=400)
    pairs = [(j, m.subsamples) for j in jobs for m in j.members]
    engine.eval_pairs(pairs)         # flushes the freshly-alloc'd states
    engine.train_micro_many(jobs)
    s = engine.bank.stats
    s.reset()
    engine.eval_pairs(pairs)
    engine.train_micro_many(jobs)
    engine.eval_jobs(jobs)
    assert (s.h2d_syncs, s.d2h_syncs) == (0, 0)
    assert (s.h2d_bytes, s.d2h_bytes) == (0, 0)


def test_host_reads_sync_lazily_and_cache(engines):
    """`job.state` pulls the device row at most once per invalidation:
    the first read after a device-side train pays one d2h row sync, a
    repeat read is free, and the next batched train re-invalidates."""
    engine, _ = engines
    jobs = _make_fleet(engine, jobs=4, members=2, seed0=420)
    engine.train_micro_many(jobs)    # rows now device-authoritative
    s = engine.bank.stats
    s.reset()
    st = jobs[0].state
    assert s.d2h_syncs == 1
    assert s.d2h_bytes == engine.bank.state_row_nbytes
    assert _states_equal(st, jobs[0].state)     # mirror hit: no new sync
    assert s.d2h_syncs == 1
    engine.train_micro_many(jobs)
    assert s.h2d_syncs == 0          # trained on resident rows directly
    jobs[0].state
    assert s.d2h_syncs == 2


def test_host_write_visible_to_fleet_calls(engines):
    """A host-side state write (`job.state = ...`: checkpoint restore,
    model-zoo seeding) must reach the resident stack via the next
    batched entry point's shared flush — ONE h2d sync, and the fleet
    call scores the new state bit-identically."""
    engine, _ = engines
    jobs = _make_fleet(engine, jobs=2, members=1, seed0=440)
    a, b = jobs
    data = a.members[0].subsamples
    engine.train_micro_many([a])     # make a's state distinct from b's
    ref = a.eval_on(data)
    b.state = a.state
    s = engine.bank.stats
    s.reset()
    assert engine.eval_pairs([(b, data)]) == [ref]
    assert s.h2d_syncs == 1
    assert s.h2d_bytes == engine.bank.state_row_nbytes


def test_checkpoint_restore_writes_through_cache(engines, tmp_path):
    """save reads through the lazy host sync; restore_job writes back
    through the cache and the restored row is what fleet calls see."""
    from repro.distributed.checkpoint import restore_job, save

    engine, _ = engines
    rng = np.random.default_rng(13)
    job = RetrainJob(engine, _req("ck0", _data(rng, 8)), micro_steps=2,
                     batch=4, seed=7)
    data = job.members[0].subsamples
    job.train_micro()                # device-authoritative row
    snap = job.state
    acc0 = job.eval_on(data)
    save(str(tmp_path), 3, job.state, extra={"acc": acc0})
    job.train_micro()                # diverge past the snapshot
    s = engine.bank.stats
    s.reset()
    extra = restore_job(str(tmp_path), 3, job)
    assert s.d2h_syncs == 0          # template is structure-only: the
    assert s.h2d_syncs == 0          # restore itself moves no state
    assert _states_equal(job.state, snap)
    assert job.eval_on(data) == acc0 == extra["acc"]


@pytest.mark.parametrize("alloc_cls", [ECCOAllocator, UniformAllocator])
def test_allocator_decisions_identical_batched_vs_scalar(engines, alloc_cls):
    engine, scalar_engine = engines
    fast = _make_fleet(engine, jobs=3, members=2, seed0=80)
    slow = _make_fleet(scalar_engine, jobs=3, members=2, seed0=80)
    # canonicalize: job ids differ (global counter), map by position
    tf = alloc_cls().run_window(fast, window_micro=7)
    ts = alloc_cls().run_window(slow, window_micro=7)
    fmap = {j.job_id: f"g{i}" for i, j in enumerate(fast)}
    smap = {j.job_id: f"g{i}" for i, j in enumerate(slow)}
    assert [fmap[x] for x in tf.order] == [smap[x] for x in ts.order]
    assert {fmap[k]: v for k, v in tf.shares.items()} == \
        {smap[k]: v for k, v in ts.shares.items()}
    assert {fmap[k]: v for k, v in tf.gpu_time.items()} == \
        {smap[k]: v for k, v in ts.gpu_time.items()}
    assert {fmap[k]: v for k, v in tf.acc.items()} == \
        {smap[k]: v for k, v in ts.acc.items()}
