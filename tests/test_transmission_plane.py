"""FleetTransmissionPlane (§3.2 batched): decision parity with the
scalar `TransmissionController.decide` loop, `best_many` vs `best`,
warm-started GAIMD convergence, flow-row churn discipline, and the
controller-level bandwidth-cap invariant."""
import numpy as np
import pytest

from repro.core import gaimd
from repro.core import transmission as tx


def _table(levels=3):
    cfgs = [tx.SamplingConfig(rate=r, resolution=q)
            for r in (2, 4, 8) for q in (16, 32, 64)]
    t = tx.ProfileTable(cfgs)
    rng = np.random.default_rng(0)
    for lvl in range(levels):
        for i in range(len(cfgs)):
            t.record(lvl, i, float(rng.uniform(0.2, 0.9)))
    return t


def _flows(n, seed=0, *, zero_bw_every=0):
    rng = np.random.default_rng(seed)
    shares = rng.uniform(0.05, 1.0, n)
    members = rng.integers(1, 6, n)
    bw = rng.uniform(0.0, 80.0, n)
    if zero_bw_every:
        bw[::zero_bw_every] = 0.0
    levels = [int(l) for l in rng.integers(0, 4, n)]     # incl. unprofiled
    budgets = [None if i % 5 == 4 else float(b)
               for i, b in enumerate(rng.uniform(16, 600, n))]
    return shares, members, bw, levels, budgets


def _scalar_loop(table, shares, members, bw, levels, budgets, *,
                 bytes_per_token=2.0, window_seconds=10.0):
    ctrl = tx.TransmissionController(table, bytes_per_token=bytes_per_token)
    return [ctrl.decide(gpu_budget_level=levels[i], token_budget=budgets[i],
                        p_share=float(shares[i]), n_members=int(members[i]),
                        achieved_bandwidth=float(bw[i]),
                        window_seconds=window_seconds)
            for i in range(len(shares))]


# ---------------------------------------------------------------------------
# best_many == best, row for row
# ---------------------------------------------------------------------------
def test_best_many_matches_best():
    t = _table()
    rng = np.random.default_rng(1)
    levels = [int(l) for l in rng.integers(0, 5, 64)]    # 3,4 unprofiled
    budgets = [None if i % 4 == 3 else float(b)
               for i, b in enumerate(rng.uniform(8, 700, 64))]
    idx = t.best_many(levels, budgets)
    for i in range(64):
        want = t.best(levels[i], budgets[i])
        assert t.configs[idx[i]] == want, (i, levels[i], budgets[i])


def test_best_many_tie_breaks_match_scalar():
    """Profiled ties go to the largest config index (max((acc, idx)));
    fallback ties to the first sparsest (min(key=tokens))."""
    cfgs = [tx.SamplingConfig(2, 16), tx.SamplingConfig(4, 8),
            tx.SamplingConfig(1, 32)]          # all 32 tokens: full tie
    t = tx.ProfileTable(cfgs)
    for i in range(3):
        t.record(0, i, 0.5)                    # equal accuracies
    assert t.best(0) is t.configs[t.best_many([0], None)[0]]
    assert t.best_many([0], None)[0] == 2      # largest idx on acc tie
    assert t.best(9) is t.configs[t.best_many([9], None)[0]]
    assert t.best_many([9], None)[0] == 0      # first sparsest on fallback


def test_best_many_empty_table():
    t = tx.ProfileTable([])
    assert t.best(0) is None
    assert (t.best_many([0, 1, 2], None) == -1).all()


# ---------------------------------------------------------------------------
# decide_many == scalar decide loop, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,zero_every", [(1, 0), (17, 3), (64, 0)])
def test_decide_many_parity(n, zero_every):
    t = _table()
    plane = tx.FleetTransmissionPlane(t, bytes_per_token=2.0)
    shares, members, bw, levels, budgets = _flows(n, seed=n,
                                                  zero_bw_every=zero_every)
    batch = plane.decide_many(budget_levels=levels, token_budgets=budgets,
                              p_shares=shares, n_members=members,
                              achieved_bw=bw, window_seconds=10.0)
    scalar = _scalar_loop(t, shares, members, bw, levels, budgets)
    assert batch.as_decisions() == scalar


def test_decide_many_parity_empty_table():
    plane = tx.FleetTransmissionPlane(tx.ProfileTable([]),
                                      bytes_per_token=1.0)
    shares, members, bw, levels, budgets = _flows(9, seed=9)
    batch = plane.decide_many(budget_levels=levels, token_budgets=budgets,
                              p_shares=shares, n_members=members,
                              achieved_bw=bw, window_seconds=10.0)
    scalar = _scalar_loop(tx.ProfileTable([]), shares, members, bw,
                          levels, budgets, bytes_per_token=1.0)
    assert batch.as_decisions() == scalar
    assert (batch.delivered == 0).all()        # empty table sends nothing


def test_decide_many_zero_bandwidth_delivers_nothing():
    """The seed's controller forced >= 1 sequence per member even at
    zero bandwidth; the decision plane must deliver 0 tokens."""
    t = _table()
    plane = tx.FleetTransmissionPlane(t, bytes_per_token=2.0)
    batch = plane.decide_many(budget_levels=[0, 0], token_budgets=None,
                              p_shares=[0.5, 0.5], n_members=[1, 1],
                              achieved_bw=[0.0, 50.0], window_seconds=10.0)
    assert batch.delivered[0] == 0
    assert batch.delivered[1] > 0


def test_decide_many_duck_typed_table_falls_back():
    """A scripted fake table without best_many routes through the
    scalar loop (same dispatch contract as core/batching.py) — and the
    result still matches driving the scalar controller directly."""
    class FakeTable:
        configs = [tx.SamplingConfig(4, 32)]

        def best(self, level, token_budget=None):
            return self.configs[0]

    fake = FakeTable()
    assert tx.batchable_table(fake) is None
    plane = tx.FleetTransmissionPlane(fake, bytes_per_token=2.0)
    shares, members, bw, levels, budgets = _flows(7, seed=2)
    batch = plane.decide_many(budget_levels=levels, token_budgets=budgets,
                              p_shares=shares, n_members=members,
                              achieved_bw=bw, window_seconds=10.0)
    scalar = _scalar_loop(fake, shares, members, bw, levels, budgets)
    assert batch.as_decisions() == scalar
    assert tx.batchable_table(_table()) is not None

    # a table exposing best/best_many but NOT the dense per-config
    # arrays the batched path reads must also fall back, not crash
    class HalfBatchable(FakeTable):
        def best_many(self, levels, budgets=None):
            return np.zeros(len(levels), np.int64)

    half = HalfBatchable()
    assert tx.batchable_table(half) is None
    plane2 = tx.FleetTransmissionPlane(half, bytes_per_token=2.0)
    batch2 = plane2.decide_many(budget_levels=levels,
                                token_budgets=budgets, p_shares=shares,
                                n_members=members, achieved_bw=bw,
                                window_seconds=10.0)
    assert batch2.as_decisions() == \
        _scalar_loop(half, shares, members, bw, levels, budgets)


def test_controller_rejects_mismatched_resolution_table():
    """The ring pool holds fixed-width (seq_len,) rows: a profile table
    whose configs use another resolution must be rejected at
    construction, not crash ingest mid-run."""
    import dataclasses as dc
    from repro.configs import smoke_config
    from repro.core.controller import ControllerConfig, ECCOController
    from repro.core.trainer import SharedEngine
    cfg = dc.replace(smoke_config("olmo-1b"), vocab_size=64)
    engine = SharedEngine(cfg)
    bad = tx.ProfileTable([tx.SamplingConfig(4, 16)])    # seq_len is 32
    with pytest.raises(ValueError, match="resolution"):
        ECCOController(engine, [],
                       ControllerConfig(profile_table=bad), seed=0)
    ok = tx.ProfileTable([tx.SamplingConfig(4, 32)])
    ECCOController(engine, [], ControllerConfig(profile_table=ok), seed=0)


def test_decide_many_respects_bandwidth_budget():
    t = _table()
    plane = tx.FleetTransmissionPlane(t, bytes_per_token=2.0)
    shares, members, bw, levels, budgets = _flows(40, seed=5,
                                                  zero_bw_every=7)
    batch = plane.decide_many(budget_levels=levels, token_budgets=budgets,
                              p_shares=shares, n_members=members,
                              achieved_bw=bw, window_seconds=10.0)
    assert (batch.delivered <= bw * 10.0 / 2.0).all()
    assert (batch.delivered <= batch.deliverable).all()


# ---------------------------------------------------------------------------
# warm-started GAIMD + flow-row churn discipline
# ---------------------------------------------------------------------------
def test_allocate_churn_rows():
    """add/remove-flow keeps warm-start rows dense and per-flow: a
    departed camera's rate must not leak into a joiner, and surviving
    flows keep their state across the removal (FleetDriftDetector
    swap-compaction discipline)."""
    plane = tx.FleetTransmissionPlane(tx.ProfileTable([]))
    ids = [f"f{i}" for i in range(5)]
    caps = np.full(5, np.inf, np.float32)
    plane.allocate(ids, [0.2] * 5, [1] * 5, caps, shared_cap=10.0)
    states = {f: plane.rate_state(f) for f in ids}
    assert all(v > 0 for v in states.values())
    plane.remove_flow("f2")
    assert "f2" not in plane
    assert len(plane) == 4
    for f in ("f0", "f1", "f3", "f4"):       # survivors keep their state
        assert plane.rate_state(f) == states[f]
    # a new joiner starts cold, not from f2's vacated row
    plane.allocate(["f5"], [0.2], [1], np.array([np.inf], np.float32),
                   shared_cap=10.0)
    assert "f5" in plane and plane.rate_state("f5") > 0
    # and allocating a mixed old/new set gathers the right r0 rows
    r = plane.allocate(["f0", "f6", "f4"], [0.3] * 3, [1] * 3,
                       np.full(3, np.inf, np.float32), shared_cap=10.0)
    assert r.shape == (3,)


def test_allocate_warm_start_converges_faster_and_matches_cold():
    alpha = np.array([0.2, 0.4, 0.8], np.float32)
    beta = np.full(3, 0.5, np.float32)
    caps = np.full(3, np.inf, np.float32)
    cold, final, steps_cold = gaimd.simulate_warm(alpha, beta, caps, 12.0)
    warm, _, steps_warm = gaimd.simulate_warm(alpha, beta, caps, 12.0,
                                              r0=final)
    assert steps_warm <= steps_cold
    assert gaimd.proportionality_error(warm, cold) < 0.05
    # and both track the alpha/(1-beta) target
    assert gaimd.proportionality_error(warm, alpha / (1 - beta)) < 0.1


def test_simulate_warm_short_circuits():
    """A constrained fleet reaches its steady cycle well before the
    4000-step cold budget; the chunked simulation must stop there."""
    alpha = np.array([0.5, 1.0], np.float32)
    beta = np.full(2, 0.5, np.float32)
    caps = np.full(2, np.inf, np.float32)
    _, _, steps = gaimd.simulate_warm(alpha, beta, caps, 6.0)
    assert steps < 4000


def test_allocate_equal_mode_matches_equal_share_baseline():
    plane = tx.FleetTransmissionPlane(tx.ProfileTable([]))
    caps = np.full(4, np.inf, np.float32)
    r = plane.allocate([f"f{i}" for i in range(4)],
                       [0.7, 0.1, 0.1, 0.1], [1] * 4, caps,
                       shared_cap=20.0, mode="equal")
    # plain AIMD equal competition: near-equal shares despite skewed p
    assert r.max() / max(r.min(), 1e-9) < 1.3


# hypothesis property: warm steady state ~= cold steady state
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(alphas=st.lists(st.floats(0.1, 1.0), min_size=2, max_size=5),
           seed=st.integers(0, 20))
    @settings(max_examples=15, deadline=None)
    def test_warm_start_steady_state_property(alphas, seed):
        """For any share vector, a warm-started window's steady-state
        estimate matches the cold-started one within tolerance (the
        transient it skips must not bias the steady cycle)."""
        rng = np.random.default_rng(seed)
        a = np.asarray(alphas, np.float32)
        b = np.full(len(a), 0.5, np.float32)
        caps = rng.uniform(2.0, 50.0, len(a)).astype(np.float32)
        cold, final, _ = gaimd.simulate_warm(a, b, caps, shared_cap=15.0)
        warm, _, _ = gaimd.simulate_warm(a, b, caps, shared_cap=15.0,
                                         r0=final)
        assert gaimd.proportionality_error(warm, cold) < 0.08, (cold, warm)
        np.testing.assert_allclose(warm, cold, rtol=0.25, atol=0.3)
except ImportError:                                    # pragma: no cover
    pass


# ---------------------------------------------------------------------------
# controller level: the bandwidth cap is inviolable end to end
# ---------------------------------------------------------------------------
def test_controller_delivered_never_exceeds_bandwidth_budget():
    """Every grouped member's ingested tokens stay within
    achieved_bw * window_seconds / bytes_per_token, every window —
    including under the bandwidth_contention bottleneck and its
    profiled config table."""
    from repro.data.scenarios import build_scenario
    from repro.testing.trace import make_engine_for, run_scenario
    sc = build_scenario("bandwidth_contention", seed=0, regions=2,
                        streams_per_region=2, windows=3,
                        shared_bandwidth=24.0, cap_range=(2.0, 10.0))
    engine = make_engine_for(sc)
    ctl = run_scenario("ecco", sc, engine=engine, window_micro=2,
                       micro_steps=1, train_batch=8)
    checked = 0
    for wm in ctl.history:
        for sid, d in wm.delivered.items():
            budget = wm.bandwidth[sid] * ctl.cc.window_seconds \
                / ctl.cc.bytes_per_token
            assert d <= budget, (sid, d, budget)
            checked += 1
    assert checked > 0


def test_controller_large_group_members_still_deliver():
    """Regression: a group larger than the config sampling rate gives
    each member a fractional f*/n_j share (< one sequence). The
    whole-sequence floor must quantize UP to one sequence when the
    bandwidth affords it — not starve the entire group forever."""
    from repro.data.streams import make_fleet
    import dataclasses as dc
    from repro.configs import smoke_config
    from repro.core.controller import ControllerConfig, ECCOController
    from repro.core.trainer import SharedEngine
    cfg = dc.replace(smoke_config("olmo-1b"), vocab_size=64)
    engine = SharedEngine(cfg)
    bank, streams = make_fleet(vocab=64, regions=1, streams_per_region=3,
                               dim=4, switch_times=(5.0,), seed=2)
    # sample_rate 2 < group size 3: per-member share is 2/3 sequence
    cc = ControllerConfig(window_micro=2, micro_steps=1, train_batch=8,
                          p_drop=0.5, sample_rate=2,
                          shared_bandwidth=1e9)
    ctl = ECCOController(engine, streams, cc, seed=0)
    ctl.warmup()
    for _ in range(3):
        wm = ctl.run_window()
    big = [j for j in ctl.jobs if j.num_members >= 3]
    assert big, wm.groups                     # the region did group up
    for m in big[0].members:
        assert wm.delivered.get(m.stream_id, 0) >= cc.seq_len, \
            (m.stream_id, wm.delivered)


def test_controller_zero_bandwidth_member_ingests_nothing():
    """A grouped camera whose local uplink cap is ~0 must not be
    force-fed the seed's 1-sequence minimum."""
    from repro.data.streams import make_fleet
    import dataclasses as dc
    from repro.configs import smoke_config
    from repro.core.controller import ControllerConfig, ECCOController
    from repro.core.trainer import SharedEngine
    cfg = dc.replace(smoke_config("olmo-1b"), vocab_size=64)
    engine = SharedEngine(cfg)
    bank, streams = make_fleet(vocab=64, regions=1, streams_per_region=2,
                               dim=4, switch_times=(5.0,), seed=0)
    dead = streams[0].stream_id
    cc = ControllerConfig(window_micro=2, micro_steps=1, train_batch=8,
                          p_drop=0.5, shared_bandwidth=64.0,
                          local_caps={dead: 1e-6})
    ctl = ECCOController(engine, streams, cc, seed=0)
    ctl.warmup()
    for _ in range(3):
        wm = ctl.run_window()
    grouped = {m for g in wm.groups.values() for m in g}
    assert dead in grouped                    # it drifted and grouped
    assert wm.delivered.get(dead, 0) == 0     # ...but transmitted nothing
    assert wm.bandwidth[dead] < 1e-3
