"""Documentation link integrity: every intra-repo link in the repo's
markdown surface (README.md, docs/, ROADMAP.md, ...) must resolve to a
file or directory that exists, so the README/architecture pointers
can't rot as modules move. External URLs and pure anchors are skipped;
CI's docs job runs this plus the README quickstart command.
"""
from __future__ import annotations

import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

# [text](target) — excluding images' "!" prefix is irrelevant here:
# image targets must resolve too
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

# bare `path` references in the docs we also promise stay valid
_CODE_PATH = re.compile(
    r"`((?:src|docs|tests|benchmarks|examples)/[A-Za-z0-9_./-]+)`")


def _md_files():
    files = sorted(REPO.glob("*.md")) + sorted(REPO.glob("docs/*.md"))
    assert files, "no markdown files found — wrong repo root?"
    return files


def _targets(md: Path):
    """(target, base_dir) pairs: markdown links resolve relative to the
    file; backtick code paths are written repo-root-relative."""
    text = md.read_text()
    for m in _LINK.finditer(text):
        yield m.group(1), md.parent
    for m in _CODE_PATH.finditer(text):
        yield m.group(1), REPO


@pytest.mark.parametrize("md", _md_files(), ids=lambda p: str(p.relative_to(REPO)))
def test_intra_repo_links_resolve(md):
    missing = []
    for target, base in _targets(md):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (base / path).resolve().exists():
            missing.append(target)
    assert not missing, (
        f"{md.relative_to(REPO)} has dangling intra-repo links: {missing}")


def test_readme_and_architecture_exist():
    """The documentation surface the ROADMAP promises."""
    for p in ("README.md", "docs/architecture.md", "docs/scenarios.md",
              "docs/training_plane.md"):
        assert (REPO / p).is_file(), p
