"""Fleet serving plane tests: serving store churn, the validated hot
swap (EdgeSync-style gate), batched mixed-group decode parity against
dedicated per-group loops, and the controller integration (serving is
read-only w.r.t. the decision planes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.controller import ControllerConfig, ECCOController
from repro.core.trainer import SharedEngine
from repro.data.streams import make_fleet
from repro.serve.kvcache import ServeLoop
from repro.serve.plane import (FleetServePlane, ServeConfig, ServingStore,
                               _pad_size)

VOCAB = 64


@pytest.fixture(scope="module")
def engine():
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=VOCAB)
    return SharedEngine(cfg)


def _params(engine, seed):
    return engine.model.init(jax.random.PRNGKey(seed))


def _prompts(n, slen, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, VOCAB, size=slen) for _ in range(n)]


def _solo(engine, params, prompt, max_new, capacity):
    loop = ServeLoop(engine.model, params, num_slots=1, capacity=capacity,
                     max_new=max_new)
    loop.submit("solo", prompt)
    loop.run_until_drained()
    return loop.outputs["solo"]


# -- shape grid ---------------------------------------------------------------

def test_pad_size_grid():
    assert [_pad_size(n) for n in range(1, 9)] == [1, 2, 3, 4, 6, 6, 8, 8]
    assert _pad_size(9) == 12 and _pad_size(13) == 16


# -- serving store ------------------------------------------------------------

def test_store_install_overwrite_remove(engine):
    st = ServingStore()
    p0, p1, p2 = (_params(engine, s) for s in (0, 1, 2))
    for gid, p in (("g0", p0), ("g1", p1), ("g2", p2)):
        st.install(gid, p)
    assert len(st) == 3
    leaf = lambda p: np.asarray(jax.tree.leaves(p)[0])

    np.testing.assert_array_equal(leaf(st.row("g1")), leaf(p1))
    st.install("g1", p0)                      # overwrite in place
    np.testing.assert_array_equal(leaf(st.row("g1")), leaf(p0))

    st.remove("g1")                           # swap-with-last removal
    assert len(st) == 2 and "g1" not in st
    np.testing.assert_array_equal(leaf(st.row("g0")), leaf(p0))
    np.testing.assert_array_equal(leaf(st.row("g2")), leaf(p2))

    # growth past the initial registry capacity keeps rows intact
    for i in range(3, 9):
        st.install(f"g{i}", p1)
    np.testing.assert_array_equal(leaf(st.row("g2")), leaf(p2))
    assert len(st) == 8


# -- validated hot swap -------------------------------------------------------

def test_gate_seeds_ungated_then_accepts_tie(engine):
    plane = FleetServePlane(engine, ServeConfig(num_slots=4))
    p = _params(engine, 0)
    sample = np.stack(_prompts(4, 16, seed=1))
    d0 = plane.publish("g0", p, sample)
    assert d0.seeded and d0.accepted and np.isnan(d0.incumbent_acc)
    assert plane.swap_seeded == 1 and plane.staleness["g0"] == 0
    # identical candidate ties the incumbent: accepted at margin 0.0
    d1 = plane.publish("g0", p, sample)
    assert not d1.seeded and d1.accepted
    assert d1.candidate_acc == d1.incumbent_acc
    assert plane.swap_accepted == 1 and plane.staleness["g0"] == 0


def test_gate_rejection_keeps_incumbent_serving(engine):
    scfg = ServeConfig(num_slots=4, capacity=32, max_new=4,
                       gate_margin=1.1)   # > any accuracy delta: no
    plane = FleetServePlane(engine, scfg)  # candidate can ever pass
    inc, cand = _params(engine, 0), _params(engine, 1)
    sample = np.stack(_prompts(4, 16, seed=2))
    plane.publish("g0", inc, sample)      # seeding ignores the margin

    for k in (1, 2):                      # repeated misses accumulate
        d = plane.publish("g0", cand, sample)
        assert not d.accepted and not d.seeded
        assert plane.swap_rejected == k and plane.staleness["g0"] == k

    # the incumbent, not the rejected candidate, answers queries
    prompt = _prompts(1, 8, seed=3)[0]
    plane.submit("q", prompt, group="g0")
    plane.run_until_drained()
    assert plane.outputs["q"] == _solo(engine, inc, prompt, 4, 32)
    rep = plane.window_report()
    assert rep["swap_rejected"] == 2 and rep["staleness"] == {"g0": 2}
    assert [g["accepted"] for g in rep["gate"]] == [True, False, False]


def test_gate_accepts_when_candidate_clears_margin(engine):
    plane = FleetServePlane(engine, ServeConfig(num_slots=4, capacity=32,
                                                max_new=4,
                                                gate_margin=-1.1))
    inc, cand = _params(engine, 0), _params(engine, 1)
    sample = np.stack(_prompts(4, 16, seed=4))
    plane.publish("g0", inc, sample)
    d = plane.publish("g0", cand, sample)  # margin -1.1: always clears
    assert d.accepted and plane.swap_accepted == 1
    prompt = _prompts(1, 8, seed=5)[0]
    plane.submit("q", prompt, group="g0")
    plane.run_until_drained()
    assert plane.outputs["q"] == _solo(engine, cand, prompt, 4, 32)


# -- batched fleet decode -----------------------------------------------------

def test_fleet_parity_mixed_groups_with_churn(engine):
    """More queries than slots across two groups with DIFFERENT params:
    the shared-tick vmapped decode plus slot recycling must reproduce
    each dedicated per-group loop bit-for-bit."""
    scfg = ServeConfig(num_slots=3, capacity=32, max_new=5, prompt_len=8)
    plane = FleetServePlane(engine, scfg)
    pa, pb = _params(engine, 0), _params(engine, 1)
    sample = np.stack(_prompts(2, 16, seed=6))
    plane.publish("ga", pa, sample)
    plane.publish("gb", pb, sample)
    want = {}
    for q in range(4):
        for gid, p in (("ga", pa), ("gb", pb)):
            prompt = _prompts(1, 8, seed=10 + 2 * q + (gid == "gb"))[0]
            plane.enqueue(f"{gid}/q{q}", gid, prompt)
            want[f"{gid}/q{q}"] = _solo(engine, p, prompt, 5, 32)
    plane.pump()
    got = plane.drain()
    assert got == want
    rep = plane.window_report()
    assert rep["queries"] == 8 and rep["dropped"] == 0
    assert rep["ticks"] > 0 and rep["p99_tick_ms"] > 0.0


def test_enqueue_validates_capacity_and_unknown_group_drops(engine):
    scfg = ServeConfig(num_slots=2, capacity=16, max_new=4)
    plane = FleetServePlane(engine, scfg)
    plane.publish("g0", _params(engine, 0),
                  np.stack(_prompts(2, 16, seed=7)))
    with pytest.raises(ValueError, match="does not fit"):
        plane.enqueue("big", "g0", _prompts(1, 14, seed=8)[0])
    plane.enqueue("ghost", "dead-group", _prompts(1, 8, seed=9)[0])
    plane.pump()
    assert plane.window_report()["dropped"] == 1
    assert "ghost" not in plane.outputs


def test_drop_group_retires_inflight_and_queued(engine):
    scfg = ServeConfig(num_slots=4, capacity=32, max_new=6)
    plane = FleetServePlane(engine, scfg)
    plane.publish("g0", _params(engine, 0),
                  np.stack(_prompts(2, 16, seed=11)))
    plane.submit("live", _prompts(1, 8, seed=12)[0], group="g0")
    plane.enqueue("queued", "g0", _prompts(1, 8, seed=13)[0])
    assert plane.mgr.active()
    plane.drop_group("g0")
    assert not plane.mgr.active() and not plane._queue
    assert len(plane.store) == 0 and plane._new_tokens == {}
    assert plane.pump() == 0


# -- controller integration ---------------------------------------------------

def _mini_fleet(seed=0):
    _, streams = make_fleet(regions=2, streams_per_region=2,
                            switch_times=(10.0,), seed=seed)
    return streams


def _mini_cc(**over):
    return ControllerConfig(window_micro=2, micro_steps=2, train_batch=4,
                            sample_rate=4, eval_batch=8, p_drop=0.0,
                            **over)


def _decisions(history):
    """Decision-plane surface with job ids canonicalized by first
    appearance (raw ids come from a process-global counter)."""
    name = {}

    def canon(jid):
        return name.setdefault(jid, f"g{len(name)}")

    out = []
    for wm in history:
        out.append({
            "t": wm.t,
            "groups": {canon(j): sorted(m) for j, m in wm.groups.items()},
            "shares": {canon(j): round(v, 6)
                       for j, v in wm.shares.items()},
            "acc": {s: None if np.isnan(v) else round(v, 6)
                    for s, v in wm.per_stream_acc.items()},
        })
    return out


def test_controller_serving_is_readonly(engine):
    """Enabling the serving plane must not move a single decision:
    same grouping, same shares, same accuracies, window for window."""
    off = ECCOController(engine, _mini_fleet(), _mini_cc(), seed=0)
    off.run(3)
    scfg = ServeConfig(num_slots=8, capacity=32, max_new=4, prompt_len=8)
    on = ECCOController(engine, _mini_fleet(), _mini_cc(serve=scfg),
                        seed=0)
    on.run(3)
    assert _decisions(off.history) == _decisions(on.history)
    assert all(wm.serve is None for wm in off.history)
    # ...and the plane actually served once groups formed (t=20)
    assert on.history[2].serve["queries"] > 0


def test_controller_serve_window_reports_and_gate(engine):
    """Window reports carry qps/latency and the swap audit: groups are
    seeded ungated the window they form; with an impossible margin
    every later publish is rejected and staleness grows while the
    incumbent keeps serving."""
    scfg = ServeConfig(num_slots=8, capacity=32, max_new=4, prompt_len=8,
                       gate_margin=1.1)
    ctl = ECCOController(engine, _mini_fleet(), _mini_cc(serve=scfg),
                         seed=0)
    ctl.run(4)
    h = ctl.history
    assert h[0].serve["queries"] == 0          # no groups yet: idle plane
    for wm in h[1:]:                           # every serving window:
        s = wm.serve
        assert s["groups"] == len(wm.groups)   # store mirrors live groups
        assert set(s["staleness"]) == set(wm.groups)
        assert s["queries"] == sum(len(m) for m in wm.groups.values())
        assert s["tokens"] > 0 and s["qps"] > 0 and s["p99_tick_ms"] > 0
        # a group is seeded ungated the window it appears...
        fresh = [g for g in s["gate"] if g["seeded"]]
        assert all(g["accepted"] for g in fresh)
        # ...and with an impossible margin every later publish misses
        assert all(not g["accepted"] for g in s["gate"] if not g["seeded"])
    assert h[-1].serve["swap_accepted"] == 0
    # final window: groups are stable, so every candidate hits the gate,
    # misses, and staleness ticks up while the incumbent keeps serving
    last = h[-1].serve
    assert last["swap_rejected"] == len(h[-1].groups) and last["groups"] > 0
    assert all(v == 1 for v in last["staleness"].values())
