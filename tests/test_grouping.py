"""Algorithm 2 (dynamic grouping) tests: metadata pre-filter,
performance check, periodic eviction + requeue."""
import pytest

from repro.core.grouping import Grouper, Request


class FakeJob:
    _n = 0

    def __init__(self, req, acc_on=None):
        FakeJob._n += 1
        self.job_id = f"fj{FakeJob._n}"
        self.members = [req]
        self.acc_on = acc_on or {}

    def eval_on(self, samples):
        return self.acc_on.get(id(samples), self.acc_on.get("*", 0.5))

    def add_member(self, req):
        self.members.append(req)

    def remove_member(self, sid):
        self.members = [m for m in self.members if m.stream_id != sid]


def _req(sid, t=0.0, loc=(0, 0), acc=0.2, sub=None):
    return Request(stream_id=sid, t=t, loc=loc, subsamples=sub or object(),
                   acc=acc)


def _grouper(**kw):
    kw.setdefault("eps_t", 10.0)
    kw.setdefault("delta_loc", 50.0)
    kw.setdefault("new_job_fn", lambda r: FakeJob(r, {"*": 0.9}))
    return Grouper(**kw)


def test_new_request_creates_job_when_no_candidates():
    g = _grouper()
    jobs = []
    g.group_request(jobs, _req("s1"))
    assert len(jobs) == 1
    assert jobs[0].members[0].stream_id == "s1"


def test_metadata_prefilter_blocks_far_requests():
    g = _grouper()
    jobs = []
    g.group_request(jobs, _req("s1", t=0.0, loc=(0, 0)))
    # close in time, far in space -> new job
    g.group_request(jobs, _req("s2", t=1.0, loc=(1000, 0)))
    assert len(jobs) == 2
    # far in time, close in space -> new job
    g.group_request(jobs, _req("s3", t=100.0, loc=(0, 1)))
    assert len(jobs) == 3


def test_performance_check_gates_admission():
    """Metadata matches but the job model underperforms the request's own
    accuracy -> new job (paper line 6)."""
    sub = object()
    g = Grouper(eps_t=10, delta_loc=50,
                new_job_fn=lambda r: FakeJob(r, {"*": 0.05}))
    jobs = []
    g.group_request(jobs, _req("s1", acc=0.0, sub=sub))
    # job evals at 0.05 on anything; new request has own acc 0.5 > 0.05
    g.group_request(jobs, _req("s2", acc=0.5, sub=sub))
    assert len(jobs) == 2


def test_best_candidate_wins():
    sub = object()
    g = _grouper()
    jobs = [FakeJob(_req("a"), {"*": 0.4}), FakeJob(_req("b"), {"*": 0.8})]
    r = _req("s2", acc=0.1, sub=sub)
    g.group_request(jobs, r)
    assert any(m.stream_id == "s2" for m in jobs[1].members)
    assert all(m.stream_id != "s2" for m in jobs[0].members)


def test_metadata_must_match_every_member():
    """Alg. 2 line 4 quantifies over ALL members of a job."""
    g = _grouper()
    jobs = []
    g.group_request(jobs, _req("s1", t=0.0, loc=(0, 0)))
    jobs[0].acc_on = {"*": 0.9}
    g.group_request(jobs, _req("s2", t=9.0, loc=(0, 0)))   # joins
    assert len(jobs) == 1
    # s3 matches s2 (t=15 within 10 of 9) but not s1 (t=0) -> new job
    g.group_request(jobs, _req("s3", t=15.0, loc=(0, 0)))
    assert len(jobs) == 2


def test_eviction_on_accuracy_drop_and_requeue():
    g = _grouper(p_drop=0.1)
    jobs = []
    g.group_request(jobs, _req("s1"))
    job = jobs[0]
    job.add_member(_req("s2"))
    # first window: establish acc_prev = 0.9 for both
    job.acc_on = {"*": 0.9}
    g.update_grouping(jobs, now=10.0)
    assert all(m.acc_prev == 0.9 for m in job.members)
    # second window: acc drops 50% -> both evicted, requeued into new job
    job.acc_on = {"*": 0.45}
    g.update_grouping(jobs, now=20.0)
    evict_events = [e for e in g.events if e["kind"] == "evict"]
    assert len(evict_events) == 2
    # evicted members were re-grouped (possibly together in a fresh job)
    assert all(j.members for j in jobs)
    total = sum(len(j.members) for j in jobs)
    assert total == 2


def test_no_eviction_within_threshold():
    g = _grouper(p_drop=0.5)
    jobs = []
    g.group_request(jobs, _req("s1"))
    jobs[0].acc_on = {"*": 0.8}
    g.update_grouping(jobs, now=1.0)
    jobs[0].acc_on = {"*": 0.6}        # -25% > -50% threshold: stays
    g.update_grouping(jobs, now=2.0)
    assert len(jobs) == 1 and len(jobs[0].members) == 1
    assert not [e for e in g.events if e["kind"] == "evict"]


def test_empty_jobs_are_dropped():
    g = _grouper(p_drop=0.01)
    jobs = []
    g.group_request(jobs, _req("s1"))
    jobs[0].acc_on = {"*": 0.9}
    g.update_grouping(jobs, now=1.0)
    jobs[0].acc_on = {"*": 0.1}
    g.update_grouping(jobs, now=2.0)
    # s1 evicted from original job -> original dropped; requeued to fresh
    assert all(j.members for j in jobs)
