"""Algorithm 2 (dynamic grouping) tests: metadata pre-filter,
performance check, periodic eviction + requeue, and equivalence of the
SignatureIndex shortlist path with the seed's pure-Python scan."""
import numpy as np
import pytest

from repro.core.grouping import Grouper, Request
from repro.core.signature_index import SignatureIndex


class FakeJob:
    _n = 0

    def __init__(self, req, acc_on=None):
        FakeJob._n += 1
        self.job_id = f"fj{FakeJob._n}"
        self.members = [req]
        self.acc_on = acc_on or {}

    def eval_on(self, samples):
        return self.acc_on.get(id(samples), self.acc_on.get("*", 0.5))

    def add_member(self, req):
        self.members.append(req)

    def remove_member(self, sid):
        self.members = [m for m in self.members if m.stream_id != sid]


def _req(sid, t=0.0, loc=(0, 0), acc=0.2, sub=None):
    return Request(stream_id=sid, t=t, loc=loc, subsamples=sub or object(),
                   acc=acc)


def _grouper(**kw):
    kw.setdefault("eps_t", 10.0)
    kw.setdefault("delta_loc", 50.0)
    kw.setdefault("new_job_fn", lambda r: FakeJob(r, {"*": 0.9}))
    return Grouper(**kw)


def test_new_request_creates_job_when_no_candidates():
    g = _grouper()
    jobs = []
    g.group_request(jobs, _req("s1"))
    assert len(jobs) == 1
    assert jobs[0].members[0].stream_id == "s1"


def test_metadata_prefilter_blocks_far_requests():
    g = _grouper()
    jobs = []
    g.group_request(jobs, _req("s1", t=0.0, loc=(0, 0)))
    # close in time, far in space -> new job
    g.group_request(jobs, _req("s2", t=1.0, loc=(1000, 0)))
    assert len(jobs) == 2
    # far in time, close in space -> new job
    g.group_request(jobs, _req("s3", t=100.0, loc=(0, 1)))
    assert len(jobs) == 3


def test_performance_check_gates_admission():
    """Metadata matches but the job model underperforms the request's own
    accuracy -> new job (paper line 6)."""
    sub = object()
    g = Grouper(eps_t=10, delta_loc=50,
                new_job_fn=lambda r: FakeJob(r, {"*": 0.05}))
    jobs = []
    g.group_request(jobs, _req("s1", acc=0.0, sub=sub))
    # job evals at 0.05 on anything; new request has own acc 0.5 > 0.05
    g.group_request(jobs, _req("s2", acc=0.5, sub=sub))
    assert len(jobs) == 2


def test_best_candidate_wins():
    sub = object()
    g = _grouper()
    jobs = [FakeJob(_req("a"), {"*": 0.4}), FakeJob(_req("b"), {"*": 0.8})]
    r = _req("s2", acc=0.1, sub=sub)
    g.group_request(jobs, r)
    assert any(m.stream_id == "s2" for m in jobs[1].members)
    assert all(m.stream_id != "s2" for m in jobs[0].members)


def test_metadata_must_match_every_member():
    """Alg. 2 line 4 quantifies over ALL members of a job."""
    g = _grouper()
    jobs = []
    g.group_request(jobs, _req("s1", t=0.0, loc=(0, 0)))
    jobs[0].acc_on = {"*": 0.9}
    g.group_request(jobs, _req("s2", t=9.0, loc=(0, 0)))   # joins
    assert len(jobs) == 1
    # s3 matches s2 (t=15 within 10 of 9) but not s1 (t=0) -> new job
    g.group_request(jobs, _req("s3", t=15.0, loc=(0, 0)))
    assert len(jobs) == 2


def test_eviction_on_accuracy_drop_and_requeue():
    g = _grouper(p_drop=0.1)
    jobs = []
    g.group_request(jobs, _req("s1"))
    job = jobs[0]
    job.add_member(_req("s2"))
    # first window: establish acc_prev = 0.9 for both
    job.acc_on = {"*": 0.9}
    g.update_grouping(jobs, now=10.0)
    assert all(m.acc_prev == 0.9 for m in job.members)
    # second window: acc drops 50% -> both evicted, requeued into new job
    job.acc_on = {"*": 0.45}
    g.update_grouping(jobs, now=20.0)
    evict_events = [e for e in g.events if e["kind"] == "evict"]
    assert len(evict_events) == 2
    # evicted members were re-grouped (possibly together in a fresh job)
    assert all(j.members for j in jobs)
    total = sum(len(j.members) for j in jobs)
    assert total == 2


def test_no_eviction_within_threshold():
    g = _grouper(p_drop=0.5)
    jobs = []
    g.group_request(jobs, _req("s1"))
    jobs[0].acc_on = {"*": 0.8}
    g.update_grouping(jobs, now=1.0)
    jobs[0].acc_on = {"*": 0.6}        # -25% > -50% threshold: stays
    g.update_grouping(jobs, now=2.0)
    assert len(jobs) == 1 and len(jobs[0].members) == 1
    assert not [e for e in g.events if e["kind"] == "evict"]


def test_empty_jobs_are_dropped():
    g = _grouper(p_drop=0.01)
    jobs = []
    g.group_request(jobs, _req("s1"))
    jobs[0].acc_on = {"*": 0.9}
    g.update_grouping(jobs, now=1.0)
    jobs[0].acc_on = {"*": 0.1}
    g.update_grouping(jobs, now=2.0)
    # s1 evicted from original job -> original dropped; requeued to fresh
    assert all(j.members for j in jobs)


# ---------------------------------------------------------------------------
# SignatureIndex shortlist path
# ---------------------------------------------------------------------------
class DetJob:
    """Deterministic eval_on keyed on (job, samples) for replayable
    grouping decisions across grouper instances."""

    def __init__(self, req, counter):
        self.job_id = f"dj{counter[0]}"
        counter[0] += 1
        self.members = [req]

    def eval_on(self, samples):
        seed = abs(hash((self.job_id, samples))) % (2 ** 31)
        return float(np.random.default_rng(seed).random())

    def add_member(self, req):
        self.members.append(req)

    def remove_member(self, sid):
        self.members = [m for m in self.members if m.stream_id != sid]


def _run_scenario(n_requests=60, **grouper_kwargs):
    """Clustered random requests with periodic update_grouping; returns
    (partition of streams into jobs, event trace)."""
    rng = np.random.default_rng(7)
    counter = [0]
    g = Grouper(eps_t=5.0, delta_loc=30.0, p_drop=0.05,
                new_job_fn=lambda r: DetJob(r, counter), **grouper_kwargs)
    jobs = []
    for i in range(n_requests):
        req = Request(
            stream_id=f"s{i}", t=float(rng.integers(0, 20)),
            loc=(float(rng.integers(0, 4) * 25),
                 float(rng.integers(0, 2) * 25)),
            subsamples=i, acc=float(rng.random() * 0.5),
            sig=rng.random(64).astype(np.float32))
        g.group_request(jobs, req)
        if i % 10 == 9:
            g.update_grouping(jobs, now=req.t + 1.0)
    partition = sorted(sorted(m.stream_id for m in j.members) for j in jobs)
    events = [(e["kind"], e["stream"]) for e in g.events]
    return partition, events


def test_index_shortlist_reproduces_python_decisions():
    """For k >= |jobs| (and k == 0, i.e. uncapped) the signature
    shortlist path must make bit-identical Alg. 2 decisions, through
    joins, new jobs, evictions and requeues."""
    want = _run_scenario()
    for k in (0, 10_000):
        got = _run_scenario(index=SignatureIndex(buckets=64),
                            shortlist_k=k)
        assert got == want, f"shortlist_k={k} diverged from python scan"


def test_small_shortlist_is_valid_grouping():
    """k=1 may legitimately differ from the exhaustive scan but must
    still produce a full partition of the streams."""
    partition, _ = _run_scenario(index=SignatureIndex(buckets=64),
                                 shortlist_k=1)
    streams = sorted(s for group in partition for s in group)
    assert streams == sorted(f"s{i}" for i in range(60))


def test_shortlist_caps_eval_on_calls():
    """The whole point: eval_on runs on at most k jobs per request."""
    calls = []

    class CountingJob(DetJob):
        def eval_on(self, samples):
            calls.append(self.job_id)
            return 0.0          # never beats the request -> all new jobs

    counter = [0]
    g = Grouper(eps_t=1e9, delta_loc=1e9, p_drop=0.5,
                new_job_fn=lambda r: CountingJob(r, counter),
                index=SignatureIndex(buckets=8), shortlist_k=3)
    jobs = []
    rng = np.random.default_rng(0)
    for i in range(12):
        req = Request(stream_id=f"s{i}", t=0.0, loc=(0, 0), subsamples=i,
                      acc=1.0, sig=rng.random(8).astype(np.float32))
        calls.clear()
        g.group_request(jobs, req)
        # every prior job passes the (infinite) prefilter, yet at most
        # k=3 paid the model evaluation
        assert len(calls) <= 3


def test_index_tracks_membership_through_eviction():
    idx = SignatureIndex(buckets=4)
    g = _grouper(index=idx)
    jobs = []
    g.group_request(jobs, _req("s1"))
    g.group_request(jobs, _req("s2", loc=(1000, 0)))   # too far: own job
    job_of = {s: k for s, k in
              ((m.stream_id, idx._job[idx._row[m.stream_id]])
               for j in jobs for m in j.members)}
    assert job_of["s1"] >= 0 and job_of["s2"] >= 0
    # force eviction of everyone, then requeue reassigns
    jobs[0].acc_on = {"*": 0.9}
    jobs[1].acc_on = {"*": 0.9}
    g.update_grouping(jobs, now=1.0)
    for j in jobs:
        j.acc_on = {"*": 0.0}
    g.update_grouping(jobs, now=2.0)
    for j in jobs:
        for m in j.members:
            assert idx._job[idx._row[m.stream_id]] == \
                idx.job_key(j.job_id)


def test_no_stale_lookup_after_drop_and_requeue_append():
    """Regression: update_grouping dropping an empty job and a
    no-candidate requeue appending a fresh one leaves `jobs` with the
    same identity and length but different contents. A key->position
    map cached on (identity, len) survived that churn and joined the
    wrong job; the lookup must reflect the current list."""
    idx = SignatureIndex(buckets=4)
    g = _grouper(index=idx)
    jobs = []
    g.group_request(jobs, _req("s1", t=0.0, loc=(0, 0)))
    g.group_request(jobs, _req("s2", t=0.0, loc=(1000, 0)))
    job_near, job_far = jobs
    # a join (len unchanged) builds any key->position lookup state
    g.group_request(jobs, _req("s_warm", t=0.0, loc=(1000, 0)))
    assert len(jobs) == 2 and len(job_far.members) == 2
    # establish acc_prev, then crash job_near's accuracy: s1 is evicted,
    # job_near dropped, and the requeue finds no candidates (job_far is
    # 1000 away, job_near excluded) so a fresh job is appended -- same
    # list object, same length, different contents
    g.update_grouping(jobs, now=1.0)
    job_near.acc_on = {"*": 0.1}
    g.update_grouping(jobs, now=2.0)
    assert len(jobs) == 2 and jobs[0] is job_far
    assert [m.stream_id for m in jobs[1].members] == ["s1"]
    # a request next to job_far must join job_far, not s1's fresh job
    g.group_request(jobs, _req("s4", t=2.0, loc=(1000, 0)))
    assert any(m.stream_id == "s4" for m in job_far.members)
    assert all(m.stream_id != "s4" for m in jobs[1].members)


def test_index_capacity_growth():
    idx = SignatureIndex(buckets=4, capacity=8)
    for i in range(50):
        idx.upsert(f"s{i}", float(i), (0.0, 0.0))
        idx.assign(f"s{i}", f"j{i % 5}")
    assert len(idx) == 50
    assert idx.capacity >= 50
    got = idx.candidate_jobs(25.0, (0.0, 0.0), eps_t=100.0, delta_loc=1.0)
    assert got == [idx.job_key(f"j{n}") for n in range(5)]
    # tight time window: only jobs whose EVERY member is within eps pass
    got = idx.candidate_jobs(0.0, (0.0, 0.0), eps_t=1.0, delta_loc=1.0)
    assert got == []


def test_refresh_sig_preserves_assignment_and_reranks():
    """refresh_sig must update a member's signature in place (upsert
    would clear the job assignment) so the top-k shortlist tracks the
    member's CURRENT distribution."""
    idx = SignatureIndex(buckets=4)
    idx.upsert("a", 0.0, (0, 0), [1, 0, 0, 0])
    idx.assign("a", "jA")
    idx.upsert("b", 0.0, (0, 0), [0, 0, 1, 1])
    idx.assign("b", "jB")
    kw = dict(eps_t=10.0, delta_loc=10.0)
    # request signature closest to b's -> k=1 shortlists jB
    assert idx.candidate_jobs(0.0, (0, 0), sig=[0, 0, 0, 1], k=1,
                              **kw) == [idx.job_key("jB")]
    # stream a's distribution moves onto the request's: the refresh
    # keeps its assignment and flips the shortlist to jA
    idx.refresh_sig("a", [0, 0, 0, 1])
    assert idx._job[idx._row["a"]] == idx.job_key("jA")
    assert idx.candidate_jobs(0.0, (0, 0), sig=[0, 0, 0, 1], k=1,
                              **kw) == [idx.job_key("jA")]
    # unknown streams are a no-op, wrong bucket count still raises
    idx.refresh_sig("ghost", [0, 0, 0, 1])
    with pytest.raises(ValueError):
        idx.refresh_sig("a", [1, 2, 3])


def test_index_rebuild_matches_python_on_direct_jobs():
    """Jobs built outside the Grouper (like the scenarios above) work on
    the index path after rebuild(): best candidate still wins."""
    sub = object()
    jobs = [FakeJob(_req("a"), {"*": 0.4}), FakeJob(_req("b"), {"*": 0.8})]
    idx = SignatureIndex(buckets=4)
    idx.rebuild(jobs)
    g = _grouper(index=idx, shortlist_k=100)
    r = _req("s2", acc=0.1, sub=sub)
    g.group_request(jobs, r)
    assert any(m.stream_id == "s2" for m in jobs[1].members)
    assert all(m.stream_id != "s2" for m in jobs[0].members)
