"""Continuous serving example: retrain-then-serve.

A group model is retrained on a drifted stream, then serves batched
generation requests through the slot-pool KV cache (repro.serve.kvcache)
— the "updated model back to the devices" half of the ECCO loop, plus
server-side shadow serving.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import smoke_config
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob, SharedEngine
from repro.data.streams import DomainBank
from repro.serve.kvcache import ServeLoop


def main():
    vocab = 64
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=vocab)
    engine = SharedEngine(cfg)
    bank = DomainBank(vocab, 4, dim=4, seed=0)
    rng = np.random.default_rng(0)

    # 1. retrain a group model on the drifted domain
    dom = 1
    toks = bank.sample(dom, rng, 8, 32)
    job = RetrainJob(engine, Request("cam0", 0.0, (0, 0), toks, 0.0,
                                     train_data=toks),
                     micro_steps=4, batch=16, seed=0)
    print("retraining group model on drifted domain...")
    for w in range(8):
        job.ingest(bank.sample(dom, rng, 8, 32))
        job.train_micro()
    acc = engine.accuracy(job.state["params"],
                          bank.sample(dom, rng, 16, 32))
    print(f"retrained accuracy: {acc:.3f}")

    # 2. serve batched requests with the retrained model
    loop = ServeLoop(engine.model, job.state["params"], num_slots=4,
                     capacity=64, max_new=12)
    prompts = {f"req{i}": bank.sample(dom, rng, 1, 16)[0]
               for i in range(8)}
    pending = list(prompts.items())
    t0 = time.time()
    ticks = 0
    while pending or loop.mgr.active():
        while pending and loop.mgr.free_slots():
            rid, prompt = pending.pop(0)
            loop.submit(rid, prompt)
        loop.tick()
        ticks += 1
    dt = time.time() - t0
    total = sum(len(v) for v in loop.outputs.values())
    print(f"served {len(loop.outputs)} requests / {total} tokens in "
          f"{dt:.2f}s ({total / dt:.0f} tok/s, {ticks} ticks, "
          f"4-slot pool)")

    # 3. sanity: generated continuations follow the drifted bigram
    hit = n = 0
    for rid, out in loop.outputs.items():
        prev = int(prompts[rid][-1])
        for t in out:
            hit += bank.P[dom][prev].argmax() == t
            prev = int(t)
            n += 1
    print(f"generated tokens matching the domain's argmax transition: "
          f"{hit / n:.2f} (drifted-domain fidelity)")


if __name__ == "__main__":
    main()
