"""Run any fleet scenario under any framework and dump its trace.

    PYTHONPATH=src python examples/run_scenario.py camera_churn ecco
    PYTHONPATH=src python examples/run_scenario.py flash_crowd recl \
        --windows 6 --out /tmp/trace.json

The scenario library (repro.data.scenarios) covers drift waves, diurnal
recurrence, camera churn, flash crowds, and bandwidth contention; the
trace JSON is the same format the golden-trace regression tests pin
(docs/scenarios.md).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.baselines import FRAMEWORKS
from repro.data.scenarios import SCENARIOS, build_scenario
from repro.testing import trace as T


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("scenario", choices=sorted(SCENARIOS))
    ap.add_argument("framework", nargs="?", default="ecco",
                    choices=sorted(FRAMEWORKS))
    ap.add_argument("--windows", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", help="write the trace JSON here")
    args = ap.parse_args()

    sc = build_scenario(args.scenario, seed=args.seed)
    caps = f", {len(sc.local_caps)} uplink caps" if sc.local_caps else ""
    churn = f", {len(sc.churn)} churn events" if sc.churn else ""
    print(f"scenario {sc.name}: {len(sc.streams)} streams, "
          f"{sc.windows} windows{caps}{churn}")

    trace = {}
    ctl = T.run_scenario(args.framework, sc, windows=args.windows,
                         trace=trace, window_micro=4, micro_steps=2,
                         train_batch=8, p_drop=0.5)
    for w in trace["windows"]:
        accs = {k: v for k, v in w["acc"].items() if v is not None}
        mean = sum(accs.values()) / len(accs) if accs else float("nan")
        print(f"[t={w['t']:5.1f}] groups={w['groups']} "
              f"events={len(w['events'])} mean_acc={mean:.3f}")
    print(f"\nfinal mean accuracy ({args.framework}): "
          f"{ctl.mean_accuracy(last_k=2):.3f}")
    if args.out:
        T.save_trace(trace, args.out)
        print(f"trace written to {args.out}")


if __name__ == "__main__":
    main()
