"""End-to-end driver: train a ~100M-parameter student with group
retraining for a few hundred steps, with teacher distillation,
checkpointing, and a failure/recovery drill.

By default builds a ~100M-class config (a scaled-down olmo: 8 layers,
d_model 512) and runs 200 optimizer steps of group retraining on CPU —
expect ~10-20 min. `--tiny` drops to the smoke config for a fast pass
(CI uses that).

    PYTHONPATH=src python examples/train_group_retraining.py --tiny
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def build_100m():
    from repro.configs.base import DENSE, ModelConfig
    return ModelConfig(
        name="olmo-100m", family=DENSE, num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=8192,
        norm="nonparam_ln", act="swiglu", rope_theta=10000.0)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-scale model (fast CI pass)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/ecco_e2e_ckpt")
    args = ap.parse_args()

    from repro.configs import smoke_config
    from repro.configs.base import TrainConfig
    from repro.core.grouping import Request
    from repro.core.trainer import RetrainJob, SharedEngine
    from repro.data.streams import DomainBank
    from repro.distributed.checkpoint import (AsyncCheckpointer,
                                              latest_step, restore_job)

    if args.tiny:
        cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=256)
        steps = min(args.steps, 60)
    else:
        cfg = build_100m()
        steps = args.steps
    vocab = min(cfg.vocab_size, 256)
    cfg = dataclasses.replace(cfg, vocab_size=vocab)

    tcfg = TrainConfig(learning_rate=1e-3, b2=0.999, weight_decay=0.0,
                       warmup_steps=10, total_steps=max(steps, 100),
                       remat="none")
    engine = SharedEngine(cfg, tcfg)
    n_params = engine.model.num_params()
    print(f"model: {cfg.name}  params={n_params:,}")

    # three correlated streams form one group retraining job
    bank = DomainBank(vocab, 4, dim=4, seed=0)
    rng = np.random.default_rng(0)
    dom = 0

    def req(sid):
        toks = bank.sample(dom, rng, 8, 32)
        return Request(stream_id=sid, t=0.0, loc=(0, 0),
                       subsamples=toks, acc=0.0, train_data=toks)

    micro_steps = 5
    job = RetrainJob(engine, req("cam0"), micro_steps=micro_steps,
                     batch=16, seed=0)
    job.add_member(req("cam1"))
    job.add_member(req("cam2"))

    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=2)
    ev = bank.sample(dom, rng, 32, 32)
    t0 = time.time()
    done = 0
    micro = 0
    while done < steps:
        # fresh correlated inflow from all three members each "window"
        for _ in range(3):
            job.ingest(bank.sample(dom, rng, 4, 32))
        job.train_micro()
        micro += 1
        done += micro_steps
        if micro % 5 == 0:
            acc = engine.accuracy(job.state["params"], ev)
            dt = time.time() - t0
            tok_s = done * 16 * 32 / dt
            print(f"step {done:4d}  acc={acc:.3f}  "
                  f"({dt:5.1f}s, {tok_s:,.0f} tok/s)")
            ckpt.save_async(done, job.state, extra={"acc": float(acc)})

    # failure drill: clobber the job state, restore from checkpoint
    # (restore_job writes through the JobBank residency cache — the
    # device row is re-flushed by the next train/eval call)
    ckpt.wait()
    step = latest_step(args.ckpt_dir)
    print(f"\nsimulating failure; restoring from checkpoint step {step}")
    job.state = jax.tree.map(jnp.zeros_like, job.state)
    extra = restore_job(args.ckpt_dir, step, job)
    acc = engine.accuracy(job.state["params"], ev)
    print(f"restored: acc={acc:.3f} (checkpointed acc={extra['acc']:.3f})")
    assert abs(acc - extra["acc"]) < 1e-3, "restore mismatch"
    print("recovery verified ✓")


if __name__ == "__main__":
    main()
