"""Quickstart: the ECCO loop in ~60 seconds on CPU.

Builds a 4-stream fleet with correlated drift, runs the full ECCO
control loop (drift detection -> grouping -> Alg.1 GPU allocation ->
GAIMD transmission -> group retraining) for a few windows, and prints
the grouping + accuracy trace.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import smoke_config
from repro.core.controller import ControllerConfig, ECCOController
from repro.core.trainer import SharedEngine
from repro.data.streams import make_fleet


def main():
    # 1. a lightweight student family (reduced olmo for CPU)
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=64)
    engine = SharedEngine(cfg)
    print(f"student: {cfg.name} ({engine.model.num_params():,} params)")

    # 2. a fleet: 2 regions x 2 streams, drift hits each region at t=10
    bank, streams = make_fleet(regions=2, streams_per_region=2,
                               switch_times=(10.0,), seed=0)
    print(f"fleet: {[s.stream_id for s in streams]}")

    # 3. the ECCO controller
    cc = ControllerConfig(window_micro=8, micro_steps=4, train_batch=16,
                          p_drop=0.5, shared_bandwidth=1e9)
    ctl = ECCOController(engine, streams, cc, seed=0)
    ctl.warmup()

    # 4. run retraining windows
    for w in range(6):
        wm = ctl.run_window()
        accs = {k: round(v, 2) for k, v in wm.per_stream_acc.items()}
        print(f"[window {w}] groups={wm.groups} acc={accs}")

    print(f"\nfinal mean accuracy: {ctl.mean_accuracy(last_k=2):.3f}")
    print(f"grouping events: {ctl.grouper.events}")


if __name__ == "__main__":
    main()
