"""Paper Fig. 10: ECCO's GPU allocator vs RECL's on a 2-group workload
(3 correlated streams + 1 singleton). RECL's total-accuracy objective
starves the singleton; ECCO's fairness term keeps per-group accuracy
near-synchronous. Reports the allocation trace and the max accuracy gap.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, make_engine
from repro.core.allocator import ECCOAllocator, RECLAllocator
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob
from repro.data.streams import DomainBank

VOCAB = 64
WINDOWS = 4
MICRO = 8
MICRO_STEPS = 8


def _mk_jobs(engine, bank, rng):
    """Both groups see the SAME domain (equal task difficulty) so the
    only asymmetry is group size — the paper's §3.1 mechanism isolated:
    RECL's n-weighted objective favors the 3-stream group, starving the
    singleton; ECCO's fairness bonus must not."""
    def req(sid, dom):
        toks = bank.sample(dom, rng, 32, 32)
        return Request(stream_id=sid, t=0.0, loc=(0, 0), subsamples=toks,
                       acc=0.0, train_data=toks)

    g1 = RetrainJob(engine, req("a0", 0), micro_steps=MICRO_STEPS, batch=16, seed=0)
    g1.add_member(req("a1", 0))
    g1.add_member(req("a2", 0))
    g2 = RetrainJob(engine, req("b0", 0), micro_steps=MICRO_STEPS, batch=16, seed=1)
    return g1, g2


def _run(alloc, engine, bank, rng):
    g1, g2 = _mk_jobs(engine, bank, rng)
    gaps, trace = [], []
    for w in range(WINDOWS):
        for i in range(3):
            g1.ingest(bank.sample(0, rng, 4, 32))
        g2.ingest(bank.sample(0, rng, 4, 32))
        t = alloc.run_window([g1, g2], MICRO)
        a1, a2 = g1.eval(), g2.eval()
        gaps.append(abs(a1 - a2))
        trace.append((t.gpu_time.get(g1.job_id, 0),
                      t.gpu_time.get(g2.job_id, 0), a1, a2))
    return gaps, trace


def run():
    rows = Rows("allocator")
    engine = make_engine()
    bank = DomainBank(VOCAB, 4, dim=4, seed=0)

    gaps_e, trace_e = _run(ECCOAllocator(), engine, bank,
                           np.random.default_rng(0))
    gaps_r, trace_r = _run(RECLAllocator(), engine, bank,
                           np.random.default_rng(0))

    # fairness is judged once the allocator has a measured trajectory
    # (window 0 opens a gap for both: no signal yet)
    rows.add("ecco_late_gap", float(np.mean(gaps_e[WINDOWS // 2:])))
    rows.add("recl_late_gap", float(np.mean(gaps_r[WINDOWS // 2:])))
    rows.add("ecco_final_gap", gaps_e[-1])
    rows.add("recl_final_gap", gaps_r[-1])
    for w, (g1t, g2t, a1, a2) in enumerate(trace_e):
        rows.add(f"ecco_w{w}_gpu_split", f"{g1t}:{g2t}")
        rows.add(f"ecco_w{w}_acc_g1", a1)
        rows.add(f"ecco_w{w}_acc_g2", a2)
    for w, (g1t, g2t, a1, a2) in enumerate(trace_r):
        rows.add(f"recl_w{w}_gpu_split", f"{g1t}:{g2t}")
        rows.add(f"recl_w{w}_acc_g1", a1)
        rows.add(f"recl_w{w}_acc_g2", a2)
    # overall accuracy comparable while fairness improves
    fin_e = (trace_e[-1][2] + trace_e[-1][3]) / 2
    fin_r = (trace_r[-1][2] + trace_r[-1][3]) / 2
    rows.add("ecco_mean_final_acc", fin_e)
    rows.add("recl_mean_final_acc", fin_r)
    rows.add("fairness_improved",
             int(np.mean(gaps_e[WINDOWS // 2:]) <
                 np.mean(gaps_r[WINDOWS // 2:])))
    return rows.emit()


if __name__ == "__main__":
    run()
