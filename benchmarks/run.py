"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only similarity,...]

Prints ``benchmark,metric,value`` CSV rows. Mapping to the paper:
    similarity      — Fig. 2c / Fig. 8 (group vs independent, similarity)
    trainer         — training-plane batching (JobBank vmapped
                      executables vs per-member/per-job loops)
    end_to_end      — Fig. 6 (accuracy vs GPU / bandwidth budgets)
    scalability     — Fig. 7 (accuracy + response time vs #streams)
    grouping        — Fig. 9 (dynamic regrouping trace)
    allocator       — Fig. 10 (ECCO vs RECL allocator fairness)
    transmission    — Fig. 11 + Table 1 (controller ablation)
    responsiveness  — Fig. 12 / 13 (model reuse, data aggregation)
    kernels         — substrate microbench + interpret spot checks
    roofline        — §Roofline table from the dry-run artifact
    faults          — checkpoint/restore + straggler mitigation drill
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

BENCHES = [
    "kernels",
    "roofline",
    "faults",
    "similarity",
    "trainer",
    "allocator",
    "grouping",
    "transmission",
    "responsiveness",
    "scalability",
    "end_to_end",
]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)
    names = (args.only.split(",") if args.only else BENCHES)

    print("benchmark,metric,value")
    failures = []
    t0 = time.time()
    for name in names:
        mod = importlib.import_module(f"benchmarks.bench_{name}")
        try:
            mod.run()
        except Exception as e:
            traceback.print_exc()
            failures.append(name)
            print(f"{name},ERROR,{type(e).__name__}")
    print(f"total,wall_seconds,{time.time() - t0:.1f}")
    if failures:
        print(f"total,failed_benchmarks,{';'.join(failures)}")
        sys.exit(1)
    print(f"total,benchmarks_passed,{len(names)}")


if __name__ == "__main__":
    main()
