"""Shared benchmark harness: fleet construction, controller runs, and
CSV row collection. Scales are reduced (CPU container) but the
*comparisons* mirror the paper's figures 1:1 — same frameworks, same
metrics (mAP-analogue accuracy, response time), same resource axes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs import smoke_config
from repro.core.baselines import FRAMEWORKS
from repro.core.controller import ControllerConfig
from repro.core.trainer import SharedEngine
from repro.data.streams import make_fleet

VOCAB = 64


def make_engine(arch: str = "olmo-1b", vocab: int = VOCAB) -> SharedEngine:
    cfg = dataclasses.replace(smoke_config(arch), vocab_size=vocab)
    return SharedEngine(cfg)


def run_framework(framework: str, engine: SharedEngine, streams,
                  *, windows: int = 8, window_micro: int = 8,
                  shared_bandwidth: float = 1e9,
                  local_caps: Optional[dict] = None,
                  micro_steps: int = 4, train_batch: int = 16,
                  sample_rate: int = 8, p_drop: float = 0.5,
                  seed: int = 0):
    """Run one framework over a fleet; returns the controller."""
    cc = ControllerConfig(window_micro=window_micro,
                          shared_bandwidth=shared_bandwidth,
                          local_caps=local_caps,
                          micro_steps=micro_steps,
                          train_batch=train_batch,
                          sample_rate=sample_rate,
                          p_drop=p_drop)
    ctl = FRAMEWORKS[framework](engine, streams, cc, seed=seed)
    ctl.warmup()
    for _ in range(windows):
        ctl.run_window()
    return ctl


class Rows:
    """CSV row collector: benchmark,metric,value. Raw (unformatted)
    values are kept in `metrics` so benchmarks can persist a
    machine-readable JSON next to the stdout CSV."""

    def __init__(self, bench: str):
        self.bench = bench
        self.rows: List[str] = []
        self.metrics: Dict[str, object] = {}
        self.t0 = time.time()

    def add(self, metric: str, value):
        self.metrics[metric] = value
        if isinstance(value, float):
            value = f"{value:.4f}"
        self.rows.append(f"{self.bench},{metric},{value}")

    def emit(self) -> List[str]:
        self.add("wall_seconds", time.time() - self.t0)
        for r in self.rows:
            print(r, flush=True)
        return self.rows
