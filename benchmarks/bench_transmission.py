"""Paper Fig. 11 + Table 1 + Fig. 5 + the fleet decision plane.

(a) Fig. 11 left — accuracy vs shared bandwidth with the controller ON
    (GAIMD alpha = p_j/n_j) vs OFF (fixed sampling, plain AIMD),
    with one group's cameras capped by a weak local uplink.
(b) Fig. 11 right — realized per-group bandwidth vs the ideal
    GPU-proportional target (proportionality error metric).
(c) Table 1 — equal vs GPU-proportional bandwidth split, accuracy of a
    2-stream workload whose GPU shares are 30/70.
(d) Fig. 5 — PROFILE the sampling-config table for real: retrain the
    reduced model under each (rate, resolution at the stream width)
    config at each budget level, record the accuracy, then run the
    bandwidth_contention scenario end to end with the profiled table
    (the §3.2 pipeline the controller actually executes).
(e) decision plane — scalar `TransmissionController.decide` loop vs
    `FleetTransmissionPlane.decide_many` at 100/1k/10k flows
    (parity-asserted while timed), the warm-vs-cold GAIMD
    steps-to-convergence, and the proportionality error of realized
    rates vs the alpha/(1-beta) targets. Every flow's delivered tokens
    are asserted <= its bandwidth budget. Results persist to
    BENCH_transmission.json (CI bench-smoke uploads it).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import Rows, make_engine, run_framework
from repro.core import gaimd
from repro.core import transmission as tx
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob
from repro.data.scenarios import build_scenario
from repro.data.streams import DomainBank, make_fleet
from repro.testing.trace import run_scenario

VOCAB = 64
SEQ = 32
OUT_JSON = "BENCH_transmission.json"


def _fig11_left(rows, engine):
    for bw in (24.0, 96.0):
        for controller in ("on", "off"):
            _, streams = make_fleet(regions=3, streams_per_region=2,
                                    switch_times=(10.0,), seed=0)
            caps = {streams[0].stream_id: bw / 8,
                    streams[1].stream_id: bw / 8}
            if controller == "on":
                ctl = run_framework("ecco", engine, streams, windows=6,
                                    window_micro=8, shared_bandwidth=bw,
                                    local_caps=caps)
            else:
                # ablation: equal-share AIMD + fixed sampling = the
                # naive baseline's transmission with ECCO's grouping
                ctl = run_framework("ecco", engine, streams, windows=6,
                                    window_micro=8,
                                    shared_bandwidth=bw,
                                    local_caps=caps, sample_rate=4)
                # override: equal shares (alpha=1 equivalent)
                ctl.allocator.estimate_shares = \
                    lambda jobs, gains=None: {j.job_id: 1 / len(jobs)
                                              for j in jobs}
            rows.add(f"bw{int(bw)}_controller_{controller}_acc",
                     ctl.mean_accuracy(last_k=2))


def _fig11_right(rows):
    """Realized vs ideal GPU-proportional bandwidth, 3 groups at
    3:5:2 GPU shares, group A locally capped."""
    shares = [0.3, 0.3, 0.5, 0.5, 0.2, 0.2]     # per-flow group share
    members = [2, 2, 2, 2, 2, 2]
    caps = np.array([1.0, 1.0, np.inf, np.inf, np.inf, np.inf],
                    np.float32)
    alpha, beta = gaimd.ecco_params(shares, members)
    r = gaimd.steady_state_rates(alpha, beta, caps, shared_cap=9.0)
    target = np.asarray(shares) / np.sum(shares) * 9.0 / 2
    err_ecco = gaimd.proportionality_error(r, target)
    # baseline: plain AIMD (equal aggressiveness)
    r0 = gaimd.steady_state_rates(np.ones(6, np.float32),
                                  np.full(6, 0.5, np.float32), caps,
                                  shared_cap=9.0)
    err_base = gaimd.proportionality_error(r0, target)
    rows.add("proportionality_error_ecco", err_ecco)
    rows.add("proportionality_error_baseline", err_base)
    rows.add("gaimd_tracks_target", int(err_ecco < err_base))


def _table1(rows, engine):
    """Two streams, GPU split 30/70, bandwidth 3 units: equal (1.5/1.5)
    vs proportional (0.9/2.1). Accuracy under matched data delivery."""
    bank = DomainBank(VOCAB, 4, dim=4, seed=0)
    rng = np.random.default_rng(0)

    def req(sid, dom):
        toks = bank.sample(dom, rng, 4, SEQ)
        return Request(stream_id=sid, t=0.0, loc=(0, 0),
                       subsamples=toks, acc=0.0, train_data=toks)

    def run_split(bw_a, bw_b, micro_a, micro_b):
        ja = RetrainJob(engine, req("a", 0), micro_steps=4, batch=16,
                        seed=0)
        jb = RetrainJob(engine, req("b", 2), micro_steps=4, batch=16,
                        seed=0)
        for w in range(6):
            # bandwidth -> sequences deliverable (1 seq = 32 tokens = 1
            # bandwidth unit here)
            ja.ingest(bank.sample(0, rng, max(1, int(bw_a * 2)), SEQ))
            jb.ingest(bank.sample(2, rng, max(1, int(bw_b * 2)), SEQ))
            for _ in range(micro_a):
                ja.train_micro()
            for _ in range(micro_b):
                jb.train_micro()
        ea = bank.sample(0, rng, 16, SEQ)
        eb = bank.sample(2, rng, 16, SEQ)
        return (engine.accuracy(ja.state["params"], ea),
                engine.accuracy(jb.state["params"], eb))

    # GPU 30/70 -> micro windows 1/3 per window
    a_eq, b_eq = run_split(1.5, 1.5, 1, 3)
    a_pr, b_pr = run_split(0.9, 2.1, 1, 3)
    rows.add("table1_equal_a", a_eq)
    rows.add("table1_equal_b", b_eq)
    rows.add("table1_equal_overall", (a_eq + b_eq) / 2)
    rows.add("table1_prop_a", a_pr)
    rows.add("table1_prop_b", b_pr)
    rows.add("table1_prop_overall", (a_pr + b_pr) / 2)
    rows.add("proportional_wins_overall",
             int((a_pr + b_pr) >= (a_eq + b_eq)))


# ---------------------------------------------------------------------------
# (d) Fig. 5: profile the table for real, then run §3.2 end to end
# ---------------------------------------------------------------------------
def _fig5_profile(rows, engine, results, *, levels=2, windows=2):
    """Retrain the reduced model under each sampling config at each
    budget level (budget level -> micro-windows of accelerator time)
    and record the reached accuracy — the profiled (levels, configs)
    matrix ProfileTable.best_many selects from."""
    bank = DomainBank(VOCAB, 4, dim=4, seed=0)
    rng = np.random.default_rng(0)
    dom = 1
    configs = [tx.SamplingConfig(r, SEQ) for r in (2, 4, 8)]
    table = tx.ProfileTable(configs)
    evals = bank.sample(dom, rng, 16, SEQ)
    prof = []
    for lvl in range(levels):
        micro = 1 + lvl                  # budget level -> training time
        for i, cfg in enumerate(configs):
            job = RetrainJob(
                engine,
                Request(stream_id=f"prof{lvl}_{i}", t=0.0, loc=(0, 0),
                        subsamples=evals, acc=0.0,
                        train_data=bank.sample(dom, rng, cfg.rate, SEQ)),
                micro_steps=4, batch=8, seed=0)
            for _ in range(windows):
                job.ingest(bank.sample(dom, rng, cfg.rate,
                                       cfg.resolution))
                for _ in range(micro):
                    job.train_micro()
            acc = float(engine.accuracy(job.state["params"], evals))
            table.record(lvl, i, acc)
            prof.append(dict(level=lvl, rate=cfg.rate,
                             resolution=cfg.resolution,
                             tokens=cfg.tokens, acc=round(acc, 4)))
            rows.add(f"fig5_l{lvl}_r{cfg.rate}_acc", acc)
            job.release()
    results["fig5_profile"] = prof
    return table


def _contention_end_to_end(rows, engine, table, results, *, windows=4):
    """bandwidth_contention with the PROFILED table: the full §3.2
    pipeline (table lookup -> f*/n_j -> GAIMD -> compression) in the
    controller loop. Asserts the bandwidth-cap invariant on every
    delivered window."""
    sc = build_scenario("bandwidth_contention", seed=0, windows=windows)
    ctl = run_scenario("ecco", sc, engine=engine, window_micro=4,
                       micro_steps=2, train_batch=8,
                       profile_table=table)
    checked = 0
    for wm in ctl.history:
        for sid, d in wm.delivered.items():
            budget = wm.bandwidth[sid] * ctl.cc.window_seconds \
                / ctl.cc.bytes_per_token
            assert d <= budget, \
                f"flow {sid} delivered {d} > budget {budget}"
            checked += 1
    assert checked > 0, "no transmission decisions exercised"
    rows.add("contention_profiled_acc", ctl.mean_accuracy(last_k=2))
    rows.add("contention_budget_checks", checked)
    results["contention"] = dict(
        acc=round(ctl.mean_accuracy(last_k=2), 4),
        budget_checks=checked,
        gaimd_steps_last_window=ctl.tx_plane.last_steps)


# ---------------------------------------------------------------------------
# (e) decision plane: scalar loop vs batched, 100/1k/10k flows
# ---------------------------------------------------------------------------
def _decision_plane(rows, results, sizes, *, window_seconds=10.0,
                    bytes_per_token=2.0):
    cfgs = [tx.SamplingConfig(r, q) for r in (2, 4, 8)
            for q in (16, 32, 64)]
    table = tx.ProfileTable(cfgs)
    rng = np.random.default_rng(7)
    for lvl in range(4):
        for i in range(len(cfgs)):
            table.record(lvl, i, float(rng.uniform(0.2, 0.9)))
    ctrl = tx.TransmissionController(table,
                                     bytes_per_token=bytes_per_token)
    for n in sizes:
        shares = rng.uniform(0.05, 1.0, n)
        members = rng.integers(1, 8, n)
        bw = rng.uniform(0.0, 64.0, n)
        bw[:: max(1, n // 16)] = 0.0          # mix in dead uplinks
        levels = [int(l) for l in rng.integers(0, 5, n)]
        budgets = [float(b) for b in rng.uniform(16, 700, n)]
        plane = tx.FleetTransmissionPlane(
            table, bytes_per_token=bytes_per_token)

        def run_scalar():
            # fleetlint: disable=per-member-loop -- the timed scalar
            # REFERENCE twin the batched decide_many is measured
            # against; the speedup column is this loop's cost
            return [ctrl.decide(gpu_budget_level=levels[i],
                                token_budget=budgets[i],
                                p_share=float(shares[i]),
                                n_members=int(members[i]),
                                achieved_bandwidth=float(bw[i]),
                                window_seconds=window_seconds)
                    for i in range(n)]

        def run_batched():
            return plane.decide_many(budget_levels=levels,
                                     token_budgets=budgets,
                                     p_shares=shares, n_members=members,
                                     achieved_bw=bw,
                                     window_seconds=window_seconds)

        def best_of(fn, repeats=5):
            # sub-ms regions: warm once, report the best of several
            # passes so allocator/cache jitter doesn't swamp the signal
            out, best = fn(), np.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                out = fn()
                best = min(best, time.perf_counter() - t0)
            return out, best

        scalar, t_scalar = best_of(run_scalar)
        batch, t_batched = best_of(run_batched)

        assert batch.as_decisions() == scalar, \
            "decision plane drifted from scalar loop"
        # no flow's delivered tokens may exceed its bandwidth budget
        budget_tokens = bw * window_seconds / bytes_per_token
        assert (batch.delivered <= budget_tokens).all(), \
            "a flow delivered beyond its bandwidth budget"
        assert (batch.delivered[bw == 0.0] == 0).all()

        # proportionality of a realized allocation vs the decisions'
        # alpha/(1-beta) targets (the §3.2 reporting loop). Without
        # local caps the synchronized-loss fluid model is EXACTLY
        # proportional, so cap a slice of uplinks to make the error a
        # live metric (capped flows pin, the rest split the remainder)
        caps = np.full(n, np.inf, np.float32)
        caps[:: max(1, n // 8)] = 0.5
        realized = plane.allocate([f"f{i}" for i in range(n)], shares,
                                  members, caps,
                                  shared_cap=float(n * 2.0))
        err = gaimd.proportionality_error(realized, batch.target_rate)
        steps_cold = plane.last_steps
        realized2 = plane.allocate([f"f{i}" for i in range(n)], shares,
                                   members, caps,
                                   shared_cap=float(n * 2.0))
        steps_warm = plane.last_steps
        err2 = gaimd.proportionality_error(realized2, batch.target_rate)

        sp = t_scalar / max(t_batched, 1e-9)
        rows.add(f"decide_n{n}_scalar_s", t_scalar)
        rows.add(f"decide_n{n}_batched_s", t_batched)
        rows.add(f"decide_n{n}_speedup", sp)
        rows.add(f"decide_n{n}_prop_err", err)
        rows.add(f"decide_n{n}_gaimd_steps_cold", steps_cold)
        rows.add(f"decide_n{n}_gaimd_steps_warm", steps_warm)
        results["decision_plane"].append(dict(
            flows=n, scalar_s=round(t_scalar, 5),
            batched_s=round(t_batched, 5), speedup=round(sp, 2),
            proportionality_error=round(err, 5),
            proportionality_error_warm=round(err2, 5),
            gaimd_steps_cold=steps_cold, gaimd_steps_warm=steps_warm,
            gaimd_steps_seed=4000))   # the fixed budget the seed burnt


def run(smoke: bool = False):
    rows = Rows("transmission")
    engine = make_engine()
    results = {"smoke": smoke, "decision_plane": []}
    _fig11_right(rows)
    if smoke:
        _decision_plane(rows, results, (100, 1000))
        table = _fig5_profile(rows, engine, results, levels=2, windows=1)
        _contention_end_to_end(rows, engine, table, results, windows=3)
    else:
        _decision_plane(rows, results, (100, 1000, 10000))
        table = _fig5_profile(rows, engine, results)
        _contention_end_to_end(rows, engine, table, results)
        _table1(rows, engine)
        _fig11_left(rows, engine)
    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=1)
        f.write("\n")
    rows.add("json_out", OUT_JSON)
    return rows.emit()


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:] or bool(os.environ.get("SMOKE")))
