"""Paper Fig. 11 + Table 1: the resource-aware transmission controller.

(a) Fig. 11 left — accuracy vs shared bandwidth with the controller ON
    (GAIMD alpha = p_j/n_j) vs OFF (fixed sampling, plain AIMD),
    with one group's cameras capped by a weak local uplink.
(b) Fig. 11 right — realized per-group bandwidth vs the ideal
    GPU-proportional target (proportionality error metric).
(c) Table 1 — equal vs GPU-proportional bandwidth split, accuracy of a
    2-stream workload whose GPU shares are 30/70.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, make_engine, run_framework
from repro.core import gaimd
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob
from repro.data.streams import DomainBank, make_fleet

VOCAB = 64


def _fig11_left(rows, engine):
    for bw in (24.0, 96.0):
        for controller in ("on", "off"):
            _, streams = make_fleet(regions=3, streams_per_region=2,
                                    switch_times=(10.0,), seed=0)
            caps = {streams[0].stream_id: bw / 8,
                    streams[1].stream_id: bw / 8}
            if controller == "on":
                ctl = run_framework("ecco", engine, streams, windows=6,
                                    window_micro=8, shared_bandwidth=bw,
                                    local_caps=caps)
            else:
                # ablation: equal-share AIMD + fixed sampling = the
                # naive baseline's transmission with ECCO's grouping
                ctl = run_framework("ecco", engine, streams, windows=6,
                                    window_micro=8,
                                    shared_bandwidth=bw,
                                    local_caps=caps, sample_rate=4)
                # override: equal shares (alpha=1 equivalent)
                ctl.allocator.estimate_shares = \
                    lambda jobs, gains=None: {j.job_id: 1 / len(jobs)
                                              for j in jobs}
            rows.add(f"bw{int(bw)}_controller_{controller}_acc",
                     ctl.mean_accuracy(last_k=2))


def _fig11_right(rows):
    """Realized vs ideal GPU-proportional bandwidth, 3 groups at
    3:5:2 GPU shares, group A locally capped."""
    shares = [0.3, 0.3, 0.5, 0.5, 0.2, 0.2]     # per-flow group share
    members = [2, 2, 2, 2, 2, 2]
    caps = np.array([1.0, 1.0, np.inf, np.inf, np.inf, np.inf],
                    np.float32)
    alpha, beta = gaimd.ecco_params(shares, members)
    r = gaimd.steady_state_rates(alpha, beta, caps, shared_cap=9.0)
    target = np.asarray(shares) / np.sum(shares) * 9.0 / 2
    err_ecco = gaimd.proportionality_error(r, target)
    # baseline: plain AIMD (equal aggressiveness)
    r0 = gaimd.steady_state_rates(np.ones(6, np.float32),
                                  np.full(6, 0.5, np.float32), caps,
                                  shared_cap=9.0)
    err_base = gaimd.proportionality_error(r0, target)
    rows.add("proportionality_error_ecco", err_ecco)
    rows.add("proportionality_error_baseline", err_base)
    rows.add("gaimd_tracks_target", int(err_ecco < err_base))


def _table1(rows, engine):
    """Two streams, GPU split 30/70, bandwidth 3 units: equal (1.5/1.5)
    vs proportional (0.9/2.1). Accuracy under matched data delivery."""
    bank = DomainBank(VOCAB, 4, dim=4, seed=0)
    rng = np.random.default_rng(0)

    def req(sid, dom):
        toks = bank.sample(dom, rng, 4, 32)
        return Request(stream_id=sid, t=0.0, loc=(0, 0),
                       subsamples=toks, acc=0.0, train_data=toks)

    def run_split(bw_a, bw_b, micro_a, micro_b):
        ja = RetrainJob(engine, req("a", 0), micro_steps=4, batch=16,
                        seed=0)
        jb = RetrainJob(engine, req("b", 2), micro_steps=4, batch=16,
                        seed=0)
        for w in range(6):
            # bandwidth -> sequences deliverable (1 seq = 32 tokens = 1
            # bandwidth unit here)
            ja.ingest(bank.sample(0, rng, max(1, int(bw_a * 2)), 32))
            jb.ingest(bank.sample(2, rng, max(1, int(bw_b * 2)), 32))
            for _ in range(micro_a):
                ja.train_micro()
            for _ in range(micro_b):
                jb.train_micro()
        ea = bank.sample(0, rng, 16, 32)
        eb = bank.sample(2, rng, 16, 32)
        return (engine.accuracy(ja.state["params"], ea),
                engine.accuracy(jb.state["params"], eb))

    # GPU 30/70 -> micro windows 1/3 per window
    a_eq, b_eq = run_split(1.5, 1.5, 1, 3)
    a_pr, b_pr = run_split(0.9, 2.1, 1, 3)
    rows.add("table1_equal_a", a_eq)
    rows.add("table1_equal_b", b_eq)
    rows.add("table1_equal_overall", (a_eq + b_eq) / 2)
    rows.add("table1_prop_a", a_pr)
    rows.add("table1_prop_b", b_pr)
    rows.add("table1_prop_overall", (a_pr + b_pr) / 2)
    rows.add("proportional_wins_overall",
             int((a_pr + b_pr) >= (a_eq + b_eq)))


def run():
    rows = Rows("transmission")
    engine = make_engine()
    _fig11_right(rows)
    _table1(rows, engine)
    _fig11_left(rows, engine)
    return rows.emit()


if __name__ == "__main__":
    run()
