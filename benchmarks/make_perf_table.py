"""Generate the EXPERIMENTS.md §Perf optimized-vs-baseline table from
dryrun_baseline.json + dryrun_optimized.json."""
import json
import sys


def load(path):
    with open(path) as f:
        return {(r["arch"], r["shape"], r["mesh"]): r
                for r in json.load(f) if r["status"] == "ok"}


def main():
    base = load("dryrun_baseline.json")
    opt = load("dryrun_optimized.json")
    rows = []
    print("| arch | shape | mesh | frac (tp) | frac (zero) | Δ | new dominant |")
    print("|---|---|---|---|---|---|---|")
    gains = []
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        fb, fo = b["roofline_fraction"], o["roofline_fraction"]
        if b["shape"] in ("decode_32k", "long_500k"):
            continue      # decode cells use tp in both profiles
        d = (fo / fb) if fb > 0 else float("inf")
        gains.append(d)
        print(f"| {key[0]} | {key[1]} | {key[2]} | {fb:.4f} | {fo:.4f} "
              f"| {d:.2f}x | {o['dominant'].replace('_s','')} |")
    gains.sort()
    n = len(gains)
    print(f"\ngeometric-ish summary: median gain "
          f"{gains[n // 2]:.2f}x over {n} train/prefill cells; "
          f"min {gains[0]:.2f}x, max {gains[-1]:.2f}x")


if __name__ == "__main__":
    main()
