"""Serving-plane benchmark: batched fleet inference under concurrent
retraining.

Three sections:
  (a) fleet serving, 1k streams — the full `ECCOController` window
      loop with `ControllerConfig.serve` on: every window retrains the
      live groups (step 4) and THEN serves one query per grouped
      stream through the slot-pool plane (step 6), so reported tick
      latencies include contention with training dispatch in the same
      process. Reported: aggregate qps, pooled p50/p99 tick latency —
      both over ALL ticks and steady-state (excluding the first tick
      of each padded lane-count shape, which pays the XLA compile) —
      plus the swap-gate counters (seeded / accepted / rejected).
  (b) fleet serving, 10k streams — the serve-plane loop with REAL
      `RetrainJob`s retraining in the same window loop (ingest fresh
      window tokens -> `train_micro` micro-windows -> snapshot ->
      gated `publish` -> 10k queries pumped through the slot pool).
      The full controller is bypassed at this size on purpose: Alg. 2
      regrouping of 10k simultaneously-drifted streams dominates wall
      time by orders of magnitude and is benchmarked separately
      (bench_scalability.py); here the serving plane and the training
      dispatch it contends with are the measured system. Same metric
      keys as (a).
  (c) swap gate — a mini fleet run at `gate_margin=0.0` (ties accept:
      swaps land every window) and at an impossible margin (every
      post-seed candidate misses: the incumbent keeps serving and
      staleness grows), so BOTH gate outcomes are visible in the
      bench counters, mirroring tests/test_serve_plane.py.

`--smoke` (or SMOKE=1) shrinks the fleet sizes for CI: the point there
is that the serving path executes end to end, not the numbers.

Results go to stdout as CSV rows AND to BENCH_serving.json (next to
BENCH_scalability.json) so serving perf is machine-readable across
PRs; CI's bench-smoke job uploads both.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import Rows, make_engine
from repro.core.controller import ControllerConfig, ECCOController
from repro.data.streams import make_fleet
from repro.serve.plane import FleetServePlane, ServeConfig

WINDOWS = 4              # switch at t=10: windows 2-4 retrain AND serve
OUT_JSON = "BENCH_serving.json"


def _controller(engine, n_streams, scfg, *, seed=0):
    _, streams = make_fleet(regions=2, streams_per_region=n_streams // 2,
                            switch_times=(10.0,), seed=seed)
    cc = ControllerConfig(window_micro=4, micro_steps=2, train_batch=8,
                          sample_rate=4, eval_batch=16, p_drop=0.5,
                          shared_bandwidth=1e9, serve=scfg)
    return ECCOController(engine, streams, cc, seed=seed)


def _tick_stats(tick_log):
    """Pooled latency percentiles from the plane's run-lifetime tick
    log. Steady-state drops the FIRST tick of each padded lane-count
    shape (that tick pays the XLA compile for the shape bucket; the
    {2^k, 3*2^(k-2)} pad grid keeps those buckets to ~2 per octave)."""
    all_ms = np.asarray([s for _, s in tick_log], np.float64) * 1e3
    seen, steady = set(), []
    for pad, s in tick_log:
        if pad in seen:
            steady.append(s * 1e3)
        else:
            seen.add(pad)
    steady_ms = np.asarray(steady, np.float64)

    def pcts(a):
        if a.size == 0:
            return 0.0, 0.0
        return (float(np.percentile(a, 50)), float(np.percentile(a, 99)))

    p50, p99 = pcts(all_ms)
    s50, s99 = pcts(steady_ms)
    return {"ticks": len(tick_log), "compile_ticks": len(seen),
            "p50_tick_ms": p50, "p99_tick_ms": p99,
            "p50_tick_ms_steady": s50, "p99_tick_ms_steady": s99}


def _scale_config(n):
    return ServeConfig(num_slots=min(256, max(8, n // 4)),
                       capacity=32, max_new=4, prompt_len=8)


def _emit_scale_rows(rows: Rows, tag, sp, scfg, queries, serve_s, wall,
                     windows):
    rows.add(f"{tag}_queries", queries)
    rows.add(f"{tag}_qps", queries / serve_s if serve_s else 0.0)
    for k, v in _tick_stats(sp.tick_log).items():
        rows.add(f"{tag}_{k}", v)
    rows.add(f"{tag}_slots", scfg.num_slots)
    rows.add(f"{tag}_swap_seeded", sp.swap_seeded)
    rows.add(f"{tag}_swap_accepted", sp.swap_accepted)
    rows.add(f"{tag}_swap_rejected", sp.swap_rejected)
    rows.add(f"{tag}_serve_seconds", serve_s)
    rows.add(f"{tag}_window_wall_seconds", wall / windows)
    assert queries > 0, "serving plane never admitted a query"


def _serve_full_controller(rows: Rows, engine, sizes, windows):
    for n in sizes:
        scfg = _scale_config(n)
        ctl = _controller(engine, n, scfg)
        t0 = time.time()
        for w in range(windows):
            tw = time.time()
            ctl.run_window()
            print(f"# n{n} window {w}: {time.time() - tw:.1f}s",
                  file=sys.stderr, flush=True)
        wall = time.time() - t0
        queries = sum(wm.serve["queries"] for wm in ctl.history)
        serve_s = sum(wm.serve["serve_seconds"] for wm in ctl.history)
        _emit_scale_rows(rows, f"n{n}", ctl.serve_plane, scfg, queries,
                         serve_s, wall, windows)


def _serve_under_retraining(rows: Rows, engine, n, windows, *,
                            groups=16, vocab=64, seq=32):
    """Section (b): retraining and serving contend in one loop, the
    grouping plane out of the picture. `groups` RetrainJobs (real
    JobBank slots) each own n/groups streams; every window each job
    ingests fresh window tokens and runs its micro-windows, then its
    snapshot rides the validation gate and every stream issues one
    query against its group's SERVING row."""
    from repro.core.grouping import Request
    from repro.core.trainer import RetrainJob

    rng = np.random.default_rng(0)
    scfg = _scale_config(n)
    plane = FleetServePlane(engine, scfg)
    jobs = []
    for g in range(groups):
        tok = rng.integers(0, vocab, size=(8, seq))
        jobs.append(RetrainJob(
            engine, Request(stream_id=f"s{g}_0", t=0.0, loc=(0.0, 0.0),
                            subsamples=tok, acc=0.0, train_data=tok),
            micro_steps=2, batch=8, seed=g))
    queries = serve_s = 0
    t0 = time.time()
    for w in range(windows):
        tw = time.time()
        evals = {}
        for j in jobs:                      # retraining, same loop
            j.ingest(rng.integers(0, vocab, size=(8, seq)))
            for _ in range(2):
                j.train_micro()
            evals[j.job_id] = rng.integers(0, vocab, size=(4, seq))
            plane.publish(j.job_id, j.serving_snapshot(),
                          evals[j.job_id])
        for s in range(n):                  # one query per stream
            j = jobs[s % groups]
            prompt = rng.integers(0, vocab, size=scfg.prompt_len)
            plane.enqueue(f"s{s}/w{w}", j.job_id, prompt)
        plane.pump()
        plane.drain()
        rep = plane.window_report()
        queries += rep["queries"]
        serve_s += rep["serve_seconds"]
        print(f"# n{n} (retrain-loop) window {w}: "
              f"{time.time() - tw:.1f}s queries={rep['queries']} "
              f"ticks={rep['ticks']}", file=sys.stderr, flush=True)
    _emit_scale_rows(rows, f"n{n}", plane, scfg, queries, serve_s,
                     time.time() - t0, windows)
    for j in jobs:
        j.release()


def _gate_outcomes(rows: Rows, engine, n, windows):
    """Both gate outcomes, visible in counters: margin 0.0 lets every
    retrained candidate land (ties accept), an impossible margin
    rejects every post-seed candidate so staleness accumulates."""
    for tag, margin in (("open", 0.0), ("closed", 1.1)):
        scfg = ServeConfig(num_slots=8, capacity=32, max_new=4,
                           prompt_len=8, gate_margin=margin)
        ctl = _controller(engine, n, scfg)
        ctl.run(windows)
        sp = ctl.serve_plane
        rows.add(f"gate_{tag}_seeded", sp.swap_seeded)
        rows.add(f"gate_{tag}_accepted", sp.swap_accepted)
        rows.add(f"gate_{tag}_rejected", sp.swap_rejected)
        rows.add(f"gate_{tag}_max_staleness",
                 max(sp.staleness.values(), default=0))
    assert rows.metrics["gate_closed_rejected"] > 0
    assert rows.metrics["gate_closed_accepted"] == 0


def run(smoke: bool = False):
    rows = Rows("serving")
    engine = make_engine()
    if smoke:
        _serve_full_controller(rows, engine, sizes=(8,), windows=3)
        _serve_under_retraining(rows, engine, n=16, windows=2, groups=2)
        _gate_outcomes(rows, engine, n=4, windows=3)
    else:
        _serve_full_controller(rows, engine, sizes=(1000,),
                               windows=WINDOWS)
        _serve_under_retraining(rows, engine, n=10000, windows=WINDOWS)
        _gate_outcomes(rows, engine, n=8, windows=WINDOWS)
    metrics = {k: (None if isinstance(v, float) and not np.isfinite(v)
                   else v)
               for k, v in rows.metrics.items()}
    with open(OUT_JSON, "w") as f:
        json.dump({"smoke": smoke, "metrics": metrics}, f, indent=1,
                  allow_nan=False)
        f.write("\n")
    rows.add("json_out", OUT_JSON)
    return rows.emit()


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:] or bool(os.environ.get("SMOKE")))
