"""Kernel micro-benchmarks: wall time of the XLA substrate paths on CPU
(this container's measurable proxy) + interpret-mode correctness spot
checks. TPU roofline expectations are derived in EXPERIMENTS.md from the
dry-run; these numbers track substrate regressions across commits.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows


def _time(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6    # us


def run():
    rows = Rows("kernels")
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # attention (XLA blockwise exact) — train-ish shape
    from repro.kernels import ref
    B, S, H, K, hd = 2, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    att = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    rows.add("attention_xla_512_us", _time(att, q, k, v))
    flops = 4 * B * S * S * H * hd
    rows.add("attention_512_gflops",
             flops / (_time(att, q, k, v) * 1e-6) / 1e9)

    # mLSTM chunked (XLA)
    from repro.models.xlstm import mlstm_chunked
    B, S, H, P = 2, 512, 4, 64
    qm = jax.random.normal(ks[3], (B, S, H, P))
    ig = jax.random.normal(ks[4], (B, S, H))
    fg = jax.random.normal(ks[5], (B, S, H)) + 1
    ml = jax.jit(lambda q, i, f: mlstm_chunked(q, q, q, i, f, chunk=64))
    rows.add("mlstm_xla_512_us", _time(ml, qm, ig, fg))

    # SSD chunked (XLA)
    from repro.models.ssm import ssd_chunked
    N = 16
    x = jax.random.normal(ks[6], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[7], (B, S, H)))
    A = -jnp.ones((H,))
    Bm = jax.random.normal(ks[0], (B, S, N))
    Cm = jax.random.normal(ks[1], (B, S, N))
    D = jnp.ones((H,))
    sd = jax.jit(lambda x, dt, Bm, Cm: ssd_chunked(x, dt, A, Bm, Cm, D,
                                                   chunk=64))
    rows.add("ssd_xla_512_us", _time(sd, x, dt, Bm, Cm))

    # GAIMD simulator throughput (control-plane scalability: 4096 flows)
    from repro.core import gaimd
    alpha = np.ones(4096, np.float32)
    beta = np.full(4096, 0.5, np.float32)
    caps = np.full(4096, np.inf, np.float32)
    t0 = time.perf_counter()
    gaimd.steady_state_rates(alpha, beta, caps, 1000.0, steps=2000)
    rows.add("gaimd_4096flows_2000rtt_ms",
             (time.perf_counter() - t0) * 1e3)

    # interpret-mode spot correctness (kernels vs oracle)
    from repro.kernels.flash_attention import flash_attention
    q2 = q[:1, :128]
    k2 = k[:1, :128]
    v2 = v[:1, :128]
    o1 = flash_attention(q2, k2, v2, interpret=True, q_block=64,
                         kv_block=64)
    o2 = ref.attention_ref(q2, k2, v2)
    rows.add("flash_attention_interpret_maxdiff",
             float(jnp.max(jnp.abs(o1 - o2))))
    return rows.emit()


if __name__ == "__main__":
    run()
