"""Paper Fig. 9: dynamic regrouping trace, plus the fleet-scale
candidate-selection sweep.

Trace: three mobile streams share a region; mid-run one diverges to a
different domain (the tunnel). The grouper must (i) group all three
initially, (ii) evict the diverged stream at a window boundary,
(iii) give it a fresh job.

Scale sweep: synthetic fleets of 100 -> 10k streams; times Alg. 2
candidate selection via the seed's pure-Python all-pairs scan vs the
SignatureIndex vectorized prefilter (+ batched-JS top-k), and checks
the two return identical candidate sets.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Rows, make_engine
from repro.core.controller import ControllerConfig, ECCOController
from repro.core.grouping import Grouper, Request
from repro.core.signature_index import SignatureIndex
from repro.data.streams import DomainBank, Region, Stream

FLEET_SIZES = (100, 1000, 10000)
GROUP_SIZE = 4          # avg members per job
N_REQUESTS = 32
EPS_T, DELTA_LOC = 60.0, 100.0
BUCKETS = 64


class _MetaJob:
    """Selection-only job stub: metadata + membership, no model."""

    __slots__ = ("job_id", "members")

    def __init__(self, job_id, members):
        self.job_id = job_id
        self.members = members


def _make_fleet(n, rng):
    """n streams in n/GROUP_SIZE spatiotemporally coherent jobs."""
    jobs = []
    sid = 0
    for j in range(max(1, n // GROUP_SIZE)):
        t0 = float(rng.uniform(0, 5000))
        x0, y0 = rng.uniform(0, 5000, size=2)
        members = []
        for _ in range(GROUP_SIZE):
            r = Request(
                stream_id=f"s{sid}", t=t0 + float(rng.uniform(0, EPS_T / 4)),
                loc=(x0 + float(rng.uniform(0, DELTA_LOC / 4)),
                     y0 + float(rng.uniform(0, DELTA_LOC / 4))),
                subsamples=None, acc=0.0,
                sig=rng.random(BUCKETS).astype(np.float32))
            members.append(r)
            sid += 1
        jobs.append(_MetaJob(f"job{j}", members))
    reqs = []
    for i in range(N_REQUESTS):
        j = jobs[int(rng.integers(0, len(jobs)))]
        anchor = j.members[0]
        reqs.append(Request(
            stream_id=f"q{i}", t=anchor.t + float(rng.uniform(0, EPS_T / 4)),
            loc=(anchor.loc[0] + float(rng.uniform(0, DELTA_LOC / 4)),
                 anchor.loc[1]),
            subsamples=None, acc=0.0,
            sig=rng.random(BUCKETS).astype(np.float32)))
    return jobs, reqs


def run_scale(rows: Rows):
    rng = np.random.default_rng(0)
    for n in FLEET_SIZES:
        jobs, reqs = _make_fleet(n, rng)
        py = Grouper(eps_t=EPS_T, delta_loc=DELTA_LOC)
        index = SignatureIndex(buckets=BUCKETS, capacity=2 * n)
        index.rebuild(jobs)
        ix = Grouper(eps_t=EPS_T, delta_loc=DELTA_LOC, index=index)
        ts = [r.t for r in reqs]
        locs = [r.loc for r in reqs]
        sigs = [r.sig for r in reqs]
        kw = dict(eps_t=EPS_T, delta_loc=DELTA_LOC)
        # warmups: jit the JS kernel at both query shapes, build the
        # segment cache
        ix._index_candidates(jobs, reqs[0])
        index.candidate_jobs_batch(ts, locs, sigs=sigs, k=16, **kw)

        t0 = time.perf_counter()
        want = [py._python_candidates(jobs, r) for r in reqs]
        t_py = time.perf_counter() - t0

        # one-at-a-time index queries, mirroring the live group_request
        # path: each request upserts its own row first, which bumps the
        # index generation and forces the per-query segment rebuild the
        # live path always pays
        t0 = time.perf_counter()
        got_single = []
        for r in reqs:
            index.upsert(r.stream_id, r.t, r.loc, r.sig)
            got_single.append(ix._index_candidates(jobs, r))
        t_ix = time.perf_counter() - t0

        # the batched engine: all requests of the window in one call
        t0 = time.perf_counter()
        got_keys = index.candidate_jobs_batch(ts, locs, **kw)
        t_batch = time.perf_counter() - t0

        t0 = time.perf_counter()
        index.candidate_jobs_batch(ts, locs, sigs=sigs, k=16, **kw)
        t_batch16 = time.perf_counter() - t0

        key_to_idx = index.key_to_position(jobs)
        got_batch = [[key_to_idx[k] for k in ks] for ks in got_keys]
        rows.add(f"n{n}_python_ms", 1e3 * t_py / N_REQUESTS)
        rows.add(f"n{n}_index_ms", 1e3 * t_ix / N_REQUESTS)
        rows.add(f"n{n}_batch_ms", 1e3 * t_batch / N_REQUESTS)
        rows.add(f"n{n}_batch_top16_ms", 1e3 * t_batch16 / N_REQUESTS)
        rows.add(f"n{n}_selection_speedup", t_py / max(t_batch, 1e-9))
        rows.add(f"n{n}_candidates_match",
                 int(want == got_single == got_batch))


def run():
    rows = Rows("grouping")
    run_scale(rows)
    engine = make_engine()
    bank = DomainBank(64, 6, dim=4, seed=0)
    # region trajectory: domain 0, switching to 1 at t=10 (shared drift)
    shared = Region("r0", [(0.0, 0), (10.0, 1)])
    # the diverging stream follows domain 1 until t=40, then domain 3
    diverge = Region("r1", [(0.0, 0), (10.0, 1), (40.0, 3)])
    streams = [
        Stream("cam1", bank, shared, (0, 0), seed=1),
        Stream("cam2", bank, shared, (1, 0), seed=2),
        Stream("cam3", bank, diverge, (2, 0), seed=3),
    ]
    cc = ControllerConfig(window_micro=8, micro_steps=4, train_batch=16,
                          p_drop=0.3, shared_bandwidth=1e9)
    ctl = ECCOController(engine, streams, cc, seed=0)
    ctl.warmup()
    for _ in range(8):
        ctl.run_window()

    events = ctl.grouper.events
    joins = [e for e in events if e["kind"] in ("join", "new")]
    evicts = [e for e in events if e["kind"] == "evict"]
    rows.add("n_join_events", len(joins))
    rows.add("n_evict_events", len(evicts))
    # (i) all three grouped together at some point
    together = any(len(g) == 3 for wm in ctl.history
                   for g in wm.groups.values())
    rows.add("all_three_grouped", int(together))
    # (ii) cam3 evicted after diverging
    cam3_evicted = any(e["stream"] == "cam3" for e in evicts)
    rows.add("cam3_evicted_after_divergence", int(cam3_evicted))
    # (iii) final grouping separates cam3
    final = ctl.history[-1].groups
    cam3_alone = any(set(g) == {"cam3"} for g in final.values())
    rows.add("cam3_regrouped_alone", int(cam3_alone))
    rows.add("final_mean_acc", ctl.mean_accuracy(last_k=2))
    for wm in ctl.history:
        rows.add(f"t{int(wm.t)}_groups",
                 ";".join("|".join(sorted(m)) for m in
                          wm.groups.values()))
    return rows.emit()


if __name__ == "__main__":
    run()
