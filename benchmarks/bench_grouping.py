"""Paper Fig. 9: dynamic regrouping trace. Three mobile streams share a
region; mid-run one diverges to a different domain (the tunnel). The
grouper must (i) group all three initially, (ii) evict the diverged
stream at a window boundary, (iii) give it a fresh job.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, make_engine
from repro.core.controller import ControllerConfig, ECCOController
from repro.data.streams import DomainBank, Region, Stream


def run():
    rows = Rows("grouping")
    engine = make_engine()
    bank = DomainBank(64, 6, dim=4, seed=0)
    # region trajectory: domain 0, switching to 1 at t=10 (shared drift)
    shared = Region("r0", [(0.0, 0), (10.0, 1)])
    # the diverging stream follows domain 1 until t=40, then domain 3
    diverge = Region("r1", [(0.0, 0), (10.0, 1), (40.0, 3)])
    streams = [
        Stream("cam1", bank, shared, (0, 0), seed=1),
        Stream("cam2", bank, shared, (1, 0), seed=2),
        Stream("cam3", bank, diverge, (2, 0), seed=3),
    ]
    cc = ControllerConfig(window_micro=8, micro_steps=4, train_batch=16,
                          p_drop=0.3, shared_bandwidth=1e9)
    ctl = ECCOController(engine, streams, cc, seed=0)
    ctl.warmup()
    for _ in range(8):
        ctl.run_window()

    events = ctl.grouper.events
    joins = [e for e in events if e["kind"] in ("join", "new")]
    evicts = [e for e in events if e["kind"] == "evict"]
    rows.add("n_join_events", len(joins))
    rows.add("n_evict_events", len(evicts))
    # (i) all three grouped together at some point
    together = any(len(g) == 3 for wm in ctl.history
                   for g in wm.groups.values())
    rows.add("all_three_grouped", int(together))
    # (ii) cam3 evicted after diverging
    cam3_evicted = any(e["stream"] == "cam3" for e in evicts)
    rows.add("cam3_evicted_after_divergence", int(cam3_evicted))
    # (iii) final grouping separates cam3
    final = ctl.history[-1].groups
    cam3_alone = any(set(g) == {"cam3"} for g in final.values())
    rows.add("cam3_regrouped_alone", int(cam3_alone))
    rows.add("final_mean_acc", ctl.mean_accuracy(last_k=2))
    for wm in ctl.history:
        rows.add(f"t{int(wm.t)}_groups",
                 ";".join("|".join(sorted(m)) for m in
                          wm.groups.values()))
    return rows.emit()


if __name__ == "__main__":
    run()
