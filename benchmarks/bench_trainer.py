"""Training-plane wall-clock sweeps: seed per-member/per-job loops vs
the JobBank vmapped executables (batched_accuracy / train_micro_many).

Two sweeps, both over fleet MEMBER counts (100 / 1k / 10k full, shrunk
under --smoke):
  * eval plane  — score every (member, job) pair of the fleet: the
    seed's one `accuracy` device launch per member vs chunked
    `batched_accuracy` fleet calls. This is the allocator measurement
    pass + controller metrics hot path.
  * train plane — one micro-window for every job: the seed's
    per-job `train_micro` loop vs one vmapped `train_micro_many`
    dispatch per shape group.

Both paths are asserted bit-identical while being timed (the parity
suite in tests/test_trainer_bank.py pins the semantics; here it guards
the benchmark itself). Results go to stdout as CSV rows and to
BENCH_trainer.json so the perf trajectory is tracked across PRs.

Each sweep also records the JobBank residency-cache counters
(TransferStats) around its timed region: `*_sync` columns report
host<->device STATE crossings (sync events + bytes). The batched
passes run on the device-resident bank and must show ZERO syncs per
timed pass — asserted here — while the host-resident scalar twin pays
a full state round-trip per job per micro-window; that per-call
transfer is exactly what the slot cache removes on launch-bound
accelerators.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, make_engine
from repro.configs import smoke_config
from repro.core.batching import job_precision
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob, SharedEngine

VOCAB = 64
SEQ = 32
EVAL_BATCH = 4          # subsample sequences per member
POOL_ROWS = 64
TRAIN_BATCH = 8
MICRO_STEPS = 4
MEMBERS_PER_JOB = 16
MAX_JOBS = 100          # caps bank memory at the 10k-member point

OUT_JSON = "BENCH_trainer.json"


def _scalar_engine() -> SharedEngine:
    # the seed twin: no vmapped dispatch AND the host-resident bank, so
    # its transfer counters show the per-job state round-trips the
    # device-resident cache eliminates
    cfg = dataclasses.replace(smoke_config("olmo-1b"), vocab_size=VOCAB)
    return SharedEngine(cfg, batched=False, resident=False)


def _sync_cols(rows: Rows, tag: str, before: dict, after: dict) -> dict:
    """Diff two TransferStats snapshots into CSV rows + a JSON blob."""
    d = {k: after[k] - before[k] for k in before}
    rows.add(f"{tag}_h2d_syncs", d["h2d_syncs"])
    rows.add(f"{tag}_d2h_syncs", d["d2h_syncs"])
    rows.add(f"{tag}_state_bytes", d["h2d_bytes"] + d["d2h_bytes"])
    return d


def _fleet(engine, members: int, *, seed0: int = 0):
    """`members` streams spread over min(MAX_JOBS, members//10) jobs,
    identically seeded so the batched/scalar fleets are twins."""
    n_jobs = max(1, min(MAX_JOBS, members // MEMBERS_PER_JOB))
    rng = np.random.default_rng(1234)
    jobs, pairs = [], []
    for j in range(n_jobs):
        lo = j * members // n_jobs
        hi = (j + 1) * members // n_jobs
        first = Request(stream_id=f"s{lo}", t=0.0, loc=(0.0, 0.0),
                        subsamples=rng.integers(
                            0, VOCAB, size=(EVAL_BATCH, SEQ)),
                        acc=0.0,
                        train_data=rng.integers(
                            0, VOCAB, size=(POOL_ROWS, SEQ)))
        job = RetrainJob(engine, first, micro_steps=MICRO_STEPS,
                         batch=TRAIN_BATCH, seed=seed0 + j,
                         pool_rows=POOL_ROWS)
        for m in range(lo + 1, hi):
            job.add_member(Request(
                stream_id=f"s{m}", t=0.0, loc=(0.0, 0.0),
                subsamples=rng.integers(0, VOCAB, size=(EVAL_BATCH, SEQ)),
                acc=0.0))
        jobs.append(job)
        pairs.extend((job, mem.subsamples) for mem in job.members)
    return jobs, pairs


def _eval_plane(rows: Rows, engine, sizes, results):
    """Fleet eval pass: per-member loop vs batched fleet calls."""
    for members in sizes:
        jobs, pairs = _fleet(engine, members)
        # seed loop kept params per job on device (no bank read per
        # member): prefetch once, then one `accuracy` launch per member
        params_by_job = {id(j): jax.tree.map(jnp.asarray,
                                             j.state["params"])
                         for j in jobs}
        # warm both executables on the real shapes (chunk sizes pad to
        # powers of two, so a 1-pair warm call would leave the big
        # chunk shapes compiling inside the timed region)
        engine.accuracy(params_by_job[id(jobs[0])], pairs[0][1])
        engine.eval_pairs(pairs)
        t0 = time.perf_counter()
        scalar = [engine.accuracy(params_by_job[id(j)], s)
                  for j, s in pairs]
        t_scalar = time.perf_counter() - t0

        before = engine.bank.stats.snapshot()
        t0 = time.perf_counter()
        batched = engine.eval_pairs(pairs)
        t_batched = time.perf_counter() - t0
        sync = _sync_cols(rows, f"eval_n{members}_batched", before,
                          engine.bank.stats.snapshot())
        # the resident fleet was flushed by the warm call: the timed
        # batched pass must not move ANY state across the host boundary
        assert sync["h2d_syncs"] == 0 and sync["d2h_syncs"] == 0, \
            "batched eval pass transferred bank state"

        assert batched == scalar, "eval plane drifted from scalar loop"
        sp = t_scalar / max(t_batched, 1e-9)
        rows.add(f"eval_n{members}_scalar_s", t_scalar)
        rows.add(f"eval_n{members}_batched_s", t_batched)
        rows.add(f"eval_n{members}_speedup", sp)
        results["eval_plane"].append(dict(
            members=members, jobs=len(jobs), pairs=len(pairs),
            precision=job_precision(jobs[0]),
            scalar_s=round(t_scalar, 4), batched_s=round(t_batched, 4),
            speedup=round(sp, 2), batched_sync=sync))
        for j in jobs:
            j.release()


def _train_plane(rows: Rows, engine, scalar_engine, sizes, results,
                 micro_windows: int = 2):
    """One micro-window for every job of the fleet, `micro_windows`
    times: sequential train_micro on the scalar twin vs
    train_micro_many on the batched engine (identical seeds, identical
    trajectories)."""
    for members in sizes:
        fast, _ = _fleet(engine, members, seed0=members)
        slow, _ = _fleet(scalar_engine, members, seed0=members)

        # warm the compile caches with window 0 on BOTH fleets
        # (untimed) so the timed windows compare identical work and the
        # twin trajectories stay in lock-step
        engine.train_micro_many(fast)
        for j in slow:
            j.train_micro()

        before = engine.bank.stats.snapshot()
        t0 = time.perf_counter()
        for _ in range(micro_windows):
            engine.train_micro_many(fast)
        t_batched = time.perf_counter() - t0
        bsync = _sync_cols(rows, f"train_n{members}_batched", before,
                           engine.bank.stats.snapshot())
        assert bsync["h2d_syncs"] == 0 and bsync["d2h_syncs"] == 0, \
            "batched train pass transferred bank state"

        before = scalar_engine.bank.stats.snapshot()
        t0 = time.perf_counter()
        for _ in range(micro_windows):
            for j in slow:
                j.train_micro()
        t_scalar = time.perf_counter() - t0
        ssync = _sync_cols(rows, f"train_n{members}_scalar", before,
                           scalar_engine.bank.stats.snapshot())

        for f, s in zip(fast, slow):
            af = engine.eval_pairs([(f, m.subsamples)
                                    for m in f.members[:1]])
            # fleetlint: disable=per-member-loop -- the parity check's
            # scalar REFERENCE twin: the whole point is comparing the
            # batched plane against this exact loop
            as_ = [s.eval_on(m.subsamples) for m in s.members[:1]]
            assert af == as_, "train plane drifted from scalar loop"
        sp = t_scalar / max(t_batched, 1e-9)
        rows.add(f"train_n{members}_scalar_s", t_scalar)
        rows.add(f"train_n{members}_batched_s", t_batched)
        rows.add(f"train_n{members}_speedup", sp)
        results["train_plane"].append(dict(
            members=members, jobs=len(fast),
            precision=job_precision(fast[0]),
            micro_windows=micro_windows,
            scalar_s=round(t_scalar, 4), batched_s=round(t_batched, 4),
            speedup=round(sp, 2), batched_sync=bsync, scalar_sync=ssync))
        for j in fast + slow:
            j.release()


def run(smoke: bool = False):
    rows = Rows("trainer")
    engine = make_engine()
    scalar_engine = _scalar_engine()
    results = {"smoke": smoke, "eval_plane": [], "train_plane": []}
    if smoke:
        _eval_plane(rows, engine, (40, 120), results)
        _train_plane(rows, engine, scalar_engine, (40,), results,
                     micro_windows=1)
    else:
        _eval_plane(rows, engine, (100, 1000, 10000), results)
        _train_plane(rows, engine, scalar_engine, (100, 1000, 10000),
                     results)
    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=1)
        f.write("\n")
    rows.add("json_out", OUT_JSON)
    return rows.emit()


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:] or bool(os.environ.get("SMOKE")))
