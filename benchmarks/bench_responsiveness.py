"""Paper Fig. 12 + 13: responsiveness.

Fig. 12 (natural model reuse): streams join an ongoing group job one
window apart; later joiners must start from the group's already-adapted
model — higher initial accuracy than a cold start (and than a stale
zoo model).

Fig. 13 (data aggregation): time-to-threshold under per-stream uplink
caps. Group retraining aggregates three trickles into one usable stream;
independent retraining waits on a single trickle.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, make_engine
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob
from repro.data.streams import DomainBank

VOCAB = 64
THRESHOLD = 0.35


def _req(bank, rng, sid, dom):
    toks = bank.sample(dom, rng, 4, 32)
    return Request(stream_id=sid, t=0.0, loc=(0, 0), subsamples=toks,
                   acc=0.0, train_data=toks)


def run():
    rows = Rows("responsiveness")
    engine = make_engine()
    bank = DomainBank(VOCAB, 4, dim=4, seed=0)
    rng = np.random.default_rng(0)
    dom = 0

    # ---- Fig. 12: natural model reuse --------------------------------
    job = RetrainJob(engine, _req(bank, rng, "s0", dom), micro_steps=4,
                     batch=16, seed=0)
    initial = {}
    for w, joiner in ((0, None), (1, "s1"), (2, "s2")):
        if joiner:
            ev = bank.sample(dom, rng, 16, 32)
            initial[joiner + "_group"] = engine.accuracy(
                job.state["params"], ev)          # joiner's t0 accuracy
            cold = engine.fresh_state(1)
            initial[joiner + "_cold"] = engine.accuracy(cold["params"],
                                                        ev)
            job.add_member(_req(bank, rng, joiner, dom))
        job.ingest(bank.sample(dom, rng, 8, 32))
        for _ in range(3):
            job.train_micro()
    for k, v in initial.items():
        rows.add(f"fig12_initial_{k}", v)
    rows.add("fig12_reuse_beats_cold",
             int(initial["s1_group"] > initial["s1_cold"] + 0.1 and
                 initial["s2_group"] > initial["s2_cold"] + 0.1))

    # ---- Fig. 13: data aggregation under low uplinks -----------------
    # each stream can deliver only 2 seqs/window; threshold accuracy
    for caps_label, per_stream in (("low_bw", 2), ("very_low_bw", 1)):
        ev = bank.sample(dom, rng, 16, 32)

        # group: 3 trickles aggregate
        g = RetrainJob(engine, _req(bank, rng, "g0", dom), micro_steps=4,
                       batch=16, seed=0)
        g.add_member(_req(bank, rng, "g1", dom))
        g.add_member(_req(bank, rng, "g2", dom))
        t_group = None
        for w in range(12):
            for _ in range(3):
                g.ingest(bank.sample(dom, rng, per_stream, 32))
            g.train_micro()
            if t_group is None and \
                    engine.accuracy(g.state["params"], ev) >= THRESHOLD:
                t_group = w + 1
        # independent: one trickle
        j = RetrainJob(engine, _req(bank, rng, "i0", dom), micro_steps=4,
                       batch=16, seed=0)
        t_ind = None
        for w in range(12):
            j.ingest(bank.sample(dom, rng, per_stream, 32))
            j.train_micro()
            if t_ind is None and \
                    engine.accuracy(j.state["params"], ev) >= THRESHOLD:
                t_ind = w + 1
        rows.add(f"fig13_{caps_label}_group_windows_to_{THRESHOLD}",
                 t_group if t_group else ">12")
        rows.add(f"fig13_{caps_label}_indep_windows_to_{THRESHOLD}",
                 t_ind if t_ind else ">12")
        if t_group and t_ind:
            rows.add(f"fig13_{caps_label}_speedup", t_ind / t_group)
    return rows.emit()


if __name__ == "__main__":
    run()
