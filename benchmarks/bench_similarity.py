"""Paper Fig. 2c + Fig. 8: group vs independent retraining as a function
of cross-stream similarity — plus the fleet-scale drift-signature
similarity sweep (per-pair Python js_divergence loop vs the batched
pairwise_js kernel, 100 -> 10k stream signatures).

High similarity   — all 3 streams in one region (same domain trajectory)
Medium similarity — 2 streams share a domain, 1 drifts to a neighbour
                    domain mixture
Low similarity    — 3 streams on 3 unrelated domains

Group retraining trains ONE model on the pooled inflow with the full
micro-window budget; independent retrains one model per stream with 1/3
of the budget each. The paper's claim: group wins at high similarity,
the advantage shrinks with similarity, and roughly vanishes (or
reverses) at low similarity.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Rows, make_engine
from repro.core.drift import js_divergence
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob
from repro.data.streams import DomainBank
from repro.kernels import ops

VOCAB = 64
WINDOWS = 6
MICRO_PER_WINDOW = 2        # group budget / window (indep: 2/3 each)

SIG_FLEET_SIZES = (100, 1000, 10000)
SIG_REQUESTS = 8
SIG_BUCKETS = 64


def run_signature_scale(rows: Rows):
    """(R, N) JS-divergence matrix: Python double loop vs one batched
    pairwise_js call, swept over fleet size."""
    rng = np.random.default_rng(0)
    for n in SIG_FLEET_SIZES:
        sigs = rng.random((n, SIG_BUCKETS)).astype(np.float32)
        reqs = rng.random((SIG_REQUESTS, SIG_BUCKETS)).astype(np.float32)
        ops.pairwise_js(reqs, sigs)                     # jit warmup

        t0 = time.perf_counter()
        loop = np.array([[js_divergence(r, s) for s in sigs]
                         for r in reqs])
        t_py = time.perf_counter() - t0

        t0 = time.perf_counter()
        batched = np.asarray(ops.pairwise_js(reqs, sigs))
        t_batch = time.perf_counter() - t0

        rows.add(f"sig_n{n}_python_ms", 1e3 * t_py)
        rows.add(f"sig_n{n}_batched_ms", 1e3 * t_batch)
        rows.add(f"sig_n{n}_speedup", t_py / max(t_batch, 1e-9))
        rows.add(f"sig_n{n}_max_abs_err",
                 float(np.abs(batched - loop).max()))


def _req(sid, toks):
    return Request(stream_id=sid, t=0.0, loc=(0, 0), subsamples=toks,
                   acc=0.0, train_data=toks)


def _run_setting(engine, bank, domains, rng):
    """domains: per-stream domain id per window (list of 3 callables)."""
    evals = [bank.sample(domains[i](WINDOWS - 1), rng, 16, 32)
             for i in range(3)]

    def inflow(i, w):
        return bank.sample(domains[i](w), rng, 4, 32)

    # group retraining
    gjob = RetrainJob(engine, _req("s0", inflow(0, 0)), micro_steps=4,
                      batch=16, seed=0)
    gjob.add_member(_req("s1", inflow(1, 0)))
    gjob.add_member(_req("s2", inflow(2, 0)))
    for w in range(WINDOWS):
        for i in range(3):
            gjob.ingest(inflow(i, w))
        for _ in range(MICRO_PER_WINDOW):
            gjob.train_micro()
    group = float(np.mean([engine.accuracy(gjob.state["params"], ev)
                           for ev in evals]))

    # independent retraining: 3 jobs, each 1/3 of the micro budget
    accs = []
    total_micro = WINDOWS * MICRO_PER_WINDOW
    per_job = total_micro // 3
    for i in range(3):
        job = RetrainJob(engine, _req(f"s{i}", inflow(i, 0)),
                         micro_steps=4, batch=16, seed=0)
        done = 0
        for w in range(WINDOWS):
            job.ingest(inflow(i, w))
            if done < per_job and w % (WINDOWS // max(1, per_job)) == 0:
                job.train_micro()
                done += 1
        accs.append(engine.accuracy(job.state["params"], evals[i]))
    indep = float(np.mean(accs))
    return group, indep


def run():
    rows = Rows("similarity")
    run_signature_scale(rows)
    engine = make_engine()
    bank = DomainBank(VOCAB, 6, dim=4, seed=0)
    rng = np.random.default_rng(0)

    settings = {
        # high: everyone on domain 0
        "high": [lambda w: 0, lambda w: 0, lambda w: 0],
        # medium: stream 2 alternates into domain 1
        "medium": [lambda w: 0, lambda w: 0,
                   lambda w: 0 if w % 2 == 0 else 1],
        # low: disjoint domains
        "low": [lambda w: 0, lambda w: 2, lambda w: 4],
    }
    deltas = {}
    for name, doms in settings.items():
        group, indep = _run_setting(engine, bank, doms, rng)
        rows.add(f"{name}_group_acc", group)
        rows.add(f"{name}_indep_acc", indep)
        rows.add(f"{name}_group_advantage", group - indep)
        deltas[name] = group - indep
    # paper claims (Fig. 8): group retraining wins under correlated
    # drift and the advantage vanishes/reverses for unrelated streams
    rows.add("group_wins_at_high_similarity", int(deltas["high"] > 0.02))
    rows.add("advantage_collapses_at_low_similarity",
             int(deltas["low"] < deltas["high"] - 0.05))
    return rows.emit()


if __name__ == "__main__":
    run()
