"""Roofline summary table from the multi-pod dry-run results
(dryrun_results.json — produced by repro.launch.dryrun). This is the
source for EXPERIMENTS.md §Roofline: per (arch x shape x mesh) the three
roofline terms, the dominant bottleneck, and the roofline fraction.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import Rows

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")


def run():
    rows = Rows("roofline")
    if not os.path.exists(RESULTS):
        rows.add("status", "missing dryrun_results.json — run "
                 "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return rows.emit()
    with open(RESULTS) as f:
        results = json.load(f)
    ok = [r for r in results if r["status"] == "ok"]
    skip = [r for r in results if r["status"].startswith("skip")]
    rows.add("cells_ok", len(ok))
    rows.add("cells_skipped_documented", len(skip))
    rows.add("cells_error", len(results) - len(ok) - len(skip))
    for r in ok:
        key = f"{r['arch']}.{r['shape']}.{r['mesh']}"
        t = r["roofline"]
        rows.add(f"{key}.compute_s", t["compute_s"])
        rows.add(f"{key}.memory_s", t["memory_s"])
        rows.add(f"{key}.collective_s", t["collective_s"])
        rows.add(f"{key}.dominant", r["dominant"].replace("_s", ""))
        rows.add(f"{key}.useful_flops_ratio", r["useful_flops_ratio"])
        rows.add(f"{key}.roofline_fraction", r["roofline_fraction"])
    # fleet-level aggregates
    fracs = [r["roofline_fraction"] for r in ok]
    rows.add("mean_roofline_fraction", sum(fracs) / len(fracs))
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    rows.add("worst_cell",
             f"{worst['arch']}.{worst['shape']}.{worst['mesh']}")
    return rows.emit()


if __name__ == "__main__":
    run()
