"""Fault-tolerance drill: checkpoint save/restore latency + fidelity,
mid-training failure recovery, elastic window checkpoint/restore cost,
straggler quota renormalization — and the hostile-scenario sweep: the
four adversarial workloads from repro.data.scenarios run end to end
with the window invariants (repro.testing.invariants) ENABLED, a
10k-join registry stress, and a sensor blackout composed with a
mid-window device loss (FleetElastic) in a 2-device subprocess.

`--smoke` (or SMOKE=1) runs the hostile sweep at golden scale for CI;
the full run uses larger fleets and adds the 10k-join stress.

Results go to stdout as CSV rows AND to BENCH_faults.json so the
recovery-cost trajectory is machine-readable across PRs; CI's
bench-smoke and adversarial-smoke jobs upload it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, make_engine
from repro.core.grouping import Request
from repro.core.rows import RowRegistry
from repro.core.trainer import RetrainJob
from repro.data.scenarios import HOSTILE_SCENARIOS, build_scenario
from repro.data.streams import DomainBank
from repro.distributed import checkpoint as ckpt
from repro.distributed.elastic import FleetElastic
from repro.distributed.stragglers import StragglerPolicy
from repro.testing.trace import (HOSTILE_GOLDEN, hostile_controller_kwargs,
                                 run_scenario)

OUT_JSON = "BENCH_faults.json"

# full-mode hostile fleets: bigger than the goldens, still CPU-sized.
# flash_crowd's registry/bank growth at the real 10k is covered by
# _registry_stress below — a 10k-joiner *training* run is not a CPU job.
_FULL_HOSTILE = {
    "flash_crowd_10k": dict(seed=0, joiners=48, base_regions=2,
                            streams_per_region=2, join_window=1,
                            windows=5),
    "sensor_blackout": dict(seed=0),
    "oscillating_drift": dict(seed=0),
    "bandwidth_collapse": dict(seed=0),
}


def _checkpoint_drills(rows: Rows, engine):
    bank = DomainBank(64, 4, dim=4, seed=0)
    rng = np.random.default_rng(0)
    toks = bank.sample(0, rng, 8, 32)
    job = RetrainJob(engine, Request("s0", 0.0, (0, 0), toks, 0.0,
                                     train_data=toks),
                     micro_steps=4, batch=16, seed=0)
    for _ in range(4):
        job.ingest(bank.sample(0, rng, 8, 32))
        job.train_micro()
    ev = bank.sample(0, rng, 16, 32)
    acc_before = engine.accuracy(job.state["params"], ev)

    with tempfile.TemporaryDirectory() as d:
        # blocking save latency
        t0 = time.perf_counter()
        ckpt.save(d, 1, job.state)
        rows.add("save_blocking_ms", (time.perf_counter() - t0) * 1e3)
        # async save does not block the training thread
        c = ckpt.AsyncCheckpointer(d)
        t0 = time.perf_counter()
        c.save_async(2, job.state)
        rows.add("save_async_dispatch_ms",
                 (time.perf_counter() - t0) * 1e3)
        c.wait()
        # failure: clobber state, restore, verify accuracy identical
        nbytes = sum(np.asarray(x).nbytes
                     for x in jax.tree.leaves(job.state))
        rows.add("state_megabytes", nbytes / 1e6)
        job.state = jax.tree.map(jnp.zeros_like, job.state)
        t0 = time.perf_counter()
        job.state, _ = ckpt.restore(d, ckpt.latest_step(d), job.state)
        rows.add("restore_ms", (time.perf_counter() - t0) * 1e3)
        acc_after = engine.accuracy(job.state["params"], ev)
        rows.add("acc_before_failure", acc_before)
        rows.add("acc_after_recovery", acc_after)
        rows.add("recovery_exact", int(abs(acc_before - acc_after) < 1e-6))

    # elastic window protocol cost: the per-window recovery point
    # (disk checkpoint of every job's train-state) and the rollback's
    # restore-through-the-bank path (docs/distributed_plane.md). A
    # 4-job fleet exercises the {job_id: state} tree shape.
    jobs = [job] + [RetrainJob(engine,
                               Request(f"s{i}", 0.0, (0, 0), toks, 0.0,
                                       train_data=toks),
                               micro_steps=4, batch=16, seed=i)
                    for i in range(1, 4)]
    with tempfile.TemporaryDirectory() as d:
        el = FleetElastic(d)
        t0 = time.perf_counter()
        el.on_window_start(jobs)
        rows.add("elastic_window_ckpt_ms",
                 (time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        el.restore_jobs(jobs)
        rows.add("elastic_restore_jobs_ms",
                 (time.perf_counter() - t0) * 1e3)
        acc_el = engine.accuracy(jobs[0].state["params"], ev)
        rows.add("elastic_restore_exact",
                 int(abs(acc_before - acc_el) < 1e-6))


def _straggler_drill(rows: Rows):
    # straggler mitigation: wall time per micro-window stays bounded
    pol = StragglerPolicy(threshold=2.0)
    rngs = np.random.default_rng(1)
    base = 8
    wall_naive, wall_mitigated = 0.0, 0.0
    for w in range(16):
        for jid, t in (("a", 1.0), ("b", 1.1), ("slow", 4.0)):
            step_t = t * (1 + 0.05 * rngs.standard_normal())
            pol.record(jid, step_t)
            wall_naive += base * step_t
            wall_mitigated += pol.quota(jid, base) * step_t
    rows.add("straggler_wall_naive_s", wall_naive)
    rows.add("straggler_wall_mitigated_s", wall_mitigated)
    rows.add("straggler_wall_reduction",
             wall_naive / max(wall_mitigated, 1e-9))
    rows.add("straggler_flagged", int(pol.is_straggler("slow")))


def _hostile_sweep(rows: Rows, engine, *, smoke: bool):
    """The four adversarial scenarios end to end, invariants ON
    (run_scenario's default): every window is checked against the
    bandwidth/share/grouping/residency laws, so a row here certifies
    the hostile regime ran clean — not just that it ran."""
    for name in HOSTILE_SCENARIOS:
        spec = (HOSTILE_GOLDEN[name]["scenario"] if smoke
                else _FULL_HOSTILE[name])
        for fw in ("ecco", "naive"):
            sc = build_scenario(name, **spec)
            ctl = run_scenario(fw, sc, engine=engine,
                               **hostile_controller_kwargs(name))
            rows.add(f"{name}_{fw}_acc", ctl.mean_accuracy(last_k=2))
            rows.add(f"{name}_{fw}_jobs", len(ctl.jobs))
            rows.add(f"{name}_{fw}_invariant_windows",
                     getattr(ctl, "invariant_windows", 0))


def _registry_stress(rows: Rows, n: int = 10_000):
    """flash_crowd_10k's control-plane growth path at full scale: 10k
    dense-row joins, then a half-fleet eviction storm, without the
    training loop in the way. The registry must stay a dense prefix
    throughout — the contract every batched plane kernels against."""
    reg = RowRegistry(capacity=2)
    t0 = time.perf_counter()
    for i in range(n):
        reg.add(f"crowd{i}")
    rows.add("registry_10k_join_ms", (time.perf_counter() - t0) * 1e3)
    t0 = time.perf_counter()
    for i in range(0, n, 2):
        reg.remove(f"crowd{i}")
    rows.add("registry_10k_evict_half_ms",
             (time.perf_counter() - t0) * 1e3)
    dense = sorted(reg[r] for r in reg.ids) == list(range(len(reg)))
    rows.add("registry_10k_dense_after_churn", int(dense))
    rows.add("registry_10k_survivors", len(reg))


# sensor blackout composed with a device failure: the doomed region's
# streams leave at the window boundary AND the elastic runtime loses a
# device mid-window, so the retry re-runs the shrunken fleet on the
# shrunken mesh — with the invariant checker watching every window.
# Device count is fixed at jax import, hence the subprocess.
_BLACKOUT_ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import dataclasses, json, tempfile
    import jax

    from repro.configs import smoke_config
    from repro.core.baselines import FRAMEWORKS
    from repro.core.controller import ControllerConfig
    from repro.core.trainer import SharedEngine
    from repro.data.scenarios import build_scenario
    from repro.distributed.elastic import FleetElastic
    from repro.launch.mesh import make_fleet_mesh
    from repro.testing.invariants import InvariantChecker

    assert jax.device_count() == 2, jax.devices()
    spec = json.loads(os.environ["BLACKOUT_SPEC"])
    sc = build_scenario("sensor_blackout", **spec["scenario"])
    engine = SharedEngine(dataclasses.replace(
        smoke_config("olmo-1b"), vocab_size=sc.bank.vocab))
    kw = dict(window_seconds=sc.window_seconds,
              shared_bandwidth=sc.shared_bandwidth,
              local_caps=sc.local_caps)
    kw.update(spec["controller"])
    cc = ControllerConfig(**kw)
    with tempfile.TemporaryDirectory() as d:
        el = FleetElastic(d, mesh=make_fleet_mesh(2))
        ctl = FRAMEWORKS["ecco"](engine, list(sc.streams), cc, seed=0,
                                 elastic=el)
        ctl.warmup()
        chk = InvariantChecker(label="sensor_blackout/ecco+elastic")
        blackout = spec["scenario"]["blackout_window"]
        for w in range(sc.windows):
            churned = set()
            for ev in sc.events_at(w):
                if ev.kind == "join" and ev.stream is not None:
                    ctl.add_stream(ev.stream)
                    churned.add(ev.stream_id)
                elif ev.kind == "leave":
                    ctl.remove_stream(ev.stream_id)
                    churned.add(ev.stream_id)
            if w == blackout:
                # the region dies and takes a device with it mid-window
                el.schedule_failure(1, after_barriers=2)
            chk.before_window(ctl, churned)
            n_ev = len(ctl.grouper.events)
            wm = ctl.run_window()
            chk.after_window(ctl, wm, ctl.grouper.events[n_ev:])
        acc = float(ctl.mean_accuracy(last_k=2))
        print(json.dumps({
            "windows": chk.windows_checked,
            "devices_after": len(el.devices()),
            "acc": None if acc != acc else acc,
        }))
""")


def _blackout_elastic(rows: Rows):
    spec = {"scenario": HOSTILE_GOLDEN["sensor_blackout"]["scenario"],
            "controller": hostile_controller_kwargs("sensor_blackout")}
    env = dict(os.environ, BLACKOUT_SPEC=json.dumps(spec))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _BLACKOUT_ELASTIC_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    rows.add("blackout_elastic_invariant_windows", out["windows"])
    rows.add("blackout_elastic_devices_after", out["devices_after"])
    rows.add("blackout_elastic_acc",
             float("nan") if out["acc"] is None else out["acc"])
    rows.add("blackout_elastic_clean", 1)


def run(smoke: bool = False):
    rows = Rows("faults")
    engine = make_engine()
    _checkpoint_drills(rows, engine)
    _straggler_drill(rows)
    _hostile_sweep(rows, engine, smoke=smoke)
    if not smoke:
        _registry_stress(rows)
    _blackout_elastic(rows)
    metrics = {k: (None if isinstance(v, float) and not np.isfinite(v)
                   else v)
               for k, v in rows.metrics.items()}
    with open(OUT_JSON, "w") as f:
        json.dump({"smoke": smoke, "metrics": metrics}, f, indent=1,
                  allow_nan=False)
        f.write("\n")
    rows.add("json_out", OUT_JSON)
    return rows.emit()


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:] or bool(os.environ.get("SMOKE")))
