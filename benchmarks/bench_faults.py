"""Fault-tolerance drill: checkpoint save/restore latency + fidelity,
mid-training failure recovery, elastic window checkpoint/restore cost,
and straggler quota renormalization — the operational half of "runs on
thousands of nodes".

Results go to stdout as CSV rows AND to BENCH_faults.json so the
recovery-cost trajectory is machine-readable across PRs; CI's
bench-smoke job uploads it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, make_engine
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob
from repro.data.streams import DomainBank
from repro.distributed import checkpoint as ckpt
from repro.distributed.elastic import FleetElastic
from repro.distributed.stragglers import StragglerPolicy

OUT_JSON = "BENCH_faults.json"


def run():
    rows = Rows("faults")
    engine = make_engine()
    bank = DomainBank(64, 4, dim=4, seed=0)
    rng = np.random.default_rng(0)
    toks = bank.sample(0, rng, 8, 32)
    job = RetrainJob(engine, Request("s0", 0.0, (0, 0), toks, 0.0,
                                     train_data=toks),
                     micro_steps=4, batch=16, seed=0)
    for _ in range(4):
        job.ingest(bank.sample(0, rng, 8, 32))
        job.train_micro()
    ev = bank.sample(0, rng, 16, 32)
    acc_before = engine.accuracy(job.state["params"], ev)

    with tempfile.TemporaryDirectory() as d:
        # blocking save latency
        t0 = time.perf_counter()
        ckpt.save(d, 1, job.state)
        rows.add("save_blocking_ms", (time.perf_counter() - t0) * 1e3)
        # async save does not block the training thread
        c = ckpt.AsyncCheckpointer(d)
        t0 = time.perf_counter()
        c.save_async(2, job.state)
        rows.add("save_async_dispatch_ms",
                 (time.perf_counter() - t0) * 1e3)
        c.wait()
        # failure: clobber state, restore, verify accuracy identical
        nbytes = sum(np.asarray(x).nbytes
                     for x in jax.tree.leaves(job.state))
        rows.add("state_megabytes", nbytes / 1e6)
        job.state = jax.tree.map(jnp.zeros_like, job.state)
        t0 = time.perf_counter()
        job.state, _ = ckpt.restore(d, ckpt.latest_step(d), job.state)
        rows.add("restore_ms", (time.perf_counter() - t0) * 1e3)
        acc_after = engine.accuracy(job.state["params"], ev)
        rows.add("acc_before_failure", acc_before)
        rows.add("acc_after_recovery", acc_after)
        rows.add("recovery_exact", int(abs(acc_before - acc_after) < 1e-6))

    # elastic window protocol cost: the per-window recovery point
    # (disk checkpoint of every job's train-state) and the rollback's
    # restore-through-the-bank path (docs/distributed_plane.md). A
    # 4-job fleet exercises the {job_id: state} tree shape.
    jobs = [job] + [RetrainJob(engine,
                               Request(f"s{i}", 0.0, (0, 0), toks, 0.0,
                                       train_data=toks),
                               micro_steps=4, batch=16, seed=i)
                    for i in range(1, 4)]
    with tempfile.TemporaryDirectory() as d:
        el = FleetElastic(d)
        t0 = time.perf_counter()
        el.on_window_start(jobs)
        rows.add("elastic_window_ckpt_ms",
                 (time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        el.restore_jobs(jobs)
        rows.add("elastic_restore_jobs_ms",
                 (time.perf_counter() - t0) * 1e3)
        acc_el = engine.accuracy(jobs[0].state["params"], ev)
        rows.add("elastic_restore_exact",
                 int(abs(acc_before - acc_el) < 1e-6))

    # straggler mitigation: wall time per micro-window stays bounded
    pol = StragglerPolicy(threshold=2.0)
    rngs = np.random.default_rng(1)
    base = 8
    wall_naive, wall_mitigated = 0.0, 0.0
    for w in range(16):
        for jid, t in (("a", 1.0), ("b", 1.1), ("slow", 4.0)):
            step_t = t * (1 + 0.05 * rngs.standard_normal())
            pol.record(jid, step_t)
            wall_naive += base * step_t
            wall_mitigated += pol.quota(jid, base) * step_t
    rows.add("straggler_wall_naive_s", wall_naive)
    rows.add("straggler_wall_mitigated_s", wall_mitigated)
    rows.add("straggler_wall_reduction",
             wall_naive / max(wall_mitigated, 1e-9))
    rows.add("straggler_flagged", int(pol.is_straggler("slow")))
    metrics = {k: (None if isinstance(v, float) and not np.isfinite(v)
                   else v)
               for k, v in rows.metrics.items()}
    with open(OUT_JSON, "w") as f:
        json.dump({"metrics": metrics}, f, indent=1, allow_nan=False)
        f.write("\n")
    rows.add("json_out", OUT_JSON)
    return rows.emit()


if __name__ == "__main__":
    run()
