"""Paper Fig. 6: end-to-end accuracy of ECCO vs baselines across
(a) compute budgets (micro-windows per retraining window — the GPU
count analogue) and (b) shared-bandwidth budgets.

All frameworks run the same fleet (2 regions x 3 streams, one drift
event) and the same substrate; only the coordination differs:
  naive — independent jobs, round-robin compute, equal bandwidth
  ekya  — independent jobs, greedy microprofiled compute
  recl  — ekya + model-zoo reuse
  ecco  — group retraining + Alg.1 compute + GAIMD bandwidth
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, make_engine, run_framework
from repro.data.scenarios import build_scenario
from repro.data.streams import make_fleet
from repro.testing.trace import run_scenario

WINDOWS = 8


def run():
    rows = Rows("end_to_end")
    engine = make_engine()

    # --- (a) accuracy vs compute budget at constrained bandwidth -------
    for budget in (4, 8, 16):
        for fw in ("naive", "ekya", "recl", "ecco"):
            _, streams = make_fleet(regions=2, streams_per_region=3,
                                    switch_times=(10.0,), seed=0)
            ctl = run_framework(fw, engine, streams, windows=WINDOWS,
                                window_micro=budget,
                                shared_bandwidth=96.0)
            rows.add(f"gpu{budget}_{fw}_acc", ctl.mean_accuracy(last_k=3))

    # --- (b) accuracy vs shared bandwidth at fixed compute -------------
    for bw in (24.0, 48.0, 192.0):
        for fw in ("naive", "recl", "ecco"):
            _, streams = make_fleet(regions=2, streams_per_region=3,
                                    switch_times=(10.0,), seed=0)
            ctl = run_framework(fw, engine, streams, windows=WINDOWS,
                                window_micro=8, shared_bandwidth=bw)
            rows.add(f"bw{int(bw)}_{fw}_acc", ctl.mean_accuracy(last_k=3))

    # --- (c) drift-pattern diversity (repro.data.scenarios) ------------
    # the recurring and correlated-burst patterns stress model reuse and
    # grouping in ways the single-switch fleet above cannot
    for name in ("diurnal", "flash_crowd"):
        for fw in ("recl", "ecco"):
            sc = build_scenario(name, seed=0)
            ctl = run_scenario(fw, sc, engine=engine, window_micro=8,
                               shared_bandwidth=96.0)
            rows.add(f"{name}_{fw}_acc", ctl.mean_accuracy(last_k=3))
    return rows.emit()


if __name__ == "__main__":
    run()
