"""Paper Fig. 7: scalability — accuracy and response time as the number
of streams grows under a FIXED compute budget. Independent retraining's
demand grows linearly with streams; group retraining aggregates
correlated streams, so degradation is milder (the paper reports 3.3x
more cameras at equal accuracy).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, make_engine, run_framework
from repro.data.streams import make_fleet

WINDOWS = 8
BUDGET = 8          # micro-windows/window, fixed while streams grow
ACC_THRESHOLD = 0.4


def run():
    rows = Rows("scalability")
    engine = make_engine()
    summary = {}
    for n_per in (1, 2, 4):        # 2 regions x n = 2/4/8 streams
        for fw in ("recl", "ecco"):
            _, streams = make_fleet(regions=2, streams_per_region=n_per,
                                    switch_times=(10.0,), seed=0)
            ctl = run_framework(fw, engine, streams, windows=WINDOWS,
                                window_micro=BUDGET,
                                shared_bandwidth=96.0)
            acc = ctl.mean_accuracy(last_k=3)
            rt = ctl.response_times(ACC_THRESHOLD)
            mean_rt = (float(np.mean(list(rt.values())))
                       if rt else float("inf"))
            n = 2 * n_per
            rows.add(f"n{n}_{fw}_acc", acc)
            rows.add(f"n{n}_{fw}_response_time", mean_rt)
            summary[(n, fw)] = acc
    # paper claim: ECCO degrades slower with scale than RECL
    drop_ecco = summary[(2, "ecco")] - summary[(8, "ecco")]
    drop_recl = summary[(2, "recl")] - summary[(8, "recl")]
    rows.add("acc_drop_2to8_ecco", drop_ecco)
    rows.add("acc_drop_2to8_recl", drop_recl)
    rows.add("ecco_degrades_slower", int(drop_ecco < drop_recl + 0.02))
    # supported streams at the accuracy RECL achieves with 8 streams
    target = summary[(8, "recl")]
    for n in (2, 4, 8):
        if summary[(n, "ecco")] >= target:
            rows.add("ecco_supports_n_at_recl8_acc", n)
    return rows.emit()


if __name__ == "__main__":
    run()
