"""Paper Fig. 7 + fleet-scale extensions.

Three sections:
  (a) scalability — accuracy and response time as the number of streams
      grows under a FIXED compute budget (the paper's 3.3x claim).
  (b) drift-detection speedup — the per-stream token_histogram +
      js_divergence Python loop vs FleetDriftDetector's one batched
      call, at 1k and 10k streams.
  (c) scenario sweep — all five scenarios from repro.data.scenarios run
      end to end under ECCO and a baseline.

`--smoke` (or SMOKE=1) shrinks every axis for CI: the point there is
that scenario/benchmark code paths execute, not the numbers.

Results go to stdout as CSV rows AND to BENCH_scalability.json (next
to BENCH_trainer.json) so the fleet-scale perf trajectory is
machine-readable across PRs; CI's bench-smoke job uploads both.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import Rows, make_engine, run_framework
from repro.core.drift import DriftDetector, FleetDriftDetector
from repro.data.scenarios import SCENARIOS, build_scenario
from repro.data.streams import make_fleet
from repro.testing.trace import run_scenario

WINDOWS = 8
BUDGET = 8          # micro-windows/window, fixed while streams grow
ACC_THRESHOLD = 0.4

OUT_JSON = "BENCH_scalability.json"


def _scalability(rows: Rows, engine, windows: int, sizes):
    summary = {}
    for n_per in sizes:            # 2 regions x n streams each
        for fw in ("recl", "ecco"):
            _, streams = make_fleet(regions=2, streams_per_region=n_per,
                                    switch_times=(10.0,), seed=0)
            ctl = run_framework(fw, engine, streams, windows=windows,
                                window_micro=BUDGET,
                                shared_bandwidth=96.0)
            acc = ctl.mean_accuracy(last_k=3)
            rt = ctl.response_times(ACC_THRESHOLD)
            mean_rt = (float(np.mean(list(rt.values())))
                       if rt else float("inf"))
            n = 2 * n_per
            rows.add(f"n{n}_{fw}_acc", acc)
            rows.add(f"n{n}_{fw}_response_time", mean_rt)
            summary[(n, fw)] = acc
    lo, hi = 2 * sizes[0], 2 * sizes[-1]
    drop_ecco = summary[(lo, "ecco")] - summary[(hi, "ecco")]
    drop_recl = summary[(lo, "recl")] - summary[(hi, "recl")]
    rows.add(f"acc_drop_{lo}to{hi}_ecco", drop_ecco)
    rows.add(f"acc_drop_{lo}to{hi}_recl", drop_recl)
    rows.add("ecco_degrades_slower", int(drop_ecco < drop_recl + 0.02))
    # supported streams at the accuracy RECL achieves at the top size
    target = summary[(hi, "recl")]
    for n_per in sizes:
        if summary[(2 * n_per, "ecco")] >= target:
            rows.add(f"ecco_supports_n_at_recl{hi}_acc", 2 * n_per)


def _drift_speedup(rows: Rows, sizes, *, batch=8, seq=32, vocab=64,
                   buckets=64, repeats=3):
    """Window-loop drift detection: scalar per-stream Python loop vs
    one batched FleetDriftDetector call on identical data."""
    rng = np.random.default_rng(0)
    for n in sizes:
        ref_toks = rng.integers(0, vocab, size=(n, batch, seq))
        live_toks = rng.integers(0, vocab, size=(n, batch, seq))
        ids = [f"s{i}" for i in range(n)]

        dets = {sid: DriftDetector(threshold=0.25, buckets=buckets,
                                   vocab=vocab) for sid in ids}
        for sid, tk in zip(ids, ref_toks):
            dets[sid].set_reference(tk)
        t0 = time.perf_counter()
        for _ in range(repeats):
            scalar_trig = [sid for sid, tk in zip(ids, live_toks)
                           if dets[sid].observe(tk)]
        t_scalar = (time.perf_counter() - t0) / repeats

        fleet = FleetDriftDetector(threshold=0.25, buckets=buckets,
                                   vocab=vocab)
        fleet.set_references(ids, ref_toks)
        t0 = time.perf_counter()
        for _ in range(repeats):
            fleet_trig = fleet.observe(ids, live_toks)
        t_fleet = (time.perf_counter() - t0) / repeats

        assert fleet_trig == scalar_trig     # decisions bit-identical
        rows.add(f"drift_n{n}_scalar_ms", 1e3 * t_scalar)
        rows.add(f"drift_n{n}_fleet_ms", 1e3 * t_fleet)
        rows.add(f"drift_n{n}_speedup", t_scalar / max(t_fleet, 1e-9))


# smoke runs are only 3 windows long; pull every scenario's drift /
# churn events early enough to actually exercise grouping
_SMOKE_OVERRIDES = {
    "drift_wave": dict(wave_start=5.0, wave_step=5.0),
    "diurnal": dict(period=10.0),
    "flash_crowd": dict(flash_time=5.0),
    "camera_churn": dict(switch_time=5.0, join_window=1, leave_window=2),
    "bandwidth_contention": dict(switch_time=5.0),
}


def _scenarios(rows: Rows, engine, windows=None, *,
               frameworks=("ecco", "naive"), overrides=None):
    """Every scenario runs end to end under ECCO and a baseline (one
    shared engine: scenario banks share the benchmark vocab)."""
    for name in sorted(SCENARIOS):
        for fw in frameworks:
            sc = build_scenario(name, seed=0, **(overrides or {}).get(
                name, {}))
            ctl = run_scenario(fw, sc, engine=engine, windows=windows,
                               window_micro=4, micro_steps=2,
                               train_batch=8, p_drop=0.5)
            rows.add(f"{name}_{fw}_acc", ctl.mean_accuracy(last_k=2))
            rows.add(f"{name}_{fw}_jobs", len(ctl.jobs))


def run(smoke: bool = False):
    rows = Rows("scalability")
    engine = make_engine()
    if smoke:
        _scalability(rows, engine, windows=2, sizes=(1, 2))
        _drift_speedup(rows, sizes=(100, 1000), repeats=1)
        _scenarios(rows, engine, windows=3, overrides=_SMOKE_OVERRIDES)
    else:
        _scalability(rows, engine, windows=WINDOWS, sizes=(1, 2, 4))
        _drift_speedup(rows, sizes=(1000, 10000))
        _scenarios(rows, engine)         # scenario-native horizons
    # response times can legitimately be inf (no stream recrossed the
    # accuracy threshold) and accuracies NaN (no graded window); strict
    # JSON has no tokens for either, so map non-finite floats to null
    # rather than emitting an artifact jq/JSON.parse reject
    metrics = {k: (None if isinstance(v, float) and not np.isfinite(v)
                   else v)
               for k, v in rows.metrics.items()}
    with open(OUT_JSON, "w") as f:
        json.dump({"smoke": smoke, "metrics": metrics}, f, indent=1,
                  allow_nan=False)
        f.write("\n")
    rows.add("json_out", OUT_JSON)
    return rows.emit()


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:] or bool(os.environ.get("SMOKE")))
