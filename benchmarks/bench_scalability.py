"""Paper Fig. 7 + fleet-scale extensions.

Four sections:
  (a) scalability — accuracy and response time as the number of streams
      grows under a FIXED compute budget (the paper's 3.3x claim).
  (b) drift-detection speedup — the per-stream token_histogram +
      js_divergence Python loop vs FleetDriftDetector's one batched
      call, at 1k/10k/100k streams (the batched path must not fall off
      a memory cliff at scale — the chunked+LUT histogram fix).
  (c) scenario sweep — all five scenarios from repro.data.scenarios run
      end to end under ECCO and a baseline.
  (d) device sweep — the sharded decision planes (ops.fleet_drift,
      ops.pairwise_js under a fleet mesh) timed at 1/2/4/8 forced host
      devices, one subprocess per count (device count is fixed at jax
      import), with a cross-count bit-identity digest check.

`--smoke` (or SMOKE=1) shrinks every axis for CI: the point there is
that scenario/benchmark code paths execute, not the numbers.

Results go to stdout as CSV rows AND to BENCH_scalability.json (next
to BENCH_trainer.json) so the fleet-scale perf trajectory is
machine-readable across PRs; CI's bench-smoke job uploads both.
"""
from __future__ import annotations

import gc
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from benchmarks.common import Rows, make_engine, run_framework
from repro.core.drift import DriftDetector, FleetDriftDetector
from repro.data.scenarios import (HOSTILE_SCENARIOS, SCENARIOS,
                                  build_scenario)
from repro.data.streams import make_fleet
from repro.testing.trace import run_scenario

WINDOWS = 8
BUDGET = 8          # micro-windows/window, fixed while streams grow
ACC_THRESHOLD = 0.4

OUT_JSON = "BENCH_scalability.json"


def _scalability(rows: Rows, engine, windows: int, sizes):
    summary = {}
    for n_per in sizes:            # 2 regions x n streams each
        for fw in ("recl", "ecco"):
            _, streams = make_fleet(regions=2, streams_per_region=n_per,
                                    switch_times=(10.0,), seed=0)
            ctl = run_framework(fw, engine, streams, windows=windows,
                                window_micro=BUDGET,
                                shared_bandwidth=96.0)
            acc = ctl.mean_accuracy(last_k=3)
            rt = ctl.response_times(ACC_THRESHOLD)
            mean_rt = (float(np.mean(list(rt.values())))
                       if rt else float("inf"))
            n = 2 * n_per
            rows.add(f"n{n}_{fw}_acc", acc)
            rows.add(f"n{n}_{fw}_response_time", mean_rt)
            summary[(n, fw)] = acc
    lo, hi = 2 * sizes[0], 2 * sizes[-1]
    drop_ecco = summary[(lo, "ecco")] - summary[(hi, "ecco")]
    drop_recl = summary[(lo, "recl")] - summary[(hi, "recl")]
    rows.add(f"acc_drop_{lo}to{hi}_ecco", drop_ecco)
    rows.add(f"acc_drop_{lo}to{hi}_recl", drop_recl)
    rows.add("ecco_degrades_slower", int(drop_ecco < drop_recl + 0.02))
    # supported streams at the accuracy RECL achieves at the top size
    target = summary[(hi, "recl")]
    for n_per in sizes:
        if summary[(2 * n_per, "ecco")] >= target:
            rows.add(f"ecco_supports_n_at_recl{hi}_acc", 2 * n_per)


def _drift_speedup(rows: Rows, sizes, *, batch=8, seq=32, vocab=64,
                   buckets=64, repeats=9):
    """Window-loop drift detection: scalar per-stream Python loop vs
    one batched FleetDriftDetector call on identical data.

    Methodology (each choice counters a measured bias on shared-core
    runners):
      * each timed rep cycles through distinct live-token windows —
        production never re-observes the same tokens, and re-timing
        one array keeps a small fleet's whole working set
        cache-resident, inflating its figure relative to large fleets;
      * rounds are interleaved ACROSS sizes, so a slow machine epoch
        (steal, frequency, allocator state) hits every size's sample
        instead of whichever size happened to be measured then;
      * reported times are median-of-reps (a mean absorbs steal
        spikes, a min is biased low for whichever side gets more reps);
      * GC is disabled inside the timed region (as timeit does): the
        collector's scan cost is fixed per pass over a by-now-large
        heap, which bills disproportionate time to short loops.
    Parity — trigger decisions bit-identical between the scalar loop
    and the batched call — is asserted per variant, outside the timed
    region, where it doubles as warmup."""
    rng = np.random.default_rng(0)
    setups = []
    for n in sizes:
        # one scalar pass at 100k streams is ~3s of pure Python loop;
        # a single repeat is plenty of signal at that size. The fleet
        # call is ~ms at small n, so a stable median needs its rep
        # count to scale up as the per-rep time scales down.
        reps = repeats if n < 100_000 else 1
        fleet_reps = max(repeats, min(50, 200_000 // n))
        # enough distinct live windows that the cycled live set
        # (~128 MB) exceeds any L3 at every size — otherwise small
        # fleets get an artificial cache-residency edge
        var_bytes = n * batch * seq * 8
        n_var = max(2, min(fleet_reps, (128 << 20) // var_bytes))
        ref_toks = rng.integers(0, vocab, size=(n, batch, seq))
        live_vars = [rng.integers(0, vocab, size=(n, batch, seq))
                     for _ in range(n_var)]
        ids = [f"s{i}" for i in range(n)]

        dets = {sid: DriftDetector(threshold=0.25, buckets=buckets,
                                   vocab=vocab) for sid in ids}
        for sid, tk in zip(ids, ref_toks):
            dets[sid].set_reference(tk)
        fleet = FleetDriftDetector(threshold=0.25, buckets=buckets,
                                   vocab=vocab)
        fleet.set_references(ids, ref_toks)
        for lv in live_vars[:max(2, reps)]:  # parity + warmup, untimed
            scalar_trig = [sid for sid, tk in zip(ids, lv)
                           if dets[sid].observe(tk)]
            assert fleet.observe(ids, lv) == scalar_trig
        setups.append(dict(n=n, ids=ids, dets=dets, fleet=fleet,
                           vars=live_vars, reps=reps,
                           fpr=max(1, fleet_reps // max(reps, 1)),
                           ts=[], tf=[]))

    gc_was_on = gc.isenabled()
    gc.disable()
    try:
        for r in range(max(s["reps"] for s in setups)):
            for s in setups:
                if r >= s["reps"]:
                    continue
                lv = s["vars"][r % len(s["vars"])]
                dets, ids, fleet = s["dets"], s["ids"], s["fleet"]
                t0 = time.perf_counter()
                for sid, tk in zip(ids, lv):
                    dets[sid].observe(tk)
                s["ts"].append(time.perf_counter() - t0)
                for k in range(s["fpr"]):
                    lv = s["vars"][(r * s["fpr"] + k) % len(s["vars"])]
                    t0 = time.perf_counter()
                    fleet.observe(ids, lv)
                    s["tf"].append(time.perf_counter() - t0)
    finally:
        if gc_was_on:
            gc.enable()

    for s in setups:
        n = s["n"]
        t_scalar = float(np.median(s["ts"]))
        t_fleet = float(np.median(s["tf"]))
        rows.add(f"drift_n{n}_scalar_ms", 1e3 * t_scalar)
        rows.add(f"drift_n{n}_fleet_ms", 1e3 * t_fleet)
        rows.add(f"drift_n{n}_speedup", t_scalar / max(t_fleet, 1e-9))


_DEVICE_SWEEP_SCRIPT = textwrap.dedent("""
    import hashlib, json, os, time
    d = int(os.environ["FLEET_DEVICES"])
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%d" % d)
    import numpy as np, jax
    from repro.kernels import ops
    from repro.launch.mesh import make_fleet_mesh
    assert jax.device_count() == d
    n = int(os.environ["SWEEP_N"])
    reps = int(os.environ["SWEEP_REPEATS"])
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (n, 32))
    ref = rng.random((n, 64)); ref /= ref.sum(1, keepdims=True)
    p = rng.random((64, 64)); p /= p.sum(1, keepdims=True)
    mesh = make_fleet_mesh(d)

    def timed(f):
        out = f(); jax.block_until_ready(out)      # warm/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = f()
            jax.block_until_ready(out)
        return out, 1e3 * (time.perf_counter() - t0) / reps

    (scores, _), drift_ms = timed(lambda: ops.fleet_drift(
        toks, ref, buckets=64, vocab=64, impl="xla", mesh=mesh))
    dmat, js_ms = timed(lambda: ops.pairwise_js(
        p, ref, impl="xla", mesh=mesh, shard="cols"))
    digest = hashlib.sha256(np.asarray(scores).tobytes()
                            + np.asarray(dmat).tobytes()).hexdigest()
    print(json.dumps({"drift_ms": drift_ms, "js_ms": js_ms,
                      "digest": digest}))
""")


def _device_sweep(rows: Rows, *, n=4096, counts=(1, 2, 4, 8),
                  repeats=3):
    """Sharded decision-plane wall time per fleet-mesh size. Forced
    host devices split the same CPU, so this charts sharding overhead
    (shard_map + padding), not speedup — the bit-identity digest is
    the real assertion: every device count produces byte-identical
    scores."""
    digests = {}
    for d in counts:
        env = dict(os.environ, FLEET_DEVICES=str(d), SWEEP_N=str(n),
                   SWEEP_REPEATS=str(repeats))
        r = subprocess.run([sys.executable, "-c", _DEVICE_SWEEP_SCRIPT],
                           capture_output=True, text=True, env=env,
                           timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        out = json.loads(r.stdout.strip().splitlines()[-1])
        rows.add(f"devices{d}_fleet_drift_ms", out["drift_ms"])
        rows.add(f"devices{d}_pairwise_js_ms", out["js_ms"])
        digests[d] = out["digest"]
    assert len(set(digests.values())) == 1, digests
    rows.add("device_sweep_bit_identical", 1)


# smoke runs are only 3 windows long; pull every scenario's drift /
# churn events early enough to actually exercise grouping
_SMOKE_OVERRIDES = {
    "drift_wave": dict(wave_start=5.0, wave_step=5.0),
    "diurnal": dict(period=10.0),
    "flash_crowd": dict(flash_time=5.0),
    "camera_churn": dict(switch_time=5.0, join_window=1, leave_window=2),
    "bandwidth_contention": dict(switch_time=5.0),
}


def _scenarios(rows: Rows, engine, windows=None, *,
               frameworks=("ecco", "naive"), overrides=None):
    """Every benign scenario runs end to end under ECCO and a baseline
    (one shared engine: scenario banks share the benchmark vocab). The
    hostile scenarios live in bench_faults — flash_crowd_10k at its
    native 10k joiners has no business in this sweep's budget."""
    for name in sorted(set(SCENARIOS) - set(HOSTILE_SCENARIOS)):
        for fw in frameworks:
            sc = build_scenario(name, seed=0, **(overrides or {}).get(
                name, {}))
            ctl = run_scenario(fw, sc, engine=engine, windows=windows,
                               window_micro=4, micro_steps=2,
                               train_batch=8, p_drop=0.5)
            rows.add(f"{name}_{fw}_acc", ctl.mean_accuracy(last_k=2))
            rows.add(f"{name}_{fw}_jobs", len(ctl.jobs))


def run(smoke: bool = False):
    rows = Rows("scalability")
    engine = make_engine()
    if smoke:
        _scalability(rows, engine, windows=2, sizes=(1, 2))
        _drift_speedup(rows, sizes=(100, 1000), repeats=1)
        _scenarios(rows, engine, windows=3, overrides=_SMOKE_OVERRIDES)
        _device_sweep(rows, n=512, counts=(1, 2), repeats=1)
    else:
        _scalability(rows, engine, windows=WINDOWS, sizes=(1, 2, 4))
        _drift_speedup(rows, sizes=(1000, 10000, 100000))
        _scenarios(rows, engine)         # scenario-native horizons
        _device_sweep(rows)
    # response times can legitimately be inf (no stream recrossed the
    # accuracy threshold) and accuracies NaN (no graded window); strict
    # JSON has no tokens for either, so map non-finite floats to null
    # rather than emitting an artifact jq/JSON.parse reject
    metrics = {k: (None if isinstance(v, float) and not np.isfinite(v)
                   else v)
               for k, v in rows.metrics.items()}
    with open(OUT_JSON, "w") as f:
        json.dump({"smoke": smoke, "metrics": metrics}, f, indent=1,
                  allow_nan=False)
        f.write("\n")
    rows.add("json_out", OUT_JSON)
    return rows.emit()


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:] or bool(os.environ.get("SMOKE")))
