"""Heterogeneous fleets on one roofline budget: the headline sweep.

ECCO's claim is more concurrent cameras at equal accuracy out of a
FIXED accelerator budget. This bench pins one per-window roofline
budget (modeled device-seconds, launch/roofline.CostTable) and sweeps
concurrent retraining jobs under two fleet policies:

  * homogeneous — every job on the big backbone, fp32 decision screens
    (the seed fleet). Under budget pressure the metered allocator can
    afford only a few micro-windows, so most jobs starve.
  * heterogeneous — each new job takes the costliest model-class tier
    whose micro-window fits its fair share of the window budget (the
    controller's `_pick_engine` rule, emulated here fleet-by-fleet),
    with bf16 decision screens. Cheap tiers keep the whole fleet
    training inside the same budget.

For each policy the sweep reports the LARGEST job count whose final
mean accuracy stays >= ACC_TARGET after a fixed number of windows; the
headline `jobs_ratio` is heterogeneous/homogeneous max sustainable
jobs (>= 1.5x expected at these scales). Same budget, same data
distribution, same window count — only backbone class and screen
precision differ, which is exactly the tentpole's claim.

CSV to stdout, JSON artifact to BENCH_heterogeneity.json (uploaded by
the CI bench-smoke job).
"""
from __future__ import annotations

import dataclasses
import json
import sys

import numpy as np

from benchmarks.common import Rows
from repro.configs import smoke_config
from repro.core.allocator import ECCOAllocator
from repro.core.batching import engine_groups
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob, SharedEngine
from repro.launch.roofline import CostTable, RooflineMeter

VOCAB = 64
SEQ = 32
EVAL_BATCH = 4
TRAIN_BATCH = 8
MICRO_STEPS = 4
WINDOW_MICRO = 16       # upper cap; the BUDGET is the real constraint
WINDOWS = 3
ACC_TARGET = 0.55
OUT_JSON = "BENCH_heterogeneity.json"

# the model zoo: one big backbone (the homogeneous fleet's only
# choice) and one cheap tier the heterogeneous fleet may fall back to
BIG = dataclasses.replace(smoke_config("olmo-1b"), name="zoo-big",
                          vocab_size=VOCAB, d_model=128, d_ff=512,
                          num_heads=8, num_kv_heads=8, num_layers=4)
SMALL = dataclasses.replace(smoke_config("olmo-1b"), name="zoo-small",
                            vocab_size=VOCAB, d_model=64, d_ff=256,
                            num_heads=4, num_kv_heads=4, num_layers=2)


def _rows_for_job(rng, n_rows: int = 32) -> np.ndarray:
    """Learnable stream data: one cyclic token run per job (next token
    is a deterministic function of the current one) — easy enough that
    a few micro-windows converge on ANY zoo tier, so the sweep
    measures starvation, not model capacity."""
    start = int(rng.integers(0, VOCAB))
    base = ((start + np.arange(SEQ)) % VOCAB).astype(np.int32)
    return np.tile(base, (n_rows, 1))


def _micro_seconds(table: CostTable, cfg, precision: str) -> float:
    return (MICRO_STEPS * table.seconds(cfg, batch=TRAIN_BATCH, seq=SEQ,
                                        kind="train", precision=precision)
            + 2 * table.seconds(cfg, batch=EVAL_BATCH, seq=SEQ,
                                kind="eval", precision=precision))


def _build_fleet(engines, table, budget, n_jobs, *, precision: str,
                 zoo: bool, seed: int = 0):
    """Emulates ECCOController._pick_engine placement, job by job: the
    costliest tier whose micro-window fits the job's fair share
    `budget / (window_micro * (jobs + 1))`; without a zoo every job
    lands on the big backbone."""
    rng = np.random.default_rng(seed)
    tiers = sorted(engines, reverse=True,
                   key=lambda e: _micro_seconds(table, e.cfg, precision))
    jobs = []
    for i in range(n_jobs):
        eng = tiers[0]
        if zoo:
            fair = budget / WINDOW_MICRO / (len(jobs) + 1)
            eng = next((e for e in tiers
                        if _micro_seconds(table, e.cfg, precision)
                        <= fair), tiers[-1])
        data = _rows_for_job(rng)
        req = Request(stream_id=f"s{i}", t=0.0, loc=(0.0, 0.0),
                      subsamples=data[:EVAL_BATCH], acc=0.0,
                      train_data=data)
        jobs.append(RetrainJob(eng, req, micro_steps=MICRO_STEPS,
                               batch=TRAIN_BATCH, seed=seed + i,
                               precision=precision))
    return jobs


def _run_fleet(jobs, table, budget):
    """WINDOWS metered retraining windows; returns (final mean fp32
    accuracy, trained-job fraction, last window's budget report)."""
    alloc = ECCOAllocator()
    report = None
    for _ in range(WINDOWS):
        meter = RooflineMeter(table, budget, seq_len=SEQ,
                              eval_batch=EVAL_BATCH)
        trace = alloc.run_window(jobs, WINDOW_MICRO, meter=meter)
        report = trace.budget
    # final score in fp32 for BOTH policies: the comparison must not
    # reward bf16 fleets with a cheaper grader. Graded through the
    # batched plane API (one eval_jobs call per model class, fp32
    # override) — bit-identical to the old per-member eval_on loop
    # (parity test: tests/test_fleetlint.py::test_eval_jobs_precision_
    # override_matches_scalar_loop)
    accs = [0.0] * len(jobs)
    for eng, idxs in engine_groups(jobs):
        if eng is None:
            for i in idxs:
                # fleetlint: disable=per-member-loop -- scalar fallback
                # for probe-rejected jobs, same as the plane dispatch
                ma = [jobs[i].eval_on(m.subsamples, precision="fp32")
                      for m in jobs[i].members]
                accs[i] = float(np.mean(ma))
            continue
        for i, a in zip(idxs, eng.eval_jobs([jobs[i] for i in idxs],
                                            precision="fp32")):
            accs[i] = a
    trained = sum(1 for j in jobs if j.gpu_time > 0) / max(1, len(jobs))
    return float(np.mean(accs)), trained, report


def _sweep(rows, label, engines, table, budget, counts, *,
           precision, zoo, results):
    """Max sustainable jobs: largest count whose final mean accuracy
    clears ACC_TARGET. Counts are ascending; the sweep records every
    point (no silent truncation)."""
    best = 0
    for n in counts:
        jobs = _build_fleet(engines, table, budget, n,
                            precision=precision, zoo=zoo, seed=17)
        acc, trained, report = _run_fleet(jobs, table, budget)
        tiers = {}
        for j in jobs:
            tiers[j.engine.cfg.name] = tiers.get(j.engine.cfg.name, 0) + 1
        results["sweep"].append(dict(
            policy=label, jobs=n, precision=precision,
            final_acc=round(acc, 4), trained_frac=round(trained, 3),
            tiers=tiers, budget=report))
        rows.add(f"{label}_n{n}_acc", acc)
        rows.add(f"{label}_n{n}_trained_frac", trained)
        if acc >= ACC_TARGET:
            best = n
        for j in jobs:
            j.release()
    return best


def run(smoke: bool = False):
    rows = Rows("heterogeneity")
    table = CostTable()
    engines = [SharedEngine(BIG), SharedEngine(SMALL)]

    # fixed budget: ~4 big-backbone micro-windows per window. A small
    # homogeneous fleet trains fully; a large one starves (the metered
    # allocator can afford only the first 4 fp32 micros), while the
    # cheap tier's micro-windows fit an order of magnitude more jobs —
    # the regime the paper's headline lives in
    budget = 4.5 * _micro_seconds(table, BIG, "fp32")
    rows.add("window_budget_s", budget)
    rows.add("big_micro_s", _micro_seconds(table, BIG, "fp32"))
    rows.add("small_micro_s", _micro_seconds(table, SMALL, "bf16"))

    counts = [4, 8] if smoke else [2, 4, 8, 12, 16]
    results = {"budget_seconds": budget, "acc_target": ACC_TARGET,
               "windows": WINDOWS, "window_micro": WINDOW_MICRO,
               "sweep": []}

    homo = _sweep(rows, "homogeneous", engines[:1], table, budget,
                  counts, precision="fp32", zoo=False, results=results)
    het = _sweep(rows, "heterogeneous", engines, table, budget,
                 counts, precision="bf16", zoo=True, results=results)

    ratio = het / max(1, homo)
    results["homogeneous_max_jobs"] = homo
    results["heterogeneous_max_jobs"] = het
    results["jobs_ratio"] = round(ratio, 3)
    rows.add("homogeneous_max_jobs", homo)
    rows.add("heterogeneous_max_jobs", het)
    rows.add("jobs_ratio", ratio)
    if not smoke:
        assert ratio >= 1.5, (
            f"headline regression: heterogeneous fleet sustains only "
            f"{ratio:.2f}x the homogeneous job count at acc >= "
            f"{ACC_TARGET}")

    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=1, allow_nan=False)
        f.write("\n")
    rows.add("json_out", OUT_JSON)
    return rows.emit()


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
