"""Gradient compression for the slow cross-pod hop.

At 512 chips the (pod=2) axis crosses DCN/optical links that are an order
of magnitude slower than in-pod ICI, so the cross-pod gradient reduction
is the collective to compress. Two schemes, both with error feedback so
compression noise is unbiased over time:

  * int8 quantized all-reduce: per-tensor symmetric scale, reduce in
    int32-widened space, dequantize. 4x wire-byte reduction at <1e-2
    relative error per step (error feedback carries the residual).
  * top-k sparsification (magnitude): keep the k largest entries per
    tensor, all-reduce the dense masked tensor (wire bytes shrink only
    with sparse transport; on TPU we model it as compute-side sparsity +
    int8, and record the bytes win in EXPERIMENTS.md from the int8 path).

Used by train_step when TrainConfig.compress_pod_grads is set: gradients
are reduced over ("data",) in full precision by GSPMD as usual, then the
pod-axis mean is taken explicitly on compressed values under shard_map.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def topk_mask(x, frac: float):
    """Keep the `frac` largest-magnitude entries (per tensor)."""
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compressed_psum(x, axis_name: str, *, scheme: str = "int8",
                    topk_frac: float = 0.01):
    """Mean over `axis_name` with wire compression. Call inside shard_map.

    int8: each participant quantizes, the all-reduce runs on the
    int32-widened tensor (wire = 1B/el + one scale), then dequantizes.
    topk: sparsify-then-int8 (compute-side sparsity).
    """
    n = jax.lax.psum(1, axis_name)
    if scheme == "none":
        return jax.lax.pmean(x, axis_name)
    if scheme == "topk":
        x = topk_mask(x, topk_frac)
    q, scale = quantize_int8(x)
    # int8 sums can overflow int8; widen to int32 for the reduction. The
    # wire transfer of a ring all-reduce moves the *input* representation,
    # so bytes-on-wire ~ 1B/element.
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # scales differ per participant; reduce them too (max keeps dequant
    # conservative and unbiased with error feedback)
    smax = jax.lax.pmax(scale, axis_name)
    return (total.astype(jnp.float32) * smax / n).astype(x.dtype)


def with_error_feedback(grads, residual, compress_fn):
    """Classic EF: g' = compress(g + r); r' = (g + r) - g'.

    grads/residual: pytrees. Returns (compressed_grads, new_residual).
    """
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)
    corrected = jax.tree.map(jnp.add, grads, residual)
    compressed = jax.tree.map(compress_fn, corrected)
    new_residual = jax.tree.map(jnp.subtract, corrected, compressed)
    return compressed, new_residual


def pod_mean_compressed(grads, mesh, *, scheme: str = "int8",
                        axis: str = "pod"):
    """Explicit compressed mean over the pod axis for a grad pytree whose
    leaves are already reduced over the in-pod data axis.

    GSPMD emits the fp32 cross-pod all-reduce by default; this replaces
    it with an int8 one under shard_map (4x fewer wire bytes on the slow
    hop). No-op when the mesh has no pod axis.
    """
    if axis not in mesh.shape or mesh.shape[axis] == 1:
        return grads
    from jax.sharding import PartitionSpec as P

    def reduce_leaf(g):
        spec = P(*([None] * g.ndim))

        def body(gl):
            return compressed_psum(gl, axis, scheme=scheme)

        from repro.kernels._compat import shard_map
        return shard_map(body, mesh=mesh, in_specs=spec,
                         out_specs=spec)(g)

    return jax.tree.map(reduce_leaf, grads)


def wire_bytes_saved(num_params: int, pods: int = 2) -> dict:
    """Napkin accounting for EXPERIMENTS.md: fp32 vs int8 ring all-reduce
    over the pod axis (2(p-1)/p x N bytes per participant)."""
    ring = 2 * (pods - 1) / pods * num_params
    return {"fp32_bytes": 4 * ring, "int8_bytes": 1 * ring,
            "reduction": 4.0}
