"""AdamW with warmup + cosine decay, as plain pytree ops (no optax
dependency). Optimizer state shapes mirror parameters, so ZeRO-style
sharding is inherited from the parameter shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def init_opt_state(params):
    return {
        "mu": jax.tree.map(jnp.zeros_like, params),
        "nu": jax.tree.map(jnp.zeros_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def lr_schedule(tcfg: TrainConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, tcfg.warmup_steps))
    prog = jnp.clip((step - tcfg.warmup_steps)
                    / max(1, tcfg.total_steps - tcfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(tcfg: TrainConfig, params, grads, opt_state):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    lr = lr_schedule(tcfg, opt_state["count"])
    b1, b2 = tcfg.b1, tcfg.b2
    bc1 = 1 - b1 ** cf
    bc2 = 1 - b2 ** cf

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        step = mhat / (jnp.sqrt(nhat) + 1e-8) + tcfg.weight_decay * p32
        return (p32 - lr * step).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
