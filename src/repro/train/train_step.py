"""Train-step factory: loss (next-token CE + MoE aux + z-loss),
microbatched gradient accumulation, remat, mixed precision, and sharded
AdamW update — all inside one jit-able function.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ENCODER, ModelConfig, TrainConfig
from repro.models.layers import padded_vocab
from repro.models.model import Model
from repro.models.transformer import NULL_CTX, ShardCtx
from repro.train import optimizer as opt_lib

AUX_WEIGHT = 0.01
Z_WEIGHT = 1e-4


def softmax_xent(cfg: ModelConfig, logits, labels):
    """Stable CE over the (padded, possibly sharded) vocab axis.
    logits (B,S,V), labels (B,S) int. Returns (mean CE, mean z-loss)."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    shifted = lf - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    lab = jax.nn.one_hot(labels, lf.shape[-1], dtype=jnp.float32)
    picked = jnp.sum(lf * lab, axis=-1)
    ce = jnp.mean(lse - picked)
    z = jnp.mean(jnp.square(lse))
    return ce, z


def make_loss_fn(model: Model, tcfg: TrainConfig, *, ctx: ShardCtx = NULL_CTX,
                 mesh=None, moe_impl: str = "dense",
                 distill_weight: float = 0.0, ssm_impl: str = "gspmd"):
    cfg = model.cfg
    compute_dtype = jnp.dtype(tcfg.compute_dtype)

    def loss_fn(params, batch):
        # Cast the fp32 master params to the compute dtype ONCE, outside
        # the remat'd layer bodies: FSDP all-gathers then move bf16 (2x
        # fewer wire bytes) and per-layer HBM reads halve. Gradients flow
        # back through the convert and accumulate in fp32.
        if compute_dtype != jnp.float32:
            params = jax.tree.map(
                lambda p: p.astype(compute_dtype)
                if p.dtype == jnp.float32 else p, params)
        logits, aux = model.apply(
            params, batch["inputs"], ctx=ctx, mesh=mesh, moe_impl=moe_impl,
            remat=tcfg.remat, compute_dtype=compute_dtype,
            ssm_impl=ssm_impl)
        if cfg.family == ENCODER or not cfg.causal:
            lab = batch["labels"]
            lg = logits
        else:
            lg = logits[:, :-1]
            lab = batch["labels"][:, 1:]
        ce, z = softmax_xent(cfg, lg, lab)
        loss = ce + AUX_WEIGHT * aux + Z_WEIGHT * z
        if distill_weight and "teacher_logits" in batch:
            tl = batch["teacher_logits"].astype(jnp.float32)
            sl = jax.nn.log_softmax(lg.astype(jnp.float32)[..., :tl.shape[-1]])
            tp = jax.nn.softmax(tl)
            kd = -jnp.mean(jnp.sum(tp * sl, axis=-1))
            loss = loss + distill_weight * kd
        return loss, {"ce": ce, "aux": aux, "z": z}

    return loss_fn


def make_train_step(model: Model, tcfg: TrainConfig, *, mesh=None, rules=None,
                    moe_impl: str = "dense", distill_weight: float = 0.0,
                    ssm_impl: str = "gspmd"):
    """Returns train_step(state, batch) -> (state, metrics). state is
    {"params","opt"}; batch holds global arrays (sharded by in_shardings
    when jitted)."""
    ctx = ShardCtx(mesh, rules) if mesh is not None else NULL_CTX
    loss_fn = make_loss_fn(model, tcfg, ctx=ctx, mesh=mesh,
                           moe_impl=moe_impl, distill_weight=distill_weight,
                           ssm_impl=ssm_impl)
    k = tcfg.microbatches

    def grads_of(params, batch):
        if k <= 1:
            (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            return loss, met, grads
        # gradient accumulation over k microbatches
        def split(x):
            return x.reshape((k, x.shape[0] // k) + x.shape[1:])
        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, loss_sum = carry
            (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_sum + loss), met

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (acc, loss_sum), mets = jax.lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: g / k, acc)
        met = jax.tree.map(lambda m: m[-1], mets)
        return loss_sum / k, met, grads

    def train_step(state, batch):
        loss, met, grads = grads_of(state["params"], batch)
        new_params, new_opt, omet = opt_lib.adamw_update(
            tcfg, state["params"], grads, state["opt"])
        metrics = {"loss": loss, **met, **omet}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_train_step_many(model: Model, tcfg: TrainConfig, *, steps: int = 1,
                         mesh=None, rules=None, moe_impl: str = "dense",
                         distill_weight: float = 0.0,
                         ssm_impl: str = "gspmd"):
    """vmap-compatible multi-step trainer over STACKED job states.

    Returns train_steps_many(states, batches) -> (states, metrics):
    `states` is a state pytree with a leading jobs axis on every leaf,
    `batches` holds arrays of shape (jobs, steps, ...). Each lane runs
    `steps` sequential train_step updates on its own state (lax.scan
    keeps the compiled graph one-step-sized), so lane j is bit-identical
    to running make_train_step on state j with its `steps` batches in
    order — the JobBank parity suite asserts it. Metrics are the last
    step's, stacked over jobs.
    """
    step = make_train_step(model, tcfg, mesh=mesh, rules=rules,
                           moe_impl=moe_impl, distill_weight=distill_weight,
                           ssm_impl=ssm_impl)

    def train_steps_many(states, batches):
        def per_job(state, bats):
            def body(st, b):
                st, metrics = step(st, b)
                return st, metrics
            st, metrics = jax.lax.scan(body, state, bats)
            return st, jax.tree.map(lambda m: m[-1], metrics)
        return jax.vmap(per_job)(states, batches)

    return train_steps_many


def init_state(model: Model, key, tcfg: Optional[TrainConfig] = None):
    params = model.init(key, jnp.dtype((tcfg or TrainConfig()).param_dtype))
    return {"params": params, "opt": opt_lib.init_opt_state(params)}


def abstract_state(model: Model, mesh, rules, tcfg: Optional[TrainConfig] = None):
    """ShapeDtypeStruct state tree for the dry-run (no allocation)."""
    dtype = jnp.dtype((tcfg or TrainConfig()).param_dtype)
    params = model.abstract_params(mesh, rules, dtype)

    def like(x):
        return jax.ShapeDtypeStruct(x.shape, jnp.float32, sharding=x.sharding)

    return {"params": params,
            "opt": {"mu": jax.tree.map(like, params),
                    "nu": jax.tree.map(like, params),
                    "count": jax.ShapeDtypeStruct((), jnp.int32)}}
