"""qwen3-moe-30b-a3b — 128 routed experts, top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family=MOE,
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=151936,
    head_dim=128,                 # qwen3 uses head_dim 128 (> d_model/heads)
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1000000.0,
    qk_norm=True,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family=MOE, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=0, vocab_size=256, head_dim=16,
        norm="rmsnorm", act="swiglu", qk_norm=True,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64))
