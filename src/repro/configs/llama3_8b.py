"""llama3-8b — dense, GQA kv=8, 128k vocab [arXiv:2407.21783]."""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family=DENSE,
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=500000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke", family=DENSE, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=224, vocab_size=256,
        norm="rmsnorm", act="swiglu", rope_theta=500000.0)
