"""xlstm-350m — alternating sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.configs.base import SSM, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family=SSM,
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                      # xLSTM blocks embed their own up-projection
    vocab_size=50304,
    norm="layernorm",
    act="gelu",
    ssm=SSMConfig(state_dim=0, conv_width=4, expand=2, slstm_every=2),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke", family=SSM, num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=256,
        norm="layernorm", act="gelu",
        ssm=SSMConfig(state_dim=0, conv_width=4, expand=2, slstm_every=2))
