"""Architecture registry: `get_config(arch)`, `smoke_config(arch)`, SHAPES.

Each assigned architecture lives in its own module with the exact published
dimensions; `smoke_config()` returns a reduced same-family variant used by
CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    DENSE, ENCODER, HYBRID, MOE, SSM, VLM,
    MeshConfig, ModelConfig, MoEConfig, SSMConfig, ShapeConfig,
    TrainConfig, SHAPES,
)

_ARCH_MODULES: Dict[str, str] = {
    "olmo-1b": "repro.configs.olmo_1b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "llama3-8b": "repro.configs.llama3_8b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "chameleon-34b": "repro.configs.chameleon_34b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def smoke_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch]).smoke_config()


def cell_is_runnable(cfg: ModelConfig, shape_name: str) -> str:
    """Return 'ok' or a skip reason for an (arch, shape) cell."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and not cfg.has_decode:
        return "skip: encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return "skip: full-attention arch; 524k decode needs sub-quadratic attention"
    return "ok"
