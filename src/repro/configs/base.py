"""Config dataclasses for models, shapes, meshes, and training.

Every assigned architecture gets a `ModelConfig` in its own module under
`repro.configs`; the registry in `__init__.py` exposes `get_config(arch)`
and `SHAPES`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"          # xLSTM-style recurrent
HYBRID = "hybrid"    # parallel attention + SSM heads (hymba)
ENCODER = "encoder"  # bidirectional, no decode (hubert)
VLM = "vlm"          # early-fusion token VLM (chameleon)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int          # routed experts (logical, pre-padding)
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0      # total shared-expert ffn width
    router_aux_weight: float = 0.01
    # experts are padded up to a multiple of the EP shard count at build
    # time; padded experts get -inf router logits.


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16       # per-channel state (mamba) / head_dim (mLSTM)
    conv_width: int = 4
    expand: int = 2           # d_inner = expand * d_model
    num_ssm_heads: int = 0    # hymba: number of mamba heads in parallel mix
    slstm_every: int = 2      # xlstm: one sLSTM block per this many blocks


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    norm: str = "rmsnorm"               # rmsnorm | layernorm | nonparam_ln
    act: str = "swiglu"                 # swiglu | gelu
    rope_theta: float = 10000.0
    qk_norm: bool = False               # chameleon / qwen3
    tie_embeddings: bool = False
    causal: bool = True                 # False for encoder-only
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # attention pattern: full everywhere, or sliding window with a few
    # global layers (hymba)
    sliding_window: int = 0             # 0 -> full attention
    global_attn_layers: Tuple[int, ...] = ()
    meta_tokens: int = 0                # hymba learned prefix tokens
    # modality frontend stub: if set, inputs are precomputed embeddings
    # (batch, seq, d_model) instead of token ids
    embedding_frontend: bool = False
    dtype: str = "bfloat16"
    # -- derived ------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context without O(L) KV cache
        attention per step over the full context?"""
        return self.family in (SSM, HYBRID)

    @property
    def has_decode(self) -> bool:
        return self.causal

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS=6ND)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in (DENSE, MOE, VLM, ENCODER):
            per_layer += d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
            per_layer += (self.num_heads * hd) * d
        if self.family == HYBRID:
            per_layer += d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
            per_layer += (self.num_heads * hd) * d
            di = self.ssm.expand * d
            per_layer += 2 * d * di + di * d + di * (self.ssm.state_dim * 2 + 1)
        if self.family == SSM:
            # mLSTM/sLSTM projections (approx): qkv + gates + out
            di = self.ssm.expand * d
            per_layer += 2 * d * di + di * d + 3 * d * d
        if self.moe is not None:
            mult = 3 if self.act == "swiglu" else 2
            per_layer += self.moe.num_experts * mult * d * self.moe.d_ff_expert
            per_layer += self.moe.num_shared_experts and mult * d * self.moe.d_ff_shared
            per_layer += d * self.moe.num_experts  # router
        elif self.d_ff:
            mult = 3 if self.act == "swiglu" else 2
            per_layer += mult * d * self.d_ff
        return emb + L * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        mult = 3 if self.act == "swiglu" else 2
        full_experts = self.moe.num_experts * mult * d * self.moe.d_ff_expert
        active_experts = self.moe.top_k * mult * d * self.moe.d_ff_expert
        return self.param_count() - L * (full_experts - active_experts)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Training / runtime configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    remat: str = "full"          # none | dots | full
    microbatches: int = 1        # gradient accumulation
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    compress_pod_grads: bool = False   # int8 cross-pod all-reduce
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Logical mesh description; see repro.launch.mesh."""
    multi_pod: bool = False

    @property
    def shape(self):
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self):
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")
