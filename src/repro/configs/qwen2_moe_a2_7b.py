"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family=MOE,
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=151936,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                  num_shared_experts=4, d_ff_shared=5632),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke", family=MOE, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
        norm="rmsnorm", act="swiglu",
        moe=MoEConfig(num_experts=6, top_k=2, d_ff_expert=64,
                      num_shared_experts=2, d_ff_shared=128))
