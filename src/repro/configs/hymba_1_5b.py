"""hymba-1.5b — parallel attention + Mamba heads per block [arXiv:2411.13676].

Sliding-window attention everywhere except 3 global layers (first, middle,
last); 128 learned meta tokens prepended to every sequence.
"""
from repro.configs.base import HYBRID, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family=HYBRID,
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10000.0,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    meta_tokens=128,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family=HYBRID, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=256,
        norm="rmsnorm", act="swiglu",
        ssm=SSMConfig(state_dim=8, conv_width=4, expand=2),
        sliding_window=16, global_attn_layers=(0,), meta_tokens=4)
