"""stablelm-3b — dense [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family=DENSE,
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    norm="layernorm",
    act="swiglu",
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b-smoke", family=DENSE, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=192, vocab_size=256,
        norm="layernorm", act="swiglu")
