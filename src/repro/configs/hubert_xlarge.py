"""hubert-xlarge — encoder-only audio backbone [arXiv:2106.07447].

The conv feature-extractor frontend is a STUB per the brief: `input_specs`
provides precomputed frame embeddings (batch, frames, d_model).
"""
from repro.configs.base import ENCODER, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family=ENCODER,
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    norm="layernorm",
    act="gelu",
    causal=False,
    embedding_frontend=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family=ENCODER, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=64,
        norm="layernorm", act="gelu", causal=False, embedding_frontend=True)
