"""starcoder2-3b — dense, GQA kv=2, RoPE, GELU MLP [arXiv:2402.19173]."""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family=DENSE,
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    norm="layernorm",
    act="gelu",
    rope_theta=100000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke", family=DENSE, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256,
        norm="layernorm", act="gelu")
