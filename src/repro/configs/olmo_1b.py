"""olmo-1b — dense, non-parametric LayerNorm [arXiv:2402.00838]."""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family=DENSE,
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparam_ln",
    act="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke", family=DENSE, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=256,
        norm="nonparam_ln", act="swiglu", tie_embeddings=True)
