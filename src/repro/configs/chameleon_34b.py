"""chameleon-34b — early-fusion VLM; VQ image tokens share the 65536 vocab
[arXiv:2405.09818]. The VQ image tokenizer frontend is a STUB per the brief
(inputs are token ids; image regions are just token spans).
"""
from repro.configs.base import VLM, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family=VLM,
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10000.0,
    qk_norm=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke", family=VLM, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=256,
        norm="rmsnorm", act="swiglu", qk_norm=True)
