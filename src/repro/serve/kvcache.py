"""KV-cache management for batched continuous serving.

The serving side of the fleet: edge models run inference locally; the
server also serves the *current group models* for shadow evaluation and
for clients without local compute. This module manages slot-based cache
admission (a TPU-friendly stand-in for paged attention: fixed-capacity
slots, free-list allocation, batched decode over active slots).

TPU adaptation note: GPU paged-attention's per-block indirection tables
defeat the MXU's appetite for dense tiles; on TPU the idiomatic design is
fixed-capacity per-slot caches (static shapes, no gather in the hot
loop) with host-side slot recycling — which is what this implements.

Slot lifecycle (shared by `ServeLoop` and the fleet plane in
`repro.serve.plane`): admit -> prefill (the prefill's argmax IS the
first emitted token, so EOS/max_new are checked at submit time, not
first at the next tick) -> decode ticks -> retire. Retirement releases
the cache slot AND clears the per-slot pending-token entry; finished
outputs accumulate until `drain()` hands them to the caller — under
continuous serving the caller MUST drain, or completed transcripts
pile up unboundedly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class SlotState:
    request_id: Optional[str] = None
    pos: int = 0                 # absolute position (incl. meta offset)
    done: bool = True
    group: Optional[str] = None  # serving-group tag (fleet plane)


class CacheManager:
    """Fixed-slot KV cache pool with free-list admission.

    All device state is one cache tree of leading dim `num_slots`
    (static shapes; decode steps run over the whole pool every tick and
    inactive slots are masked on the host side).
    """

    def __init__(self, model: Model, *, num_slots: int, capacity: int,
                 dtype=jnp.bfloat16):
        self.model = model
        self.num_slots = num_slots
        self.user_capacity = capacity            # prompt+generation budget
        self.capacity = capacity + model.cfg.meta_tokens
        self.cache = model.init_cache(num_slots, self.capacity, dtype)
        self.slots: List[SlotState] = [SlotState() for _ in
                                       range(num_slots)]

    # -- admission ----------------------------------------------------------
    def check_fit(self, prompt_len: int, max_new: int):
        """A request's LAST decode step writes cache position
        prompt_len + meta_tokens + max_new - 2 (prefill consumes
        prompt_len + meta positions and already emits token #1), so the
        whole request fits iff prompt_len + max_new - 1 <=
        user_capacity. The seed prefilled unconditionally: an oversized
        prompt silently overflowed the slot (jnp clamps out-of-range
        dynamic_update_slice indices, corrupting the newest cache rows
        instead of raising) — fail admission loudly instead."""
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1; got {max_new}")
        if prompt_len + max_new - 1 > self.user_capacity:
            raise ValueError(
                f"request does not fit its slot: prompt_len={prompt_len} "
                f"+ max_new={max_new} - 1 > capacity={self.user_capacity} "
                f"(largest admissible prompt is "
                f"{self.user_capacity - max_new + 1} tokens)")

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.done]

    def admit(self, request_id: str, *, prompt_len: Optional[int] = None,
              max_new: int = 1, group: Optional[str] = None) -> int:
        if prompt_len is not None:
            self.check_fit(prompt_len, max_new)
        free = self.free_slots()
        if not free:
            raise RuntimeError("cache pool exhausted")
        i = free[0]
        self.slots[i] = SlotState(request_id=request_id, pos=0, done=False,
                                  group=group)
        return i

    def release(self, slot: int):
        self.slots[slot] = SlotState()

    def write_prefill(self, slot: int, slot_cache, pos: int):
        """Merge a single-request prefill cache (leading dim 1) into the
        pool at `slot`."""
        self.write_prefill_many([slot], slot_cache, pos)

    def write_prefill_many(self, slots: List[int], batch_cache, pos: int):
        """Merge a batched prefill cache (leading dim >= len(slots);
        extra lanes are shape-grid padding and are dropped) into the
        pool at `slots` — one scatter per leaf for the whole admission
        wave instead of one per request."""
        n = len(slots)
        sel = jnp.asarray(slots)

        def put(pool, many):
            return pool.at[:, sel].set(many[:, :n].astype(pool.dtype))
        # cache trees are {"segments": [ {k,v,...}, ... ]} with per-leaf
        # layout (layers, batch, ...)
        self.cache = jax.tree.map(put, self.cache, batch_cache)
        for i in slots:
            self.slots[i].pos = int(pos)

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.done]

    def utilization(self) -> float:
        return len(self.active()) / self.num_slots


class ServeLoop:
    """Batched continuous serving driver: admit -> prefill -> decode
    ticks over the slot pool, retiring requests at EOS/limit."""

    def __init__(self, model: Model, params, *, num_slots: int = 8,
                 capacity: int = 256, eos_id: Optional[int] = None,
                 max_new: int = 32):
        self.model = model
        self.params = params
        self.mgr = CacheManager(model, num_slots=num_slots,
                                capacity=capacity)
        self.eos_id = eos_id
        self.max_new = max_new
        self.outputs: Dict[str, List[int]] = {}
        self._new_tokens: Dict[int, int] = {}
        self._finished: List[str] = []

        from repro.serve.serve_step import make_decode_step, \
            make_prefill_step
        self._prefill = jax.jit(make_prefill_step(model,
                                                  self.mgr.capacity))
        self._decode = jax.jit(make_decode_step(model))

    # -- slot lifecycle ------------------------------------------------------
    def _retire(self, slot: int):
        """Release the cache slot AND the per-slot decode state. The
        seed's release left `_new_tokens[slot]` holding the dead
        request's last token — a recycled slot driven by raw
        `mgr.admit` (no fresh prefill write) would replay it into the
        next request's decode."""
        st = self.mgr.slots[slot]
        if st.request_id is not None:
            self._finished.append(st.request_id)
        self._new_tokens.pop(slot, None)
        self.mgr.release(slot)

    def _record_first(self, request_id: str, slot: int, first: int) -> bool:
        """Record the prefill's argmax as emitted token #1 and apply the
        retirement rule to it. The seed skipped this check: a request
        with max_new == 1 (or EOS on the prefill token) stayed active,
        burned a decode tick, and over-emitted a token past its limit
        before tick() retired it. Returns True when the request already
        finished at submit time."""
        self.outputs[request_id] = [first]
        if (self.eos_id is not None and first == self.eos_id) \
                or self.max_new <= 1:
            self._retire(slot)
            return True
        self._new_tokens[slot] = first
        return False

    def _emit(self, slot: int, token: int) -> str:
        """One decoded token for `slot`: advance the position, record
        the token, retire at EOS/limit."""
        st = self.mgr.slots[slot]
        st.pos += 1
        rid = st.request_id
        self.outputs[rid].append(token)
        if (self.eos_id is not None and token == self.eos_id) or \
                len(self.outputs[rid]) >= self.max_new:
            self._retire(slot)
        else:
            self._new_tokens[slot] = token
        return rid

    def drain(self) -> Dict[str, List[int]]:
        """Hand over (and forget) every finished request's output.
        Under continuous serving this is the retirement API that keeps
        `outputs` bounded: the seed grew it without bound."""
        done = {}
        for rid in self._finished:
            if rid in self.outputs:
                done[rid] = self.outputs.pop(rid)
        self._finished.clear()
        return done

    # -- request path --------------------------------------------------------
    def submit(self, request_id: str, prompt: np.ndarray) -> int:
        """prompt: (S,) ints. Prefills into a fresh slot; the slot is
        already retired on return when the prefill token finishes the
        request (max_new == 1 / EOS on token #1)."""
        prompt = np.asarray(prompt)
        slot = self.mgr.admit(request_id, prompt_len=prompt.shape[-1],
                              max_new=self.max_new)
        tok, cache, pos = self._prefill(self.params,
                                        jnp.asarray(prompt)[None])
        self.mgr.write_prefill(slot, cache, int(pos))
        self._record_first(request_id, slot, int(np.asarray(tok)[0]))
        return slot

    def tick(self) -> Dict[str, int]:
        """One decode step over every active slot (batched)."""
        act = self.mgr.active()
        if not act:
            return {}
        # all active slots decode at their own pos; group by pos so each
        # jitted call uses a single scalar (positions differ across
        # requests in steady state — one call per distinct pos)
        emitted: Dict[str, int] = {}
        by_pos: Dict[int, List[int]] = {}
        for i in act:
            by_pos.setdefault(self.mgr.slots[i].pos, []).append(i)
        for pos, slots in by_pos.items():
            toks = jnp.asarray([[self._new_tokens[i]] for i in slots],
                               jnp.int32)
            sub = jax.tree.map(lambda c: c[:, jnp.asarray(slots)],
                               self.mgr.cache)
            nxt, new_sub = self._decode(self.params, toks, sub,
                                        jnp.asarray(pos, jnp.int32))

            def put(pool, one):
                return pool.at[:, jnp.asarray(slots)].set(
                    one.astype(pool.dtype))
            self.mgr.cache = jax.tree.map(put, self.mgr.cache, new_sub)
            nxt = np.asarray(nxt)[:, 0]
            for j, i in enumerate(slots):
                rid = self._emit(i, int(nxt[j]))
                emitted[rid] = int(nxt[j])
        return emitted

    def run_until_drained(self, max_ticks: int = 256):
        for _ in range(max_ticks):
            if not self.mgr.active():
                break
            self.tick()
        return self.outputs
