"""KV-cache management for batched continuous serving.

The serving side of the fleet: edge models run inference locally; the
server also serves the *current group models* for shadow evaluation and
for clients without local compute. This module manages slot-based cache
admission (a TPU-friendly stand-in for paged attention: fixed-capacity
slots, free-list allocation, batched decode over active slots).

TPU adaptation note: GPU paged-attention's per-block indirection tables
defeat the MXU's appetite for dense tiles; on TPU the idiomatic design is
fixed-capacity per-slot caches (static shapes, no gather in the hot
loop) with host-side slot recycling — which is what this implements.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class SlotState:
    request_id: Optional[str] = None
    pos: int = 0                 # absolute position (incl. meta offset)
    done: bool = True


class CacheManager:
    """Fixed-slot KV cache pool with free-list admission.

    All device state is one cache tree of leading dim `num_slots`
    (static shapes; decode steps run over the whole pool every tick and
    inactive slots are masked on the host side).
    """

    def __init__(self, model: Model, *, num_slots: int, capacity: int,
                 dtype=jnp.bfloat16):
        self.model = model
        self.num_slots = num_slots
        self.capacity = capacity + model.cfg.meta_tokens
        self.cache = model.init_cache(num_slots, self.capacity, dtype)
        self.slots: List[SlotState] = [SlotState() for _ in
                                       range(num_slots)]

    # -- admission ----------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.done]

    def admit(self, request_id: str) -> int:
        free = self.free_slots()
        if not free:
            raise RuntimeError("cache pool exhausted")
        i = free[0]
        self.slots[i] = SlotState(request_id=request_id, pos=0, done=False)
        return i

    def release(self, slot: int):
        self.slots[slot] = SlotState()

    def write_prefill(self, slot: int, slot_cache, pos: int):
        """Merge a single-request prefill cache (leading dim 1) into the
        pool at `slot`."""
        def put(pool, one):
            return pool.at[:, slot].set(one[:, 0].astype(pool.dtype))
        # cache trees are {"segments": [ {k,v,...}, ... ]} with per-leaf
        # layout (layers, batch, ...)
        self.cache = jax.tree.map(put, self.cache, slot_cache)
        self.slots[slot].pos = int(pos)

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.done]

    def utilization(self) -> float:
        return len(self.active()) / self.num_slots


class ServeLoop:
    """Batched continuous serving driver: admit -> prefill -> decode
    ticks over the slot pool, retiring requests at EOS/limit."""

    def __init__(self, model: Model, params, *, num_slots: int = 8,
                 capacity: int = 256, eos_id: Optional[int] = None,
                 max_new: int = 32):
        self.model = model
        self.params = params
        self.mgr = CacheManager(model, num_slots=num_slots,
                                capacity=capacity)
        self.eos_id = eos_id
        self.max_new = max_new
        self.outputs: Dict[str, List[int]] = {}
        self._new_tokens: Dict[int, int] = {}

        from repro.serve.serve_step import make_decode_step, \
            make_prefill_step
        self._prefill = jax.jit(make_prefill_step(model,
                                                  self.mgr.capacity))
        self._decode = jax.jit(make_decode_step(model))

    def submit(self, request_id: str, prompt: np.ndarray) -> int:
        """prompt: (S,) ints. Prefills into a fresh slot."""
        slot = self.mgr.admit(request_id)
        tok, cache, pos = self._prefill(self.params,
                                        jnp.asarray(prompt)[None])
        self.mgr.write_prefill(slot, cache, int(pos))
        first = int(np.asarray(tok)[0])
        self.outputs[request_id] = [first]
        self._new_tokens[slot] = first
        return slot

    def tick(self) -> Dict[str, int]:
        """One decode step over every active slot (batched)."""
        act = self.mgr.active()
        if not act:
            return {}
        # all active slots decode at their own pos; group by pos so each
        # jitted call uses a single scalar (positions differ across
        # requests in steady state — one call per distinct pos)
        emitted: Dict[str, int] = {}
        by_pos: Dict[int, List[int]] = {}
        for i in act:
            by_pos.setdefault(self.mgr.slots[i].pos, []).append(i)
        for pos, slots in by_pos.items():
            toks = jnp.asarray([[self._new_tokens[i]] for i in slots],
                               jnp.int32)
            sub = jax.tree.map(lambda c: c[:, jnp.asarray(slots)],
                               self.mgr.cache)
            nxt, new_sub = self._decode(self.params, toks, sub,
                                        jnp.asarray(pos, jnp.int32))

            def put(pool, one):
                return pool.at[:, jnp.asarray(slots)].set(
                    one.astype(pool.dtype))
            self.mgr.cache = jax.tree.map(put, self.mgr.cache, new_sub)
            nxt = np.asarray(nxt)[:, 0]
            for j, i in enumerate(slots):
                st = self.mgr.slots[i]
                st.pos = pos + 1
                t = int(nxt[j])
                self._new_tokens[i] = t
                rid = st.request_id
                self.outputs[rid].append(t)
                emitted[rid] = t
                if (self.eos_id is not None and t == self.eos_id) or \
                        len(self.outputs[rid]) >= self.max_new:
                    self.mgr.release(i)
        return emitted

    def run_until_drained(self, max_ticks: int = 256):
        for _ in range(max_ticks):
            if not self.mgr.active():
                break
            self.tick()
        return self.outputs
