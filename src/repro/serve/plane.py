"""Live serving plane: batched fleet inference from validated per-group
serving snapshots — the fourth plane (docs/serving_plane.md).

While `ECCOController.run_window` retrains group models, this plane
answers stream queries from a SEPARATE set of committed per-group
params — the *serving snapshots* — stacked in one device pytree
(`ServingStore`, `RowRegistry` churn discipline like every other fleet
plane). Queries for any mix of groups decode together: every tick is
ONE vmapped launch over all active slots, each lane selecting its own
params row and decoding at its own position
(`serve_step.make_fleet_decode_step`), with admission batching prefills
per (group, prompt-length) bucket.

A freshly retrained model is NOT what serves next window by default:
EdgeSync (PAPERS.md) shows naive hot swaps of continuously retrained
edge models can regress live accuracy, so `publish` runs an
update-validation gate — the candidate must beat the incumbent on the
group's held-out eval sample (by `gate_margin`; ties accept at the
default margin 0.0, since an equal-accuracy fresher snapshot costs
nothing and resets staleness). On failure the incumbent keeps serving,
the miss is counted, and the group's staleness (windows since the
serving snapshot last changed) grows — making accuracy-vs-staleness
measurable when swaps lag retraining.

Candidate params come from the training plane under the JobBank
residency discipline: `RetrainJob.serving_snapshot()` compacts the bank
and returns a committed, independent device copy of the params row
(`params_stack()` itself is borrowed and must never be held across a
bank write — see docs/training_plane.md).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rows import RowRegistry
from repro.serve.kvcache import ServeLoop
from repro.serve.serve_step import make_fleet_decode_step


def _pad_size(n: int, floor: int = 1) -> int:
    """Smallest size >= n from the {2^k, 3*2^(k-2)} shape grid — the
    training plane's padding rule (core.trainer._pad_size), repeated
    here so the serve plane does not import the training stack: the
    vmapped decode compiles for ~2 lane counts per octave instead of
    one per admission pattern."""
    if n <= floor:
        return floor
    k = (n - 1).bit_length()
    half = 3 << (k - 2) if k >= 2 else 1 << k
    return half if half >= n else 1 << k


@dataclasses.dataclass
class ServeConfig:
    """Controller-side switch for the serving plane
    (`ControllerConfig.serve`; None = plane off, the default — golden
    traces never see it)."""
    num_slots: int = 32          # shared KV-cache slot pool size
    capacity: int = 64           # per-slot prompt+generation budget
    max_new: int = 8             # tokens per query (incl. prefill token)
    prompt_len: int = 8          # query prompt tokens (from window data)
    queries_per_stream: int = 1  # queries each grouped stream issues/window
    eos_id: Optional[int] = None
    gate_margin: float = 0.0     # candidate must beat incumbent by this
    gate_members: int = 2        # members whose eval draws form the gate set
    max_ticks_per_window: Optional[int] = None   # None = drain fully


@dataclasses.dataclass
class GateDecision:
    """One `publish` outcome (the swap-gate audit record)."""
    group_id: str
    candidate_acc: float
    incumbent_acc: float         # nan when the group was first seeded
    accepted: bool
    seeded: bool                 # first snapshot: installed ungated


class ServingStore:
    """Stacked per-group serving params: one device pytree with leaves
    (capacity, ...), rows keyed by group id through `RowRegistry`
    (amortized doubling, swap-with-last removal). Rows are COMMITTED
    copies owned by the store — installs overwrite a row, they never
    alias the training bank's donated buffers."""

    def __init__(self):
        self.reg = RowRegistry(capacity=4)
        self._stack = None           # device leaves (capacity, ...)

    def __contains__(self, group_id: str) -> bool:
        return group_id in self.reg

    def __len__(self) -> int:
        return len(self.reg)

    @property
    def group_ids(self) -> List[str]:
        return self.reg.ids

    def install(self, group_id: str, params):
        """Set `group_id`'s serving row to `params` (add or overwrite)."""
        row, _ = self.reg.add(group_id)
        if self._stack is None:
            self._stack = jax.tree.map(
                lambda x: jnp.zeros((self.reg.capacity,)
                                    + tuple(np.shape(x)),
                                    jnp.asarray(x).dtype), params)
        elif self.reg.capacity > jax.tree.leaves(self._stack)[0].shape[0]:
            pad = self.reg.capacity - jax.tree.leaves(self._stack)[0].shape[0]
            self._stack = jax.tree.map(
                lambda x: jnp.concatenate(
                    [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]),
                self._stack)
        self._stack = jax.tree.map(
            lambda s, p: s.at[row].set(jnp.asarray(p).astype(s.dtype)),
            self._stack, params)

    def remove(self, group_id: str):
        mv = self.reg.remove(group_id)
        if mv is None:
            return
        dst, src = mv
        if dst != src:
            self._stack = jax.tree.map(lambda x: x.at[dst].set(x[src]),
                                       self._stack)

    def row(self, group_id: str):
        """One group's serving params (fresh device buffers)."""
        r = self.reg[group_id]
        return jax.tree.map(lambda x: x[r], self._stack)

    def stack(self):
        """The full stacked params tree (leaves (capacity, ...))."""
        return self._stack


class FleetServePlane(ServeLoop):
    """Batched fleet serving over the slot-pool cache, one model per
    group, with the validated hot swap. Extends `ServeLoop` (admission
    bookkeeping, retirement rule, drain API) with a query queue, a
    `ServingStore` of per-group snapshots, per-(group, length) batched
    admission, and a per-slot-params vmapped decode tick."""

    def __init__(self, engine, scfg: Optional[ServeConfig] = None):
        self.scfg = scfg = scfg or ServeConfig()
        super().__init__(engine.model, None, num_slots=scfg.num_slots,
                         capacity=scfg.capacity, eos_id=scfg.eos_id,
                         max_new=scfg.max_new)
        self.engine = engine
        self.store = ServingStore()
        self._fleet_decode = jax.jit(make_fleet_decode_step(engine.model))
        self._queue: Deque[Tuple[str, str, np.ndarray]] = deque()
        # swap-gate counters (cumulative) + per-group staleness
        self.swap_seeded = 0
        self.swap_accepted = 0
        self.swap_rejected = 0
        self.staleness: Dict[str, int] = {}
        # run-lifetime tick log for pooled latency percentiles
        # ((padded_lanes, seconds) per tick — the pad size marks which
        # ticks compiled a new lane-count shape; one float pair per
        # tick, negligible next to the KV pool)
        self.tick_log: List[Tuple[int, float]] = []
        self._last_pad = 0
        # per-window accumulators (reset by window_report)
        self._gate_log: List[GateDecision] = []
        self._tick_times: List[float] = []
        self._queries = 0
        self._tokens = 0
        self._ticks = 0
        self._serve_seconds = 0.0
        self._dropped = 0

    # -- validated hot swap --------------------------------------------------
    def publish(self, group_id: str, candidate_params,
                eval_sample) -> GateDecision:
        """Offer a freshly retrained `candidate_params` as `group_id`'s
        serving snapshot. First publish seeds the group ungated (there
        is no incumbent to regress); afterwards the candidate must beat
        the incumbent on `eval_sample` by `gate_margin` or the
        incumbent keeps serving and the miss is recorded."""
        cand = float(self.engine.accuracy(candidate_params, eval_sample))
        if group_id not in self.store:
            self.store.install(group_id, candidate_params)
            self.swap_seeded += 1
            self.staleness[group_id] = 0
            dec = GateDecision(group_id, cand, float("nan"), True, True)
        else:
            inc = float(self.engine.accuracy(self.store.row(group_id),
                                             eval_sample))
            if cand >= inc + self.scfg.gate_margin:
                self.store.install(group_id, candidate_params)
                self.swap_accepted += 1
                self.staleness[group_id] = 0
                dec = GateDecision(group_id, cand, inc, True, False)
            else:
                self.swap_rejected += 1
                self.staleness[group_id] = self.staleness.get(group_id,
                                                              0) + 1
                dec = GateDecision(group_id, cand, inc, False, False)
        self._gate_log.append(dec)
        return dec

    def drop_group(self, group_id: str):
        """A group died (regrouping / fleet churn): retire its in-flight
        requests, drop its queued queries, and free its serving row."""
        for i, st in enumerate(self.mgr.slots):
            if not st.done and st.group == group_id:
                self._retire(i)
        if self._queue:
            kept = [q for q in self._queue if q[1] != group_id]
            self._dropped += len(self._queue) - len(kept)
            self._queue = deque(kept)
        self.store.remove(group_id)
        self.staleness.pop(group_id, None)

    def prune(self, live_group_ids):
        """Drop every serving row whose group is no longer live."""
        live = set(live_group_ids)
        for gid in list(self.store.group_ids):
            if gid not in live:
                self.drop_group(gid)

    # -- query path ----------------------------------------------------------
    def enqueue(self, request_id: str, group_id: str, prompt):
        """Queue one query against `group_id`'s serving snapshot.
        Capacity is validated here (admission would only defer the
        error); unknown groups are resolved at admission time, when the
        store membership is current."""
        prompt = np.asarray(prompt)
        self.mgr.check_fit(prompt.shape[-1], self.max_new)
        self._queue.append((request_id, group_id, prompt))

    def submit(self, request_id: str, prompt, *,
               group: Optional[str] = None) -> int:
        """Immediate single-request admission (tests / interactive
        use); the window loop goes through enqueue + pump."""
        if group is None:
            raise TypeError("FleetServePlane.submit requires group=")
        prompt = np.asarray(prompt)
        slot = self.mgr.admit(request_id, prompt_len=prompt.shape[-1],
                              max_new=self.max_new, group=group)
        tok, cache, pos = self._prefill(self.store.row(group),
                                        jnp.asarray(prompt)[None])
        self.mgr.write_prefill(slot, cache, int(pos))
        self._queries += 1
        self._record_first(request_id, slot, int(np.asarray(tok)[0]))
        return slot

    def _admit_from_queue(self):
        """Admit as many queued queries as there are free slots, one
        batched prefill per (group, prompt-length) bucket."""
        free = len(self.mgr.free_slots())
        if not free or not self._queue:
            return
        take: List[Tuple[str, str, np.ndarray]] = []
        while self._queue and len(take) < free:
            rid, gid, prompt = self._queue.popleft()
            if gid not in self.store:
                self._dropped += 1
                continue
            take.append((rid, gid, prompt))
        buckets: Dict[Tuple[str, int], List[Tuple[str, str, np.ndarray]]] = {}
        for item in take:
            buckets.setdefault((item[1], item[2].shape[-1]),
                               []).append(item)
        for (gid, _slen), items in buckets.items():
            prompts = np.stack([p for _, _, p in items])
            n = len(items)
            pad = _pad_size(n)
            if pad != n:        # pad lanes compute, never admit
                prompts = np.concatenate(
                    [prompts, np.repeat(prompts[-1:], pad - n, axis=0)])
            tok, cache, pos = self._prefill(self.store.row(gid),
                                            jnp.asarray(prompts))
            slots = [self.mgr.admit(rid, prompt_len=prompts.shape[-1],
                                    max_new=self.max_new, group=gid)
                     for rid, _, _ in items]
            self.mgr.write_prefill_many(slots, cache, int(pos))
            toks = np.asarray(tok)[:n]
            self._queries += n
            for (rid, _, _), slot, t in zip(items, slots, toks):
                self._record_first(rid, slot, int(t))

    def tick(self) -> Dict[str, int]:
        """One decode step for EVERY active slot in ONE launch: lanes
        carry their own params row and position, so mixed groups and
        staggered admissions still share the tick."""
        act = self.mgr.active()
        if not act:
            return {}
        rows, toks, poss = [], [], []
        for i in act:
            st = self.mgr.slots[i]
            rows.append(self.store.reg[st.group])
            toks.append(self._new_tokens[i])
            poss.append(st.pos)
        n = len(act)
        pad = _pad_size(n)
        self._last_pad = pad
        lanes = act + [act[-1]] * (pad - n)
        rows += [rows[-1]] * (pad - n)
        toks += [toks[-1]] * (pad - n)
        poss += [poss[-1]] * (pad - n)
        sub = jax.tree.map(lambda c: c[:, jnp.asarray(lanes)],
                           self.mgr.cache)
        nxt, new_sub = self._fleet_decode(
            self.store.stack(), jnp.asarray(rows, jnp.int32),
            jnp.asarray(toks, jnp.int32), sub,
            jnp.asarray(poss, jnp.int32))
        sel = jnp.asarray(act)

        def put(pool, one):
            return pool.at[:, sel].set(one[:, :n].astype(pool.dtype))
        self.mgr.cache = jax.tree.map(put, self.mgr.cache, new_sub)
        nxt = np.asarray(nxt)[:n]
        emitted: Dict[str, int] = {}
        for i, t in zip(act, nxt):
            rid = self._emit(i, int(t))
            emitted[rid] = int(t)
        self._ticks += 1
        self._tokens += n
        return emitted

    def pump(self, *, max_ticks: Optional[int] = None) -> int:
        """Admit + tick until the queue and the pool drain (or
        `max_ticks` decode ticks elapse). Returns ticks run."""
        if max_ticks is None:
            max_ticks = self.scfg.max_ticks_per_window
        t_start = time.perf_counter()
        ran = 0
        while self._queue or self.mgr.active():
            if max_ticks is not None and ran >= max_ticks:
                break
            self._admit_from_queue()
            if not self.mgr.active():
                if not self._queue:
                    break
                continue
            t0 = time.perf_counter()
            self.tick()
            dt = time.perf_counter() - t0
            self._tick_times.append(dt)
            self.tick_log.append((self._last_pad, dt))
            ran += 1
        self._serve_seconds += time.perf_counter() - t_start
        return ran

    # -- reporting -----------------------------------------------------------
    def window_report(self) -> Dict:
        """Per-window serving metrics; resets the window accumulators
        (swap counters stay cumulative, mirroring the bench JSON)."""
        tt = np.asarray(self._tick_times, np.float64)
        rep = {
            "queries": self._queries,
            "tokens": self._tokens,
            "ticks": self._ticks,
            "dropped": self._dropped,
            "serve_seconds": self._serve_seconds,
            "qps": (self._queries / self._serve_seconds
                    if self._serve_seconds > 0 else 0.0),
            "p50_tick_ms": (float(np.percentile(tt, 50)) * 1e3
                            if tt.size else 0.0),
            "p99_tick_ms": (float(np.percentile(tt, 99)) * 1e3
                            if tt.size else 0.0),
            "groups": len(self.store),
            "swap_seeded": self.swap_seeded,
            "swap_accepted": self.swap_accepted,
            "swap_rejected": self.swap_rejected,
            "staleness": dict(self.staleness),
            "gate": [dataclasses.asdict(d) for d in self._gate_log],
        }
        self._gate_log = []
        self._tick_times = []
        self._queries = self._tokens = self._ticks = 0
        self._dropped = 0
        self._serve_seconds = 0.0
        return rep
