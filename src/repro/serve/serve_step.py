"""Serving step factories: prefill (prompt -> cache + first token) and
decode (one token against a static-capacity cache), both jit-able and
shardable. Greedy sampling by default.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models.transformer import NULL_CTX, ShardCtx


def make_prefill_step(model: Model, cap: int, *, mesh=None, rules=None,
                      moe_impl: str = "dense", compute_dtype=jnp.bfloat16,
                      ssm_impl: str = "gspmd"):
    ctx = ShardCtx(mesh, rules) if mesh is not None else NULL_CTX

    def prefill_step(params, inputs):
        last_logits, cache, pos = model.prefill(
            params, inputs, cap, ctx=ctx, mesh=mesh, moe_impl=moe_impl,
            compute_dtype=compute_dtype, ssm_impl=ssm_impl)
        tok = jnp.argmax(last_logits.astype(jnp.float32), axis=-1)
        return tok, cache, pos

    return prefill_step


def make_decode_step(model: Model, *, mesh=None, rules=None,
                     moe_impl: str = "dense", compute_dtype=jnp.bfloat16):
    ctx = ShardCtx(mesh, rules) if mesh is not None else NULL_CTX

    def decode_step(params, token, cache, pos):
        logits, new_cache = model.decode(
            params, token, cache, pos, ctx=ctx, mesh=mesh,
            moe_impl=moe_impl, compute_dtype=compute_dtype)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt[:, None], new_cache

    return decode_step


def make_fleet_decode_step(model: Model, *, moe_impl: str = "dense",
                           compute_dtype=jnp.bfloat16):
    """One decode step for a POOL of slots that serve DIFFERENT models:
    each lane selects its own params row from a stacked per-group
    params pytree and decodes at its own absolute position, so one
    launch advances every active request of the fleet regardless of
    which group model it queries or how far along it is (the scalar
    `make_decode_step` shares one params tree and one scalar pos across
    the batch, forcing one launch per (model, pos) bucket).

    Returns fn(params_stack, rows, tokens, cache, pos) -> (next, cache):
      * params_stack — leaves (groups, ...), the serving store's stack
      * rows         — (A,) int32 params row per lane
      * tokens       — (A,) int32 last emitted token per lane
      * cache        — pool cache subtree with slot axis 1, A lanes
      * pos          — (A,) int32 absolute position per lane

    Per-lane math is exactly the B=1 scalar decode (vmap lanes are
    independent), so emitted tokens are bit-identical to decoding each
    slot alone — asserted by tests/test_serve.py.
    """
    def one(params, token, cache, pos):
        cache_b = jax.tree.map(lambda c: c[:, None], cache)
        logits, new_c = model.decode(params, token[None, None], cache_b,
                                     pos, ctx=NULL_CTX, moe_impl=moe_impl,
                                     compute_dtype=compute_dtype)
        nxt = jnp.argmax(logits[0, -1].astype(jnp.float32), axis=-1)
        return nxt.astype(jnp.int32), jax.tree.map(lambda c: c[:, 0], new_c)

    def fleet_decode_step(params_stack, rows, tokens, cache, pos):
        params = jax.tree.map(lambda x: x[rows], params_stack)
        return jax.vmap(one, in_axes=(0, 0, 1, 0),
                        out_axes=(0, 1))(params, tokens, cache, pos)

    return fleet_decode_step


def make_encode_step(model: Model, *, mesh=None, rules=None,
                     compute_dtype=jnp.bfloat16):
    """Encoder-only archs: full-sequence forward returning logits."""
    ctx = ShardCtx(mesh, rules) if mesh is not None else NULL_CTX

    def encode_step(params, inputs):
        logits, _ = model.apply(params, inputs, ctx=ctx, mesh=mesh,
                                compute_dtype=compute_dtype)
        return logits

    return encode_step
