"""Serving step factories: prefill (prompt -> cache + first token) and
decode (one token against a static-capacity cache), both jit-able and
shardable. Greedy sampling by default.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models.transformer import NULL_CTX, ShardCtx


def make_prefill_step(model: Model, cap: int, *, mesh=None, rules=None,
                      moe_impl: str = "dense", compute_dtype=jnp.bfloat16,
                      ssm_impl: str = "gspmd"):
    ctx = ShardCtx(mesh, rules) if mesh is not None else NULL_CTX

    def prefill_step(params, inputs):
        last_logits, cache, pos = model.prefill(
            params, inputs, cap, ctx=ctx, mesh=mesh, moe_impl=moe_impl,
            compute_dtype=compute_dtype, ssm_impl=ssm_impl)
        tok = jnp.argmax(last_logits.astype(jnp.float32), axis=-1)
        return tok, cache, pos

    return prefill_step


def make_decode_step(model: Model, *, mesh=None, rules=None,
                     moe_impl: str = "dense", compute_dtype=jnp.bfloat16):
    ctx = ShardCtx(mesh, rules) if mesh is not None else NULL_CTX

    def decode_step(params, token, cache, pos):
        logits, new_cache = model.decode(
            params, token, cache, pos, ctx=ctx, mesh=mesh,
            moe_impl=moe_impl, compute_dtype=compute_dtype)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt[:, None], new_cache

    return decode_step


def make_encode_step(model: Model, *, mesh=None, rules=None,
                     compute_dtype=jnp.bfloat16):
    """Encoder-only archs: full-sequence forward returning logits."""
    ctx = ShardCtx(mesh, rules) if mesh is not None else NULL_CTX

    def encode_step(params, inputs):
        logits, _ = model.apply(params, inputs, ctx=ctx, mesh=mesh,
                                compute_dtype=compute_dtype)
        return logits

    return encode_step
