"""Logical-axis -> mesh-axis sharding policies.

The mesh is (data, model) single-pod or (pod, data, model) multi-pod.

Two policies:

``tp`` — the paper-faithful baseline (MaxText-style 2D sharding):
  * batch           -> (pod, data)         pure DP across pods + data rows
  * vocab/heads/mlp -> model               Megatron tensor parallelism
  * experts         -> model               expert parallelism (MoE)
  * fsdp            -> data                ZeRO-3 parameter+optimizer shard
  * kv_heads        -> model when divisible, else replicated (GQA with few
                       KV heads: replication beats GSPMD padding waste)
  * heads           -> model when >= model-axis size (uneven dims are
                       GSPMD-padded, e.g. starcoder2's 24 heads -> 32)
  * seq             -> model (Megatron-SP between blocks)

``zero`` — the beyond-paper optimized policy for train/prefill
(EXPERIMENTS.md §Perf): student-fleet models are small relative to a
256-chip pod, so Megatron TP buys nothing and its per-layer activation
all-reduces dominate the collective term. Instead: pure DP + ZeRO-3.
  * batch           -> (pod, data)
  * heads/kv_heads/mlp/seq -> None          (no TP; no SP)
  * vocab           -> model               (column-parallel unembed keeps
                                            the (B,S,V) logits sharded —
                                            CE reduces over V with small
                                            scalar all-reduces)
  * experts         -> model               (EP unchanged; MoE FFNs are the
                                            exception where intra-layer
                                            parallelism pays)
  * fsdp            -> data; ("data","model") for very large dense archs
                       (>=16B: optimizer state would not fit 16-way),
                       where vocab then reverts to None (axis conflict on
                       the embedding table).

Decode shapes always use ``tp``: serving is KV-cache-bandwidth-bound and
sharding KV heads over the model axis is what divides those reads.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig

# beyond this many params, fp32 param+Adam state (16 B/param -> 1 B/param
# per chip at 16-way ZeRO) exceeds a v5e chip's HBM share and params must
# shard over both mesh axes (256-way)
_FSDP2D_PARAM_THRESHOLD = 12e9


def mesh_rules(mesh, cfg: Optional[ModelConfig] = None, *,
               fsdp: bool = True, policy: str = "tp") -> dict:
    axes = dict(mesh.shape)
    model_n = axes.get("model", 1)
    batch = tuple(a for a in ("pod", "data") if a in axes)
    batch_rule = batch if len(batch) > 1 else (batch[0] if batch else None)
    data_n = axes.get("data", 1)

    if policy == "zero":
        rules = {
            "batch": batch_rule,
            "vocab": "model" if model_n > 1 else None,
            "mlp": None,
            "experts": "model" if model_n > 1 else None,
            "heads": None,
            "kv_heads": None,
            "fsdp": "data" if (fsdp and data_n > 1) else None,
            "seq": None,
            "layers": None,
        }
        if cfg is not None and fsdp and model_n > 1 and data_n > 1 \
                and cfg.moe is None \
                and cfg.param_count() > _FSDP2D_PARAM_THRESHOLD \
                and cfg.d_model % (data_n * model_n) == 0:
            rules["fsdp"] = ("data", "model")
            rules["vocab"] = None      # embed table: fsdp owns both axes
        return rules

    assert policy == "tp", policy
    rules = {
        "batch": batch_rule,
        "vocab": "model" if model_n > 1 else None,
        "mlp": "model" if model_n > 1 else None,
        "experts": "model" if model_n > 1 else None,
        "heads": "model" if model_n > 1 else None,
        "kv_heads": "model" if model_n > 1 else None,
        "fsdp": "data" if (fsdp and data_n > 1) else None,
        # Megatron-SP: residual activations (and remat saves) sharded over
        # the model axis along sequence; GSPMD inserts the all-gather /
        # reduce-scatter pairs around attention/MLP.
        "seq": "model" if model_n > 1 else None,
        "layers": None,
    }
    if cfg is not None and model_n > 1:
        if cfg.num_kv_heads % model_n != 0:
            rules["kv_heads"] = None          # replicate small KV-head sets
        # heads are padded per-kv-group up to MAX_HEAD_PAD_RATIO (see
        # layers.padded_heads); if padding can't make them divisible
        # cheaply (e.g. hymba's 25 heads / 5 kv), replicate instead.
        from repro.models.layers import padded_heads
        if padded_heads(cfg, model_n) % model_n != 0:
            rules["heads"] = None
    return rules


def batch_pspec(mesh):
    from jax.sharding import PartitionSpec as P
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes if len(axes) > 1 else axes[0])


# -- fleet decision-plane sharding -----------------------------------------
# The batched control planes (JobBank stacked pytree, fleet_drift rows,
# decide_many flows, pairwise_js signatures) all shard ONE leading axis —
# the job/stream row axis — over a 1-D fleet mesh (launch.mesh.
# make_fleet_mesh). Per-row math is independent, so block-sharding the
# leading axis is bit-identical to single-device; capacity alignment
# (core.rows.RowRegistry.align) keeps the blocks equal so churn never
# re-pads the global shape.

def fleet_axis(mesh) -> str:
    """The mesh axis fleet rows shard along (leading axis by
    convention: 'fleet' for make_fleet_mesh, 'data' for a reused
    production mesh)."""
    return tuple(mesh.axis_names)[0]


def fleet_devices(mesh) -> int:
    """Shard count along the fleet axis."""
    return int(mesh.shape[fleet_axis(mesh)])


def row_pspec(mesh):
    """PartitionSpec sharding a leading row axis (rank-polymorphic:
    trailing dims replicate)."""
    from jax.sharding import PartitionSpec as P
    return P(fleet_axis(mesh))


def row_sharding(mesh):
    """NamedSharding for (rows, ...) dense fleet arrays — drift
    histograms, signature blocks, per-flow state."""
    from jax.sharding import NamedSharding
    return NamedSharding(mesh, row_pspec(mesh))


def stack_sharding(mesh):
    """NamedSharding for the JobBank's stacked (capacity, ...) pytree
    leaves: jobs block-sharded along the slot axis. One sharding object
    serves every leaf (PartitionSpec over the leading axis only)."""
    return row_sharding(mesh)


def replicated(mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P())
