"""Elastic scaling: survive node/slice failure by re-meshing and
resharding from the last checkpoint.

The production mesh is (pod, data, model). A host failure takes out a
row of the data axis (TPU slices fail as units). Recovery:

  1. `shrink_mesh` — build the largest valid mesh from surviving devices
     (data axis shrinks; model axis is preserved because TP shards are
     intra-host on v5e topology).
  2. re-derive sharding rules for the new mesh (same logical rules).
  3. `restore` the last checkpoint against the new shardings
     (repro.distributed.checkpoint resharding path).
  4. re-lower the step functions (compiled cache keyed by mesh shape).

The ECCO controller keeps running through this: jobs pause for the
recovery window, then the allocator's measured AccGain/sec naturally
re-prioritizes (no special-casing needed — the paper's own mechanism).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class MeshSpec:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]


def shrink_mesh(current: MeshSpec, failed_rows: int,
                *, data_axis: str = "data") -> MeshSpec:
    """New mesh spec after losing `failed_rows` rows of the data axis.
    Keeps the model axis intact; drops whole data rows (slice-granular
    failure). Raises if nothing survives."""
    idx = current.axes.index(data_axis)
    new_data = current.shape[idx] - failed_rows
    if new_data < 1:
        raise RuntimeError("no surviving data rows")
    shape = list(current.shape)
    shape[idx] = new_data
    return MeshSpec(tuple(shape), current.axes)


def build_mesh(spec: MeshSpec, *, devices=None):
    """Materialize a mesh over the first prod(shape) (surviving)
    devices."""
    from jax.sharding import AxisType
    n = int(np.prod(spec.shape))
    devices = (jax.devices() if devices is None else list(devices))[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    dev_array = np.array(devices).reshape(spec.shape)
    from jax.sharding import Mesh
    return Mesh(dev_array, spec.axes,
                axis_types=(AxisType.Auto,) * len(spec.axes))


@dataclasses.dataclass
class RecoveryPlan:
    old_mesh_shape: Tuple[int, ...]
    new_mesh_shape: Tuple[int, ...]
    restore_step: Optional[int]
    global_batch_scale: float      # DP width shrank -> scale batch or accum


def plan_recovery(current: MeshSpec, failed_rows: int, ckpt_dir: str,
                  *, data_axis: str = "data") -> RecoveryPlan:
    from repro.distributed import checkpoint as ckpt
    new = shrink_mesh(current, failed_rows, data_axis=data_axis)
    i = current.axes.index(data_axis)
    return RecoveryPlan(
        old_mesh_shape=current.shape,
        new_mesh_shape=new.shape,
        restore_step=ckpt.latest_step(ckpt_dir),
        global_batch_scale=new.shape[i] / current.shape[i],
    )


class ElasticRuntime:
    """Owns the mesh + compiled step; `fail_and_recover` swaps both.

    step_factory(mesh, rules) -> (step_fn, state_shardings) so the
    runtime can re-lower after any re-mesh. State flows through the
    checkpoint (restore with new shardings), which is the only
    correctness-preserving path when shard boundaries move.
    """

    def __init__(self, mesh_spec: MeshSpec, step_factory: Callable,
                 rules_fn: Callable, ckpt_dir: str):
        self.spec = mesh_spec
        self.step_factory = step_factory
        self.rules_fn = rules_fn
        self.ckpt_dir = ckpt_dir
        self.mesh = build_mesh(mesh_spec)
        self.rules = rules_fn(self.mesh)
        self.step, self.state_shardings = step_factory(self.mesh,
                                                       self.rules)
        self.recoveries: List[RecoveryPlan] = []

    def fail_and_recover(self, failed_rows: int, state_template):
        """Simulated failure of `failed_rows` data rows; returns the
        restored state on the shrunken mesh."""
        from repro.distributed import checkpoint as ckpt
        plan = plan_recovery(self.spec, failed_rows, self.ckpt_dir)
        self.recoveries.append(plan)
        self.spec = MeshSpec(plan.new_mesh_shape, self.spec.axes)
        self.mesh = build_mesh(self.spec)
        self.rules = self.rules_fn(self.mesh)
        self.step, self.state_shardings = self.step_factory(self.mesh,
                                                            self.rules)
        if plan.restore_step is None:
            raise RuntimeError("no checkpoint to recover from")
        state, _ = ckpt.restore(self.ckpt_dir, plan.restore_step,
                                state_template,
                                shardings=self.state_shardings)
        return state, plan
