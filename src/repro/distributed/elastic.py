"""Elastic scaling: survive node/slice failure by re-meshing and
resharding from the last checkpoint.

The production mesh is (pod, data, model). A host failure takes out a
row of the data axis (TPU slices fail as units). Recovery:

  1. `shrink_mesh` — build the largest valid mesh from surviving devices
     (data axis shrinks; model axis is preserved because TP shards are
     intra-host on v5e topology).
  2. re-derive sharding rules for the new mesh (same logical rules).
  3. `restore` the last checkpoint against the new shardings
     (repro.distributed.checkpoint resharding path).
  4. re-lower the step functions (compiled cache keyed by mesh shape).

The ECCO controller keeps running through this: jobs pause for the
recovery window, then the allocator's measured AccGain/sec naturally
re-prioritizes (no special-casing needed — the paper's own mechanism).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class MeshSpec:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]


def shrink_mesh(current: MeshSpec, failed_rows: int,
                *, data_axis: str = "data") -> MeshSpec:
    """New mesh spec after losing `failed_rows` rows of the data axis.
    Keeps the model axis intact; drops whole data rows (slice-granular
    failure). Raises if nothing survives."""
    idx = current.axes.index(data_axis)
    new_data = current.shape[idx] - failed_rows
    if new_data < 1:
        raise RuntimeError("no surviving data rows")
    shape = list(current.shape)
    shape[idx] = new_data
    return MeshSpec(tuple(shape), current.axes)


def build_mesh(spec: MeshSpec, *, devices=None):
    """Materialize a mesh over the first prod(shape) (surviving)
    devices. Version-compat construction via launch.mesh (jax 0.4.x
    has no AxisType)."""
    from repro.launch.mesh import make_mesh
    n = int(np.prod(spec.shape))
    devices = (jax.devices() if devices is None else list(devices))[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return make_mesh(spec.shape, spec.axes, devices=devices)


class DeviceFailure(RuntimeError):
    """Raised at an elastic barrier when device loss invalidates the
    in-flight retraining window. Carries how many fleet devices died."""

    def __init__(self, lost: int):
        self.lost = int(lost)
        super().__init__(f"lost {lost} fleet device(s) mid-window")


class FleetElastic:
    """Elastic runtime for the fleet decision planes (1-D fleet mesh).

    Failure model: accelerator memory is lost (the JobBank's resident
    slot stack), the host control plane survives. The window protocol
    (driven by ECCOController.run_window):

      1. `on_window_start(jobs)` — disk-checkpoint every job's
         train-state ({job_id: state} tree, atomic rename). This plus
         the controller's in-memory host snapshot is the recovery
         point.
      2. `barrier()` between the window's stages (and before every
         allocator micro-window). A failure scheduled with
         `schedule_failure` fires at its barrier and raises
         DeviceFailure; a real deployment would raise it from the
         runtime's health check instead.
      3. on DeviceFailure: `recover(lost)` shrinks the mesh to the
         surviving device prefix (slice-granular loss, same rule as
         `shrink_mesh`); the controller re-attaches every plane to the
         new mesh, rolls its host snapshot back, calls `restore_jobs`,
         and re-runs the window. Per-row math is device-local under
         block sharding, so the re-run's decisions are bit-identical
         to a run that never failed (parity-tested in
         tests/test_distributed_plane.py).
    """

    def __init__(self, ckpt_dir: str, mesh=None, *, axis: str = "fleet"):
        self.ckpt_dir = ckpt_dir
        self.axis = axis
        self.mesh = mesh            # current fleet mesh (None = 1 device)
        self.step = 0               # one checkpoint step per window
        self.barriers = 0
        self._fail_at: Optional[Tuple[int, int]] = None
        self.recoveries: List[RecoveryPlan] = []

    def devices(self) -> list:
        if self.mesh is None:
            return list(jax.devices())[:1]
        return list(np.asarray(self.mesh.devices).reshape(-1))

    def schedule_failure(self, n_devices: int = 1, *,
                         after_barriers: int = 1):
        """Arm a simulated failure: the `after_barriers`-th barrier
        from now raises DeviceFailure(n_devices)."""
        self._fail_at = (self.barriers + int(after_barriers),
                         int(n_devices))

    def barrier(self):
        """Stage-boundary health check inside a window."""
        self.barriers += 1
        if self._fail_at is not None and self.barriers >= self._fail_at[0]:
            lost = self._fail_at[1]
            self._fail_at = None
            raise DeviceFailure(lost)

    def on_window_start(self, jobs: Sequence):
        """Checkpoint every job's train-state at the window boundary.
        Reading `job.state` syncs through the bank residency cache (one
        d2h per host-stale row, nothing for host-current rows)."""
        from repro.distributed import checkpoint as ckpt
        ckpt.save(self.ckpt_dir, self.step,
                  {j.job_id: j.state for j in jobs})
        self.step += 1

    def recover(self, lost: int):
        """Shrink to the surviving device prefix; returns the new mesh
        (a 1-device mesh survives as a real mesh — sharded entry points
        degrade to the single-shard path)."""
        devs = self.devices()
        n = len(devs) - int(lost)
        if n < 1:
            raise RuntimeError("no surviving fleet devices")
        old = len(devs)
        from repro.launch.mesh import make_fleet_mesh
        self.mesh = make_fleet_mesh(n, axis=self.axis,
                                    devices=devs[:n])
        self.recoveries.append(RecoveryPlan(
            old_mesh_shape=(old,), new_mesh_shape=(n,),
            restore_step=self.step - 1,
            global_batch_scale=n / old))
        return self.mesh

    def restore_jobs(self, jobs: Sequence):
        """Restore every job's train-state from the window-start
        checkpoint, writing THROUGH the bank residency cache
        (`job.state =` stages the host mirror and marks the device row
        stale; the next batched fleet call flushes them in one
        scatter). `jobs` must be the window-start job set — the same
        ids the checkpoint holds."""
        from repro.distributed import checkpoint as ckpt
        if not jobs:
            return
        template = {j.job_id: j.state_template for j in jobs}
        tree, _ = ckpt.restore(self.ckpt_dir, self.step - 1, template)
        for j in jobs:
            j.state = tree[j.job_id]


@dataclasses.dataclass
class RecoveryPlan:
    old_mesh_shape: Tuple[int, ...]
    new_mesh_shape: Tuple[int, ...]
    restore_step: Optional[int]
    global_batch_scale: float      # DP width shrank -> scale batch or accum


def plan_recovery(current: MeshSpec, failed_rows: int, ckpt_dir: str,
                  *, data_axis: str = "data") -> RecoveryPlan:
    from repro.distributed import checkpoint as ckpt
    new = shrink_mesh(current, failed_rows, data_axis=data_axis)
    i = current.axes.index(data_axis)
    return RecoveryPlan(
        old_mesh_shape=current.shape,
        new_mesh_shape=new.shape,
        restore_step=ckpt.latest_step(ckpt_dir),
        global_batch_scale=new.shape[i] / current.shape[i],
    )


class ElasticRuntime:
    """Owns the mesh + compiled step; `fail_and_recover` swaps both.

    step_factory(mesh, rules) -> (step_fn, state_shardings) so the
    runtime can re-lower after any re-mesh. State flows through the
    checkpoint (restore with new shardings), which is the only
    correctness-preserving path when shard boundaries move.
    """

    def __init__(self, mesh_spec: MeshSpec, step_factory: Callable,
                 rules_fn: Callable, ckpt_dir: str):
        self.spec = mesh_spec
        self.step_factory = step_factory
        self.rules_fn = rules_fn
        self.ckpt_dir = ckpt_dir
        self.mesh = build_mesh(mesh_spec)
        self.rules = rules_fn(self.mesh)
        self.step, self.state_shardings = step_factory(self.mesh,
                                                       self.rules)
        self.recoveries: List[RecoveryPlan] = []

    def fail_and_recover(self, failed_rows: int, state_template):
        """Simulated failure of `failed_rows` data rows; returns the
        restored state on the shrunken mesh."""
        from repro.distributed import checkpoint as ckpt
        plan = plan_recovery(self.spec, failed_rows, self.ckpt_dir)
        self.recoveries.append(plan)
        self.spec = MeshSpec(plan.new_mesh_shape, self.spec.axes)
        self.mesh = build_mesh(self.spec)
        self.rules = self.rules_fn(self.mesh)
        self.step, self.state_shardings = self.step_factory(self.mesh,
                                                            self.rules)
        if plan.restore_step is None:
            raise RuntimeError("no checkpoint to recover from")
        state, _ = ckpt.restore(self.ckpt_dir, plan.restore_step,
                                state_template,
                                shardings=self.state_shardings)
        return state, plan
