"""Straggler detection and mitigation for micro-window scheduling.

ECCO time-shares pod slices across group-retraining jobs in micro-
windows. A straggling job (slow host ingest, contended slice, failing
NIC) stretches its micro-windows and starves the schedule. Mitigation is
*quota re-normalization*: each job's micro-window is a step quota, and
jobs whose measured step time exceeds  median * threshold  get their
quota shrunk proportionally so wall-clock stays bounded — the allocator
then sees a smaller AccGain for the straggler and de-prioritizes it,
which is exactly the paper's own feedback loop doing double duty as
straggler mitigation.

Pure control-plane host code; consumed by repro.core.controller and the
fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StepStats:
    times: List[float] = dataclasses.field(default_factory=list)

    def push(self, dt: float, *, cap: int = 64):
        self.times.append(dt)
        if len(self.times) > cap:
            self.times = self.times[-cap:]

    @property
    def mean(self) -> float:
        return float(np.mean(self.times)) if self.times else 0.0


class StragglerPolicy:
    def __init__(self, *, threshold: float = 2.0, min_quota_frac: float = 0.25,
                 window: int = 16):
        self.threshold = threshold
        self.min_quota_frac = min_quota_frac
        self.window = window
        self.stats: Dict[str, StepStats] = {}
        self.flagged: Dict[str, int] = {}

    def record(self, job_id: str, step_time: float):
        self.stats.setdefault(job_id, StepStats()).push(step_time,
                                                        cap=self.window)

    def median_step_time(self) -> float:
        means = [s.mean for s in self.stats.values() if s.times]
        return float(np.median(means)) if means else 0.0

    def is_straggler(self, job_id: str) -> bool:
        med = self.median_step_time()
        s = self.stats.get(job_id)
        if not s or not s.times or med <= 0:
            return False
        return s.mean > self.threshold * med

    def quota(self, job_id: str, base_quota: int) -> int:
        """Steps this job may run in its next micro-window. Stragglers
        get base * median/mean (bounded below) so wall time per
        micro-window stays ~constant across jobs."""
        med = self.median_step_time()
        s = self.stats.get(job_id)
        if not s or not s.times or med <= 0:
            return base_quota
        ratio = med / max(s.mean, 1e-9)
        if s.mean > self.threshold * med:
            self.flagged[job_id] = self.flagged.get(job_id, 0) + 1
            ratio = max(self.min_quota_frac, ratio)
            return max(1, int(round(base_quota * ratio)))
        return base_quota

    def report(self) -> dict:
        med = self.median_step_time()
        return {
            "median_step_time": med,
            "jobs": {
                j: {"mean": s.mean,
                    "straggler": self.is_straggler(j),
                    "times_flagged": self.flagged.get(j, 0)}
                for j, s in self.stats.items()
            },
        }
