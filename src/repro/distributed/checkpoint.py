"""Sharded, atomic, async checkpointing.

Layout: one directory per step containing
    manifest.json            — tree structure, shapes, dtypes, step meta
    <leaf-index>.npy         — one array per leaf (host-local shard in a
                               real multi-host deployment; full array on
                               a single host)
Writes go to  <dir>.tmp  and are atomically renamed, so a crash mid-write
never corrupts the latest checkpoint; `latest_step()` only sees complete
directories. `save_async` runs the serialization on a daemon thread —
the returned handle joins in tests / at the next save.

Restore supports *resharding*: arrays are loaded on host then placed with
jax.device_put against the (possibly different) target shardings, which
is what elastic re-meshing needs after losing a slice.

Retraining-job states live in the JobBank's device-resident slot cache
(docs/training_plane.md): reading `job.state` for a save lazily syncs
that job's row to the host (one d2h, cached for repeat saves), and
`restore_job` writes the loaded state back THROUGH the cache — the
assignment lands in the host mirror and marks the device row stale, so
the next batched fleet call carries it to the accelerator in its one
shared host->device flush. Callers never touch bank rows directly.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[dict] = None):
    """Blocking sharded save with atomic rename."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"{i}.npy"), arr)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)       # atomic publish
    return final


class AsyncCheckpointer:
    """Serializes saves on a background thread; at most one in flight."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree, *, extra: Optional[dict] = None):
        self.wait()
        # device_get on the caller thread (arrays may be donated after)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            self.last_path = save(self.ckpt_dir, step, host_tree,
                                  extra=extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return self._thread

    def _gc(self):
        steps = list_steps(self.ckpt_dir)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name,
                                            "manifest.json")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target_tree, *,
            shardings=None):
    """Load a checkpoint into the structure of `target_tree`.

    `shardings`: optional pytree of NamedSharding matching target_tree —
    arrays are device_put against it (elastic resharding path). Without
    it, arrays come back as host numpy in the tree structure.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(target_tree)
    assert manifest["num_leaves"] == len(leaves), (
        f"checkpoint has {manifest['num_leaves']} leaves, target "
        f"{len(leaves)} — structure changed?")
    loaded = [np.load(os.path.join(path, f"{i}.npy"))
              for i in range(len(leaves))]
    for got, want in zip(loaded, leaves):
        assert tuple(got.shape) == tuple(np.shape(want)), (
            got.shape, np.shape(want))
    if shardings is not None:
        shard_leaves = jax.tree.leaves(shardings)
        loaded = [jax.device_put(a, s) for a, s in
                  zip(loaded, shard_leaves)]
    return jax.tree.unflatten(treedef, loaded), manifest["extra"]


def restore_job(ckpt_dir: str, step: int, job):
    """Restore a retraining job's train-state IN PLACE, writing through
    the JobBank residency cache.

    The checkpoint is loaded against the job's shape/structure
    template (`state_template` when the job offers one — no device
    sync, since restore discards the target's values — else a plain
    `job.state` read), and the assignment goes through the state
    setter — i.e. `JobBank.write` — which stages the restored state in
    the host mirror and invalidates the device row. The next batched
    entry point flushes it in the fleet-wide sync; no caller-side
    device plumbing. Returns the manifest's `extra` dict."""
    template = getattr(job, "state_template", None)
    if template is None:
        template = job.state
    tree, extra = restore(ckpt_dir, step, template)
    job.state = tree
    return extra
