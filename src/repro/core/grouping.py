"""ECCO dynamic camera/stream grouping — Algorithm 2.

Two stages:
  * GroupRequest: a new retraining request joins an existing job iff
    (i) metadata pre-filter passes for EVERY member (request time within
    eps, location within delta), and (ii) the job model's accuracy on the
    request's subsamples beats the request's own current accuracy. Among
    candidates, the best-scoring job wins; otherwise a new job is created.
  * UpdateGrouping: at every retraining-window end, each member whose
    accuracy dropped more than fraction `p` relative to the previous
    window is evicted and re-enters GroupRequest as a fresh request.

Candidate selection scales two ways. Without an index the seed's pure
Python all-pairs scan runs. With a SignatureIndex attached, the
metadata prefilter is one vectorized call over dense fleet arrays, and
`shortlist_k` caps the number of jobs that pay the expensive `eval_on`
model check at the k signature-most-similar (batched pairwise-JS
kernel). For k >= #passing jobs (or k == 0) decisions are bit-identical
to the Python scan; the index only requires that all membership
mutations flow through this class (else call index.rebuild(jobs)).

Jobs are duck-typed: .eval_on(samples) -> float, .add_member(req),
.remove_member(stream_id), .members -> list[Request].
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.batching import job_precision, shared_engine
from repro.core.signature_index import SignatureIndex


@dataclasses.dataclass
class Request:
    stream_id: str
    t: float                      # drift-detection time
    loc: Sequence[float]          # (x, y) location / trajectory centroid
    subsamples: Any               # eval data for the performance check
    acc: float                    # current (drifted) model accuracy
    model: Any = None             # the device's current model (job seed)
    train_data: Any = None        # sampled frames to contribute
    sig: Any = None               # drift-signature histogram (buckets,)
    # bookkeeping for periodic reevaluation
    acc_prev: Optional[float] = None
    last_job: Optional[str] = None   # job that just evicted this member


def _dist(a, b) -> float:
    return math.sqrt(sum((float(x) - float(y)) ** 2 for x, y in zip(a, b)))


class Grouper:
    def __init__(self, *, eps_t: float = 60.0, delta_loc: float = 100.0,
                 p_drop: float = 0.1,
                 new_job_fn: Callable[[Request], Any] = None,
                 index: Optional[SignatureIndex] = None,
                 shortlist_k: int = 0, rescore_margin: float = 0.0):
        self.eps_t = eps_t
        self.delta_loc = delta_loc
        self.p_drop = p_drop
        self.new_job_fn = new_job_fn
        self.index = index               # fleet signature/metadata arrays
        self.shortlist_k = shortlist_k   # 0 = evaluate every passing job
        # fp32-screen/rescore discipline for reduced-precision fleets
        # (docs/scheduling.md): a bf16 job whose screened accuracy
        # lands within `rescore_margin` of a join/evict threshold is
        # re-scored once in fp32 and the decision uses the fp32 value.
        # 0.0 (default) + all-fp32 fleet = the seed decision path.
        self.rescore_margin = float(rescore_margin)
        self.events: List[dict] = []     # grouping decisions (for Fig. 9)

    def _rescore(self, job, samples, screened: float,
                 threshold: float) -> float:
        """fp32 rescore of a near-threshold reduced-precision screen;
        passthrough for fp32 jobs, wide margins, or duck-typed jobs
        whose eval_on has no precision knob."""
        if (self.rescore_margin <= 0.0 or job_precision(job) == "fp32"
                or abs(screened - threshold) > self.rescore_margin):
            return screened
        try:
            return float(job.eval_on(samples, precision="fp32"))
        except TypeError:
            return screened

    # -- candidate selection --------------------------------------------------
    def _python_candidates(self, jobs: List, req: Request) -> List[int]:
        """Seed all-pairs metadata scan (reference path, O(fleet))."""
        out = []
        for idx, job in enumerate(jobs):
            if not job.members:
                continue
            # a member evicted for diverging must not rejoin the same
            # job this round (its model trivially scores >= the member's
            # own accuracy — it IS the member's model); the paper
            # initiates a separate retraining job for it
            if req.last_job is not None and job.job_id == req.last_job:
                continue
            correlated = all(
                abs(r.t - req.t) <= self.eps_t
                and _dist(r.loc, req.loc) <= self.delta_loc
                for r in job.members)
            if correlated:
                out.append(idx)
        return out

    def _index_candidates(self, jobs: List, req: Request) -> List[int]:
        """Vectorized prefilter + batched-JS top-k via the index."""
        keys = self.index.candidate_jobs(
            req.t, req.loc, eps_t=self.eps_t, delta_loc=self.delta_loc,
            exclude_job=req.last_job, sig=req.sig, k=self.shortlist_k)
        if not keys:
            return []
        key_to_idx = self.index.key_to_position(jobs)
        return sorted(key_to_idx[k] for k in keys if k in key_to_idx)

    # -- Alg. 2 GroupRequest -------------------------------------------------
    def group_request(self, jobs: List, req: Request):
        if self.index is not None:
            self.index.upsert(req.stream_id, req.t, req.loc, req.sig)
            cand_idx = self._index_candidates(jobs, req)
        else:
            cand_idx = self._python_candidates(jobs, req)
        candidates: Dict[int, float] = {}
        if cand_idx:
            cjobs = [jobs[i] for i in cand_idx]
            eng = shared_engine(cjobs)
            if eng is not None:     # all candidates scored in one call
                accs = eng.eval_pairs([(cj, req.subsamples)
                                       for cj in cjobs])
            else:
                # fleetlint: disable=per-member-loop -- documented
                # scalar fallback when the probe rejects the candidate
                # set (fake test jobs, mixed engines); bit-identical
                accs = [cj.eval_on(req.subsamples) for cj in cjobs]
            for idx, acc_j in zip(cand_idx, accs):   # ascending: ties
                acc_j = self._rescore(jobs[idx], req.subsamples,
                                      acc_j, req.acc)
                if acc_j >= req.acc:   # resolve to the oldest passing job
                    candidates[idx] = acc_j
        if candidates:
            best = max(candidates, key=candidates.get)
            jobs[best].add_member(req)
            if self.index is not None:
                self.index.assign(req.stream_id, jobs[best].job_id)
            self.events.append({"kind": "join", "stream": req.stream_id,
                                "job": jobs[best].job_id, "t": req.t,
                                "acc_gain": candidates[best] - req.acc})
            return jobs[best]
        job = self.new_job_fn(req)
        jobs.append(job)
        if self.index is not None:
            self.index.assign(req.stream_id, job.job_id)
        self.events.append({"kind": "new", "stream": req.stream_id,
                            "job": job.job_id, "t": req.t})
        return job

    # -- Alg. 2 UpdateGrouping ------------------------------------------------
    def update_grouping(self, jobs: List, now: float):
        """Window-end reevaluation. Returns list of re-queued requests.

        The reference accuracy is an EMA over windows rather than the
        raw previous value: young models oscillate window-to-window and
        a raw comparison evicts on training noise, while a true second
        drift collapses accuracy far below any smoothed reference.
        """
        requeued: List[Request] = []
        # window-end member evals: ONE batched fleet call. Eval mutates
        # nothing, membership only shrinks during the loop, and a
        # member belongs to exactly one job — so a snapshot taken here
        # covers every (job, member) eval the loop performs.
        cached: Dict[tuple, float] = {}
        eng = shared_engine(jobs) if jobs else None
        if eng is not None:
            snap = [(job, r) for job in jobs for r in job.members]
            accs = eng.eval_pairs([(job, r.subsamples) for job, r in snap])
            cached = {(id(job), id(r)): a
                      for (job, r), a in zip(snap, accs)}
        for job in list(jobs):
            # fleetlint: disable=per-member-loop -- eval_on only runs
            # on the probe-rejected path (cache miss); probe-positive
            # fleets were pre-scored by the eval_pairs call above
            for r in list(job.members):
                key = (id(job), id(r))
                acc_n = (cached[key] if key in cached
                         else job.eval_on(r.subsamples))
                if r.acc_prev is not None and r.acc_prev > 0:
                    # evict threshold in accuracy units:
                    # acc_n < acc_prev * (1 - p_drop)
                    acc_n = self._rescore(
                        job, r.subsamples, acc_n,
                        r.acc_prev * (1.0 - self.p_drop))
                    rel = (acc_n - r.acc_prev) / r.acc_prev
                    if rel < -self.p_drop:       # second drift detected
                        job.remove_member(r.stream_id)
                        if self.index is not None:
                            # detach now: later requeues this round must
                            # not see the evicted row as a member
                            self.index.unassign(r.stream_id)
                        r.t = now
                        r.acc = acc_n
                        r.acc_prev = None
                        r.last_job = job.job_id
                        requeued.append(r)
                        self.events.append({"kind": "evict",
                                            "stream": r.stream_id,
                                            "job": job.job_id, "t": now})
                        continue
                    r.acc_prev = 0.5 * r.acc_prev + 0.5 * acc_n
                else:
                    r.acc_prev = acc_n
        # drop empty jobs, then re-group evicted members
        jobs[:] = [j for j in jobs if j.members]
        for r in requeued:
            self.group_request(jobs, r)
        return requeued
