"""Group-retraining jobs: one shared student model per camera group,
trained on the group's aggregated stream data (knowledge-distilled from
the teacher's soft labels).

All jobs of a fleet share ONE compiled train/eval executable (same model
config), so micro-window context switches are cheap — the TPU analogue of
ECCO's job switching on a time-shared GPU.

Training-plane layout (docs/training_plane.md): every job's train-state
lives in ONE stacked pytree (`JobBank`, amortized-doubling capacity,
swap-compaction on job death — same row discipline as
FleetDriftDetector), every job's data pool is a fixed-capacity dense
ring buffer of (seq,) token rows with per-row stream tags
(`TokenRingPool`), and `SharedEngine` exposes vmapped executables —
`batched_accuracy` scores every (member, job) pair of the fleet in one
call per chunk, `train_micro_many` runs one micro-window for a SET of
jobs via vmap over the stacked states. `RetrainJob` stays the thin
duck-typed handle the allocator/grouper drive; the batched paths are
bit-identical to its scalar loop (tests/test_trainer_bank.py), so they
change dispatch cost, never decisions.
"""
from __future__ import annotations

import itertools
import weakref
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.grouping import Request
from repro.models.model import Model, build_model
from repro.train.train_step import (init_state, make_train_step,
                                    make_train_step_many)

_job_counter = itertools.count()


def _pad_size(n: int, floor: int = 4) -> int:
    """Smallest size >= n from the {2^k, 3*2^(k-2)} grid (>= floor):
    the jitted vmapped executables compile for ~2 shapes per octave
    instead of one per fleet size, while padding waste stays <= 1/3
    (pure powers of two waste up to 2x — measurable wall-clock on the
    compute-bound CPU path)."""
    if n <= floor:
        return floor
    k = (n - 1).bit_length()            # 2^k is the next power of two
    half = 3 << (k - 2) if k >= 2 else 1 << k   # 3/4 of it
    return half if half >= n else 1 << k


class TokenRingPool:
    """Fixed-capacity dense ring buffer of (seq,) token rows, each row
    tagged with the stream that contributed it.

    Replaces the seed's Python list of (B, S) arrays: `rows()` is the
    oldest->newest dense array `train_micro` samples batches from
    (bit-identical to the seed's per-micro-window np.concatenate
    order, without re-concatenating), eviction is by total pooled ROWS
    — a real token budget; the seed's 64-ENTRY sliding window was an
    unbounded memory window for variably-sized entries — and the
    per-row stream tag lets camera churn purge a departed stream's
    rows (`purge`).
    """

    def __init__(self, capacity_rows: int = 512):
        if capacity_rows <= 0:
            raise ValueError("capacity_rows must be positive")
        self.capacity = int(capacity_rows)
        self._rows: Optional[np.ndarray] = None    # (capacity, seq)
        self._src = np.empty(self.capacity, object)  # stream tag per row
        self._start = 0                            # oldest row position
        self._count = 0

    def __len__(self) -> int:
        return self._count

    @property
    def seq(self) -> Optional[int]:
        return None if self._rows is None else self._rows.shape[1]

    def _order(self) -> np.ndarray:
        """Physical indices of the live rows, oldest -> newest."""
        return (self._start + np.arange(self._count)) % self.capacity

    def add(self, tokens, stream_id: Optional[str] = None):
        arr = np.asarray(tokens)
        rows = arr.reshape(-1, arr.shape[-1])
        if self._rows is None:
            self._rows = np.zeros((self.capacity, rows.shape[1]), arr.dtype)
        if rows.shape[1] != self._rows.shape[1]:
            raise ValueError(
                f"pool rows are (seq={self._rows.shape[1]},); got "
                f"seq={rows.shape[1]}")
        n = rows.shape[0]
        if n >= self.capacity:
            # a single oversized entry: only its newest `capacity` rows
            # fit the budget
            self._rows[:] = rows[-self.capacity:]
            self._src[:] = stream_id
            self._start, self._count = 0, self.capacity
            return
        end = (self._start + self._count) % self.capacity
        idx = (end + np.arange(n)) % self.capacity
        self._rows[idx] = rows
        self._src[idx] = stream_id
        over = self._count + n - self.capacity
        if over > 0:                  # evict the oldest rows
            self._start = (self._start + over) % self.capacity
            self._count = self.capacity
        else:
            self._count += n

    def rows(self) -> np.ndarray:
        """All pooled rows as one dense (count, seq) array, oldest ->
        newest — what train batches are sampled from."""
        if self._rows is None or self._count == 0:
            return np.zeros((0, self.seq or 0), np.int64)
        return self._rows[self._order()]

    def sources(self) -> List[Optional[str]]:
        """Per-row stream tags, oldest -> newest (parallel to rows())."""
        if self._count == 0:
            return []
        return list(self._src[self._order()])

    def purge(self, stream_id: str):
        """Drop every row contributed by `stream_id`, preserving the
        relative order of the survivors."""
        if self._count == 0:
            return
        order = self._order()
        keep_mask = np.array([self._src[i] != stream_id for i in order])
        keep = order[keep_mask]
        kept_rows = self._rows[keep]           # fancy index: copies
        kept_src = self._src[keep]
        self._start = 0
        self._count = kept_rows.shape[0]
        self._rows[:self._count] = kept_rows
        self._src[:self._count] = kept_src


class _Slot:
    """Mutable bank position for one job. Swap-compaction retargets the
    moved survivor by rewriting `idx` in place; a freed-and-compacted
    slot has idx=None. `dead` marks slots queued for compaction."""
    __slots__ = ("idx", "dead")

    def __init__(self, idx: int):
        self.idx: Optional[int] = idx
        self.dead = False


class JobBank:
    """All job train-states in ONE stacked pytree.

    Leaves are host arrays of shape (capacity, ...): capacity grows by
    amortized doubling, job death swap-compacts the dead row with the
    last live one (same discipline as FleetDriftDetector rows), and
    the vmapped executables gather/scatter only the slots they touch.
    Reads return independent copies — a bank row may be overwritten by
    compaction after the caller lets go of its job handle.
    """

    def __init__(self, engine: "SharedEngine", capacity: int = 4):
        self.engine = engine
        self._cap = int(capacity)
        self._stack = None           # state pytree, leaves (cap, ...)
        self._treedef = None
        self._slots: List[_Slot] = []
        self._dead: List[_Slot] = []

    def __len__(self) -> int:
        """Live slots, including dead-but-not-yet-compacted ones."""
        return len(self._slots)

    @property
    def capacity(self) -> int:
        return self._cap

    def _init_stack(self, template):
        leaves, self._treedef = jax.tree.flatten(template)
        self._stack = jax.tree.unflatten(self._treedef, [
            np.zeros((self._cap,) + np.shape(x), np.asarray(x).dtype)
            for x in leaves])

    def _grow_to(self, need: int):
        """Amortized doubling: allocating the Nth job is O(state), not
        O(N * state)."""
        if need <= self._cap:
            return
        new_cap = max(need, 2 * self._cap)
        pad = new_cap - self._cap
        if self._stack is not None:
            self._stack = jax.tree.map(
                lambda x: np.concatenate(
                    [x, np.zeros((pad,) + x.shape[1:], x.dtype)]),
                self._stack)
        self._cap = new_cap

    def _state_leaves(self, state) -> List:
        leaves, treedef = jax.tree.flatten(state)
        if treedef != self._treedef:
            raise ValueError(
                f"state tree mismatch: bank holds {self._treedef}, "
                f"got {treedef}")
        return leaves

    def alloc(self, state) -> _Slot:
        self.compact()
        if self._stack is None:
            self._init_stack(state)
        self._grow_to(len(self._slots) + 1)
        slot = _Slot(len(self._slots))
        self._slots.append(slot)
        self.write(slot.idx, state)
        return slot

    def free(self, slot: _Slot):
        """QUEUE the slot for reclamation; rows do not move here.

        free() runs from GC finalizers, i.e. at arbitrary allocation
        points — job handles can sit in cyclic garbage (controllers
        hold reference cycles) and die mid-operation in a LATER run on
        the same engine. Batched callers capture slot indices right
        before a fleet call, so moving rows here would silently
        evaluate/train the wrong job. Actual swap-compaction happens in
        compact(), which every allocating or batched entry point runs
        FIRST — before any index is captured. Idempotent."""
        if slot.idx is None or slot.dead:
            return
        slot.dead = True
        self._dead.append(slot)

    def compact(self):
        """Swap-with-last removal of every queued-dead slot, keeping
        live rows dense (capacity is retained; rows beyond len(self)
        are garbage). Only called at deterministic safe points."""
        while self._dead:
            slot = self._dead.pop()
            idx = slot.idx
            last = len(self._slots) - 1
            if idx != last:
                moved = self._slots[last]
                for x in jax.tree.leaves(self._stack):
                    x[idx] = x[last]
                moved.idx = idx
                self._slots[idx] = moved
            self._slots.pop()
            slot.idx = None

    @staticmethod
    def _check_idx(idx):
        """A freed-and-compacted slot has idx=None; numpy would treat
        None as np.newaxis and broadcast a write across the WHOLE bank
        (silent fleet-wide corruption) — fail loudly instead."""
        if idx is None:
            raise ValueError("use-after-release: job's bank slot was freed")
        return idx

    def read(self, idx: int):
        """Slot `idx`'s state as an independent pytree copy."""
        self._check_idx(idx)
        return jax.tree.map(lambda x: np.array(x[idx]), self._stack)

    def read_params(self, idx: int):
        """Params-only copy of slot `idx` — the eval hot path doesn't
        pay for copying the Adam moments (~2x params)."""
        self._check_idx(idx)
        return jax.tree.map(lambda x: np.array(x[idx]),
                            self._stack["params"])

    def write(self, idx: int, state):
        self._check_idx(idx)
        for dst, src in zip(jax.tree.leaves(self._stack),
                            self._state_leaves(state)):
            dst[idx] = np.asarray(src)

    def gather(self, idxs: Sequence[int]):
        """Stacked device states for the selected slots (leaves
        (k, ...)) — the input of the vmapped executables."""
        sel = np.asarray(idxs, np.int64)
        return jax.tree.map(lambda x: jnp.asarray(x[sel]), self._stack)

    def scatter(self, idxs: Sequence[int], states):
        sel = np.asarray(idxs, np.int64)
        for dst, src in zip(jax.tree.leaves(self._stack),
                            self._state_leaves(states)):
            dst[sel] = np.asarray(src)

    def params_stack(self):
        """The stacked params subtree (leaves (capacity, ...)) —
        `batched_accuracy`'s params_stack argument."""
        return None if self._stack is None else self._stack["params"]


class SharedEngine:
    """Compiled train/eval executables shared by every job of a fleet.

    Scalar executables (`accuracy`, `train_steps`) serve single jobs;
    the vmapped ones (`batched_accuracy`, `eval_pairs`, `eval_jobs`,
    `train_micro_many`) serve the whole fleet per device call and are
    bit-identical to looping the scalar path. `batched=False` disables
    the vmapped dispatch everywhere (the duck-typed probe in
    repro.core.batching reports the engine as not batch-capable), which
    the parity tests and benchmarks use as the reference scalar twin.
    """

    def __init__(self, cfg: ModelConfig, tcfg: Optional[TrainConfig] = None,
                 *, distill_weight: float = 1.0, batched: bool = True,
                 eval_chunk: int = 128, batch_min_jobs: int = 4):
        self.cfg = cfg
        self.model = build_model(cfg)
        # b2=0.999 + no decay: the small-batch streaming regime needs the
        # long second-moment horizon (b2=0.95 oscillates; see
        # EXPERIMENTS.md calibration notes)
        self.tcfg = tcfg or TrainConfig(learning_rate=1e-3, b2=0.999,
                                        weight_decay=0.0, warmup_steps=5,
                                        total_steps=100000, remat="none")
        self._distill_weight = distill_weight
        self._train = jax.jit(make_train_step(
            self.model, self.tcfg, distill_weight=distill_weight))

        def _acc(params, toks):
            logits, _ = self.model.apply(params, toks,
                                         compute_dtype=jnp.float32)
            pred = jnp.argmax(logits[:, :-1].astype(jnp.float32), axis=-1)
            return jnp.mean((pred == toks[:, 1:]).astype(jnp.float32))
        self._acc = jax.jit(_acc)

        self.batched = bool(batched)
        self.eval_chunk = int(eval_chunk)
        # vmapped train only pays off once lane padding + state
        # gather/scatter amortize over enough jobs; smaller groups take
        # the scalar step (identical numbers, and small fleets skip the
        # vmapped-executable compile entirely)
        self.batch_min_jobs = int(batch_min_jobs)
        self.bank = JobBank(self)

        # flattened fleet eval: a job's members ride the EXAMPLE axis of
        # one forward (params read once per job, GEMMs see M*B rows);
        # one jitted executable per member-batch size B
        self._acc_flat: Dict[int, Callable] = {}
        self._train_many: Dict[int, Callable] = {}

    def fresh_state(self, seed: int = 0):
        return init_state(self.model, jax.random.PRNGKey(seed), self.tcfg)

    def train_steps(self, state, batches):
        m = {}
        for b in batches:
            state, m = self._train(state, b)
        return state, m

    def accuracy(self, params, tokens) -> float:
        """Top-1 next-token accuracy — the mAP analogue."""
        return float(self._acc(params, jnp.asarray(tokens)))

    # -- batched eval plane -------------------------------------------------
    def _acc_flat_fn(self, b: int) -> Callable:
        """Jitted flat eval for member-batch size `b`: takes (M*b, S)
        token rows + one job's params, returns (M,) per-member
        accuracies — each member's logits/argmax/mean identical to its
        own scalar `_acc` call (rows of a batch are independent)."""
        fn = self._acc_flat.get(b)
        if fn is None:
            def flat(params, toks):
                logits, _ = self.model.apply(params, toks,
                                             compute_dtype=jnp.float32)
                pred = jnp.argmax(logits[:, :-1].astype(jnp.float32),
                                  axis=-1)
                ok = (pred == toks[:, 1:]).astype(jnp.float32)
                return jnp.mean(ok.reshape(toks.shape[0] // b, b, -1),
                                axis=(1, 2))
            fn = jax.jit(flat)
            self._acc_flat[b] = fn
        return fn

    def batched_accuracy(self, params_stack, tokens, job_ids) -> np.ndarray:
        """Score every (tokens[i], params_stack[job_ids[i]]) pair of the
        fleet, bit-identical to calling `accuracy` per pair.

        tokens is (P, B, S) — pair i's eval batch; job_ids (P,) indexes
        the stacked params (JobBank slots). Pairs are grouped by job and
        each job's member batches are FLATTENED into the example axis of
        one forward per chunk of ~eval_chunk rows: the job's params are
        read once per chunk instead of once per member, the GEMMs see
        M*B rows instead of B (the measured win on CPU — per-pair eval
        is compute/memory-bound, not launch-bound), and device launches
        drop from one per member to one per (job, chunk). Member counts
        pad to a multiple of 8 so the executable compiles for a handful
        of shapes; padded lanes are discarded.
        """
        toks = np.asarray(tokens)
        ids = np.asarray(job_ids, np.int64)
        out = np.empty(ids.shape[0], np.float32)
        if ids.shape[0] == 0:
            return out
        if toks.ndim != 3:
            raise ValueError(f"tokens must be (P, B, S); got {toks.shape}")
        b = toks.shape[1]
        groups: Dict[int, List[int]] = {}
        for i, j in enumerate(ids):
            groups.setdefault(int(j), []).append(i)
        m_chunk = max(1, self.eval_chunk // b)     # members per flat call
        fn = self._acc_flat_fn(b)
        for jid, members in groups.items():
            params = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[jid]),
                                  params_stack)
            for lo in range(0, len(members), m_chunk):
                sel = members[lo:lo + m_chunk]
                m = len(sel)
                m_pad = min(m_chunk, -(-m // 8) * 8)
                tk = np.zeros((m_pad * b,) + toks.shape[2:], toks.dtype)
                tk[:m * b] = toks[sel].reshape(m * b, -1)
                res = fn(params, jnp.asarray(tk))
                out[sel] = np.asarray(res)[:m]
        return out

    def _bank_backed(self, jobs) -> bool:
        def live(j):
            slot = getattr(j, "_slot", None)
            return (slot is not None and slot.idx is not None
                    and not slot.dead)
        return (self.batched and self.bank.params_stack() is not None
                and all(getattr(j, "engine", None) is self and live(j)
                        for j in jobs))

    def eval_pairs(self, pairs) -> List[float]:
        """pairs: [(job, samples)]. Returns per-pair accuracies,
        bit-identical to [job.eval_on(s) for job, s in pairs], with
        each distinct sample shape dispatched as one batched call."""
        if not pairs:
            return []
        self.bank.compact()     # BEFORE capturing any slot index
        if not self._bank_backed([j for j, _ in pairs]):
            return [job.eval_on(s) for job, s in pairs]
        out: List[float] = [0.0] * len(pairs)
        arrs = [np.asarray(s) for _, s in pairs]
        by_shape: Dict[tuple, List[int]] = {}
        for i, a in enumerate(arrs):
            by_shape.setdefault(a.shape, []).append(i)
        stack = self.bank.params_stack()
        for idxs in by_shape.values():
            toks = np.stack([arrs[i] for i in idxs])
            jids = np.array([pairs[i][0]._slot.idx for i in idxs])
            for i, a in zip(idxs, self.batched_accuracy(stack, toks, jids)):
                out[i] = float(a)
        return out

    def eval_jobs(self, jobs) -> List[float]:
        """Batched RetrainJob.eval: every (member, job) subsample pair
        of `jobs` scored in one fleet call, then averaged per job with
        the same float64 np.mean the scalar path uses."""
        pairs, spans = [], []
        for j in jobs:
            ms = list(j.members)
            spans.append(len(ms))
            pairs.extend((j, m.subsamples) for m in ms)
        accs = self.eval_pairs(pairs)
        out, k = [], 0
        for n in spans:
            out.append(float(np.mean(accs[k:k + n])) if n else 0.0)
            k += n
        return out

    # -- vmapped train plane ------------------------------------------------
    def _train_many_fn(self, steps: int) -> Callable:
        fn = self._train_many.get(steps)
        if fn is None:
            fn = jax.jit(make_train_step_many(
                self.model, self.tcfg, steps=steps,
                distill_weight=self._distill_weight))
            self._train_many[steps] = fn
        return fn

    def _train_job_scalar(self, job, toks):
        """The seed per-job micro-window, with the batches pre-drawn."""
        batches = [{"inputs": jnp.asarray(t), "labels": jnp.asarray(t)}
                   for t in toks]
        state, _ = self.train_steps(job.state, batches)
        job.state = state

    def train_micro_many(self, jobs) -> None:
        """One micro-window for each job in `jobs`.

        Batches are drawn on the host with each job's OWN rng in the
        same order the scalar loop would draw them, then jobs whose
        batches share a shape run as ONE vmapped multi-step call per
        group; stragglers (pool smaller than the batch size, foreign
        jobs, groups below batch_min_jobs) take the scalar path.
        Either way the result is bit-identical to calling
        job.train_micro() per job.
        """
        self.bank.compact()     # BEFORE capturing any slot index
        groups: Dict[Tuple[int, tuple], List[tuple]] = {}
        for job in jobs:
            data = job.pool.rows()
            if data.shape[0] == 0:
                continue                       # train_micro no-ops
            k = min(job.batch, data.shape[0])
            toks = np.stack(
                [data[job.rng.integers(0, data.shape[0], size=k)]
                 for _ in range(job.micro_steps)])
            job.gpu_time += 1
            if (not self.batched or k != job.batch
                    or not self._bank_backed([job])):
                self._train_job_scalar(job, toks)
                continue
            groups.setdefault((job.micro_steps, toks.shape),
                              []).append((job, toks))

        for (steps, _shape), items in groups.items():
            if len(items) < self.batch_min_jobs:
                for job, toks in items:
                    self._train_job_scalar(job, toks)
                continue
            n = len(items)
            idxs = [job._slot.idx for job, _ in items]
            batch_np = np.stack([t for _, t in items])  # (J, steps, k, S)
            pad = _pad_size(n, floor=min(4, max(2, self.batch_min_jobs)))
            if pad != n:            # pad lanes compute, never scatter
                idxs = idxs + [idxs[0]] * (pad - n)
                batch_np = np.concatenate(
                    [batch_np] + [batch_np[:1]] * (pad - n))
            states = self.bank.gather(idxs)
            toks_dev = jnp.asarray(batch_np)
            new_states, _ = self._train_many_fn(steps)(
                states, {"inputs": toks_dev, "labels": toks_dev})
            self.bank.scatter(idxs[:n],
                              jax.tree.map(lambda x: x[:n], new_states))


class RetrainJob:
    """One group-retraining job (Alg. 1/2 unit): a thin handle over a
    JobBank slot (the train-state) plus host-side bookkeeping (members,
    token ring pool, rng). The duck-typed allocator/grouper interface
    is unchanged from the seed."""

    def __init__(self, engine: SharedEngine, first: Request, *,
                 micro_steps: int = 4, batch: int = 8, seed: int = 0,
                 init_state_tree=None, pool_rows: int = 512):
        self.job_id = f"job{next(_job_counter)}"
        self.engine = engine
        self.members: List[Request] = []
        self.pool = TokenRingPool(pool_rows)
        self.micro_steps = micro_steps
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        init = (init_state_tree if init_state_tree is not None
                else (first.model if first.model is not None
                      else engine.fresh_state(seed)))
        self._slot = engine.bank.alloc(init)
        # dying jobs return their bank slot as soon as the last handle
        # ref drops (mid-window death triggers swap-compaction)
        self._finalizer = weakref.finalize(self, engine.bank.free,
                                           self._slot)
        self.gpu_time = 0
        self.add_member(first)

    # -- bank-backed state --------------------------------------------------
    @property
    def state(self):
        """The job's {"params", "opt"} train-state, read from its bank
        slot as an independent copy (safe to hold across compaction)."""
        return self.engine.bank.read(self._slot.idx)

    @state.setter
    def state(self, tree):
        self.engine.bank.write(self._slot.idx, tree)

    def release(self):
        """Return the bank slot (idempotent). Runs automatically when
        the handle is garbage-collected."""
        self._finalizer()

    # -- grouping interface ---------------------------------------------------
    @property
    def num_members(self) -> int:
        return len(self.members)

    @property
    def _pool_src(self) -> List[Optional[str]]:
        """Per-row stream tags, oldest first (tests/inspection)."""
        return self.pool.sources()

    def add_member(self, req: Request):
        self.members.append(req)
        if req.train_data is not None:
            self.pool.add(req.train_data, req.stream_id)

    def remove_member(self, stream_id: str):
        self.members = [m for m in self.members if m.stream_id != stream_id]

    def purge_stream_data(self, stream_id: str):
        """Drop a stream's pooled training data. Used when a camera
        LEAVES the fleet (churn): the group must stop doing SGD on a
        distribution no live member has. Eviction/regrouping does NOT
        purge — an evicted member's data contributed while it was a
        member (seed semantics, pinned by the golden traces)."""
        self.pool.purge(stream_id)

    def eval_on(self, samples) -> float:
        return self.engine.accuracy(
            self.engine.bank.read_params(self._slot.idx), samples)

    # -- allocator interface ---------------------------------------------------
    def eval(self) -> float:
        """Accuracy averaged over member subsamples (A_j in Eq. 1)."""
        if not self.members:
            return 0.0
        return self.engine.eval_jobs([self])[0]

    def train_micro(self):
        """One micro-window: `micro_steps` SGD steps on pool batches."""
        self.engine.train_micro_many([self])

    # -- data plane -------------------------------------------------------------
    def ingest(self, tokens: np.ndarray, stream_id: Optional[str] = None):
        """New window data from a member's transmission. `stream_id`
        attributes each row so churn can purge a departed camera's
        data (purge_stream_data). The ring pool evicts the OLDEST rows
        once the row budget is exceeded."""
        self.pool.add(tokens, stream_id)
