"""Group-retraining jobs: one shared student model per camera group,
trained on the group's aggregated stream data (knowledge-distilled from
the teacher's soft labels).

All jobs of a fleet share ONE compiled train/eval executable (same model
config), so micro-window context switches are cheap — the TPU analogue of
ECCO's job switching on a time-shared GPU.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.grouping import Request
from repro.models.model import Model, build_model
from repro.train.train_step import init_state, make_train_step

_job_counter = itertools.count()


class SharedEngine:
    """Compiled train/eval executables shared by every job of a fleet."""

    def __init__(self, cfg: ModelConfig, tcfg: Optional[TrainConfig] = None,
                 *, distill_weight: float = 1.0):
        self.cfg = cfg
        self.model = build_model(cfg)
        # b2=0.999 + no decay: the small-batch streaming regime needs the
        # long second-moment horizon (b2=0.95 oscillates; see
        # EXPERIMENTS.md calibration notes)
        self.tcfg = tcfg or TrainConfig(learning_rate=1e-3, b2=0.999,
                                        weight_decay=0.0, warmup_steps=5,
                                        total_steps=100000, remat="none")
        self._train = jax.jit(make_train_step(
            self.model, self.tcfg, distill_weight=distill_weight))

        def _acc(params, toks):
            logits, _ = self.model.apply(params, toks,
                                         compute_dtype=jnp.float32)
            pred = jnp.argmax(logits[:, :-1].astype(jnp.float32), axis=-1)
            return jnp.mean((pred == toks[:, 1:]).astype(jnp.float32))
        self._acc = jax.jit(_acc)

    def fresh_state(self, seed: int = 0):
        return init_state(self.model, jax.random.PRNGKey(seed), self.tcfg)

    def train_steps(self, state, batches):
        m = {}
        for b in batches:
            state, m = self._train(state, b)
        return state, m

    def accuracy(self, params, tokens) -> float:
        """Top-1 next-token accuracy — the mAP analogue."""
        return float(self._acc(params, jnp.asarray(tokens)))


class RetrainJob:
    """One group-retraining job (Alg. 1/2 unit)."""

    def __init__(self, engine: SharedEngine, first: Request, *,
                 micro_steps: int = 4, batch: int = 8, seed: int = 0,
                 init_state_tree=None):
        self.job_id = f"job{next(_job_counter)}"
        self.engine = engine
        self.members: List[Request] = []
        self.pool: List[np.ndarray] = []      # (B,S) token arrays
        self._pool_src: List[Optional[str]] = []   # stream per pool entry
        self.soft_pool: List[np.ndarray] = [] # optional teacher soft labels
        self.micro_steps = micro_steps
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        self.state = (init_state_tree if init_state_tree is not None
                      else (first.model if first.model is not None
                            else engine.fresh_state(seed)))
        self.gpu_time = 0
        self.add_member(first)

    # -- grouping interface ---------------------------------------------------
    @property
    def num_members(self) -> int:
        return len(self.members)

    def add_member(self, req: Request):
        self.members.append(req)
        if req.train_data is not None:
            self.pool.append(np.asarray(req.train_data))
            self._pool_src.append(req.stream_id)

    def remove_member(self, stream_id: str):
        self.members = [m for m in self.members if m.stream_id != stream_id]

    def purge_stream_data(self, stream_id: str):
        """Drop a stream's pooled training data. Used when a camera
        LEAVES the fleet (churn): the group must stop doing SGD on a
        distribution no live member has. Eviction/regrouping does NOT
        purge — an evicted member's data contributed while it was a
        member (seed semantics, pinned by the golden traces)."""
        keep = [i for i, src in enumerate(self._pool_src)
                if src != stream_id]
        self.pool = [self.pool[i] for i in keep]
        self._pool_src = [self._pool_src[i] for i in keep]

    def eval_on(self, samples) -> float:
        return self.engine.accuracy(self.state["params"], samples)

    # -- allocator interface ---------------------------------------------------
    def eval(self) -> float:
        """Accuracy averaged over member subsamples (A_j in Eq. 1)."""
        if not self.members:
            return 0.0
        return float(np.mean([self.eval_on(m.subsamples)
                              for m in self.members]))

    def train_micro(self):
        """One micro-window: `micro_steps` SGD steps on pool batches."""
        if not self.pool:
            return
        data = np.concatenate([p.reshape(-1, p.shape[-1]) for p in self.pool])
        batches = []
        for _ in range(self.micro_steps):
            idx = self.rng.integers(0, data.shape[0],
                                    size=min(self.batch, data.shape[0]))
            toks = jnp.asarray(data[idx])
            batches.append({"inputs": toks, "labels": toks})
        self.state, _ = self.engine.train_steps(self.state, batches)
        self.gpu_time += 1

    # -- data plane -------------------------------------------------------------
    def ingest(self, tokens: np.ndarray, stream_id: Optional[str] = None):
        """New window data from a member's transmission. `stream_id`
        attributes the entry so churn can purge a departed camera's
        data (purge_stream_data)."""
        self.pool.append(np.asarray(tokens))
        self._pool_src.append(stream_id)
        if len(self.pool) > 64:       # sliding data window
            self.pool = self.pool[-64:]
            self._pool_src = self._pool_src[-64:]
