"""Group-retraining jobs: one shared student model per camera group,
trained on the group's aggregated stream data (knowledge-distilled from
the teacher's soft labels).

All jobs of a fleet share ONE compiled train/eval executable (same model
config), so micro-window context switches are cheap — the TPU analogue of
ECCO's job switching on a time-shared GPU.

Training-plane layout (docs/training_plane.md): every job's train-state
lives in ONE stacked pytree (`JobBank`, amortized-doubling capacity,
swap-compaction on job death — same row discipline as
FleetDriftDetector), every job's data pool is a fixed-capacity dense
ring buffer of (seq,) token rows with per-row stream tags
(`TokenRingPool`), and `SharedEngine` exposes vmapped executables —
`batched_accuracy` scores every (member, job) pair of the fleet in one
call per chunk, `train_micro_many` runs one micro-window for a SET of
jobs via vmap over the stacked states. `RetrainJob` stays the thin
duck-typed handle the allocator/grouper drive; the batched paths are
bit-identical to its scalar loop (tests/test_trainer_bank.py), so they
change dispatch cost, never decisions.

Residency (the device-resident slot cache): by default the bank's
stacked leaves are committed jax arrays living on the accelerator (or
the CPU backend's device memory), with a per-slot host/device validity
bitmap. Batched entry points flush host-dirty rows in ONE scatter and
then gather/scatter directly on the resident stack — zero per-member
host transfer — while the scalar fallback reads/writes individual rows
via dynamic_slice/dynamic_update_slice on the same stack. Host reads
(`job.state`, checkpointing, RECL's model-zoo snapshots) sync lazily,
one row at a time, into a host mirror. `JobBank.stats` counts every
host<->device crossing of bank state; `resident=False` restores the
host-resident layout (the exactness-first mode PR 3 shipped), and both
modes are bit-identical (tests/test_trainer_bank.py).
"""
from __future__ import annotations

import functools
import itertools
import weakref
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.batching import job_precision
from repro.core.grouping import Request
from repro.models.model import Model, build_model
from repro.train.train_step import (init_state, make_train_step,
                                    make_train_step_many)

class _JobCounter:
    """Monotonic job-id source, rewindable to a snapshot. Elastic
    recovery re-runs an aborted window from its start; jobs created in
    the aborted attempt must reuse the SAME ids on the re-run (gains,
    groups, and golden traces key on job_id), so the counter position
    is part of the controller's window snapshot — `itertools.count`
    can't rewind."""

    def __init__(self):
        self.n = 0

    def __next__(self) -> int:
        v = self.n
        self.n += 1
        return v


_job_counter = _JobCounter()

# decision-plane precision policy (docs/scheduling.md): eval/screen
# dtype per job. Training compute is governed separately by
# TrainConfig.compute_dtype (bf16 compute leaves over fp32 master rows
# for every job); the per-job `precision` selects which dtype SCORES
# the job in the decision plane.
PRECISIONS = ("fp32", "bf16")
_PRECISION_DTYPE = {"fp32": jnp.float32, "bf16": jnp.bfloat16}


def _pad_size(n: int, floor: int = 4) -> int:
    """Smallest size >= n from the {2^k, 3*2^(k-2)} grid (>= floor):
    the jitted vmapped executables compile for ~2 shapes per octave
    instead of one per fleet size, while padding waste stays <= 1/3
    (pure powers of two waste up to 2x — measurable wall-clock on the
    compute-bound CPU path)."""
    if n <= floor:
        return floor
    k = (n - 1).bit_length()            # 2^k is the next power of two
    half = 3 << (k - 2) if k >= 2 else 1 << k   # 3/4 of it
    return half if half >= n else 1 << k


class TokenRingPool:
    """Fixed-capacity dense ring buffer of (seq,) token rows, each row
    tagged with the stream that contributed it.

    Replaces the seed's Python list of (B, S) arrays: `rows()` is the
    oldest->newest dense array `train_micro` samples batches from
    (bit-identical to the seed's per-micro-window np.concatenate
    order, without re-concatenating), eviction is by total pooled ROWS
    — a real token budget; the seed's 64-ENTRY sliding window was an
    unbounded memory window for variably-sized entries — and the
    per-row stream tag lets camera churn purge a departed stream's
    rows (`purge`).
    """

    def __init__(self, capacity_rows: int = 512):
        if capacity_rows <= 0:
            raise ValueError("capacity_rows must be positive")
        self.capacity = int(capacity_rows)
        self._rows: Optional[np.ndarray] = None    # (capacity, seq)
        self._src = np.empty(self.capacity, object)  # stream tag per row
        self._start = 0                            # oldest row position
        self._count = 0

    def __len__(self) -> int:
        return self._count

    @property
    def seq(self) -> Optional[int]:
        return None if self._rows is None else self._rows.shape[1]

    def _order(self) -> np.ndarray:
        """Physical indices of the live rows, oldest -> newest."""
        return (self._start + np.arange(self._count)) % self.capacity

    def add(self, tokens, stream_id: Optional[str] = None):
        arr = np.asarray(tokens)
        rows = arr.reshape(-1, arr.shape[-1])
        if self._rows is None:
            self._rows = np.zeros((self.capacity, rows.shape[1]), arr.dtype)
        if rows.shape[1] != self._rows.shape[1]:
            raise ValueError(
                f"pool rows are (seq={self._rows.shape[1]},); got "
                f"seq={rows.shape[1]}")
        n = rows.shape[0]
        if n >= self.capacity:
            # a single oversized entry: only its newest `capacity` rows
            # fit the budget
            self._rows[:] = rows[-self.capacity:]
            self._src[:] = stream_id
            self._start, self._count = 0, self.capacity
            return
        end = (self._start + self._count) % self.capacity
        idx = (end + np.arange(n)) % self.capacity
        self._rows[idx] = rows
        self._src[idx] = stream_id
        over = self._count + n - self.capacity
        if over > 0:                  # evict the oldest rows
            self._start = (self._start + over) % self.capacity
            self._count = self.capacity
        else:
            self._count += n

    def rows(self) -> np.ndarray:
        """All pooled rows as one dense (count, seq) array, oldest ->
        newest — what train batches are sampled from."""
        if self._rows is None or self._count == 0:
            return np.zeros((0, self.seq or 0), np.int64)
        return self._rows[self._order()]

    def sources(self) -> List[Optional[str]]:
        """Per-row stream tags, oldest -> newest (parallel to rows())."""
        if self._count == 0:
            return []
        return list(self._src[self._order()])

    def purge(self, stream_id: str):
        """Drop every row contributed by `stream_id`, preserving the
        relative order of the survivors."""
        if self._count == 0:
            return
        order = self._order()
        keep_mask = np.array([self._src[i] != stream_id for i in order])
        keep = order[keep_mask]
        kept_rows = self._rows[keep]           # fancy index: copies
        kept_src = self._src[keep]
        self._start = 0
        self._count = kept_rows.shape[0]
        self._rows[:self._count] = kept_rows
        self._src[:self._count] = kept_src


class _Slot:
    """Mutable bank position for one job. Swap-compaction retargets the
    moved survivor by rewriting `idx` in place; a freed-and-compacted
    slot has idx=None. `dead` marks slots queued for compaction."""
    __slots__ = ("idx", "dead")

    def __init__(self, idx: int):
        self.idx: Optional[int] = idx
        self.dead = False


class TransferStats:
    """Host<->device crossings of bank STATE (train-state rows; batch
    data is excluded — it originates on the host either way).

    One `sync` is one transfer event regardless of how many rows it
    carries, `bytes` is the payload that actually crossed (including
    shape-grid pad lanes), so "zero per-member round-trips" is
    directly checkable: the batched entry points must add 0 syncs
    once the fleet is resident. benchmarks/bench_trainer.py snapshots
    these around its timed passes; the parity suite asserts them.
    """
    __slots__ = ("h2d_syncs", "h2d_bytes", "d2h_syncs", "d2h_bytes")

    def __init__(self):
        self.reset()

    def reset(self):
        self.h2d_syncs = self.h2d_bytes = 0
        self.d2h_syncs = self.d2h_bytes = 0

    def h2d(self, nbytes: int):
        self.h2d_syncs += 1
        self.h2d_bytes += int(nbytes)

    def d2h(self, nbytes: int):
        self.d2h_syncs += 1
        self.d2h_bytes += int(nbytes)

    def snapshot(self) -> Dict[str, int]:
        return {k: getattr(self, k) for k in self.__slots__}


@functools.partial(jax.jit, donate_argnums=(0,))
def _dev_rows_set(stack, sel, rows):
    """stack[sel] = rows on device (donated: updates in place where the
    backend supports donation). `sel` may contain duplicates only if
    the duplicated rows are identical (the padding convention)."""
    return jax.tree.map(lambda x, r: x.at[sel].set(r), stack, rows)


@functools.partial(jax.jit, donate_argnums=(0,))
def _dev_row_set(stack, idx, row):
    """stack[idx] = row via dynamic_update_slice — the scalar-fallback
    write path (one row, zero host transfer)."""
    return jax.tree.map(
        lambda x, r: jax.lax.dynamic_update_slice(
            x, r[None], (idx,) + (0,) * r.ndim), stack, row)


@functools.partial(jax.jit, donate_argnums=(0,))
def _dev_rows_move(stack, dst, src):
    """stack[dst] = stack[src] for index VECTORS — swap-compaction's
    device-side moves as one launch however many slots died. The
    gathers all read the pre-update stack (functional semantics), so
    callers resolve move chains to original sources host-side."""
    return jax.tree.map(lambda x: x.at[dst].set(x[src]), stack)


def _pad_sel_rows(sel: np.ndarray, rows):
    """Pad a scatter's (sel, rows) to the {2^k, 3*2^(k-2)} size grid by
    duplicating the last entry (duplicate index + identical row is a
    well-defined scatter), so _dev_rows_set compiles for ~2 shapes per
    octave instead of one per fleet-churn pattern."""
    k = int(sel.size)
    p = _pad_size(k, floor=1)
    if p == k:
        return sel, rows
    sel = np.concatenate([sel, np.repeat(sel[-1:], p - k)])
    xp = jax.tree.map(
        lambda r: (np.concatenate([r] + [r[-1:]] * (p - k))
                   if isinstance(r, np.ndarray)
                   else jnp.concatenate([r] + [r[-1:]] * (p - k))), rows)
    return sel, xp


class JobBank:
    """All job train-states in ONE stacked pytree.

    Leaves are arrays of shape (capacity, ...): capacity grows by
    amortized doubling, job death swap-compacts the dead row with the
    last live one (same discipline as FleetDriftDetector rows), and
    the vmapped executables gather/scatter only the slots they touch.
    Reads return independent copies — a bank row may be overwritten by
    compaction after the caller lets go of its job handle.

    Residency: with `resident=True` (the default) the authoritative
    stack is a committed jax array pytree on the default device; a host
    numpy mirror stages checkpoint/zoo/state reads and writes. Two
    per-slot bitmaps track which side is current (`_host_ok`,
    `_dev_ok`; at least one is set for every live row):

      * host writes (`write`, i.e. `job.state = ...`, checkpoint
        restore, model-zoo seeding) land in the mirror and mark the
        device row stale;
      * `sync_to_device()` — run by every batched entry point AFTER
        `compact()`, before slot indices are captured — flushes ALL
        host-dirty rows in one batched scatter;
      * device writes (`scatter`, `write_row_device`) mark the mirror
        stale; host reads (`read`, `read_params`) re-sync lazily, one
        row at a time.

    Rule for new call sites: capture `params_stack()` (device leaves,
    borrowed) right before the fleet call and never cache it across a
    bank write/compaction — the resident buffers are donated to the
    update kernels. `gather`/`row_device` return fresh buffers and are
    safe to hold.
    """

    def __init__(self, engine: "SharedEngine", capacity: int = 4,
                 resident: Optional[bool] = None, mesh=None):
        self.engine = engine
        self._cap = int(capacity)
        self.resident = True if resident is None else bool(resident)
        self._host = None            # numpy mirror, leaves (cap, ...)
        self._dev = None             # committed jax stack (resident)
        self._treedef = None
        self._slots: List[_Slot] = []
        self._dead: List[_Slot] = []
        self._host_ok = np.zeros(self._cap, bool)
        self._dev_ok = np.zeros(self._cap, bool)
        # params-content version: bumped by every write/scatter/move so
        # the cached compute-precision stack (params_stack_compute)
        # knows when its cast is stale — ONE cast per flush, not one
        # per eval call
        self._version = 0
        self._compute_cache: Optional[Tuple[tuple, object]] = None
        self.stats = TransferStats()
        self.state_row_nbytes = 0    # one slot's full train-state
        self.params_row_nbytes = 0   # one slot's params subtree
        self.mesh = None
        self._sharding = None        # NamedSharding of the slot axis
        if mesh is not None:
            self.place_on(mesh)

    def place_on(self, mesh):
        """(Re)place the resident stack under a fleet mesh: slots
        block-sharded along the job axis (distributed.sharding.
        stack_sharding), capacity aligned to the device count so the
        blocks stay equal. Also the elastic re-mesh path — device_put
        against the NEW mesh's sharding moves surviving state without a
        host round-trip. mesh=None detaches (single-device placement).
        Values never change: gathers/scatters/updates are exact
        whatever the placement, so decisions stay bit-identical."""
        self.mesh = mesh
        if mesh is None or not self.resident:
            self._sharding = None
            return
        from repro.distributed.sharding import stack_sharding
        self._sharding = stack_sharding(mesh)
        self._pad_capacity(self._align(self._cap))
        self._enforce_sharding()

    def _align(self, n: int) -> int:
        """Round capacity up to a device-count multiple so the slot
        axis splits into equal per-device blocks (RowRegistry.align,
        same rule)."""
        if self.mesh is None:
            return n
        from repro.distributed.sharding import fleet_devices
        d = fleet_devices(self.mesh)
        return -(-n // d) * d

    def _enforce_sharding(self):
        """Re-place any resident leaf whose sharding drifted from the
        fleet placement (donated update kernels usually preserve it;
        growth concats and re-meshes don't). Device-to-device, no host
        crossing."""
        if self._sharding is None or self._dev is None:
            return
        s = self._sharding

        def fix(x):
            return x if getattr(x, "sharding", None) == s \
                else jax.device_put(x, s)
        self._dev = jax.tree.map(fix, self._dev)

    def invalidate_device(self):
        """Simulate accelerator-memory loss (elastic failure model: the
        device stack is gone, the host control plane survives). Every
        device row is marked stale AND zeroed — a live row whose only
        valid copy was device-side is now genuinely lost, so a recovery
        path that forgets to restore a job reads zeros instead of
        silently reusing 'dead' device values. Restore writes each job
        through `write` (host mirror + dirty mark); the next batched
        entry point flushes the fleet in one scatter."""
        self._dev_ok[:] = False
        self._version += 1
        if self._dev is not None:
            self._dev = jax.tree.map(lambda x: jnp.zeros_like(x),
                                     self._dev)
            self._enforce_sharding()

    def __len__(self) -> int:
        """Live slots, including dead-but-not-yet-compacted ones."""
        return len(self._slots)

    @property
    def capacity(self) -> int:
        return self._cap

    def _init_stack(self, template):
        leaves, self._treedef = jax.tree.flatten(template)
        self._host = jax.tree.unflatten(self._treedef, [
            np.zeros((self._cap,) + np.shape(x), np.asarray(x).dtype)
            for x in leaves])
        self.state_row_nbytes = int(sum(
            np.asarray(x).nbytes for x in leaves))
        if isinstance(template, dict) and "params" in template:
            # fleetlint: disable=host-sync -- one-time row sizing at
            # stack init over the HOST template (transfer accounting
            # metadata), not a hot path
            self.params_row_nbytes = int(sum(
                np.asarray(x).nbytes
                for x in jax.tree.leaves(template["params"])))
        if self.resident:
            self._dev = jax.tree.map(
                lambda x: jnp.zeros(x.shape, x.dtype), self._host)
            self._enforce_sharding()

    def _grow_to(self, need: int):
        """Amortized doubling: allocating the Nth job is O(state), not
        O(N * state). Under a mesh, capacity rounds up to a device
        multiple so the slot axis keeps equal per-device blocks."""
        if need <= self._cap:
            return
        self._pad_capacity(self._align(max(need, 2 * self._cap)))

    def _pad_capacity(self, new_cap: int):
        """Pad every stacked array (host mirror, resident stack,
        validity bitmaps) to exactly `new_cap` slots."""
        pad = new_cap - self._cap
        if pad <= 0:
            return
        if self._host is not None:
            self._host = jax.tree.map(
                lambda x: np.concatenate(
                    [x, np.zeros((pad,) + x.shape[1:], x.dtype)]),
                self._host)
        if self._dev is not None:
            self._dev = jax.tree.map(
                lambda x: jnp.concatenate(
                    [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]),
                self._dev)
            self._enforce_sharding()
        # fleetlint: disable=rows-discipline -- JobBank IS the training
        # plane's row registry (amortized doubling + swap-compaction,
        # docs/training_plane.md); the validity bitmaps grow in
        # lockstep with its stack
        self._host_ok = np.concatenate(
            [self._host_ok, np.zeros(pad, bool)])
        # fleetlint: disable=rows-discipline -- as above: bank-owned
        # bitmap, grown under the bank's own doubling discipline
        self._dev_ok = np.concatenate(
            [self._dev_ok, np.zeros(pad, bool)])
        self._cap = new_cap
        self._version += 1      # leaf shapes changed under the cache

    def _state_leaves(self, state) -> List:
        leaves, treedef = jax.tree.flatten(state)
        if treedef != self._treedef:
            raise ValueError(
                f"state tree mismatch: bank holds {self._treedef}, "
                f"got {treedef}")
        return leaves

    def alloc(self, state) -> _Slot:
        self.compact()
        if self._host is None:
            self._init_stack(state)
        self._grow_to(len(self._slots) + 1)
        slot = _Slot(len(self._slots))
        self._slots.append(slot)
        self.write(slot.idx, state)
        return slot

    def free(self, slot: _Slot):
        """QUEUE the slot for reclamation; rows do not move here.

        free() runs from GC finalizers, i.e. at arbitrary allocation
        points — job handles can sit in cyclic garbage (controllers
        hold reference cycles) and die mid-operation in a LATER run on
        the same engine. Batched callers capture slot indices right
        before a fleet call, so moving rows here would silently
        evaluate/train the wrong job. Actual swap-compaction happens in
        compact(), which every allocating or batched entry point runs
        FIRST — before any index is captured. Idempotent."""
        if slot.idx is None or slot.dead:
            return
        slot.dead = True
        self._dead.append(slot)

    def compact(self):
        """Swap-with-last removal of every queued-dead slot, keeping
        live rows dense (capacity is retained; rows beyond len(self)
        are garbage). Moves both the host mirror row and — when it is
        current — the resident device row, carrying the validity bits
        with them; the vacated tail row's bits are cleared so a future
        alloc at that position cannot inherit stale cache state.
        Device moves are DEFERRED and applied as one batched launch:
        a mass-churn window freeing K jobs costs one device call, not
        K. Swap chains (a survivor moved into a hole later becoming
        the move source of another hole) are resolved host-side to
        original row indices, because the batched kernel's gathers all
        read the pre-update stack. Only called at deterministic safe
        points."""
        if self._dead:
            self._version += 1      # row moves remap slot -> contents
        dev_moves: Dict[int, int] = {}     # dst row -> ORIGINAL src row
        src_of: Dict[int, int] = {}        # current row -> original row
        while self._dead:
            slot = self._dead.pop()
            idx = slot.idx
            last = len(self._slots) - 1
            if idx != last:
                moved = self._slots[last]
                # a stale mirror row is garbage by definition — only
                # copy host bytes when the mirror is authoritative
                if self._host_ok[last]:
                    for x in jax.tree.leaves(self._host):
                        x[idx] = x[last]
                self._host_ok[idx] = bool(self._host_ok[last])
                if self._dev is not None:
                    if self._dev_ok[last]:
                        orig = src_of.pop(last, last)
                        dev_moves[idx] = orig
                        src_of[idx] = orig
                    else:
                        # idx now holds a host-authoritative row; any
                        # earlier device move into it is moot (the row
                        # is marked device-stale below either way)
                        dev_moves.pop(idx, None)
                        src_of.pop(idx, None)
                    self._dev_ok[idx] = bool(self._dev_ok[last])
                moved.idx = idx
                self._slots[idx] = moved
            self._slots.pop()
            self._host_ok[last] = False
            self._dev_ok[last] = False
            dev_moves.pop(last, None)      # fell off the live range
            src_of.pop(last, None)
            slot.idx = None
        if dev_moves:
            dst = np.fromiter(dev_moves.keys(), np.int32,
                              count=len(dev_moves))
            src = np.fromiter(dev_moves.values(), np.int32,
                              count=len(dev_moves))
            dst, src = _pad_sel_rows(dst, src)
            self._dev = _dev_rows_move(self._dev, jnp.asarray(dst),
                                       jnp.asarray(src))
            self._enforce_sharding()

    @staticmethod
    def _check_idx(idx):
        """A freed-and-compacted slot has idx=None; numpy would treat
        None as np.newaxis and broadcast a write across the WHOLE bank
        (silent fleet-wide corruption) — fail loudly instead."""
        if idx is None:
            raise ValueError("use-after-release: job's bank slot was freed")
        return idx

    # -- residency sync protocol -------------------------------------------
    def sync_to_device(self):
        """Flush every host-dirty row into the resident stack as ONE
        batched scatter (one h2d sync, not one per row). Every batched
        entry point runs this after compact(), before capturing slot
        indices; no-op in host mode or when nothing is dirty."""
        if not self.resident or self._host is None:
            return
        if self._dev is None:
            self._dev = jax.tree.map(
                lambda x: jnp.zeros(x.shape, x.dtype), self._host)
        live = len(self._slots)
        dirty = np.flatnonzero(self._host_ok[:live] & ~self._dev_ok[:live])
        if dirty.size == 0:
            return
        rows = jax.tree.map(lambda x: x[dirty], self._host)
        sel, rows = _pad_sel_rows(dirty.astype(np.int32), rows)
        self._dev = _dev_rows_set(self._dev, jnp.asarray(sel),
                                  jax.tree.map(jnp.asarray, rows))
        self._enforce_sharding()
        self._dev_ok[dirty] = True
        # bytes = the payload that actually crossed, incl. pad lanes
        self.stats.h2d(int(sel.size) * self.state_row_nbytes)

    def _sync_row_to_host(self, idx: int):
        """Lazy d2h: pull the device row into the host mirror only when
        the mirror is stale (the row was last written by a batched or
        scalar-fallback device call). Repeat reads are free."""
        if self._host_ok[idx]:
            return
        # fleetlint: disable=host-sync -- this IS the residency rule's
        # lazy mirror d2h (docs/training_plane.md): one row, only when
        # the mirror is stale, metered via stats.d2h below
        row = jax.device_get(jax.tree.map(lambda x: x[idx], self._dev))
        for dst, src in zip(jax.tree.leaves(self._host),
                            jax.tree.leaves(row)):
            dst[idx] = src
        self._host_ok[idx] = True
        self.stats.d2h(self.state_row_nbytes)

    # -- host-side reads/writes (checkpoints, model zoo, job.state) --------
    def read(self, idx: int):
        """Slot `idx`'s state as an independent host pytree copy
        (lazily synced from the device when stale)."""
        self._check_idx(idx)
        self._sync_row_to_host(idx)
        return jax.tree.map(lambda x: np.array(x[idx]), self._host)

    def read_params(self, idx: int):
        """Params-only host copy of slot `idx` — the eval hot path
        doesn't pay for copying the Adam moments (~2x params)."""
        self._check_idx(idx)
        self._sync_row_to_host(idx)
        return jax.tree.map(lambda x: np.array(x[idx]),
                            self._host["params"])

    def read_template(self, idx: int):
        """Slot `idx`'s state as a shape/dtype/structure TEMPLATE: the
        host mirror row WITHOUT syncing, so the VALUES are unspecified
        when the device row is authoritative. For structure-only
        consumers (checkpoint restore targets) that would otherwise
        pay a full-row d2h just to throw the numbers away. Leaves are
        READ-ONLY views — mutating them would bypass the dirty-bit
        write protocol (use `write` / `job.state = ...`)."""
        self._check_idx(idx)

        def leaf(x):
            v = x[idx]
            if isinstance(v, np.ndarray):
                v = v.view()
                v.flags.writeable = False
            return v
        return jax.tree.map(leaf, self._host)

    def write(self, idx: int, state):
        """Host write-through: lands in the mirror and marks the device
        row stale; the next batched entry point's sync_to_device()
        carries it across in the shared flush."""
        self._check_idx(idx)
        for dst, src in zip(jax.tree.leaves(self._host),
                            self._state_leaves(state)):
            dst[idx] = np.asarray(src)
        self._host_ok[idx] = True
        self._dev_ok[idx] = False
        self._version += 1

    # -- device-side row access (scalar fallback) ---------------------------
    def row_device(self, idx: int):
        """Slot `idx`'s full state sliced from the resident stack on
        device (fresh buffers, zero host transfer)."""
        self._check_idx(idx)
        self.sync_to_device()
        return jax.tree.map(lambda x: x[idx], self._dev)

    def params_row_device(self, idx: int):
        """Params subtree of slot `idx` on device — the scalar eval
        path's zero-transfer read."""
        self._check_idx(idx)
        self.sync_to_device()
        return jax.tree.map(lambda x: x[idx], self._dev["params"])

    def write_row_device(self, idx: int, state):
        """Scalar-fallback write: ONE row updated in the resident stack
        via dynamic_update_slice (donated; zero host transfer). The
        host mirror row goes stale and re-syncs lazily on read."""
        self._check_idx(idx)
        self._state_leaves(state)          # validates the treedef
        self._dev = _dev_row_set(self._dev, jnp.int32(idx), state)
        self._enforce_sharding()
        self._dev_ok[idx] = True
        self._host_ok[idx] = False
        self._version += 1

    # -- batched access (vmapped executables) -------------------------------
    def gather(self, idxs: Sequence[int]):
        """Stacked device states for the selected slots (leaves
        (k, ...)) — the input of the vmapped executables. Resident mode
        slices the device stack (zero host transfer after the shared
        flush); host mode pays one h2d of the k rows."""
        sel = np.asarray(idxs, np.int64)
        if self.resident:
            self.sync_to_device()
            dsel = jnp.asarray(sel)
            return jax.tree.map(lambda x: x[dsel], self._dev)
        self.stats.h2d(int(sel.size) * self.state_row_nbytes)
        return jax.tree.map(lambda x: jnp.asarray(x[sel]), self._host)

    def scatter(self, idxs: Sequence[int], states):
        """Write the vmapped executables' output states back. Resident
        mode scatters on device and marks the host mirror stale (zero
        host transfer); host mode pays one d2h of the k rows."""
        sel = np.asarray(idxs, np.int64)
        if self.resident:
            if sel.size == 0:
                return
            self._state_leaves(states)     # validates the treedef
            psel, rows = _pad_sel_rows(sel.astype(np.int32), states)
            self._dev = _dev_rows_set(self._dev, jnp.asarray(psel),
                                      jax.tree.map(jnp.asarray, rows))
            self._enforce_sharding()
            self._dev_ok[sel] = True
            self._host_ok[sel] = False
            self._version += 1
            return
        for dst, src in zip(jax.tree.leaves(self._host),
                            self._state_leaves(states)):
            dst[sel] = np.asarray(src)
        self.stats.d2h(int(sel.size) * self.state_row_nbytes)
        self._version += 1

    def snapshot_params(self, idx: int):
        """COMMITTED, independent device copy of slot `idx`'s params
        subtree — unlike `params_stack()` (borrowed) this survives
        later bank writes/compaction, so long-lived consumers (the
        serve plane's swap gate holds a group's serving snapshot across
        windows) may keep it. Resident mode gathers on device (zero
        host crossing); host mode pays the one params-row h2d its
        layout implies."""
        self._check_idx(idx)
        if self.resident:
            self.sync_to_device()
            return jax.tree.map(lambda x: x[idx], self._dev["params"])
        self.stats.h2d(self.params_row_nbytes)
        return jax.tree.map(lambda x: jnp.asarray(x[idx]),
                            self._host["params"])

    def params_stack(self):
        """The stacked params subtree (leaves (capacity, ...)) —
        `batched_accuracy`'s params_stack argument. Resident mode
        returns the DEVICE leaves (synced first). BORROWED: valid only
        until the next bank write/scatter/compaction (the resident
        buffers are donated to the update kernels), so capture it right
        before the fleet call — the engine entry points already do."""
        if self._host is None:
            return None
        if self.resident:
            self.sync_to_device()
            return self._dev["params"]
        return self._host["params"]

    def params_stack_compute(self, dtype):
        """The stacked params CAST to compute dtype `dtype` — the
        precision policy's "one cast at flush" contract
        (docs/scheduling.md): fp32 master rows stay the authoritative
        stack; the bf16 compute stack is cast ONCE per bank version
        (writes/scatters/compaction bump `_version`) and cached, so a
        window's many bf16 eval calls share one cast instead of
        re-casting per call. fp32 requests return the master stack
        itself (borrowed, same as params_stack); other dtypes return
        INDEPENDENT buffers safe to hold until the next bank
        mutation."""
        dt = jnp.dtype(dtype)
        if dt == jnp.dtype(jnp.float32):
            return self.params_stack()
        base = self.params_stack()
        if base is None:
            return None
        key = (str(dt), self._version, self.resident)
        if self._compute_cache is not None \
                and self._compute_cache[0] == key:
            return self._compute_cache[1]
        stack = jax.tree.map(
            lambda x: x.astype(dt)
            if jnp.issubdtype(np.asarray(x).dtype, np.floating) else x,
            base)
        self._compute_cache = (key, stack)
        return stack


class SharedEngine:
    """Compiled train/eval executables shared by every job of a fleet.

    Scalar executables (`accuracy`, `train_steps`) serve single jobs;
    the vmapped ones (`batched_accuracy`, `eval_pairs`, `eval_jobs`,
    `train_micro_many`) serve the whole fleet per device call and are
    bit-identical to looping the scalar path. `batched=False` disables
    the vmapped dispatch everywhere (the duck-typed probe in
    repro.core.batching reports the engine as not batch-capable), which
    the parity tests and benchmarks use as the reference scalar twin.
    `resident=False` keeps the JobBank host-resident (PR 3's layout);
    the default keeps all job states device-resident and both the
    batched paths and the scalar fallback operate on the resident stack
    with zero per-call host transfer of state.
    """

    def __init__(self, cfg: ModelConfig, tcfg: Optional[TrainConfig] = None,
                 *, distill_weight: float = 1.0, batched: bool = True,
                 eval_chunk: int = 128, batch_min_jobs: int = 4,
                 resident: Optional[bool] = None, mesh=None):
        self.cfg = cfg
        self.model = build_model(cfg)
        # b2=0.999 + no decay: the small-batch streaming regime needs the
        # long second-moment horizon (b2=0.95 oscillates; see
        # EXPERIMENTS.md calibration notes)
        self.tcfg = tcfg or TrainConfig(learning_rate=1e-3, b2=0.999,
                                        weight_decay=0.0, warmup_steps=5,
                                        total_steps=100000, remat="none")
        self._distill_weight = distill_weight
        self._train = jax.jit(make_train_step(
            self.model, self.tcfg, distill_weight=distill_weight))

        def _acc(params, toks):
            logits, _ = self.model.apply(params, toks,
                                         compute_dtype=jnp.float32)
            pred = jnp.argmax(logits[:, :-1].astype(jnp.float32), axis=-1)
            return jnp.mean((pred == toks[:, 1:]).astype(jnp.float32))
        self._acc = jax.jit(_acc)
        # per-precision scalar eval executables; "fp32" aliases the
        # seed _acc above so the default path's trace is untouched
        self._acc_prec: Dict[str, Callable] = {"fp32": self._acc}

        self.batched = bool(batched)
        self.eval_chunk = int(eval_chunk)
        # vmapped train only pays off once lane padding + state
        # gather/scatter amortize over enough jobs; smaller groups take
        # the scalar step (identical numbers, and small fleets skip the
        # vmapped-executable compile entirely)
        self.batch_min_jobs = int(batch_min_jobs)
        self.bank = JobBank(self, resident=resident, mesh=mesh)

        # flattened fleet eval: a job's members ride the EXAMPLE axis of
        # one forward (params read once per job, GEMMs see M*B rows);
        # one jitted executable per (member-batch size B, precision)
        self._acc_flat: Dict[Tuple[int, str], Callable] = {}
        self._train_many: Dict[int, Callable] = {}

    def fresh_state(self, seed: int = 0):
        return init_state(self.model, jax.random.PRNGKey(seed), self.tcfg)

    def train_steps(self, state, batches):
        m = {}
        for b in batches:
            state, m = self._train(state, b)
        return state, m

    def accuracy(self, params, tokens, *, precision: str = "fp32") -> float:
        """Top-1 next-token accuracy — the mAP analogue. `precision`
        picks the decision-plane eval dtype (docs/scheduling.md);
        "fp32" is the seed executable, bit-identical to before."""
        # fleetlint: disable=host-sync -- the scalar decision API
        # returns a host float by contract; batched callers use
        # batched_accuracy, whose results cross once per chunk
        return float(self._acc_fn(precision)(params, jnp.asarray(tokens)))

    # -- batched eval plane -------------------------------------------------
    def _acc_fn(self, precision: str) -> Callable:
        fn = self._acc_prec.get(precision)
        if fn is None:
            cd = _PRECISION_DTYPE[precision]

            def _acc(params, toks):
                # screen-precision eval: params cast to the compute
                # dtype (a no-op when the caller passes the bank's
                # cast-at-flush compute stack) so weights x activations
                # stay in `cd` end to end; argmax/mean stay fp32
                params = jax.tree.map(
                    lambda x: x.astype(cd)
                    if jnp.issubdtype(x.dtype, jnp.floating) else x,
                    params)
                logits, _ = self.model.apply(params, toks,
                                             compute_dtype=cd)
                pred = jnp.argmax(logits[:, :-1].astype(jnp.float32),
                                  axis=-1)
                return jnp.mean((pred == toks[:, 1:]).astype(jnp.float32))
            fn = jax.jit(_acc)
            self._acc_prec[precision] = fn
        return fn

    def _acc_flat_fn(self, b: int, precision: str = "fp32") -> Callable:
        """Jitted flat eval for member-batch size `b`: takes (M*b, S)
        token rows + one job's params, returns (M,) per-member
        accuracies — each member's logits/argmax/mean identical to its
        own scalar `_acc` call (rows of a batch are independent). One
        executable per (b, precision); "fp32" keeps the seed trace."""
        fn = self._acc_flat.get((b, precision))
        if fn is None:
            cd = _PRECISION_DTYPE[precision]

            def flat(params, toks):
                if cd != jnp.float32:
                    params = jax.tree.map(
                        lambda x: x.astype(cd)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x,
                        params)
                logits, _ = self.model.apply(params, toks,
                                             compute_dtype=cd)
                pred = jnp.argmax(logits[:, :-1].astype(jnp.float32),
                                  axis=-1)
                ok = (pred == toks[:, 1:]).astype(jnp.float32)
                return jnp.mean(ok.reshape(toks.shape[0] // b, b, -1),
                                axis=(1, 2))
            fn = jax.jit(flat)
            self._acc_flat[(b, precision)] = fn
        return fn

    def batched_accuracy(self, params_stack, tokens, job_ids, *,
                         precision: str = "fp32") -> np.ndarray:
        """Score every (tokens[i], params_stack[job_ids[i]]) pair of the
        fleet, bit-identical to calling `accuracy` per pair.

        tokens is (P, B, S) — pair i's eval batch; job_ids (P,) indexes
        the stacked params (JobBank slots). Pairs are grouped by job and
        each job's member batches are FLATTENED into the example axis of
        one forward per chunk of ~eval_chunk rows: the job's params are
        read once per chunk instead of once per member, the GEMMs see
        M*B rows instead of B (the measured win on CPU — per-pair eval
        is compute/memory-bound, not launch-bound), and device launches
        drop from one per member to one per (job, chunk). Member counts
        pad to a multiple of 8 so the executable compiles for a handful
        of shapes; padded lanes are discarded.
        """
        toks = np.asarray(tokens)
        ids = np.asarray(job_ids, np.int64)
        out = np.empty(ids.shape[0], np.float32)
        if ids.shape[0] == 0:
            return out
        if toks.ndim != 3:
            raise ValueError(f"tokens must be (P, B, S); got {toks.shape}")
        b = toks.shape[1]
        groups: Dict[int, List[int]] = {}
        for i, j in enumerate(ids):
            groups.setdefault(int(j), []).append(i)
        m_chunk = max(1, self.eval_chunk // b)     # members per flat call
        fn = self._acc_flat_fn(b, precision)
        # a resident stack is sliced per job ON DEVICE (zero transfer);
        # host leaves pay one params-row h2d per job
        host_stack = any(isinstance(x, np.ndarray)
                         for x in jax.tree.leaves(params_stack))
        for jid, members in groups.items():
            params = jax.tree.map(lambda x: jnp.asarray(x[jid]),
                                  params_stack)
            if host_stack:
                self.bank.stats.h2d(self.bank.params_row_nbytes)
            for lo in range(0, len(members), m_chunk):
                sel = members[lo:lo + m_chunk]
                m = len(sel)
                m_pad = min(m_chunk, -(-m // 8) * 8)
                tk = np.zeros((m_pad * b,) + toks.shape[2:], toks.dtype)
                tk[:m * b] = toks[sel].reshape(m * b, -1)
                res = fn(params, jnp.asarray(tk))
                out[sel] = np.asarray(res)[:m]
        return out

    def _bank_slot(self, job) -> Optional[int]:
        """The job's live slot index in THIS engine's bank, else None
        (foreign engines, duck-typed fakes, freed/dying slots)."""
        slot = getattr(job, "_slot", None)
        if (getattr(job, "engine", None) is self and slot is not None
                and slot.idx is not None and not slot.dead):
            return slot.idx
        return None

    def _bank_backed(self, jobs) -> bool:
        return (self.batched and len(self.bank) > 0
                and all(self._bank_slot(j) is not None for j in jobs))

    def _eval_slot(self, idx, samples, *, precision: str = "fp32") -> float:
        """Scalar eval of one bank slot. Resident mode slices the job's
        params on device (dynamic row read of the resident stack, zero
        host transfer); the host-resident bank copies the row out and
        pays the implicit params h2d at dispatch. Non-fp32 precisions
        cast the row inside the jitted eval (the scalar fallback does
        not go through the bank's cast-at-flush compute stack)."""
        if self.bank.resident:
            # fleetlint: disable=host-sync -- scalar eval returns a
            # host float by contract; the params row never crosses
            # (device-side dynamic slice), only the scalar result does
            return float(self._acc_fn(precision)(
                self.bank.params_row_device(idx), jnp.asarray(samples)))
        params = self.bank.read_params(idx)
        self.bank.stats.h2d(self.bank.params_row_nbytes)
        return self.accuracy(params, samples, precision=precision)

    def eval_pairs(self, pairs, *,
                   precision: Optional[str] = None) -> List[float]:
        """pairs: [(job, samples)]. Returns per-pair accuracies,
        bit-identical to [job.eval_on(s) for job, s in pairs], with
        each distinct sample shape dispatched as one batched call.
        `precision` overrides every pair's own screen dtype (the fp32
        grading pass of mixed-precision fleets); None keeps each job's
        decision-plane precision."""
        if not pairs:
            return []
        self.bank.compact()     # BEFORE capturing any slot index
        if not self._bank_backed([j for j, _ in pairs]):
            if precision is None:
                # fleetlint: disable=per-member-loop -- the documented
                # scalar fallback for probe-rejected jobs (duck-typed
                # fakes, foreign engines); bit-identical by contract
                return [job.eval_on(s) for job, s in pairs]
            # fleetlint: disable=per-member-loop -- scalar fallback, as
            # above, with the override forwarded
            return [job.eval_on(s, precision=precision)
                    for job, s in pairs]
        out: List[float] = [0.0] * len(pairs)
        arrs = [np.asarray(s) for _, s in pairs]
        # pairs group by (shape, decision precision): every job of an
        # all-fp32 fleet lands in the same groups in the same order as
        # the seed's shape-only keying (bit-identity contract); a mixed
        # fleet dispatches one batched call per precision per shape,
        # bf16 jobs scored against the bank's cast-at-flush compute
        # stack
        by_key: Dict[tuple, List[int]] = {}
        for i, a in enumerate(arrs):
            prec = precision or job_precision(pairs[i][0])
            by_key.setdefault((a.shape, prec), []).append(i)
        stacks = {"fp32": self.bank.params_stack()}
        for (_shape, prec), idxs in by_key.items():
            stack = stacks.get(prec)
            if stack is None:
                stack = self.bank.params_stack_compute(
                    _PRECISION_DTYPE[prec])
                stacks[prec] = stack
            toks = np.stack([arrs[i] for i in idxs])
            jids = np.array([pairs[i][0]._slot.idx for i in idxs])
            for i, a in zip(idxs, self.batched_accuracy(
                    stack, toks, jids, precision=prec)):
                out[i] = float(a)
        return out

    def eval_jobs(self, jobs, *,
                  precision: Optional[str] = None) -> List[float]:
        """Batched RetrainJob.eval: every (member, job) subsample pair
        of `jobs` scored in one fleet call, then averaged per job with
        the same float64 np.mean the scalar path uses. `precision`
        forwards the eval_pairs override (fp32 grading of mixed
        fleets)."""
        pairs, spans = [], []
        for j in jobs:
            ms = list(j.members)
            spans.append(len(ms))
            pairs.extend((j, m.subsamples) for m in ms)
        accs = self.eval_pairs(pairs, precision=precision)
        out, k = [], 0
        for n in spans:
            out.append(float(np.mean(accs[k:k + n])) if n else 0.0)
            k += n
        return out

    # -- vmapped train plane ------------------------------------------------
    def _train_many_fn(self, steps: int) -> Callable:
        fn = self._train_many.get(steps)
        if fn is None:
            fn = jax.jit(make_train_step_many(
                self.model, self.tcfg, steps=steps,
                distill_weight=self._distill_weight))
            self._train_many[steps] = fn
        return fn

    def _train_job_scalar(self, job, toks):
        """The seed per-job micro-window, with the batches pre-drawn.

        A bank-backed job on a resident bank reads and writes its state
        row ON DEVICE (dynamic_slice / dynamic_update_slice on the
        resident stack — zero host round-trip per micro-window); the
        legacy `job.state` path remains for duck-typed foreign jobs and
        the host-resident bank, where the whole state crosses the
        boundary twice per micro-window."""
        batches = [{"inputs": jnp.asarray(t), "labels": jnp.asarray(t)}
                   for t in toks]
        idx = self._bank_slot(job)
        if idx is not None and self.bank.resident:
            state, _ = self.train_steps(self.bank.row_device(idx), batches)
            self.bank.write_row_device(idx, state)
            return
        if idx is not None:
            self.bank.stats.h2d(self.bank.state_row_nbytes)
            self.bank.stats.d2h(self.bank.state_row_nbytes)
        state, _ = self.train_steps(job.state, batches)
        job.state = state

    def train_micro_many(self, jobs) -> None:
        """One micro-window for each job in `jobs`.

        Batches are drawn on the host with each job's OWN rng in the
        same order the scalar loop would draw them, then jobs whose
        batches share a shape run as ONE vmapped multi-step call per
        group; stragglers (pool smaller than the batch size, foreign
        jobs, groups below batch_min_jobs) take the scalar path.
        Either way the result is bit-identical to calling
        job.train_micro() per job.
        """
        self.bank.compact()     # BEFORE capturing any slot index
        groups: Dict[Tuple[int, tuple], List[tuple]] = {}
        for job in jobs:
            data = job.pool.rows()
            if data.shape[0] == 0:
                continue                       # train_micro no-ops
            k = min(job.batch, data.shape[0])
            toks = np.stack(
                [data[job.rng.integers(0, data.shape[0], size=k)]
                 for _ in range(job.micro_steps)])
            job.gpu_time += 1
            if (not self.batched or k != job.batch
                    or self._bank_slot(job) is None):
                self._train_job_scalar(job, toks)
                continue
            groups.setdefault((job.micro_steps, toks.shape),
                              []).append((job, toks))

        for (steps, _shape), items in groups.items():
            if len(items) < self.batch_min_jobs:
                for job, toks in items:
                    self._train_job_scalar(job, toks)
                continue
            n = len(items)
            idxs = [job._slot.idx for job, _ in items]
            batch_np = np.stack([t for _, t in items])  # (J, steps, k, S)
            pad = _pad_size(n, floor=min(4, max(2, self.batch_min_jobs)))
            if pad != n:            # pad lanes compute, never scatter
                idxs = idxs + [idxs[0]] * (pad - n)
                batch_np = np.concatenate(
                    [batch_np] + [batch_np[:1]] * (pad - n))
            states = self.bank.gather(idxs)
            toks_dev = jnp.asarray(batch_np)
            new_states, _ = self._train_many_fn(steps)(
                states, {"inputs": toks_dev, "labels": toks_dev})
            self.bank.scatter(idxs[:n],
                              jax.tree.map(lambda x: x[:n], new_states))


class RetrainJob:
    """One group-retraining job (Alg. 1/2 unit): a thin handle over a
    JobBank slot (the train-state) plus host-side bookkeeping (members,
    token ring pool, rng). The duck-typed allocator/grouper interface
    is unchanged from the seed."""

    def __init__(self, engine: SharedEngine, first: Request, *,
                 micro_steps: int = 4, batch: int = 8, seed: int = 0,
                 init_state_tree=None, pool_rows: int = 512,
                 precision: str = "fp32"):
        if precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}; got {precision!r}")
        self.job_id = f"job{next(_job_counter)}"
        self.engine = engine
        # decision-plane screen precision (docs/scheduling.md): bf16
        # jobs eval against the bank's compute stack; near-threshold
        # grouping decisions and the serve gate rescore in fp32
        self.precision = precision
        self.members: List[Request] = []
        self.pool = TokenRingPool(pool_rows)
        self.micro_steps = micro_steps
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        init = (init_state_tree if init_state_tree is not None
                else (first.model if first.model is not None
                      else engine.fresh_state(seed)))
        self._slot = engine.bank.alloc(init)
        # dying jobs return their bank slot as soon as the last handle
        # ref drops (mid-window death triggers swap-compaction)
        self._finalizer = weakref.finalize(self, engine.bank.free,
                                           self._slot)
        self.gpu_time = 0
        self.add_member(first)

    # -- bank-backed state --------------------------------------------------
    @property
    def state(self):
        """The job's {"params", "opt"} train-state, read from its bank
        slot as an independent copy (safe to hold across compaction)."""
        return self.engine.bank.read(self._slot.idx)

    @state.setter
    def state(self, tree):
        self.engine.bank.write(self._slot.idx, tree)

    @property
    def state_template(self):
        """Shape/structure template of the train-state (values
        unspecified; no device sync) — what checkpoint restore loads
        against."""
        return self.engine.bank.read_template(self._slot.idx)

    def release(self):
        """Return the bank slot (idempotent). Runs automatically when
        the handle is garbage-collected."""
        self._finalizer()

    def serving_snapshot(self):
        """Committed device copy of the job's CURRENT params, safe to
        hold across future bank writes/compaction — what the serve
        plane's validation gate scores and, on acceptance, installs as
        the group's serving row. Follows the residency discipline:
        compact FIRST (a queued-dead slot must not shift this row
        after the index is captured), then read the synced row."""
        bank = self.engine.bank
        bank.compact()
        return bank.snapshot_params(self._slot.idx)

    # -- grouping interface ---------------------------------------------------
    @property
    def num_members(self) -> int:
        return len(self.members)

    @property
    def _pool_src(self) -> List[Optional[str]]:
        """Per-row stream tags, oldest first (tests/inspection)."""
        return self.pool.sources()

    def add_member(self, req: Request):
        self.members.append(req)
        if req.train_data is not None:
            self.pool.add(req.train_data, req.stream_id)

    def remove_member(self, stream_id: str):
        self.members = [m for m in self.members if m.stream_id != stream_id]

    def purge_stream_data(self, stream_id: str):
        """Drop a stream's pooled training data. Used when a camera
        LEAVES the fleet (churn): the group must stop doing SGD on a
        distribution no live member has. Eviction/regrouping does NOT
        purge — an evicted member's data contributed while it was a
        member (seed semantics, pinned by the golden traces)."""
        self.pool.purge(stream_id)

    def eval_on(self, samples, precision: Optional[str] = None) -> float:
        """Accuracy on `samples`, scored at the job's own decision
        precision by default; pass precision="fp32" for the
        near-threshold rescore (Grouper.rescore_margin, serve gate)."""
        return self.engine._eval_slot(
            self._slot.idx, samples,
            precision=self.precision if precision is None else precision)

    # -- allocator interface ---------------------------------------------------
    def eval(self) -> float:
        """Accuracy averaged over member subsamples (A_j in Eq. 1)."""
        if not self.members:
            return 0.0
        return self.engine.eval_jobs([self])[0]

    def train_micro(self):
        """One micro-window: `micro_steps` SGD steps on pool batches."""
        self.engine.train_micro_many([self])

    # -- data plane -------------------------------------------------------------
    def ingest(self, tokens: np.ndarray, stream_id: Optional[str] = None):
        """New window data from a member's transmission. `stream_id`
        attributes each row so churn can purge a departed camera's
        data (purge_stream_data). The ring pool evicts the OLDEST rows
        once the row budget is exceeded."""
        self.pool.add(tokens, stream_id)
