"""Dense row registry: the fleet-plane churn discipline, once.

Every batched plane keys dense per-entity arrays by an id -> row map
with the same three rules: rows are handed out in insertion order,
capacity grows by amortized doubling (10k-camera setup must not
reallocate 10k times), and removal swap-compacts with the last live
row so arrays stay dense (capacity is retained; rows beyond len() are
garbage). `FleetDriftDetector` and `FleetTransmissionPlane` both build
on this registry instead of hand-rolling the discipline; the registry
tracks ids and capacity, the owner moves its own array rows on the
(dst, src) swap the registry reports.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple


class RowRegistry:
    """id -> dense row index. Owners size their arrays to `capacity`
    after `add`/`reserve` and apply the row move `remove` returns."""

    def __init__(self, capacity: int = 8):
        self._row: Dict[str, int] = {}
        self._ids: List[str] = []
        self.capacity = max(1, int(capacity))

    def __len__(self) -> int:
        return len(self._ids)

    def __contains__(self, rid: str) -> bool:
        return rid in self._row

    def __getitem__(self, rid: str) -> int:
        """Row of `rid`; KeyError when absent."""
        return self._row[rid]

    def get(self, rid: str) -> Optional[int]:
        return self._row.get(rid)

    @property
    def ids(self) -> List[str]:
        """row -> id, in row order (a copy)."""
        return list(self._ids)

    def reserve(self, extra: int) -> int:
        """Grow capacity to hold `extra` more rows (amortized doubling);
        returns the new capacity for the owner to size arrays against."""
        need = len(self._ids) + int(extra)
        if need > self.capacity:
            self.capacity = max(need, 2 * self.capacity)
        return self.capacity

    def add(self, rid: str) -> Tuple[int, bool]:
        """(row, is_new). New ids append at the dense end; existing ids
        return their current row. Grows capacity as needed — the owner
        must re-check its array sizes against `capacity` afterwards."""
        row = self._row.get(rid)
        if row is not None:
            return row, False
        self.reserve(1)
        row = len(self._ids)
        self._row[rid] = row
        self._ids.append(rid)
        return row, True

    def remove(self, rid: str) -> Optional[Tuple[int, int]]:
        """Swap-with-last removal. Returns None when `rid` is absent;
        otherwise (dst, src): when dst != src the owner must copy array
        row src into dst (the vacated slot inherits the previous last
        row — never a stale departed entity's state)."""
        row = self._row.pop(rid, None)
        if row is None:
            return None
        last = len(self._ids) - 1
        if row != last:
            moved = self._ids[last]
            self._ids[row] = moved
            self._row[moved] = row
        self._ids.pop()
        return row, last
