"""Dense row registry: the fleet-plane churn discipline, once.

Every batched plane keys dense per-entity arrays by an id -> row map
with the same three rules: rows are handed out in insertion order,
capacity grows by amortized doubling (10k-camera setup must not
reallocate 10k times), and removal swap-compacts with the last live
row so arrays stay dense (capacity is retained; rows beyond len() are
garbage). `FleetDriftDetector` and `FleetTransmissionPlane` both build
on this registry instead of hand-rolling the discipline; the registry
tracks ids and capacity, the owner moves its own array rows on the
(dst, src) swap the registry reports.

Shard-awareness: when the owner's dense arrays live under a device
mesh (NamedSharding along the row axis), capacity must stay divisible
by the mesh size or every growth/churn event re-pads the global shape
and re-lays rows across devices. `align` pins capacity to a multiple
of the shard count, so the row axis always splits into equal
contiguous per-device blocks; `shard_spans` reports those blocks.
Churn then never reshards the world: adds land in the dense prefix,
swap-with-last moves copy one row between (possibly different) device
blocks, and capacity growth keeps the same block structure.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class RowRegistry:
    """id -> dense row index. Owners size their arrays to `capacity`
    after `add`/`reserve` and apply the row move `remove` returns."""

    def __init__(self, capacity: int = 8, *, align: int = 1):
        self._row: Dict[str, int] = {}
        self._ids: List[str] = []
        self.align = max(1, int(align))
        self.capacity = self._aligned(max(1, int(capacity)))
        #: bumped on every membership change (add/remove); owners use it
        #: to invalidate row-lookup caches cheaply.
        self.generation = 0

    def _aligned(self, n: int) -> int:
        a = self.align
        return ((int(n) + a - 1) // a) * a

    def __len__(self) -> int:
        return len(self._ids)

    def __contains__(self, rid: str) -> bool:
        return rid in self._row

    def __getitem__(self, rid: str) -> int:
        """Row of `rid`; KeyError when absent."""
        return self._row[rid]

    def get(self, rid: str) -> Optional[int]:
        return self._row.get(rid)

    @property
    def ids(self) -> List[str]:
        """row -> id, in row order (a copy)."""
        return list(self._ids)

    def rows_of(self, rids: Sequence[str]) -> Optional[List[int]]:
        """Rows for `rids` in one pass, or None when any id is absent
        (callers fall back to the add path). One dict lookup per id —
        the fleet window loop calls this with the full stream list."""
        row = self._row
        try:
            return [row[r] for r in rids]
        except KeyError:
            return None

    def is_row_order(self, rids: Sequence[str]) -> bool:
        """True when `rids` is exactly the full live id list in row
        order — the fleet window loop's shape. Owners use this to skip
        per-id dict lookups and fancy-indexed gathers (a contiguous
        [0, n) prefix slices instead): at 10k+ rows the lookup+gather
        path is cache-miss-bound and costs more than the math it
        feeds. The check itself is one list compare — identical string
        objects short-circuit to pointer equality."""
        ids = self._ids
        if len(rids) != len(ids):
            return False
        return rids is ids or list(rids) == ids

    def set_align(self, align: int) -> int:
        """Pin capacity to a multiple of `align` (the mesh device
        count). Returns the (possibly grown) capacity for the owner to
        size its arrays against."""
        self.align = max(1, int(align))
        self.capacity = self._aligned(self.capacity)
        return self.capacity

    def shard_spans(self, n_shards: Optional[int] = None
                    ) -> List[Tuple[int, int]]:
        """Half-open [lo, hi) row spans: the contiguous per-device
        blocks a NamedSharding along the row axis produces. Requires
        capacity % n_shards == 0 (use `align`). Live rows occupy the
        dense prefix, so block i holds live rows
        [lo, min(hi, len(self)))."""
        n = self.align if n_shards is None else int(n_shards)
        if n < 1 or self.capacity % n:
            raise ValueError(
                f"capacity {self.capacity} not divisible by {n} shards "
                f"(set align first)")
        blk = self.capacity // n
        return [(i * blk, (i + 1) * blk) for i in range(n)]

    def shard_counts(self, n_shards: Optional[int] = None) -> List[int]:
        """Live rows per shard block (load balance diagnostics)."""
        live = len(self._ids)
        return [max(0, min(hi, live) - lo)
                for lo, hi in self.shard_spans(n_shards)]

    def reserve(self, extra: int) -> int:
        """Grow capacity to hold `extra` more rows (amortized doubling,
        rounded up to the shard alignment); returns the new capacity for
        the owner to size arrays against."""
        need = len(self._ids) + int(extra)
        if need > self.capacity:
            self.capacity = self._aligned(max(need, 2 * self.capacity))
        return self.capacity

    def add(self, rid: str) -> Tuple[int, bool]:
        """(row, is_new). New ids append at the dense end; existing ids
        return their current row. Grows capacity as needed — the owner
        must re-check its array sizes against `capacity` afterwards."""
        row = self._row.get(rid)
        if row is not None:
            return row, False
        self.reserve(1)
        row = len(self._ids)
        self._row[rid] = row
        self._ids.append(rid)
        self.generation += 1
        return row, True

    def remove(self, rid: str) -> Optional[Tuple[int, int]]:
        """Swap-with-last removal. Returns None when `rid` is absent;
        otherwise (dst, src): when dst != src the owner must copy array
        row src into dst (the vacated slot inherits the previous last
        row — never a stale departed entity's state)."""
        row = self._row.pop(rid, None)
        if row is None:
            return None
        last = len(self._ids) - 1
        if row != last:
            moved = self._ids[last]
            self._ids[row] = moved
            self._row[moved] = row
        self._ids.pop()
        self.generation += 1
        return row, last
