"""ECCO's contribution: group retraining for continuous learning.

allocator.py — Alg. 1 micro-window GPU allocation (objective-gain greedy
    with the size-tempered average + max-min fairness bonus).
grouping.py — Alg. 2 dynamic grouping (metadata prefilter + accuracy
    check; periodic eviction with EMA-smoothed reference).
signature_index.py — dense fleet arrays answering "which jobs pass the
    prefilter and are drift-signature-similar" in one vectorized call
    (batched pairwise-JS kernel) so grouping scales to 10k streams.
gaimd.py — fluid-model GAIMD congestion control (rate ∝ α/(1−β)).
transmission.py — sampling-config tables + GPU-proportional bandwidth.
drift.py — JS-divergence drift detection over token histograms.
trainer.py — group retraining jobs over one shared compiled engine.
controller.py — the end-to-end window loop (Fig. 3/4).
baselines.py — Naive / Ekya / RECL on the same substrate.
"""
