"""ECCO GPU (accelerator) allocation for group retraining — Algorithm 1.

The allocator time-shares the accelerator across retraining jobs in
micro-windows. Each micro-window is greedily granted to the job with the
highest *objective gain* under the paper's objective (Eq. 1):

    max  alpha * sum_j n_j^beta A_j(g_j) / sum_j n_j^beta  +  min_j A_j(g_j)

The fairness term gives the lowest-accuracy job a bonus equal to its raw
accuracy gain, preventing starvation of small groups (paper §3.1).

Jobs are duck-typed: they expose
    .num_members          -> int (n_j)
    .eval()               -> float accuracy in [0, 1]
    .train_micro()        -> None (train for one micro-window)

`RECLAllocator` reproduces the baseline allocator ECCO compares against
(objective = total accuracy improvement, i.e. size-weighted, no fairness
term) — used by benchmarks/bench_allocator.py (paper Fig. 10).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.batching import engine_groups, shared_engine


@dataclasses.dataclass
class AllocationTrace:
    """Per-micro-window record of who ran and the measured accuracies."""
    order: List[str]                      # job id per micro-window
    acc: Dict[str, List[float]]           # accuracy trajectory per job
    shares: Dict[str, float]              # estimated GPU share p_j
    gpu_time: Dict[str, int]              # micro-windows consumed per job
    # explicit window annotations (e.g. the eval-only degrade of a
    # window whose budget is smaller than one micro-step) — empty on
    # the seed path, so golden traces never see it
    notes: List[str] = dataclasses.field(default_factory=list)
    # WindowBudget.report() of the window's meter (roofline-metered
    # windows only; None on the seed unitless path)
    budget: Optional[Dict] = None


class ECCOAllocator:
    def __init__(self, alpha: float = 1.0, beta: float = 0.5):
        self.alpha = alpha
        self.beta = beta
        # final objective gains of the last completed window (Alg. 1
        # Line 15) — what estimate_shares serves between windows
        self.last_gains: Dict[str, float] = {}

    # -- objective gain (Alg. 1, CalObjectiveGain) --------------------------
    def _objective_gains(self, jobs, acc, acc_gain):
        nbeta = {j.job_id: j.num_members ** self.beta for j in jobs}
        denom = sum(nbeta.values()) or 1.0
        # jobs that never got a micro-window (budget < |J|) have no
        # measured gain yet; treat as 0 rather than KeyError
        gains = {j.job_id: self.alpha * nbeta[j.job_id] / denom
                 * acc_gain.get(j.job_id, 0.0) for j in jobs}
        if acc:
            worst = min(acc, key=acc.get)
            gains[worst] = gains.get(worst, 0.0) + acc_gain.get(worst, 0.0)
        return gains

    def _shares_from_gains(self, jobs, gains) -> Dict[str, float]:
        pos = {j.job_id: max(gains.get(j.job_id, 0.0), 0.0) for j in jobs}
        tot = sum(pos.values())
        if tot <= 0:
            return {j.job_id: 1.0 / len(jobs) for j in jobs}
        return {k: v / tot for k, v in pos.items()}

    # -- Alg. 1 main loop ----------------------------------------------------
    def run_window(self, jobs: Sequence, window_micro: int, *,
                   stragglers=None, deadline: Optional[float] = None,
                   clock: Optional[Callable[[], float]] = None,
                   barrier: Optional[Callable[[], None]] = None,
                   meter=None) -> AllocationTrace:
        """Run one retraining window of `window_micro` micro-windows.

        `meter`: optional launch.roofline.RooflineMeter. When set, each
        micro-window is converted into metered roofline cost (the job's
        own model config, batch, and precision policy price it) and
        charged against the meter's fleet-wide WindowBudget; the greedy
        pick maximizes objective gain PER METERED COST, so a
        budget-pressured fleet prefers jobs whose backbone/precision is
        cheaper instead of starving. `window_micro` stays an upper
        bound on micro-window count. A window whose remaining budget
        cannot afford one micro-step for ANY job (or window_micro <= 0)
        degrades to an eval-only window with an explicit trace note
        instead of silently doing nothing. None = the seed unitless
        path, byte-identical (golden traces).

        `stragglers`: optional distributed.stragglers.StragglerPolicy.
        When set, every micro-window is wall-clock timed per job and a
        flagged straggler's next micro-window runs under a shrunken
        step quota (quota re-normalization) — the allocator then
        measures a smaller AccGain for it and de-prioritizes it, the
        paper's own feedback loop doing double duty. Timing needs
        per-job launches, so the batched initial pass is traded for
        the (bit-identical) scalar loop while a policy is attached.

        `deadline`: optional wall-clock budget (seconds) for this
        window, measured by `clock` (default time.monotonic; tests
        inject a fake). Once exceeded, no further greedy micro-windows
        are granted — leftover budget is dropped so a straggling fleet
        can't stretch the window (straggler-aware window deadline).

        `barrier`: optional callable invoked before every micro-window
        (FleetElastic.barrier) — the elastic runtime's health-check
        point; it raises DeviceFailure to abort the window.

        All four default to None/off, leaving the window byte-identical
        to the seed path (golden traces).
        """
        jobs = list(jobs)
        if not jobs:          # update_grouping may have dropped every job
            return AllocationTrace(order=[], acc={}, shares={}, gpu_time={})
        clock = clock if clock is not None else time.monotonic
        t0 = clock()
        budget = window_micro
        acc: Dict[str, float] = {}
        acc_gain: Dict[str, float] = {}
        order: List[str] = []
        traj: Dict[str, List[float]] = {j.job_id: [] for j in jobs}
        used: Dict[str, int] = {j.job_id: 0 for j in jobs}
        notes: List[str] = []
        # per-window metered price of one micro-window per job (the
        # meter caches compiled costs, so this is dict math)
        micro_cost: Optional[Dict[str, float]] = None
        if meter is not None:
            micro_cost = {j.job_id: max(meter.micro_cost(j), 1e-12)
                          for j in jobs}

        def record(j, a_i, a_f):
            # the ONE bookkeeping path for a measured micro-window —
            # batched and scalar passes must stay field-for-field
            # identical (bit-identity contract, golden-trace pinned)
            nonlocal budget
            budget -= 1
            if meter is not None:
                meter.charge(meter.train_cost(j), "train")
                meter.charge(2 * meter.eval_cost(j), "eval")
            acc[j.job_id] = a_f
            acc_gain[j.job_id] = a_f - a_i
            order.append(j.job_id)
            traj[j.job_id].append(a_f)
            used[j.job_id] += 1

        def eval_only(reason: str) -> AllocationTrace:
            # the degraded window: no training, but the fleet is still
            # MEASURED once (the controller's shares/metrics consumers
            # need accuracies), and the trace says why out loud.
            # last_gains is left untouched so estimate_shares keeps
            # serving the last real window's signal.
            notes.append(reason)
            vals: List[float] = [0.0] * len(jobs)
            # per-engine batched dispatch: a zoo fleet (mixed engines)
            # still evals each model class in one fleet call
            for grp_eng, idxs in engine_groups(jobs):
                if grp_eng is None:
                    for i in idxs:
                        vals[i] = jobs[i].eval()
                else:
                    sub = grp_eng.eval_jobs([jobs[i] for i in idxs])
                    for i, a in zip(idxs, sub):
                        vals[i] = a
            for j, a in zip(jobs, vals):
                acc[j.job_id] = float(a)
                traj[j.job_id].append(float(a))
                if meter is not None:
                    meter.charge(meter.eval_cost(j), "eval")
            return AllocationTrace(
                order=order, acc=traj,
                shares=self._shares_from_gains(jobs, {}), gpu_time=used,
                notes=notes,
                budget=meter.report() if meter is not None else None)

        if window_micro <= 0:
            return eval_only(
                f"window_micro={window_micro} < 1 micro-window: degraded "
                f"to eval-only window")
        if meter is not None and \
                not any(meter.can_afford(micro_cost[j.job_id])
                        for j in jobs):
            return eval_only(
                f"roofline budget (remaining "
                f"{meter.budget.remaining:.3e}s) smaller than one "
                f"micro-step for every job: degraded to eval-only window")

        def micro_retraining(j):
            if barrier is not None:
                barrier()
            if stragglers is None:
                a_i = j.eval()
                j.train_micro()
                record(j, a_i, j.eval())
                return
            base = j.micro_steps
            ts = clock()
            try:
                # quota re-normalization: a straggler trains fewer
                # steps this micro-window so its wall time re-joins
                # the fleet median
                j.micro_steps = stragglers.quota(j.job_id, base)
                a_i = j.eval()
                j.train_micro()
                record(j, a_i, j.eval())
            finally:
                j.micro_steps = base
            stragglers.record(j.job_id, clock() - ts)

        # initial training pass — with a batch-capable engine the whole
        # fleet's measurement collapses to three fleet calls (eval all,
        # one micro-window for all, eval all) instead of 4|J| member
        # launches. Bit-identical to the per-job micro_retraining loop:
        # jobs are independent (own state, own rng, own pool), so
        # reordering eval/train across jobs changes nothing per job.
        # Each entry point compacts the bank and flushes host-dirty
        # state rows to the device-resident stack before capturing slot
        # indices (the residency contract in repro.core.batching), so
        # the measurement pass itself moves no state across the host
        # boundary.
        if meter is None:
            head = jobs[:min(budget, len(jobs))]
        else:
            # metered initial pass: grant first micro-windows in fleet
            # order while the window budget can afford them; jobs left
            # out simply have no measured gain yet (0.0 in the
            # objective), exactly like budget < |J| on the seed path
            head, rem = [], meter.budget.remaining
            for j in jobs:
                if len(head) >= budget:
                    break
                c = micro_cost[j.job_id]
                if rem - c < -1e-12 * max(1.0, meter.budget.total):
                    continue
                head.append(j)
                rem -= c
        eng = shared_engine(head) if (head and stragglers is None) \
            else None
        if eng is not None:
            if barrier is not None:
                barrier()
            a_i = eng.eval_jobs(head)
            eng.train_micro_many(head)
            a_f = eng.eval_jobs(head)
            for j, ai, af in zip(head, a_i, a_f):
                record(j, ai, af)
        else:
            for j in head:
                micro_retraining(j)
        gains = self._objective_gains(jobs, acc, acc_gain)

        by_id = {j.job_id: j for j in jobs}
        while budget > 0:
            if deadline is not None and clock() - t0 >= deadline:
                break     # window deadline: drop the leftover budget
            if meter is None:
                jid = max(gains, key=gains.get)
            else:
                # Alg. 1 objective with metered cost in the
                # denominator: accuracy gain per modeled device-second,
                # restricted to jobs the remaining budget can afford —
                # a cheaper backbone/precision wins ties against an
                # equally-improving expensive one
                afford = [k for k in gains
                          if meter.can_afford(micro_cost[k])]
                if not afford:
                    notes.append(
                        "roofline budget exhausted: "
                        f"{budget} micro-window(s) dropped")
                    break
                jid = max(afford, key=lambda k: gains[k] / micro_cost[k])
            micro_retraining(by_id[jid])
            gains = self._objective_gains(jobs, acc, acc_gain)

        # GPU-share estimate for the transmission controller (§3.2):
        # Alg. 1 Line 15 derives p_j from the *final* gains of the
        # window, not the post-initial-pass snapshot
        self.last_gains = dict(gains)
        shares = self._shares_from_gains(jobs, gains)
        return AllocationTrace(order=order, acc=traj, shares=shares,
                               gpu_time=used, notes=notes,
                               budget=meter.report() if meter is not None
                               else None)

    def estimate_shares(self, jobs, gains=None) -> Dict[str, float]:
        """p_j from the latest objective gains (Line 15 of Alg. 1)."""
        if gains is None:
            known = {j.job_id: self.last_gains[j.job_id] for j in jobs
                     if j.job_id in self.last_gains}
            pos_known = [v for v in known.values() if v > 0]
            if pos_known:
                # jobs created since the last window have no measured
                # gain; seed them at the mean positive gain so new
                # groups are not starved of bandwidth before their
                # first micro-window
                fill = sum(pos_known) / len(pos_known)
                gains = {j.job_id: known.get(j.job_id, fill)
                         for j in jobs}
            else:
                # no job measured a positive gain last window (converged
                # or noisy fleet): there is no signal to apportion, so
                # every job — old or new — falls through to the uniform
                # branch of _shares_from_gains
                gains = {j.job_id: 0.0 for j in jobs}
        if not jobs:
            return {}
        return self._shares_from_gains(jobs, gains)


class RECLAllocator(ECCOAllocator):
    """Baseline allocator (RECL/Ekya-style): maximize total accuracy
    improvement; groups weighted by member count, no fairness term."""

    def _objective_gains(self, jobs, acc, acc_gain):
        return {j.job_id: j.num_members * acc_gain.get(j.job_id, 0.0)
                for j in jobs}


class UniformAllocator(ECCOAllocator):
    """Naive baseline: round-robin micro-windows, no measurement-driven
    choices."""

    def run_window(self, jobs: Sequence, window_micro: int, *,
                   barrier=None, **_ignored) -> AllocationTrace:
        jobs = list(jobs)
        if not jobs:
            return AllocationTrace(order=[], acc={}, shares={}, gpu_time={})
        order, traj, used = [], {j.job_id: [] for j in jobs}, \
            {j.job_id: 0 for j in jobs}
        acc = {}
        # round-robin, one full round per batched (train all, eval all)
        # pair of fleet calls; per-job numbers are identical to the
        # seed's interleaved train/eval loop because jobs are
        # independent
        eng = shared_engine(jobs)
        done = 0
        while done < window_micro:
            if barrier is not None:
                barrier()
            rnd = jobs[:min(len(jobs), window_micro - done)]
            if eng is not None:
                eng.train_micro_many(rnd)
                accs = eng.eval_jobs(rnd)
            else:
                accs = []
                for j in rnd:
                    j.train_micro()
                    accs.append(j.eval())
            for j, a in zip(rnd, accs):
                acc[j.job_id] = a
                order.append(j.job_id)
                traj[j.job_id].append(a)
                used[j.job_id] += 1
            done += len(rnd)
        shares = {j.job_id: 1.0 / len(jobs) for j in jobs}
        return AllocationTrace(order=order, acc=traj, shares=shares,
                               gpu_time=used)
