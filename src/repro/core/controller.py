"""ECCO end-to-end controller: drift detection -> dynamic grouping ->
GPU allocation -> transmission control -> group retraining, window by
window (Fig. 3 / Fig. 4 of the paper).
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import trainer as _trainer
from repro.core.allocator import ECCOAllocator, AllocationTrace
from repro.core.batching import engine_groups, shared_engine
from repro.core.drift import FleetDriftDetector, batch_token_histogram
from repro.core.grouping import Grouper, Request
from repro.core.signature_index import SignatureIndex
from repro.core.trainer import RetrainJob, SharedEngine
from repro.core.transmission import (FleetTransmissionPlane, ProfileTable,
                                     SamplingConfig)
from repro.data.streams import Stream
from repro.distributed.elastic import DeviceFailure
from repro.serve.plane import FleetServePlane, ServeConfig


@dataclasses.dataclass
class ControllerConfig:
    window_micro: int = 8            # W micro-windows per retraining window
    window_seconds: float = 10.0
    seq_len: int = 32
    sample_rate: int = 8             # sequences per stream per window (f)
    eval_batch: int = 16
    eps_t: float = 60.0
    delta_loc: float = 100.0
    p_drop: float = 0.15
    drift_threshold: float = 0.25
    shared_bandwidth: float = 64.0   # tokens/sec equivalents
    local_caps: Optional[Dict[str, float]] = None
    bytes_per_token: float = 1.0
    micro_steps: int = 4
    train_batch: int = 8
    sig_buckets: int = 64            # drift-signature histogram buckets
    shortlist_k: int = 0             # grouping eval_on cap (0 = no cap)
    drift_impl: str = "exact"        # FleetDriftDetector scoring backend
    # §3.2 profiled sampling-config table. None = a single fixed
    # (sample_rate, seq_len) configuration (the seed's behavior; the
    # table's configs must use resolution == seq_len because the ring
    # pool holds fixed-width rows). Populated tables come from the
    # Fig. 5 profiling procedure in benchmarks/bench_transmission.py or
    # a scenario's `profile` spec.
    profile_table: Optional[ProfileTable] = None
    # straggler-aware wall-clock budget (seconds) for one retraining
    # window's allocator loop: once exceeded, leftover micro-windows
    # are dropped (distributed.stragglers). None = no deadline (seed
    # semantics — golden traces depend on every micro-window running).
    window_deadline: Optional[float] = None
    # live serving plane (docs/serving_plane.md). None = off (the
    # default; golden traces never see it). When set, run_window step 6
    # publishes each group's freshly retrained params through the
    # EdgeSync-style validation gate and serves every grouped stream's
    # queries from the committed serving snapshots. Serving is
    # READ-ONLY w.r.t. the decision planes: it reuses the window's
    # already-drawn data (queries from window_data, the gate's held-out
    # set from the metrics eval draws), so enabling it changes no
    # retraining/grouping/transmission decision and consumes no rng.
    serve: Optional[ServeConfig] = None
    # -- roofline-budgeted co-scheduling (docs/scheduling.md) ----------
    # Fleet-wide modeled device-seconds per window, covering the train
    # pass, the allocator/grouper/metrics eval passes, and (when
    # `serve` is on) serve-plane ticks — DaCapo-style co-scheduling on
    # ONE compute budget. The grouping/metrics/serve shares are
    # RESERVED up front each window, so retraining competes only for
    # the remainder; the allocator then maximizes gain per metered
    # cost. None = the seed unitless path (golden traces).
    roofline_budget: Optional[float] = None
    # launch.roofline.CostTable to price windows with; None builds one
    # lazily on first metered window (shared across windows — the
    # cache is the point)
    cost_table: Optional[object] = None
    # decision-plane screen precision for NEW jobs ("fp32" | "bf16").
    # bf16 jobs eval against the bank's cast-at-flush compute stack;
    # near-threshold grouping decisions rescore in fp32 when
    # `rescore_margin` > 0, and the serve gate always validates fp32.
    job_precision: str = "fp32"
    rescore_margin: float = 0.0


@dataclasses.dataclass
class WindowMetrics:
    t: float
    per_stream_acc: Dict[str, float]
    groups: Dict[str, List[str]]
    shares: Dict[str, float]
    bandwidth: Dict[str, float]
    # tokens each grouped member actually ingested after §3.2
    # compression — always <= bandwidth * window_seconds / bytes_per_token
    delivered: Dict[str, int] = dataclasses.field(default_factory=dict)
    # serving-plane window report (FleetServePlane.window_report):
    # qps / tick latency / swap-gate counters / per-group staleness.
    # None whenever ControllerConfig.serve is off.
    serve: Optional[Dict] = None
    # roofline ledger for the window (WindowBudget.report plus the
    # allocator's degrade/drop notes); None when metering is off
    roofline: Optional[Dict] = None


class ECCOController:
    # GAIMD parameterization for step 2: "ecco" = alpha p_j/n_j
    # (GPU-share proportional); "equal" = plain AIMD equal competition
    # (the no-coordination baselines override this)
    bandwidth_mode = "ecco"

    def __init__(self, engine: SharedEngine, streams: Sequence[Stream],
                 cc: Optional[ControllerConfig] = None, *, seed: int = 0,
                 mesh=None, elastic=None, stragglers=None, zoo=None):
        """`mesh`: optional 1-D fleet device mesh (launch.mesh.
        make_fleet_mesh) — every decision plane shards its row axis
        over it (JobBank slots, drift rows, signature columns), with
        decisions bit-identical to single-device. `elastic`: optional
        distributed.elastic.FleetElastic — run_window then checkpoints
        at window start and survives mid-window device loss by
        re-meshing and re-running the window. `stragglers`: optional
        distributed.stragglers.StragglerPolicy, wired into the
        allocator's micro-window loop together with
        cc.window_deadline. `zoo`: optional sequence of additional
        SharedEngines (smaller model classes from configs' zoo) a
        metered controller may place NEW jobs on — under budget
        pressure `_new_job` picks the largest tier whose micro-window
        cost fits the job's fair share of the window budget
        (docs/scheduling.md). Requires cc.roofline_budget; ignored
        otherwise (seed fleets stay homogeneous)."""
        self.engine = engine
        self.streams = list(streams)
        self.cc = cc or ControllerConfig()
        self.elastic = elastic
        self.stragglers = stragglers
        if mesh is None and elastic is not None:
            mesh = elastic.mesh
        self.mesh = mesh
        if elastic is not None:
            elastic.mesh = mesh
        self.allocator = ECCOAllocator()
        self.sig_index = SignatureIndex(buckets=self.cc.sig_buckets,
                                        capacity=max(64, 2 * len(streams)),
                                        mesh=mesh)
        self.grouper = Grouper(eps_t=self.cc.eps_t,
                               delta_loc=self.cc.delta_loc,
                               p_drop=self.cc.p_drop,
                               new_job_fn=self._new_job,
                               index=self.sig_index,
                               shortlist_k=self.cc.shortlist_k,
                               rescore_margin=self.cc.rescore_margin)
        # model-class tiers for metered job placement: the primary
        # engine plus any zoo engines, priced lazily per window
        self.zoo: List[SharedEngine] = list(zoo or [])
        self._cost_table = self.cc.cost_table
        self.jobs: List[RetrainJob] = []
        table = self.cc.profile_table
        if table is None:
            # fixed sampling configuration: the window's full sample at
            # the stream's native resolution (seed semantics)
            table = ProfileTable([SamplingConfig(self.cc.sample_rate,
                                                 self.cc.seq_len)])
        else:
            # the ring pool stores fixed-width (seq_len,) rows, so a
            # config at any other resolution would be rejected at
            # ingest mid-run — fail at construction instead
            bad = [c for c in getattr(table, "configs", [])
                   if c.resolution != self.cc.seq_len]
            if bad:
                raise ValueError(
                    f"profile_table configs must use resolution == "
                    f"seq_len={self.cc.seq_len} (the token ring pool "
                    f"holds fixed-width rows); offending: {bad}")
        self.tx_plane = FleetTransmissionPlane(
            table, bytes_per_token=self.cc.bytes_per_token, mesh=mesh)
        self.fleet = FleetDriftDetector(
            threshold=self.cc.drift_threshold, buckets=self.cc.sig_buckets,
            vocab=engine.cfg.vocab_size, impl=self.cc.drift_impl,
            mesh=mesh)
        bank = getattr(engine, "bank", None)
        if mesh is not None and hasattr(bank, "place_on"):
            bank.place_on(mesh)   # job axis block-sharded over the mesh
        self.serve_plane = (FleetServePlane(engine, self.cc.serve)
                            if self.cc.serve is not None else None)
        for s in self.streams:
            self.fleet.add_stream(s.stream_id)
        self.rng = np.random.default_rng(seed)
        self.t = 0.0
        self.history: List[WindowMetrics] = []
        self.request_time: Dict[str, float] = {}
        self._seed = seed

    # ------------------------------------------------------------------
    def _new_job(self, req: Request) -> RetrainJob:
        return RetrainJob(self._pick_engine(), req,
                          micro_steps=self.cc.micro_steps,
                          batch=self.cc.train_batch, seed=self._seed,
                          precision=self.cc.job_precision)

    # -- roofline co-scheduling (docs/scheduling.md) --------------------
    def _table(self):
        """The shared CostTable, built lazily on the first metered
        window (compiled-cost caching across windows is the point)."""
        if self._cost_table is None:
            from repro.launch.roofline import CostTable
            self._cost_table = CostTable()
        return self._cost_table

    def _micro_seconds(self, cfg, precision: str) -> float:
        """Modeled seconds of one allocator micro-window (train pass +
        the two bracketing evals) for a job on `cfg` at the controller
        batch settings."""
        cc = self.cc
        tbl = self._table()
        return (cc.micro_steps * tbl.seconds(
                    cfg, batch=cc.train_batch, seq=cc.seq_len,
                    kind="train", precision=precision)
                + 2 * tbl.seconds(
                    cfg, batch=cc.eval_batch, seq=cc.seq_len,
                    kind="eval", precision=precision))

    def _pick_engine(self) -> SharedEngine:
        """Model class for a NEW job: without metering (or a zoo) the
        primary engine — seed semantics. Under a roofline budget, the
        costliest tier whose one micro-window fits the job's fair share
        of the window budget, `budget / (window_micro * (jobs + 1))`;
        a fleet under budget pressure retrains a smaller backbone
        rather than starve (Alg. 1 gain/cost discipline, DaCapo's
        accuracy-per-FLOP slicing)."""
        cc = self.cc
        if not self.zoo or cc.roofline_budget is None:
            return self.engine
        prec = cc.job_precision
        tiers = sorted(
            [self.engine] + self.zoo,
            key=lambda e: self._micro_seconds(e.cfg, prec), reverse=True)
        fair = cc.roofline_budget / max(1, cc.window_micro) \
            / (len(self.jobs) + 1)
        for e in tiers:
            if self._micro_seconds(e.cfg, prec) <= fair:
                return e
        return tiers[-1]          # nothing fits: cheapest tier

    def _window_meter(self):
        """Fresh RooflineMeter for this window, or None (seed path)."""
        if self.cc.roofline_budget is None:
            return None
        from repro.launch.roofline import RooflineMeter
        return RooflineMeter(self._table(), self.cc.roofline_budget,
                             seq_len=self.cc.seq_len,
                             eval_batch=self.cc.eval_batch)

    def _reserve_overheads(self, meter):
        """Charge the window's NON-allocator compute up front so
        retraining competes only for the remainder: the Alg. 2
        update-grouping screens (one eval per member), the window
        metrics eval (one eval per grouped stream), and — when serving
        is on — each group's fp32 gate validation plus its streams'
        query prefill/decode ticks."""
        cc = self.cc
        for j in self.jobs:
            meter.charge(meter.eval_cost(j), "grouping")
            meter.charge(meter.eval_cost(j), "metrics")
        if self.serve_plane is None:
            return
        scfg = cc.serve
        tbl = self._table()
        for j in self.jobs:
            cfg = getattr(getattr(j, "engine", None), "cfg", None)
            if not isinstance(cfg, ModelConfig):
                continue
            # validation gate: candidate + incumbent, always fp32
            meter.charge(2 * tbl.seconds(
                cfg, batch=cc.eval_batch, seq=cc.seq_len, kind="eval",
                precision="fp32"), "serve")
            meter.charge(meter.serve_cost(
                cfg, queries=j.num_members * scfg.queries_per_stream,
                prompt_len=max(1, scfg.prompt_len),
                gen_tokens=scfg.max_new), "serve")

    def _jobs_by_stream(self) -> Dict[str, RetrainJob]:
        """One O(members) pass; callers iterating the whole fleet grab
        this once instead of a per-stream linear scan (O(streams *
        fleet) per window at 10k streams)."""
        return {mem.stream_id: j for j in self.jobs for mem in j.members}

    def _token_budgets(self, fshare: Sequence[float]) -> List[float]:
        """Per-flow token budget for §3.2 config selection: the group's
        share of the accelerator tokens one retraining window can
        consume (the paper's GPU-budget axis of the Fig. 5 table)."""
        cc = self.cc
        cap = cc.window_micro * cc.micro_steps * cc.train_batch * cc.seq_len
        return [s * cap for s in fshare]

    def warmup(self):
        """Set drift references from time-0 data."""
        if not self.streams:
            return
        toks = np.stack([s.sample(0.0, self.cc.sample_rate, self.cc.seq_len)
                         for s in self.streams])
        self.fleet.set_references([s.stream_id for s in self.streams], toks)

    # -- fleet membership (camera churn) -------------------------------
    def add_stream(self, stream: Stream, *, warm: bool = True):
        """A camera joins the fleet mid-run. Its drift reference is set
        from its first window of data (deployment-time snapshot).
        Joining an id that is already live is an error: re-adding
        would silently overwrite the stream's detector reference and
        leave duplicate fleet rows behind every per-stream plane."""
        if any(s.stream_id == stream.stream_id for s in self.streams):
            raise ValueError(
                f"stream {stream.stream_id!r} is already live; remove "
                f"it before re-joining")
        self.streams.append(stream)
        self.fleet.add_stream(stream.stream_id)
        if warm:
            toks = stream.sample(self.t, self.cc.sample_rate,
                                 self.cc.seq_len)
            self.fleet.set_reference(stream.stream_id, toks)

    def remove_stream(self, stream_id: str):
        """A camera leaves the fleet: drop its detector row, its job
        membership (empty jobs die), its grouping-index row, and its
        pending-request clock (response_times must not report latencies
        for cameras no longer in the fleet)."""
        self.streams = [s for s in self.streams
                        if s.stream_id != stream_id]
        self.fleet.remove_stream(stream_id)
        job = self._jobs_by_stream().get(stream_id)
        if job is not None:
            job.remove_member(stream_id)
            job.purge_stream_data(stream_id)
        self.jobs[:] = [j for j in self.jobs if j.members]
        self.sig_index.remove(stream_id)
        self.tx_plane.remove_flow(stream_id)
        self.request_time.pop(stream_id, None)

    # -- elastic window protocol ---------------------------------------
    def _barrier(self):
        """Stage-boundary health check; DeviceFailure propagates to the
        run_window retry loop. No-op without an elastic runtime."""
        if self.elastic is not None:
            self.elastic.barrier()

    def _snapshot(self) -> dict:
        """Host control-plane snapshot at a window boundary: everything
        a window mutates outside the JobBank device stack (which the
        elastic runtime checkpoints to disk). Strong refs to the job
        handles keep their bank slots alive through the rollback."""
        return {
            "t": self.t,
            "rng": copy.deepcopy(self.rng.bit_generator.state),
            "stream_rng": {s.stream_id:
                           copy.deepcopy(s.rng.bit_generator.state)
                           for s in self.streams},
            "jobs": list(self.jobs),
            "job_host": {j.job_id: {
                "members": [copy.copy(m) for m in j.members],
                "pool": copy.deepcopy(j.pool),
                "rng": copy.deepcopy(j.rng.bit_generator.state),
                "gpu_time": j.gpu_time,
            } for j in self.jobs},
            "job_counter": _trainer._job_counter.n,
            "history_len": len(self.history),
            "request_time": dict(self.request_time),
            "gains": dict(self.allocator.last_gains),
            "grouper_events": len(self.grouper.events),
            "fleet": self.fleet.state_dict(),
            "sig": self.sig_index.state_dict(),
            "tx": self.tx_plane.state_dict(),
        }

    def _restore(self, snap: dict, mesh):
        """Roll the host control plane back to `snap` and re-attach
        every plane to (possibly shrunken) `mesh`; job train-states
        come back from the elastic runtime's window-start checkpoint.
        Jobs created by the aborted attempt lose their last reference
        here — their bank slots free via the deferred-free rule and
        compact away at the next batched entry point."""
        self.mesh = mesh
        self.t = snap["t"]
        self.rng.bit_generator.state = copy.deepcopy(snap["rng"])
        for s in self.streams:
            s.rng.bit_generator.state = \
                copy.deepcopy(snap["stream_rng"][s.stream_id])
        self.jobs[:] = snap["jobs"]
        for j in self.jobs:
            jh = snap["job_host"][j.job_id]
            j.members = [copy.copy(m) for m in jh["members"]]
            j.pool = copy.deepcopy(jh["pool"])
            j.rng.bit_generator.state = copy.deepcopy(jh["rng"])
            j.gpu_time = jh["gpu_time"]
        _trainer._job_counter.n = snap["job_counter"]
        del self.history[snap["history_len"]:]
        self.request_time = dict(snap["request_time"])
        self.allocator.last_gains = dict(snap["gains"])
        del self.grouper.events[snap["grouper_events"]:]
        self.fleet.set_mesh(mesh)
        self.fleet.load_state_dict(snap["fleet"])
        self.sig_index.set_mesh(mesh)
        self.sig_index.load_state_dict(snap["sig"])
        self.tx_plane.set_mesh(mesh)
        self.tx_plane.load_state_dict(snap["tx"])
        bank = getattr(self.engine, "bank", None)
        if hasattr(bank, "invalidate_device"):
            bank.invalidate_device()   # device memory is gone
            bank.place_on(mesh)
        if self.elastic is not None:
            self.elastic.restore_jobs(self.jobs)

    def run_window(self) -> WindowMetrics:
        """One retraining window. With an elastic runtime attached the
        window is transactional: job states checkpoint to disk and the
        host control plane snapshots at the boundary, and a mid-window
        DeviceFailure (raised at a barrier) shrinks the fleet mesh to
        the survivors, rolls everything back, and re-runs the window —
        whose decisions are bit-identical to a run that never failed,
        because every plane's math is row-local under block sharding."""
        if self.elastic is None:
            return self._run_window_inner()
        self.elastic.on_window_start(self.jobs)
        snap = self._snapshot()
        while True:
            try:
                return self._run_window_inner()
            except DeviceFailure as e:
                mesh = self.elastic.recover(e.lost)
                self._restore(snap, mesh)

    # ------------------------------------------------------------------
    def _run_window_inner(self) -> WindowMetrics:
        cc = self.cc
        t = self.t
        meter = self._window_meter()   # None = seed unmetered path
        alloc_trace: Optional[AllocationTrace] = None

        # 1. live data + drift detection -> retraining requests.
        # Sampling stays per-stream (each stream owns its rng), but
        # scoring is ONE batched fleet call (FleetDriftDetector) instead
        # of a token_histogram + js_divergence Python loop per camera.
        window_data: Dict[str, np.ndarray] = {}
        assigned = self._jobs_by_stream()
        ids = [s.stream_id for s in self.streams]
        if self.streams:
            toks_all = np.stack([s.sample(t, cc.sample_rate, cc.seq_len)
                                 for s in self.streams])
            window_data = dict(zip(ids, toks_all))
            triggered = set(self.fleet.observe(ids, toks_all))
        else:
            triggered = set()
        for s in self.streams:
            if (assigned.get(s.stream_id) is None
                    and s.stream_id in triggered):
                sub = s.sample(t, cc.eval_batch, cc.seq_len)
                acc_now = 0.0
                req = Request(stream_id=s.stream_id, t=t, loc=s.loc,
                              subsamples=sub, acc=acc_now,
                              train_data=window_data[s.stream_id],
                              sig=self.fleet.hist(s.stream_id))
                self.request_time.setdefault(s.stream_id, t)
                self.grouper.group_request(self.jobs, req)
        self._barrier()

        # 2. GPU shares estimate -> transmission control (GAIMD). The
        # plane warm-starts every flow's GAIMD rate from the state it
        # persisted at the end of the previous window (cold only on a
        # flow's first grouped window) and short-circuits the fluid
        # simulation once the steady cycle is reached.
        shares: Dict[str, float] = {}
        bw: Dict[str, float] = {}
        delivered: Dict[str, int] = {}
        if self.jobs:
            p = self.allocator.estimate_shares(self.jobs)
            members = [m for j in self.jobs for m in j.members]
            jobs_of = [j for j in self.jobs for _ in j.members]
            flows = [m.stream_id for m in members]
            fshare = [p[j.job_id] for j in jobs_of]
            fn = [j.num_members for j in jobs_of]
            caps = [(cc.local_caps or {}).get(sid, np.inf)
                    for sid in flows]
            rates = self.tx_plane.allocate(flows, fshare, fn, caps,
                                           cc.shared_bandwidth,
                                           mode=self.bandwidth_mode)
            bw = dict(zip(flows, map(float, rates)))
            shares = p
            # 3. §3.2 camera-side decisions for the whole fleet in ONE
            # batched call: sampling config from the profiled table at
            # the group's budget level, f*/n_j scaling, and compression
            # (sequence subsampling + resolution truncation) to the
            # achieved bandwidth. A zero-bandwidth camera delivers
            # NOTHING (the seed's max(1, ...) forced >= 1 sequence).
            batch = self.tx_plane.decide_many(
                budget_levels=self.tx_plane.levels_for_shares(fshare),
                token_budgets=self._token_budgets(fshare),
                p_shares=fshare, n_members=fn, achieved_bw=rates,
                window_seconds=cc.window_seconds)
            for i, (j, m) in enumerate(zip(jobs_of, members)):
                toks = window_data.get(m.stream_id)
                if toks is None:
                    continue
                res = int(batch.resolution[i])
                # sequence subsampling: whole sequences within the
                # delivered-token allowance, bounded by what the stream
                # sampled this window (configs are seq_len-wide, see
                # __init__, so no column truncation happens here)
                n_seq = int(batch.delivered[i]) // res if res else 0
                if (n_seq == 0 and res and batch.delivered[i] > 0
                        and int(batch.deliverable[i]) >= res):
                    # a group larger than the config rate gives each
                    # member a fractional f*/n_j share; quantize UP to
                    # one whole sequence when the achieved bandwidth
                    # can carry it (a zero-bandwidth flow still
                    # delivers nothing: deliverable < res)
                    n_seq = 1
                sl = toks[:n_seq]
                delivered[m.stream_id] = int(sl.shape[0]) * res
                if sl.shape[0] == 0:
                    continue
                j.ingest(sl, m.stream_id)

            # 4. allocator runs the retraining window (Alg. 1), under
            # the elastic barrier (one health check per micro-window),
            # the straggler quota policy, and the window deadline —
            # all no-ops when unset (seed semantics). With a roofline
            # budget the window's eval/serve co-tenants are charged
            # FIRST (DaCapo-style reservation) and the allocator
            # maximizes gain per metered cost over the remainder.
            if meter is not None:
                self._reserve_overheads(meter)
            alloc_trace = self.allocator.run_window(
                self.jobs, cc.window_micro,
                stragglers=self.stragglers,
                deadline=cc.window_deadline,
                barrier=(self.elastic.barrier if self.elastic is not None
                         else None),
                meter=meter)

            # 5. periodic regrouping (Alg. 2 UpdateGrouping) — evaluated
            # on each member's RECENT window data (the paper's
            # subsamples come from live transmissions), so a member that
            # diverged this window is judged on its new distribution.
            # Drift signatures are refreshed too — on the Request (an
            # evicted member re-enters group_request ranked by the
            # distribution it diverged TO) and in the index (so the
            # top-k shortlist scores a job's members by their current
            # data, not the histograms they joined with)
            members = [m for j in self.jobs for m in j.members
                       if window_data.get(m.stream_id) is not None]
            if members:
                sigs = batch_token_histogram(
                    np.stack([window_data[m.stream_id] for m in members]),
                    self.fleet.buckets, self.fleet.vocab)
                for m, sig in zip(members, sigs):
                    m.subsamples = window_data[m.stream_id]
                    m.sig = sig
                    self.sig_index.refresh_sig(m.stream_id, m.sig)
            self.grouper.update_grouping(self.jobs, t)
        self._barrier()

        # metrics: eval samples stay per-stream draws (each stream owns
        # its rng, drawn in fleet order), scoring is ONE batched fleet
        # call instead of a device launch per stream; the call reads
        # the device-resident param rows directly (zero per-member
        # state transfer — the bank syncs any host-dirty rows at entry)
        acc = {}
        by_stream = self._jobs_by_stream()
        evs = {}
        for s in self.streams:
            evs[s.stream_id] = s.sample(t + 0.5, cc.eval_batch, cc.seq_len)
        grouped = [s.stream_id for s in self.streams
                   if by_stream.get(s.stream_id) is not None]
        gjobs = [by_stream[sid] for sid in grouped]
        # per-engine batched dispatch (engine_groups): a homogeneous
        # fleet is one group in fleet order — the seed's single
        # eval_pairs call — while a zoo fleet gets one batched call per
        # model class plus a scalar fallback for probe-rejected jobs
        vals: List[float] = [0.0] * len(gjobs)
        for grp_eng, idxs in engine_groups(gjobs):
            if grp_eng is None:
                # fleetlint: disable=per-member-loop -- documented
                # scalar fallback for probe-rejected jobs; bit-identical
                # to the batched dispatch (tests/test_trainer_bank.py)
                for i in idxs:
                    vals[i] = gjobs[i].eval_on(evs[grouped[i]])
            else:
                sub = grp_eng.eval_pairs(
                    [(gjobs[i], evs[grouped[i]]) for i in idxs])
                for i, a in zip(idxs, sub):
                    vals[i] = a
        got = dict(zip(grouped, vals))
        for s in self.streams:
            acc[s.stream_id] = got.get(s.stream_id, float("nan"))

        # 6. live serving plane (off by default): validated hot swap of
        # each group's serving snapshot, then answer this window's
        # stream queries from the committed snapshots while the
        # retraining above already ran in the same window loop. Uses
        # only data drawn above (window_data prompts, evs gate sets) —
        # zero rng consumption, decisions untouched.
        serve_report = None
        if self.serve_plane is not None:
            serve_report = self._serve_window(window_data, evs)

        groups = {j.job_id: [m.stream_id for m in j.members]
                  for j in self.jobs}
        roofline = None
        if meter is not None:
            roofline = meter.report()
            roofline["notes"] = list(alloc_trace.notes) \
                if alloc_trace is not None else []
        wm = WindowMetrics(t=t, per_stream_acc=acc, groups=groups,
                           shares=shares, bandwidth=bw,
                           delivered=delivered, serve=serve_report,
                           roofline=roofline)
        self.history.append(wm)
        self.t += cc.window_seconds
        return wm

    def _serve_window(self, window_data: Dict[str, np.ndarray],
                      evs: Dict[str, np.ndarray]) -> Dict:
        """One serving pass (run_window step 6).

        Swap protocol: every live group's freshly retrained params are
        offered through the plane's validation gate against the
        group's held-out set — up to `gate_members` members' metrics
        eval draws (drawn at t+0.5, never ingested for training).
        Candidate rows follow the bank residency discipline
        (`RetrainJob.serving_snapshot`: compact, sync, committed row
        copy). Dead groups are pruned, then each grouped stream issues
        `queries_per_stream` prompts sliced from the window data it
        already transmitted, and the plane pumps the slot pool dry.
        """
        sp = self.serve_plane
        scfg = self.cc.serve
        for j in self.jobs:
            # the serve plane decodes with ITS engine's model; a zoo
            # job on a different model class can't publish its params
            # there (shape mismatch) — its streams keep the incumbent
            if getattr(j, "engine", None) is not sp.engine:
                continue
            ms = [m for m in j.members if m.stream_id in evs]
            ms = ms[:max(1, scfg.gate_members)]
            if not ms:
                continue
            sample = np.concatenate(
                [evs[m.stream_id] for m in ms])[:self.cc.eval_batch]
            sp.publish(j.job_id, j.serving_snapshot(), sample)
        sp.prune({j.job_id for j in self.jobs})
        by_stream = self._jobs_by_stream()
        w = len(self.history)
        for s in self.streams:
            j = by_stream.get(s.stream_id)
            if j is None or j.job_id not in sp.store:
                continue
            toks = window_data.get(s.stream_id)
            if toks is None or toks.shape[0] == 0:
                continue
            for q in range(scfg.queries_per_stream):
                prompt = toks[q % toks.shape[0]][:scfg.prompt_len]
                sp.enqueue(f"{s.stream_id}/w{w}q{q}", j.job_id, prompt)
        sp.pump()
        sp.drain()      # transcripts are per-window; keep memory bounded
        return sp.window_report()

    def run(self, windows: int) -> List[WindowMetrics]:
        self.warmup()
        for _ in range(windows):
            self.run_window()
        return self.history

    # -- reporting -------------------------------------------------------------
    def mean_accuracy(self, last_k: int = 1) -> float:
        vals = []
        for wm in self.history[-last_k:]:
            vals += [v for v in wm.per_stream_acc.values()
                     if not np.isnan(v)]
        return float(np.mean(vals)) if vals else float("nan")

    def response_times(self, threshold: float) -> Dict[str, float]:
        """Windows from request to reaching `threshold` accuracy."""
        out = {}
        for sid, t0 in self.request_time.items():
            for wm in self.history:
                if wm.t >= t0 and wm.per_stream_acc.get(sid, 0.0) >= threshold:
                    out[sid] = wm.t - t0
                    break
        return out
