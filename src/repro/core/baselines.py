"""Baseline continuous-learning frameworks the paper compares against:

* Naive    — independent per-stream retraining, uniform round-robin GPU,
             fixed sampling configuration, equal bandwidth shares.
* Ekya     — independent retraining + microprofiling-based greedy GPU
             allocation (no grouping, no bandwidth coordination).
* RECL     — Ekya + model-zoo reuse (retraining starts from the best
             historical model by subsample accuracy) + content-adaptive
             frame rate (AMS-style), still no bandwidth/GPU coordination.

All reuse ECCO's substrate (SharedEngine jobs, GAIMD fluid network) with
the coordination pieces swapped out, so comparisons isolate the paper's
contributions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.allocator import ECCOAllocator, RECLAllocator, UniformAllocator
from repro.core.controller import ControllerConfig, ECCOController, WindowMetrics
from repro.core.grouping import Request
from repro.core.trainer import RetrainJob, SharedEngine


class IndependentController(ECCOController):
    """Shared machinery for the no-grouping baselines: every retraining
    request becomes its own single-stream job (paper Fig. 1 left)."""

    allocator_cls = UniformAllocator
    adaptive_sampling = False     # AMS-style rate adaptation (RECL)
    use_model_zoo = False
    # no bandwidth coordination: plain AIMD (alpha=1, beta=0.5) equal
    # competition through the FleetTransmissionPlane's equal-share path
    bandwidth_mode = "equal"

    def __init__(self, engine: SharedEngine, streams, cc=None, *, seed=0):
        super().__init__(engine, streams, cc, seed=seed)
        self.allocator = self.allocator_cls()
        self.zoo: Dict[str, dict] = {}


def _independent_group_request(self, jobs, req: Request):
    if self.use_model_zoo and self.zoo:
        best, best_acc = None, -1.0
        for key, state in self.zoo.items():
            acc = self.engine.accuracy(state["params"], req.subsamples)
            if acc > best_acc:
                best, best_acc = key, acc
        # RECL's model selector only proposes zoo models that actually
        # fit the new distribution; emulate with a floor well above
        # random accuracy — without it, wrong-domain warm starts are
        # negative transfer (synthetic domains share no structure)
        floor = max(req.acc, getattr(self, "zoo_reuse_floor", 0.15))
        if best is not None and best_acc >= floor:
            job = RetrainJob(self.engine, req,
                             micro_steps=self.cc.micro_steps,
                             batch=self.cc.train_batch,
                             init_state_tree=_clone_state(self.zoo[best]))
            jobs.append(job)
            return job
    job = self._new_job(req)
    jobs.append(job)
    return job


def _clone_state(state):
    import jax
    return jax.tree.map(lambda x: x, state)


class NaiveController(IndependentController):
    allocator_cls = UniformAllocator

    def run_window(self) -> WindowMetrics:
        # equal bandwidth, fixed sampling: overwrite the grouped logic by
        # patching grouping + shares
        self.grouper.group_request = lambda jobs, req: \
            _independent_group_request(self, jobs, req)
        self.allocator.estimate_shares = lambda jobs, gains=None: {
            j.job_id: 1.0 / max(1, len(jobs)) for j in jobs}
        # disable regrouping for independent baselines
        self.grouper.update_grouping = lambda jobs, now: []
        return super().run_window()


class EkyaController(NaiveController):
    """Greedy microprofiled allocation, still independent per stream."""
    allocator_cls = RECLAllocator      # total-accuracy greedy (n_j = 1)


class RECLController(EkyaController):
    """Ekya + model zoo + content-adaptive sampling."""
    use_model_zoo = True
    adaptive_sampling = True
    zoo_reuse_floor = 0.15      # emulates RECL's model-selector gating

    def run_window(self) -> WindowMetrics:
        wm = super().run_window()
        # snapshot models into the zoo at window end
        for j in self.jobs:
            for m in j.members:
                self.zoo[f"{m.stream_id}@{wm.t}"] = _clone_state(j.state)
        if len(self.zoo) > 32:
            for k in list(self.zoo)[:-32]:
                del self.zoo[k]
        return wm


# Framework registry shared by benchmarks and the golden-trace harness.
FRAMEWORKS = {
    "ecco": ECCOController,
    "naive": NaiveController,
    "ekya": EkyaController,
    "recl": RECLController,
}
