"""Fluid-model GAIMD congestion control (paper §3.2.2).

Each flow i runs Generalized AIMD with additive increase alpha_i (rate
units per RTT) and multiplicative decrease beta_i. All flows traverse a
shared bottleneck of capacity C; flow i additionally has a local uplink
cap L_i. On bottleneck saturation every flow multiplicatively decreases
(synchronized-loss fluid model). Steady-state rate is proportional to
alpha_i / (1 - beta_i)  [Yang & Lam 2000, Eq. 21], which ECCO exploits by
setting alpha_i = p_j / n_j, beta_i = 0.5 so bandwidth approximates
GPU-share-proportional allocation.

Implemented as a vectorized `jax.lax.scan` over RTT steps so thousands of
flows simulate in microseconds; this simulator drives the data-pipeline
rate limiter (the NS-3/tc substitute).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("steps",))
def simulate(alpha, beta, local_cap, shared_cap, *, steps: int = 2000,
             r0: Optional[jnp.ndarray] = None):
    """Simulate GAIMD flows.

    alpha: (N,) additive increase per RTT
    beta:  (N,) multiplicative decrease in (0, 1)
    local_cap: (N,) per-flow uplink caps (inf for none)
    shared_cap: scalar shared bottleneck capacity
    Returns (rates (steps, N), final_rates (N,)).
    """
    alpha = jnp.asarray(alpha, jnp.float32)
    beta = jnp.asarray(beta, jnp.float32)
    local_cap = jnp.asarray(local_cap, jnp.float32)
    n = alpha.shape[0]
    r = jnp.zeros((n,), jnp.float32) if r0 is None else jnp.asarray(r0)

    def step(r, _):
        r = jnp.minimum(r + alpha, local_cap)
        overload = jnp.sum(r) > shared_cap
        r = jnp.where(overload, r * beta, r)
        return r, r

    _, rates = jax.lax.scan(step, r, None, length=steps)
    return rates, rates[-1]


def steady_state_rates(alpha, beta, local_cap, shared_cap, *,
                       steps: int = 4000, tail: int = 1000):
    """Time-averaged steady-state rate per flow (tail average)."""
    rates, _ = simulate(alpha, beta, local_cap, shared_cap, steps=steps)
    # fleetlint: disable=host-sync -- one summary d2h at simulation
    # end; GAIMD steady-state rates are consumed host-side by the
    # window controller, not inside a per-flow loop
    return np.asarray(jnp.mean(rates[-tail:], axis=0))


def simulate_warm(alpha, beta, local_cap, shared_cap, *,
                  r0: Optional[np.ndarray] = None, max_steps: int = 4000,
                  chunk: int = 500, tol: float = 0.01):
    """Chunked GAIMD simulation with warm start + convergence short-circuit.

    Runs `simulate` in `chunk`-step slices from `r0` (zeros when None —
    the cold transient) and stops as soon as two consecutive chunk
    means agree to within `tol` (relative to the rate magnitude): the
    AIMD sawtooth has entered its steady cycle and further steps only
    re-average the same cycle. A warm `r0` carried from the previous
    retraining window starts inside the cycle, so the fleet stops
    paying the from-zero transient every window.

    Returns (rates (N,), final_r (N,), steps_run): `rates` is the
    steady-cycle time average (the `steady_state_rates` analogue),
    `final_r` the instantaneous state to persist for the next window.
    """
    alpha = np.asarray(alpha, np.float32)
    n = alpha.shape[0]
    r = (np.zeros(n, np.float32) if r0 is None
         else np.asarray(r0, np.float32))
    if n == 0:
        return np.zeros(0, np.float64), r, 0
    chunk = max(1, min(int(chunk), int(max_steps)))
    prev = None
    mean = np.zeros(n, np.float64)
    steps_run = 0
    while steps_run < max_steps:
        rates, rf = simulate(alpha, beta, local_cap, shared_cap,
                             steps=chunk, r0=r)
        r = np.asarray(rf)
        # fleetlint: disable=host-sync -- one convergence-check d2h per
        # warm-up CHUNK (thousands of simulated steps), host-side by
        # design: the tolerance test drives Python control flow
        mean = np.asarray(jnp.mean(rates, axis=0), np.float64)
        steps_run += chunk
        if prev is not None and np.abs(mean - prev).max() <= \
                tol * max(1e-9, float(np.abs(prev).max())):
            break
        prev = mean
    return mean, r, steps_run


def ecco_params(p_shares, n_members, *, beta: float = 0.5,
                alpha_scale: float = 1.0):
    """Per-camera GAIMD parameters from GPU shares (paper: alpha = p_j/n_j,
    beta = 0.5). p_shares/n_members: per-flow arrays (a camera inherits its
    group's share p_j and group size n_j)."""
    p = np.asarray(p_shares, np.float32)
    n = np.asarray(n_members, np.float32)
    alpha = alpha_scale * p / np.maximum(n, 1.0)
    return alpha, np.full_like(alpha, beta)


def proportionality_error(rates, targets) -> float:
    """How far realized rates are from the GPU-proportional target
    (normalized L1). Used by tests and bench_transmission."""
    r = np.asarray(rates, np.float64)
    t = np.asarray(targets, np.float64)
    r = r / (r.sum() or 1.0)
    t = t / (t.sum() or 1.0)
    return float(np.abs(r - t).sum() / 2.0)
