"""Resource-aware transmission control (paper §3.2).

The camera-side controller:
  1. Picks a *sampling configuration* (rate f, resolution q) from an
     offline-profiled table keyed by GPU-budget level; scales f by 1/n_j
     inside a group so the group's aggregate data volume matches the
     group's compute capacity.
  2. Sets GAIMD parameters alpha = p_j / n_j, beta = 0.5 so the flow's
     steady-state bandwidth approximates its GPU-proportional share.
  3. "Compresses" (drops sequences / truncates resolution) so the
     selected configuration fits inside the bandwidth actually achieved.

In the LM mapping: f = sequences sampled per retraining window and
q = tokens per sequence (context resolution). The pixels/sec budget of
the paper becomes tokens/step the accelerator can consume.

Two granularities, mirroring the drift plane:
  * `TransmissionController` — one camera, the scalar reference
    semantics (`decide`).
  * `FleetTransmissionPlane` — the whole fleet in dense per-flow
    arrays: one `best_many` masked argmax for every flow's sampling
    config, one vectorized pass for GAIMD params / deliverable tokens /
    compression (`decide_many`, bit-identical to a per-camera `decide`
    loop), and warm-started GAIMD bandwidth estimation whose per-flow
    rate state persists across windows under camera churn
    (`FleetDriftDetector` row discipline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import gaimd
from repro.core.rows import RowRegistry


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    rate: int          # sequences per window (paper: frame rate f)
    resolution: int    # tokens per sequence  (paper: resolution q)

    @property
    def tokens(self) -> int:
        return self.rate * self.resolution


class ProfileTable:
    """Offline-profiled accuracy for (budget_level, sampling config).

    Built by benchmarks/bench_transmission.py by actually retraining a
    reduced model under each configuration (the paper's Fig. 5
    procedure); here it stores and queries the results. Accuracies live
    in a dense (levels, configs) float64 matrix so `best_many` answers
    every flow of the fleet in one masked argmax.
    """

    def __init__(self, configs: Sequence[SamplingConfig]):
        self.configs = list(configs)
        self._tokens = np.array([c.tokens for c in self.configs], np.int64)
        self._rates = np.array([c.rate for c in self.configs], np.int64)
        self._res = np.array([c.resolution for c in self.configs], np.int64)
        self._level_row: Dict[int, int] = {}
        # the ONLY accuracy store: -inf marks unprofiled cells; both
        # best() and best_many() read it, so scalar/batched can never
        # disagree about what was recorded
        self._mat = np.full((0, len(self.configs)), -np.inf, np.float64)

    @property
    def levels(self) -> List[int]:
        """Profiled budget levels, ascending."""
        return sorted(self._level_row)

    @classmethod
    def from_spec(cls, spec: dict) -> "ProfileTable":
        """Build from a plain-data spec: {"configs": [[rate, res], ...],
        "acc": [[level, cfg_idx, acc], ...]} — the form scenarios carry
        (data/ cannot import core/)."""
        t = cls([SamplingConfig(int(r), int(q)) for r, q in spec["configs"]])
        for lvl, idx, acc in spec.get("acc", []):
            t.record(int(lvl), int(idx), float(acc))
        return t

    def record(self, budget_level: int, cfg_idx: int, acc: float):
        row = self._level_row.get(budget_level)
        if row is None:
            row = len(self._level_row)
            self._level_row[budget_level] = row
            # fleetlint: disable=rows-discipline -- the profile matrix
            # grows once per NEW BUDGET LEVEL (bounded by the profiler's
            # level grid, ~5 rows), not with fleet churn; flow-indexed
            # state in this module rides RowRegistry
            self._mat = np.concatenate(
                [self._mat,
                 np.full((1, len(self.configs)), -np.inf, np.float64)])
        self._mat[row, cfg_idx] = acc

    def acc(self, budget_level: int, cfg_idx: int) -> Optional[float]:
        """Profiled accuracy for one cell, or None when unprofiled."""
        row = self._level_row.get(budget_level)
        if row is None or self._mat[row, cfg_idx] == -np.inf:
            return None
        return float(self._mat[row, cfg_idx])

    def best(self, budget_level: int, token_budget: Optional[int] = None
             ) -> Optional[SamplingConfig]:
        """Best profiled config at this budget level whose token volume
        fits `token_budget` (if given). Returns None when the table
        holds no configs at all (max() over an empty candidate AND
        fallback set used to raise ValueError)."""
        if not self.configs:
            return None
        row = self._level_row.get(budget_level)
        cands = []
        if row is not None:
            for idx in range(len(self.configs)):
                a = self._mat[row, idx]
                if a == -np.inf:
                    continue
                c = self.configs[idx]
                if token_budget is not None and c.tokens > token_budget:
                    continue
                cands.append((a, idx))
        if not cands:
            # fall back: the SPARSEST config that fits — and when even
            # nothing fits, still the sparsest overall. (The seed fell
            # back to the densest, maximally violating the very budget
            # it was asked to respect.)
            fitting = [c for c in self.configs
                       if token_budget is None or c.tokens <= token_budget]
            return min(fitting or self.configs, key=lambda c: c.tokens)
        return self.configs[max(cands)[1]]

    def best_many(self, budget_levels: Sequence[int],
                  token_budgets=None) -> np.ndarray:
        """Vectorized `best` for a whole fleet: one masked argmax over
        the (levels, configs) matrix. Returns (N,) config indices into
        `self.configs` (-1 = empty table, the scalar path's None).
        `token_budgets` is None (unbudgeted) or per-flow; None entries
        mean unbudgeted for that flow. Row i is bit-identical to
        `best(budget_levels[i], token_budgets[i])` — including the
        tie-breaks: profiled ties go to the LARGEST config index
        (max((acc, idx))), fallback ties to the FIRST sparsest
        (min(key=tokens))."""
        n = len(budget_levels)
        C = len(self.configs)
        if C == 0:
            return np.full(n, -1, np.int64)
        if token_budgets is None:
            tb = np.full(n, np.inf, np.float64)
        else:
            tb = np.array([np.inf if b is None else float(b)
                           for b in token_budgets], np.float64)
        rows = np.array([self._level_row.get(l, -1) for l in budget_levels],
                        np.int64)
        acc = np.full((n, C), -np.inf, np.float64)
        known = rows >= 0
        if known.any():
            acc[known] = self._mat[rows[known]]
        fits = self._tokens[None, :] <= tb[:, None]
        cand = fits & (acc > -np.inf)
        # profiled argmax; ties -> largest idx (argmax over the reversed
        # axis picks the last original occurrence of the max)
        masked = np.where(cand, acc, -np.inf)
        pick = C - 1 - np.argmax(masked[:, ::-1], axis=1)
        # fallback: sparsest fitting (first-index ties), else sparsest
        ftok = np.where(fits, self._tokens[None, :].astype(np.float64),
                        np.inf)
        fallback = np.where(fits.any(axis=1), np.argmin(ftok, axis=1),
                            np.argmin(self._tokens))
        return np.where(cand.any(axis=1), pick, fallback).astype(np.int64)


@dataclasses.dataclass
class TransmissionDecision:
    config: SamplingConfig
    scaled_rate: float          # f* / n_j
    gaimd_alpha: float
    gaimd_beta: float
    target_rate: float          # alpha/(1-beta)-proportional GAIMD target
    delivered_tokens: int       # after compression to achieved bandwidth


class TransmissionController:
    """One per camera/stream (the scalar reference semantics)."""

    def __init__(self, table: ProfileTable, *, bytes_per_token: float = 2.0):
        self.table = table
        self.bytes_per_token = bytes_per_token

    def decide(self, *, gpu_budget_level: int, token_budget: int,
               p_share: float, n_members: int,
               achieved_bandwidth: float, window_seconds: float
               ) -> TransmissionDecision:
        cfg = self.table.best(gpu_budget_level, token_budget)
        if cfg is None:              # empty profile table: transmit nothing
            cfg = SamplingConfig(rate=0, resolution=0)
        scaled_rate = cfg.rate / max(1, n_members)
        alpha = p_share / max(1, n_members)
        beta = 0.5
        # tokens deliverable within the achieved bandwidth
        deliverable = int(achieved_bandwidth * window_seconds
                          / self.bytes_per_token)
        want = int(scaled_rate * cfg.resolution)
        delivered = min(want, deliverable)
        # the flow's steady-state GAIMD rate is proportional to
        # alpha/(1-beta) (Yang & Lam Eq. 21) — the target the realized
        # bandwidth is graded against, NOT the achieved bandwidth
        # itself (achieved-vs-achieved makes proportionality error
        # identically zero)
        return TransmissionDecision(
            config=cfg, scaled_rate=scaled_rate, gaimd_alpha=alpha,
            gaimd_beta=beta, target_rate=alpha / (1.0 - beta),
            delivered_tokens=delivered)


def batchable_table(table) -> Optional[ProfileTable]:
    """Duck-typed probe (mirrors core/batching.shared_engine): the
    batched decision path needs EVERYTHING it dereferences — the
    `best_many` masked argmax AND the dense per-config arrays it reads
    the chosen rates/resolutions from. Tables missing any of it
    (scripted fakes that only implement `best`, third-party tables
    without the dense layout) make the plane fall back to the scalar
    per-flow `decide` loop — dispatch cost changes, decisions never
    do."""
    if table is None:
        return None
    for attr in ("best_many", "best"):
        if not callable(getattr(table, attr, None)):
            return None
    for attr in ("configs", "_rates", "_res"):
        if not hasattr(table, attr):
            return None
    return table


@dataclasses.dataclass
class FleetDecisionBatch:
    """Dense per-flow §3.2 decisions (all arrays length N, flow order).

    `as_decisions()` materializes the scalar `TransmissionDecision`
    objects for parity checks; hot paths read the arrays directly."""
    rate: np.ndarray            # (N,) int64 chosen config rate f*
    resolution: np.ndarray      # (N,) int64 chosen config resolution q
    scaled_rate: np.ndarray     # (N,) float64 f*/n_j
    gaimd_alpha: np.ndarray     # (N,) float64 p_j/n_j
    gaimd_beta: np.ndarray      # (N,) float64
    target_rate: np.ndarray     # (N,) float64 alpha/(1-beta)
    deliverable: np.ndarray     # (N,) int64 tokens the bandwidth allows
    delivered: np.ndarray       # (N,) int64 min(want, deliverable)

    def as_decisions(self) -> List[TransmissionDecision]:
        return [TransmissionDecision(
                    config=SamplingConfig(int(self.rate[i]),
                                          int(self.resolution[i])),
                    scaled_rate=float(self.scaled_rate[i]),
                    gaimd_alpha=float(self.gaimd_alpha[i]),
                    gaimd_beta=float(self.gaimd_beta[i]),
                    target_rate=float(self.target_rate[i]),
                    delivered_tokens=int(self.delivered[i]))
                for i in range(len(self.rate))]

    @classmethod
    def from_decisions(cls, decs: Sequence[TransmissionDecision],
                       deliverable: np.ndarray) -> "FleetDecisionBatch":
        return cls(
            rate=np.array([d.config.rate for d in decs], np.int64),
            resolution=np.array([d.config.resolution for d in decs],
                                np.int64),
            scaled_rate=np.array([d.scaled_rate for d in decs], np.float64),
            gaimd_alpha=np.array([d.gaimd_alpha for d in decs], np.float64),
            gaimd_beta=np.array([d.gaimd_beta for d in decs], np.float64),
            target_rate=np.array([d.target_rate for d in decs], np.float64),
            deliverable=np.asarray(deliverable, np.int64),
            delivered=np.array([d.delivered_tokens for d in decs],
                               np.int64))


class FleetTransmissionPlane:
    """The fleet's §3.2 transmission controller as dense per-flow
    arrays: batched sampling-config selection + GAIMD parameterization +
    compression (`decide_many`), and warm-started bandwidth estimation
    (`allocate`) whose per-flow GAIMD rate state persists across
    retraining windows. Flow rows follow the `FleetDriftDetector`
    churn discipline (lazy add, swap-with-last removal, amortized
    doubling)."""

    def __init__(self, table: Optional[ProfileTable] = None, *,
                 bytes_per_token: float = 2.0, max_steps: int = 4000,
                 chunk: int = 500, tol: float = 0.01, mesh=None):
        self.table = table if table is not None else ProfileTable([])
        self.bytes_per_token = bytes_per_token
        self.max_steps = int(max_steps)
        self.chunk = int(chunk)
        self.tol = float(tol)
        self.mesh = mesh
        self.last_steps = 0          # GAIMD steps burnt by last allocate
        align = int(mesh.devices.size) if mesh is not None else 1
        self._rows = RowRegistry(align=align)
        self._r = np.zeros(self._rows.capacity, np.float32)  # GAIMD rates

    def set_mesh(self, mesh):
        """(Re)attach the fleet mesh (elastic re-mesh). Decisions are
        mesh-independent: `decide_many` is elementwise per flow (each
        device block of registry rows can evaluate its own span and the
        concatenation equals the global call — see `shard_spans`), and
        `allocate` deliberately stays GLOBAL: GAIMD's shared-bottleneck
        coupling sums every flow's rate each step, and a device-sharded
        reduction could reorder that float sum and break the
        bit-identity bar."""
        self.mesh = mesh
        self._rows.set_align(int(mesh.devices.size) if mesh is not None
                             else 1)
        if self._rows.capacity > self._r.shape[0]:
            pad = self._rows.capacity - self._r.shape[0]
            self._r = np.concatenate([self._r, np.zeros(pad, np.float32)])

    def shard_spans(self):
        """Contiguous per-device [lo, hi) row blocks of the flow axis
        (mesh-aligned capacity). Parity contract: for any inputs,
        concatenating decide_many over the live parts of these spans
        equals the global decide_many row-for-row."""
        n = int(self.mesh.devices.size) if self.mesh is not None else 1
        return self._rows.shard_spans(n)

    # -- flow membership (camera churn) --------------------------------
    def __len__(self) -> int:
        return len(self._rows)

    def __contains__(self, flow_id: str) -> bool:
        return flow_id in self._rows

    @property
    def flow_ids(self) -> List[str]:
        return self._rows.ids

    def add_flow(self, flow_id: str) -> int:
        row, new = self._rows.add(flow_id)
        if self._rows.capacity > self._r.shape[0]:
            pad = self._rows.capacity - self._r.shape[0]
            self._r = np.concatenate([self._r,
                                      np.zeros(pad, np.float32)])
        if new:
            self._r[row] = 0.0
        return row

    def remove_flow(self, flow_id: str):
        """Swap-with-last removal keeps live rows dense; a departed
        camera's warm-start rate must not leak into a future joiner."""
        mv = self._rows.remove(flow_id)
        if mv is not None and mv[0] != mv[1]:
            self._r[mv[0]] = self._r[mv[1]]

    def rate_state(self, flow_id: str) -> float:
        """Persisted warm-start rate for one flow (0.0 before its first
        allocate)."""
        row = self._rows.get(flow_id)
        return float(self._r[row]) if row is not None else 0.0

    # -- bandwidth allocation (GAIMD, warm-started) --------------------
    def allocate(self, flow_ids: Sequence[str], p_shares, n_members,
                 local_caps, shared_cap: float, *, mode: str = "ecco"
                 ) -> np.ndarray:
        """Realized per-flow bandwidth for this window. `mode="ecco"`
        sets alpha = p_j/n_j, beta = 0.5 (GPU-share proportional);
        `mode="equal"` is the plain-AIMD equal-competition baseline
        (alpha = 1, beta = 0.5). Each flow's GAIMD rate warm-starts
        from the state persisted at the end of its previous window and
        the simulation short-circuits on steady-cycle convergence."""
        n = len(flow_ids)
        if n == 0:
            self.last_steps = 0
            return np.zeros(0, np.float64)
        if mode == "equal":
            alpha = np.ones(n, np.float32)
            beta = np.full(n, 0.5, np.float32)
        else:
            alpha, beta = gaimd.ecco_params(p_shares, n_members)
        known = self._rows.rows_of(flow_ids)     # fast path: no churn
        rows = (np.asarray(known, np.int64) if known is not None else
                np.array([self.add_flow(f) for f in flow_ids], np.int64))
        rates, final, steps = gaimd.simulate_warm(
            alpha, beta, np.asarray(local_caps, np.float32), shared_cap,
            r0=self._r[rows], max_steps=self.max_steps, chunk=self.chunk,
            tol=self.tol)
        self._r[rows] = final
        self.last_steps = steps
        return rates

    # -- snapshot / restore (elastic window rollback) ------------------
    def state_dict(self) -> dict:
        live = len(self._rows)
        return {"ids": self._rows.ids, "r": self._r[:live].copy(),
                "last_steps": self.last_steps}

    def load_state_dict(self, state: dict):
        align = self._rows.align
        self._rows = RowRegistry(align=align)
        self._r = np.zeros(self._rows.capacity, np.float32)
        for sid in state["ids"]:
            self.add_flow(sid)
        self._r[:len(state["ids"])] = state["r"]
        self.last_steps = state["last_steps"]

    # -- batched §3.2 decisions ----------------------------------------
    def decide_many(self, *, budget_levels: Sequence[int], token_budgets,
                    p_shares, n_members, achieved_bw,
                    window_seconds: float) -> FleetDecisionBatch:
        """One call for every flow's sampling config, GAIMD params,
        deliverable tokens, and compression — bit-identical to a
        per-camera `TransmissionController.decide` loop (parity suite
        in tests/test_transmission_plane.py). Falls back to that exact
        loop when the table is a duck-typed fake without `best_many`."""
        n = len(p_shares)
        if batchable_table(self.table) is None:
            ctrl = TransmissionController(
                self.table, bytes_per_token=self.bytes_per_token)
            tbs = ([None] * n if token_budgets is None
                   else list(token_budgets))
            # fleetlint: disable=per-member-loop -- THE documented
            # scalar fallback for duck-typed tables without best_many
            # (docs/transmission_plane.md); parity-locked to decide()
            decs = [ctrl.decide(gpu_budget_level=budget_levels[i],
                                token_budget=tbs[i],
                                p_share=float(p_shares[i]),
                                n_members=int(n_members[i]),
                                achieved_bandwidth=float(achieved_bw[i]),
                                window_seconds=window_seconds)
                    for i in range(n)]
            deliv = [int(float(achieved_bw[i]) * window_seconds
                         / self.bytes_per_token) for i in range(n)]
            return FleetDecisionBatch.from_decisions(decs, deliv)
        idx = self.table.best_many(budget_levels, token_budgets)
        if len(self.table.configs):
            safe = np.maximum(idx, 0)
            rate = np.where(idx >= 0, self.table._rates[safe], 0)
            res = np.where(idx >= 0, self.table._res[safe], 0)
        else:                       # empty table: transmit nothing
            rate = np.zeros(n, np.int64)
            res = np.zeros(n, np.int64)
        nm = np.maximum(np.asarray(n_members, np.int64), 1)
        scaled = rate / nm                                   # float64
        alpha = np.asarray(p_shares, np.float64) / nm
        beta = np.full(n, 0.5, np.float64)
        bwa = np.asarray(achieved_bw, np.float64)
        deliverable = (bwa * window_seconds
                       / self.bytes_per_token).astype(np.int64)
        want = (scaled * res).astype(np.int64)
        return FleetDecisionBatch(
            rate=rate.astype(np.int64), resolution=res.astype(np.int64),
            scaled_rate=scaled, gaimd_alpha=alpha, gaimd_beta=beta,
            target_rate=alpha / (1.0 - beta), deliverable=deliverable,
            delivered=np.minimum(want, deliverable))

    # -- budget-level / token-budget helpers ---------------------------
    def levels_for_shares(self, p_shares) -> List[int]:
        """Quantize GPU shares onto the table's profiled budget levels
        (uniform buckets over [0, 1]); 0 when the table is unprofiled
        (every lookup then falls back to the sparsest fitting config)."""
        lvls = self.table.levels if hasattr(self.table, "levels") else []
        p = np.asarray(p_shares, np.float64)
        if not lvls:
            return [0] * len(p)
        sel = np.minimum((p * len(lvls)).astype(np.int64), len(lvls) - 1)
        return [lvls[i] for i in sel]


def allocate_bandwidth(p_shares: Sequence[float], n_members: Sequence[int],
                       local_caps: Sequence[float], shared_cap: float,
                       *, steps: int = 4000) -> np.ndarray:
    """Realized per-flow bandwidth under ECCO's customized GAIMD."""
    alpha, beta = gaimd.ecco_params(p_shares, n_members)
    return gaimd.steady_state_rates(alpha, beta, np.asarray(local_caps),
                                    shared_cap, steps=steps)


def equal_share_bandwidth(n_flows: int, local_caps: Sequence[float],
                          shared_cap: float, *, steps: int = 4000
                          ) -> np.ndarray:
    """Baseline: traditional AIMD (alpha=1, beta=0.5) equal competition."""
    alpha = np.ones(n_flows, np.float32)
    beta = np.full(n_flows, 0.5, np.float32)
    return gaimd.steady_state_rates(alpha, beta, np.asarray(local_caps),
                                    shared_cap, steps=steps)
