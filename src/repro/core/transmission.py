"""Resource-aware transmission control (paper §3.2).

The camera-side controller:
  1. Picks a *sampling configuration* (rate f, resolution q) from an
     offline-profiled table keyed by GPU-budget level; scales f by 1/n_j
     inside a group so the group's aggregate data volume matches the
     group's compute capacity.
  2. Sets GAIMD parameters alpha = p_j / n_j, beta = 0.5 so the flow's
     steady-state bandwidth approximates its GPU-proportional share.
  3. "Compresses" (drops/quantizes tokens) so the selected configuration
     fits inside the bandwidth actually achieved.

In the LM mapping: f = sequences sampled per retraining window and
q = tokens per sequence (context resolution). The pixels/sec budget of
the paper becomes tokens/step the accelerator can consume.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import gaimd


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    rate: int          # sequences per window (paper: frame rate f)
    resolution: int    # tokens per sequence  (paper: resolution q)

    @property
    def tokens(self) -> int:
        return self.rate * self.resolution


class ProfileTable:
    """Offline-profiled accuracy for (budget_level, sampling config).

    Built by benchmarks/bench_transmission.py by actually retraining a
    reduced model under each configuration (the paper's Fig. 5 procedure);
    here it stores and queries the results.
    """

    def __init__(self, configs: Sequence[SamplingConfig]):
        self.configs = list(configs)
        self._acc: Dict[Tuple[int, int], float] = {}

    def record(self, budget_level: int, cfg_idx: int, acc: float):
        self._acc[(budget_level, cfg_idx)] = acc

    def best(self, budget_level: int, token_budget: Optional[int] = None
             ) -> Optional[SamplingConfig]:
        """Best profiled config at this budget level whose token volume
        fits `token_budget` (if given). Returns None when the table
        holds no configs at all (max() over an empty candidate AND
        fallback set used to raise ValueError)."""
        if not self.configs:
            return None
        cands = []
        for (lvl, idx), acc in self._acc.items():
            if lvl != budget_level:
                continue
            c = self.configs[idx]
            if token_budget is not None and c.tokens > token_budget:
                continue
            cands.append((acc, idx))
        if not cands:
            # fall back: the densest config that fits
            fitting = [c for c in self.configs
                       if token_budget is None or c.tokens <= token_budget]
            return max(fitting or self.configs, key=lambda c: c.tokens)
        return self.configs[max(cands)[1]]


@dataclasses.dataclass
class TransmissionDecision:
    config: SamplingConfig
    scaled_rate: float          # f* / n_j
    gaimd_alpha: float
    gaimd_beta: float
    target_rate: float          # steady-state GAIMD rate (bandwidth units)
    delivered_tokens: int       # after compression to achieved bandwidth


class TransmissionController:
    """One per camera/stream."""

    def __init__(self, table: ProfileTable, *, bytes_per_token: float = 2.0):
        self.table = table
        self.bytes_per_token = bytes_per_token

    def decide(self, *, gpu_budget_level: int, token_budget: int,
               p_share: float, n_members: int,
               achieved_bandwidth: float, window_seconds: float
               ) -> TransmissionDecision:
        cfg = self.table.best(gpu_budget_level, token_budget)
        if cfg is None:              # empty profile table: transmit nothing
            cfg = SamplingConfig(rate=0, resolution=0)
        scaled_rate = cfg.rate / max(1, n_members)
        alpha = p_share / max(1, n_members)
        # tokens deliverable within the achieved bandwidth
        deliverable = int(achieved_bandwidth * window_seconds
                          / self.bytes_per_token)
        want = int(scaled_rate * cfg.resolution)
        delivered = min(want, deliverable)
        return TransmissionDecision(
            config=cfg, scaled_rate=scaled_rate, gaimd_alpha=alpha,
            gaimd_beta=0.5, target_rate=achieved_bandwidth,
            delivered_tokens=delivered)


def allocate_bandwidth(p_shares: Sequence[float], n_members: Sequence[int],
                       local_caps: Sequence[float], shared_cap: float,
                       *, steps: int = 4000) -> np.ndarray:
    """Realized per-flow bandwidth under ECCO's customized GAIMD."""
    alpha, beta = gaimd.ecco_params(p_shares, n_members)
    return gaimd.steady_state_rates(alpha, beta, np.asarray(local_caps),
                                    shared_cap, steps=steps)


def equal_share_bandwidth(n_flows: int, local_caps: Sequence[float],
                          shared_cap: float, *, steps: int = 4000
                          ) -> np.ndarray:
    """Baseline: traditional AIMD (alpha=1, beta=0.5) equal competition."""
    alpha = np.ones(n_flows, np.float32)
    beta = np.full(n_flows, 0.5, np.float32)
    return gaimd.steady_state_rates(alpha, beta, np.asarray(local_caps),
                                    shared_cap, steps=steps)
