"""Data-drift signatures and detection.

A stream's observable signature is its recent token histogram (over
hashed vocab buckets). Drift score = Jensen-Shannon divergence between
the live window histogram and the reference (deployment-time) histogram.
A request fires when the score crosses `threshold` (the paper cites
[4, 21, 40] for the trigger; any detector plugs in here).

Two granularities:
  * `DriftDetector` — one stream, the scalar reference semantics.
  * `FleetDriftDetector` — the whole fleet in dense (N, buckets)
    arrays, one vectorized scoring call per window, trigger decisions
    bit-identical to running a `DriftDetector` per stream.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.rows import RowRegistry


def token_histogram(tokens, buckets: int = 64, vocab: Optional[int] = None
                    ) -> np.ndarray:
    t = np.asarray(tokens).reshape(-1)
    if vocab:
        # tokens at exactly `vocab` (or beyond) would land in bucket
        # `buckets`, growing the histogram to buckets+1 and breaking
        # shape agreement with the reference in js_divergence
        idx = np.clip((t * buckets) // vocab, 0, buckets - 1)
    else:
        idx = t % buckets
    h = np.bincount(idx.astype(np.int64), minlength=buckets).astype(np.float64)
    s = h.sum()
    return h / s if s else h


#: rows per chunk in the batched histogram / JS paths. Chunking keeps
#: the integer index temporaries inside the cache hierarchy instead of
#: first-touch-faulting hundreds of MB of fresh pages per fleet call —
#: at 100k rows the monolithic bincount spent most of its wall time in
#: page faults (the @10k-vs-@1k speedup regression). 1024 rows keeps
#: each chunk's temporaries (~3 MB) L2/L3-resident — measured ~20%
#: faster per row than 4096 at 10k–100k rows, flat across fleet sizes;
#: the extra per-chunk Python overhead is noise (~tens of µs per 100k
#: call).
_CHUNK_ROWS = 1024
#: largest vocab for which a bucket lookup table is built (int32 LUT of
#: vocab+1 entries; 4 MB at the 1M cap).
_LUT_VOCAB_MAX = 1 << 20


def batch_token_histogram(tokens, buckets: int = 64,
                          vocab: Optional[int] = None) -> np.ndarray:
    """(N, ...) tokens -> (N, buckets) float64; row i is bit-identical
    to token_histogram(tokens[i], buckets, vocab) (integer bincounts,
    then the same float64 normalization).

    Processed in row chunks with an int32 bucket LUT: identical counts
    (the LUT tabulates the same `clip((t*buckets)//vocab)` map), but
    the scatter temporaries stay cache-sized, so cost is linear in N
    up to 100k+ rows."""
    t = np.asarray(tokens)
    n = t.shape[0]
    if n == 0:
        return np.zeros((0, buckets), np.float64)
    t = t.reshape(n, -1)
    lut = None
    if vocab and vocab <= _LUT_VOCAB_MAX:
        lut = np.minimum(
            (np.arange(vocab + 1, dtype=np.int64) * buckets) // vocab,
            buckets - 1).astype(np.int32)
    out = np.empty((n, buckets), np.float64)
    offs = None
    for lo in range(0, n, _CHUNK_ROWS):
        tc = t[lo:lo + _CHUNK_ROWS]
        m = tc.shape[0]
        if lut is not None:
            idx = lut[np.clip(tc, 0, vocab)]
        elif vocab:
            idx = np.clip((tc * buckets) // vocab,
                          0, buckets - 1).astype(np.int32)
        else:
            idx = (tc % buckets).astype(np.int32)
        if offs is None or offs.shape[0] != m:
            offs = (buckets * np.arange(m, dtype=np.int32))[:, None]
        h = np.bincount((idx + offs).reshape(-1), minlength=m * buckets)
        h = h.astype(np.float64).reshape(m, buckets)
        s = h.sum(axis=1, keepdims=True)
        out[lo:lo + m] = np.divide(h, s, out=h, where=s != 0)
    return out      # zero-sum rows keep their raw (zero) counts


def js_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    p = p + eps
    q = q + eps
    p = p / p.sum()
    q = q / q.sum()
    m = 0.5 * (p + q)
    kl = lambda a, b: float(np.sum(a * np.log(a / b)))
    return 0.5 * kl(p, m) + 0.5 * kl(q, m)


def js_divergence_rows(p: np.ndarray, q: np.ndarray,
                       eps: float = 1e-12) -> np.ndarray:
    """Row-for-row JS: out[i] = js_divergence(p[i], q[i]), bit-identical
    (same float64 ops in the same order; numpy's pairwise axis reduction
    over a contiguous row matches the 1-D reduction of the scalar path).
    Row-chunked for the same page-fault reason as
    batch_token_histogram — each row's math is independent, so chunking
    cannot change any value.
    """
    p = np.asarray(p, np.float64)
    q = np.asarray(q, np.float64)
    if p.ndim <= 1 or p.shape[0] <= _CHUNK_ROWS:
        return _js_rows_block(p, q, eps)
    out = np.empty(p.shape[0], np.float64)
    for lo in range(0, p.shape[0], _CHUNK_ROWS):
        hi = lo + _CHUNK_ROWS
        out[lo:hi] = _js_rows_block(p[lo:hi], q[lo:hi], eps)
    return out


def _js_rows_block(p: np.ndarray, q: np.ndarray, eps: float) -> np.ndarray:
    p = p + eps
    q = q + eps
    p = p / p.sum(axis=-1, keepdims=True)
    q = q / q.sum(axis=-1, keepdims=True)
    m = 0.5 * (p + q)
    kl_pm = np.sum(p * np.log(p / m), axis=-1)
    kl_qm = np.sum(q * np.log(q / m), axis=-1)
    return 0.5 * kl_pm + 0.5 * kl_qm


@dataclasses.dataclass
class DriftDetector:
    threshold: float = 0.25
    buckets: int = 64
    vocab: Optional[int] = None
    reference: Optional[np.ndarray] = None
    last_score: float = 0.0
    last_hist: Optional[np.ndarray] = None   # latest window signature

    def set_reference(self, tokens):
        self.reference = token_histogram(tokens, self.buckets, self.vocab)

    def observe(self, tokens) -> bool:
        """Returns True if drift detected on this window of tokens."""
        h = token_histogram(tokens, self.buckets, self.vocab)
        self.last_hist = h
        if self.reference is None:
            self.reference = h
            return False
        self.last_score = js_divergence(h, self.reference)
        return self.last_score > self.threshold

    def rebase(self, tokens):
        """After retraining completes, the new data becomes the reference."""
        self.set_reference(tokens)


class FleetDriftDetector:
    """Drift detection for the whole fleet in one vectorized call.

    Holds dense (N, buckets) reference and live histograms keyed by
    stream id (rows are swap-compacted on removal, so arrays stay
    dense under camera churn). `observe` replaces the controller's
    per-stream `token_histogram` + `js_divergence` Python loop.

    Exactness: histograms are always exact (integer bincounts +
    float64 normalization, bit-identical to token_histogram).
    Scoring backends (`impl`):
      * "exact"  — float64 numpy rowwise JS; scores AND trigger
        decisions bit-identical to a per-stream DriftDetector.
      * "pallas" / "interpret" / "xla" / "ref" — the fused
        kernels.ops.fleet_drift call (fp32) screens the fleet, then
        every stream whose fp32 score lands above `threshold - band`
        is rescored in exact float64 and decided there. fp32 JS error
        is ~1e-7 at drift shapes, orders below the default band, so
        trigger decisions (and the scores/signatures of every
        potentially-triggered stream) remain bit-identical to the
        scalar path while far-from-threshold streams only pay fp32.
    """

    def __init__(self, threshold: float = 0.25, buckets: int = 64,
                 vocab: Optional[int] = None, *, impl: str = "exact",
                 band: float = 1e-4, mesh=None):
        self.threshold = float(threshold)
        self.buckets = int(buckets)
        self.vocab = vocab
        self.impl = impl
        self.band = float(band)
        self.mesh = mesh                     # row-axis device mesh (or None)
        align = int(mesh.devices.size) if mesh is not None else 1
        self._rows = RowRegistry(align=align)  # id -> row churn discipline
        cap = self._rows.capacity
        self._ref = np.zeros((cap, self.buckets), np.float64)
        self._has_ref = np.zeros(cap, bool)
        self._live = np.zeros((cap, self.buckets), np.float64)
        self._scores = np.zeros(cap, np.float64)

    def set_mesh(self, mesh):
        """(Re)attach a device mesh — elastic re-meshing path. Only the
        kernel dispatch and the capacity alignment change; scores and
        trigger decisions are mesh-independent (bit-identity bar)."""
        self.mesh = mesh
        self._rows.set_align(int(mesh.devices.size) if mesh is not None
                             else 1)
        self._sync_capacity()

    # -- membership (camera churn) ---------------------------------------
    def __len__(self) -> int:
        return len(self._rows)

    def __contains__(self, stream_id: str) -> bool:
        return stream_id in self._rows

    @property
    def stream_ids(self) -> List[str]:
        return self._rows.ids

    def _sync_capacity(self):
        """Amortized doubling (via the registry): per-stream appends
        stay O(1) so building a 10k-camera fleet doesn't reallocate the
        dense arrays 10k times."""
        cap = self._ref.shape[0]
        new = self._rows.capacity
        if new <= cap:
            return
        pad = new - cap
        self._ref = np.concatenate(
            [self._ref, np.zeros((pad, self.buckets), np.float64)])
        self._live = np.concatenate(
            [self._live, np.zeros((pad, self.buckets), np.float64)])
        self._has_ref = np.concatenate([self._has_ref,
                                        np.zeros(pad, bool)])
        self._scores = np.concatenate([self._scores,
                                       np.zeros(pad, np.float64)])

    def add_stream(self, stream_id: str) -> int:
        row, new = self._rows.add(stream_id)
        self._sync_capacity()
        if new:
            self._ref[row] = 0.0
            self._live[row] = 0.0
            self._has_ref[row] = False
            self._scores[row] = 0.0
        return row

    def remove_stream(self, stream_id: str):
        """Swap-with-last removal keeps the live rows dense (capacity
        is retained; rows beyond len(self) are garbage)."""
        mv = self._rows.remove(stream_id)
        if mv is None or mv[0] == mv[1]:
            return
        row, last = mv
        self._ref[row] = self._ref[last]
        self._live[row] = self._live[last]
        self._has_ref[row] = self._has_ref[last]
        self._scores[row] = self._scores[last]

    # -- references -------------------------------------------------------
    def set_reference(self, stream_id: str, tokens):
        row = self.add_stream(stream_id)
        self._ref[row] = token_histogram(tokens, self.buckets, self.vocab)
        self._has_ref[row] = True

    def set_references(self, stream_ids: Sequence[str], tokens):
        """Batched warmup: tokens is (N, ...) aligned with stream_ids."""
        self._rows.reserve(len(stream_ids))
        self._sync_capacity()
        hists = batch_token_histogram(tokens, self.buckets, self.vocab)
        for sid, h in zip(stream_ids, hists):
            row = self.add_stream(sid)
            self._ref[row] = h
            self._has_ref[row] = True

    def rebase(self, stream_id: str, tokens):
        """After retraining, the new data becomes the reference."""
        self.set_reference(stream_id, tokens)

    # -- per-stream state accessors ---------------------------------------
    def score(self, stream_id: str) -> float:
        return float(self._scores[self._rows[stream_id]])

    def hist(self, stream_id: str) -> np.ndarray:
        """Latest live window signature (float64, exact)."""
        return self._live[self._rows[stream_id]].copy()

    def reference(self, stream_id: str) -> Optional[np.ndarray]:
        row = self._rows[stream_id]
        return self._ref[row].copy() if self._has_ref[row] else None

    # -- the batched window call -------------------------------------------
    def observe(self, stream_ids: Sequence[str], tokens) -> List[str]:
        """One fleet call per window. tokens: (N, ...) aligned with
        stream_ids. Streams without a reference adopt their live
        histogram as reference and never trigger (scalar semantics).
        Returns the list of triggered stream ids, in stream_ids order.
        """
        n = len(stream_ids)
        if n == 0:
            return []
        # contiguous fast path: the window loop observes the full
        # fleet in row order, where rows are the [0, n) prefix —
        # slice views replace the per-id dict lookups and the O(n)
        # fancy-indexed ref gather (both cache-miss-bound at 10k+
        # rows). Same elements, same order, so identical floats.
        contig = self._rows.is_row_order(stream_ids)
        if contig:
            rows = np.arange(n)
        else:
            known = self._rows.rows_of(stream_ids)   # no-churn path
            rows = (np.asarray(known) if known is not None else
                    np.array([self.add_stream(s) for s in stream_ids]))
        hists = batch_token_histogram(tokens, self.buckets, self.vocab)
        if contig:
            self._live[:n] = hists
            # copy: the adopt-reference write below must not leak into
            # this call's trigger mask (scalar semantics: a stream
            # never triggers on its reference-adopting window)
            has_ref = self._has_ref[:n].copy()
        else:
            self._live[rows] = hists
            has_ref = self._has_ref[rows]

        scores = np.zeros(n, np.float64)
        if has_ref.any():
            if contig and has_ref.all():
                sub = slice(None)
                refs = self._ref[:n]                 # view, no copy
                sel_h = hists
            else:
                sub = np.nonzero(has_ref)[0]
                refs = self._ref[rows[sub]]
                sel_h = hists[sub]
            if self.impl == "exact":
                scores[sub] = js_divergence_rows(sel_h, refs)
            else:
                from repro.kernels import ops
                toks = np.asarray(tokens).reshape(n, -1)[sub]
                fs, _ = ops.fleet_drift(
                    toks, refs.astype(np.float32), buckets=self.buckets,
                    vocab=int(self.vocab or 0), impl=self.impl,
                    mesh=self.mesh)
                fs = np.asarray(fs, np.float64)
                # decisions live in the exact float64 world: rescore
                # every stream the fp32 screen puts near/above the
                # threshold (fp32 error << band)
                near = np.nonzero(fs > self.threshold - self.band)[0]
                if near.size:
                    fs[near] = js_divergence_rows(sel_h[near],
                                                  refs[near])
                scores[sub] = fs

        # first observation becomes the reference (DriftDetector.observe)
        new = rows[~has_ref]
        if new.size:
            self._ref[new] = hists[~has_ref]
            self._has_ref[new] = True
        if contig:
            self._scores[:n] = scores
        else:
            self._scores[rows] = scores
        trig = scores > self.threshold
        trig &= has_ref
        return [sid for sid, t in zip(stream_ids, trig) if t]

    # -- snapshot / restore (elastic window rollback) ----------------------
    def state_dict(self) -> dict:
        """Host-side copy of all mutable state (dense prefix only);
        `load_state_dict` restores it exactly. Used by the elastic
        runtime to re-run a window after a mid-window device loss."""
        live = len(self._rows)
        return {"ids": self._rows.ids,
                "ref": self._ref[:live].copy(),
                "has_ref": self._has_ref[:live].copy(),
                "live": self._live[:live].copy(),
                "scores": self._scores[:live].copy()}

    def load_state_dict(self, state: dict):
        align = self._rows.align
        self._rows = RowRegistry(align=align)
        self._rows.reserve(len(state["ids"]))
        self._sync_capacity()
        for i, sid in enumerate(state["ids"]):
            row = self.add_stream(sid)
            assert row == i
        live = len(state["ids"])
        self._ref[:live] = state["ref"]
        self._has_ref[:live] = state["has_ref"]
        self._live[:live] = state["live"]
        self._scores[:live] = state["scores"]
