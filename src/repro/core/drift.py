"""Data-drift signatures and detection.

A stream's observable signature is its recent token histogram (over
hashed vocab buckets). Drift score = Jensen-Shannon divergence between
the live window histogram and the reference (deployment-time) histogram.
A request fires when the score crosses `threshold` (the paper cites
[4, 21, 40] for the trigger; any detector plugs in here).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def token_histogram(tokens, buckets: int = 64, vocab: Optional[int] = None
                    ) -> np.ndarray:
    t = np.asarray(tokens).reshape(-1)
    if vocab:
        # tokens at exactly `vocab` (or beyond) would land in bucket
        # `buckets`, growing the histogram to buckets+1 and breaking
        # shape agreement with the reference in js_divergence
        idx = np.clip((t * buckets) // vocab, 0, buckets - 1)
    else:
        idx = t % buckets
    h = np.bincount(idx.astype(np.int64), minlength=buckets).astype(np.float64)
    s = h.sum()
    return h / s if s else h


def js_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    p = p + eps
    q = q + eps
    p = p / p.sum()
    q = q / q.sum()
    m = 0.5 * (p + q)
    kl = lambda a, b: float(np.sum(a * np.log(a / b)))
    return 0.5 * kl(p, m) + 0.5 * kl(q, m)


@dataclasses.dataclass
class DriftDetector:
    threshold: float = 0.25
    buckets: int = 64
    vocab: Optional[int] = None
    reference: Optional[np.ndarray] = None
    last_score: float = 0.0
    last_hist: Optional[np.ndarray] = None   # latest window signature

    def set_reference(self, tokens):
        self.reference = token_histogram(tokens, self.buckets, self.vocab)

    def observe(self, tokens) -> bool:
        """Returns True if drift detected on this window of tokens."""
        h = token_histogram(tokens, self.buckets, self.vocab)
        self.last_hist = h
        if self.reference is None:
            self.reference = h
            return False
        self.last_score = js_divergence(h, self.reference)
        return self.last_score > self.threshold

    def rebase(self, tokens):
        """After retraining completes, the new data becomes the reference."""
        self.set_reference(tokens)
