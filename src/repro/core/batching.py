"""Duck-typed probe wiring the batched training plane (JobBank +
vmapped SharedEngine executables) into the allocator/grouper/controller
loops.

Those loops operate on duck-typed jobs (their tests drive them with
scripted fakes), so the batched fast paths must not assume RetrainJob.
`shared_engine(jobs)` answers "can this set of jobs be measured and
trained in batched fleet calls?": every job must be a live handle in
the SAME SharedEngine's JobBank and the engine must have batching
enabled. Callers fall back to the seed per-job loop on None. The
batched and scalar paths are bit-identical
(tests/test_trainer_bank.py), so the probe only decides dispatch
cost, never decisions.

Residency contract (docs/training_plane.md): dispatch sites never
touch bank rows directly. A probe-positive engine guarantees that its
batched entry points (eval_pairs / eval_jobs / train_micro_many)
compact the bank AND flush host-dirty rows (`bank.sync_to_device`)
BEFORE capturing any slot index, so host-side state writes made since
the last fleet call — checkpoint restores, model-zoo seeding,
`job.state = ...` — are visible to the fleet call without the caller
doing anything. An engine whose bank lacks the compact/sync protocol
cannot uphold that ordering, so the probe rejects it and the caller
stays on the scalar loop.
"""
from __future__ import annotations

from typing import Dict, List, Tuple


def job_precision(job) -> str:
    """A job's decision-plane screen precision tag
    (docs/scheduling.md). Duck-typed fakes and legacy jobs without the
    attribute screen in fp32 — the seed path."""
    return getattr(job, "precision", "fp32") or "fp32"


def engine_groups(jobs) -> List[Tuple[object, List[int]]]:
    """Partition `jobs` into per-engine runs for batched dispatch over
    a HETEROGENEOUS fleet (zoo fleets carry several model classes, one
    SharedEngine each). Returns [(engine_or_None, indices)] with
    indices into `jobs`, preserving fleet order within each group;
    group order follows first appearance, so a single-engine fleet
    reduces to exactly one group covering today's order (bit-identity
    contract). Jobs the probe rejects (fakes, freed slots) collect
    under the None key for the caller's scalar fallback. Duplicates in
    `jobs` are fine — each position keeps its own index."""
    order: List[object] = []
    groups: Dict[object, Tuple[object, List[int]]] = {}
    for i, j in enumerate(jobs):
        eng = shared_engine([j])
        k = id(eng) if eng is not None else None
        if k not in groups:
            groups[k] = (eng, [])
            order.append(k)
        groups[k][1].append(i)
    return [groups[k] for k in order]


def shared_engine(jobs):
    """The batch-capable SharedEngine shared by every job in `jobs`,
    or None (empty set, fake test jobs, mixed engines, freed slots,
    engine.batched=False, or a bank missing the residency sync
    protocol)."""
    eng = None
    for j in jobs:
        e = getattr(j, "engine", None)
        slot = getattr(j, "_slot", None)
        if (e is None or slot is None
                or getattr(slot, "idx", None) is None
                or getattr(slot, "dead", False)):
            return None
        if eng is None:
            eng = e
        elif e is not eng:
            return None
    if eng is None or not getattr(eng, "batched", False):
        return None
    for attr in ("eval_jobs", "eval_pairs", "train_micro_many"):
        if not callable(getattr(eng, attr, None)):
            return None
    bank = getattr(eng, "bank", None)
    for attr in ("compact", "sync_to_device", "params_stack"):
        if bank is None or not callable(getattr(bank, attr, None)):
            return None
    return eng
