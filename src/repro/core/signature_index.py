"""Fleet-wide drift-signature index: the batched half of Alg. 2.

The seed's GroupRequest scans every member of every job in pure Python
(metadata prefilter) and then pays a model evaluation per surviving
job — O(fleet) Python work per request, which cannot reach the
ROADMAP's 10k-stream scale. The index keeps the fleet's request
metadata and drift signatures as dense arrays:

    t    (cap,)          request/drift-detection time
    loc  (cap, 2)        location / trajectory centroid
    sig  (cap, buckets)  latest drift histogram (token_histogram)
    job  (cap,)          interned job key, -1 = unassigned

so one `candidate_jobs` call answers "which jobs pass the time/location
prefilter for request r, ranked by signature similarity" with a
vectorized numpy prefilter plus one batched Jensen-Shannon call
(kernels.ops.pairwise_js). The Grouper then runs the expensive
`eval_on` model check only on the top-k shortlist.

Exactness: the prefilter reproduces the Python scan bit-for-bit (same
float64 ops in the same order), so for k >= #passing jobs the grouping
decisions are identical to the seed's Alg. 2 loop. The index must see
every membership change — the Grouper owns it and updates it in
group_request / update_grouping; after mutating jobs externally, call
`rebuild(jobs)`.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class SignatureIndex:
    def __init__(self, buckets: int = 64, capacity: int = 64,
                 *, impl: str = "auto", mesh=None):
        self.buckets = buckets
        self.impl = impl           # kernels.ops.pairwise_js backend
        self.mesh = mesh           # fleet mesh: signatures column-sharded
        cap = max(8, int(capacity))
        self._sig = np.zeros((cap, buckets), np.float32)
        self._has_sig = np.zeros(cap, bool)
        self._t = np.zeros(cap, np.float64)
        self._loc = np.zeros((cap, 2), np.float64)
        self._job = np.full(cap, -1, np.int64)
        self._active = np.zeros(cap, bool)
        self._row: Dict[str, int] = {}
        self._free: List[int] = list(range(cap - 1, -1, -1))
        self._jobkey: Dict[str, int] = {}
        self._gen = 0              # bumped on any mutation
        self._seg_gen = -1         # generation the segment cache is at
        self._seg = None           # (rows_sorted, starts, seg_keys)

    # -- bookkeeping --------------------------------------------------------
    def __len__(self) -> int:
        return len(self._row)

    @property
    def capacity(self) -> int:
        return self._sig.shape[0]

    def _grow(self):
        old = self.capacity
        new = old * 2
        self._sig = np.concatenate(
            [self._sig, np.zeros((old, self.buckets), np.float32)])
        self._has_sig = np.concatenate([self._has_sig, np.zeros(old, bool)])
        self._t = np.concatenate([self._t, np.zeros(old, np.float64)])
        self._loc = np.concatenate([self._loc, np.zeros((old, 2), np.float64)])
        self._job = np.concatenate([self._job, np.full(old, -1, np.int64)])
        self._active = np.concatenate([self._active, np.zeros(old, bool)])
        self._free.extend(range(new - 1, old - 1, -1))

    def job_key(self, job_id: str) -> int:
        """Intern a job id (keys are dense ints in creation order)."""
        key = self._jobkey.get(job_id)
        if key is None:
            key = len(self._jobkey)
            self._jobkey[job_id] = key
        return key

    def key_to_position(self, jobs) -> Dict[int, int]:
        """job key -> position in `jobs`. Deliberately uncached: the
        dict is O(|jobs|) to build, and any cache keyed on the list's
        identity/length is unsound under drop+append churn (the list
        can return to a prior length with different contents)."""
        return {self.job_key(job.job_id): idx
                for idx, job in enumerate(jobs)}

    # -- mutation -----------------------------------------------------------
    def _set_sig(self, row: int, sig):
        s = np.asarray(sig, np.float32).reshape(-1)
        if s.shape[0] != self.buckets:
            raise ValueError(f"signature has {s.shape[0]} buckets, "
                             f"index holds {self.buckets}")
        self._sig[row] = s
        self._has_sig[row] = True

    def upsert(self, stream_id: str, t: float, loc, sig=None) -> int:
        """Insert/refresh a stream's request row; clears job assignment
        (a stream re-enters the index exactly when it becomes a free
        retraining request)."""
        self._gen += 1
        row = self._row.get(stream_id)
        if row is None:
            if not self._free:
                self._grow()
            row = self._free.pop()
            self._row[stream_id] = row
        self._t[row] = float(t)
        self._loc[row, 0] = float(loc[0])
        self._loc[row, 1] = float(loc[1])
        if sig is not None:
            self._set_sig(row, sig)
        self._active[row] = True
        self._job[row] = -1
        return row

    def refresh_sig(self, stream_id: str, sig):
        """Update a stream's drift signature in place, PRESERVING its
        job assignment (upsert clears it: it models a stream re-entering
        as a free request). The controller calls this at window end so
        the top-k shortlist scores a job's members by their current
        distribution, not the histograms they joined with."""
        row = self._row.get(stream_id)
        if row is None:
            return
        self._gen += 1
        self._set_sig(row, sig)

    def assign(self, stream_id: str, job_id: str):
        self._gen += 1
        self._job[self._row[stream_id]] = self.job_key(job_id)

    def unassign(self, stream_id: str):
        row = self._row.get(stream_id)
        if row is not None:
            self._gen += 1
            self._job[row] = -1

    def remove(self, stream_id: str):
        row = self._row.pop(stream_id, None)
        if row is not None:
            self._gen += 1
            self._active[row] = False
            self._has_sig[row] = False
            self._job[row] = -1
            self._free.append(row)

    def set_mesh(self, mesh):
        """(Re)attach the fleet mesh (elastic re-mesh). Dispatch-only:
        scores are mesh-independent."""
        self.mesh = mesh

    # -- snapshot / restore (elastic window rollback) -----------------------
    def state_dict(self) -> dict:
        return {"sig": self._sig.copy(), "has_sig": self._has_sig.copy(),
                "t": self._t.copy(), "loc": self._loc.copy(),
                "job": self._job.copy(), "active": self._active.copy(),
                "row": dict(self._row), "free": list(self._free),
                "jobkey": dict(self._jobkey)}

    def load_state_dict(self, state: dict):
        self._sig = state["sig"].copy()
        self._has_sig = state["has_sig"].copy()
        self._t = state["t"].copy()
        self._loc = state["loc"].copy()
        self._job = state["job"].copy()
        self._active = state["active"].copy()
        self._row = dict(state["row"])
        self._free = list(state["free"])
        self._jobkey = dict(state["jobkey"])
        self._gen += 1              # invalidate the segment cache

    def rebuild(self, jobs):
        """Re-derive membership from a jobs list mutated externally."""
        self._job[:] = -1
        known = set()
        for job in jobs:
            for m in job.members:
                sig = getattr(m, "sig", None)
                self.upsert(m.stream_id, m.t, m.loc, sig)
                self.assign(m.stream_id, job.job_id)
                known.add(m.stream_id)
        for sid in [s for s in self._row if s not in known]:
            self.remove(sid)

    # -- the vectorized queries ---------------------------------------------
    def _segments(self):
        """Member rows grouped by job key, cached until the next mutation.

        Returns (rows_sorted, starts, seg_keys, meta) where meta packs
        the gathered per-row (t, x, y, has_sig) in segment order;
        `starts` are reduceat segment boundaries and seg_keys is
        ascending (== job creation order).
        """
        if self._seg is not None and self._seg_gen == self._gen:
            return self._seg
        rows = np.nonzero(self._active & (self._job >= 0))[0]
        keys = self._job[rows]
        order = np.argsort(keys, kind="stable")
        rows_sorted = rows[order]
        keys_sorted = keys[order]
        if rows_sorted.size:
            starts = np.nonzero(
                np.r_[True, keys_sorted[1:] != keys_sorted[:-1]])[0]
            seg_keys = keys_sorted[starts]
        else:
            starts = np.zeros(0, np.int64)
            seg_keys = np.zeros(0, np.int64)
        mt = self._t[rows_sorted]
        if starts.size:
            sizes = np.diff(np.r_[starts, mt.size])
            tmin = np.minimum.reduceat(mt, starts)
            tmax = np.maximum.reduceat(mt, starts)
        else:
            sizes = np.zeros(0, np.int64)
            tmin = tmax = np.zeros(0, np.float64)
        meta = (mt, self._loc[rows_sorted, 0], self._loc[rows_sorted, 1],
                self._has_sig[rows_sorted], tmin, tmax, sizes)
        self._seg = (rows_sorted, starts, seg_keys, meta)
        self._seg_gen = self._gen
        return self._seg

    def candidate_jobs(self, t: float, loc, *, eps_t: float,
                       delta_loc: float, exclude_job: Optional[str] = None,
                       sig=None, k: int = 0) -> List[int]:
        """Job keys whose EVERY member passes the time/location prefilter
        (Alg. 2 line 4), shortlisted to the k signature-most-similar
        when k > 0 and a request signature is given. Ascending key order
        (== job creation order)."""
        return self.candidate_jobs_batch(
            [t], [loc], eps_t=eps_t, delta_loc=delta_loc,
            exclude_jobs=[exclude_job],
            sigs=None if sig is None else [sig], k=k)[0]

    def candidate_jobs_batch(self, ts, locs, *, eps_t: float,
                             delta_loc: float, exclude_jobs=None,
                             sigs=None, k: int = 0) -> List[List[int]]:
        """Answer R grouping requests in one shot.

        Two exact pruning stages before any per-pair work:
          1. per-JOB time window on (R, jobs): every member within eps_t
             of the request iff tmax - tau <= eps_t and tau - tmin <=
             eps_t (IEEE subtraction is monotonic, so folding the
             per-member |t_i - tau| <= eps_t test into the segment
             min/max is bit-exact);
          2. per-member distance check only for members of
             time-surviving (request, job) pairs, folded per pair with
             reduceat.
        The top-k shortlist adds one (R, fleet) batched pairwise-JS
        kernel call.
        """
        nq = len(ts)
        if nq == 0:
            return []
        rows_sorted, starts, seg_keys, (mt, mx, my, mhas, tmin, tmax,
                                        sizes) = self._segments()
        if seg_keys.size == 0:
            return [[] for _ in range(nq)]
        tq = np.asarray(ts, np.float64)[:, None]
        lq = np.asarray(locs, np.float64).reshape(nq, 2)
        time_ok = (tmax[None, :] - tq <= eps_t) \
            & (tq - tmin[None, :] <= eps_t)                     # (R, jobs)
        jr, jc = np.nonzero(time_ok)                            # pairs
        if jr.size:
            ln = sizes[jc]
            cl = np.cumsum(ln)
            offs = np.arange(cl[-1]) - np.repeat(cl - ln, ln)
            mrow = np.repeat(starts[jc], ln) + offs   # member seg positions
            req = np.repeat(jr, ln)
            dx = mx[mrow] - lq[req, 0]
            dy = my[mrow] - lq[req, 1]
            okm = np.sqrt(dx * dx + dy * dy) <= delta_loc
            pair_ok = np.logical_and.reduceat(okm, cl - ln)
            pr, pc = jr[pair_ok], jc[pair_ok]   # row-major: pc asc within pr
        else:
            pr = pc = jr
        parts = np.split(pc, np.searchsorted(pr, np.arange(1, nq)))

        jobmin = None
        if k and sigs is not None:
            from repro.kernels import ops
            q = np.stack([np.asarray(s, np.float32).reshape(-1)
                          for s in sigs])
            # score against the full capacity block: the jitted kernel
            # sees a stable shape across membership churn and only
            # recompiles when the index grows
            d = np.asarray(ops.pairwise_js(q, self._sig, impl=self.impl,
                                           mesh=self.mesh, shard="cols"))
            d = d[:, rows_sorted].astype(np.float64)
            d = np.where(mhas[None, :], d, np.inf)
            jobmin = np.minimum.reduceat(d, starts, axis=1)     # (R, jobs)

        plain = (not k or jobmin is None) and (
            exclude_jobs is None or all(e is None for e in exclude_jobs))
        if plain:
            return [seg_keys[pos].tolist() for pos in parts]
        out: List[List[int]] = []
        for r, pos in enumerate(parts):
            ex = exclude_jobs[r] if exclude_jobs is not None else None
            if ex is not None:
                ek = self._jobkey.get(ex)
                if ek is not None:
                    pos = pos[seg_keys[pos] != ek]
            if k and pos.size > k and jobmin is not None:
                pos = np.sort(pos[np.argsort(jobmin[r, pos],
                                             kind="stable")[:k]])
            out.append(seg_keys[pos].tolist())
        return out
