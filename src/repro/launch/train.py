"""ECCO continuous-learning launcher.

Runs the full control loop — drift detection -> dynamic grouping ->
GPU allocation (Alg. 1) -> GAIMD transmission control -> group
retraining — over a synthetic fleet, with checkpointing and optional
simulated failure/recovery.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --framework ecco --windows 12 --streams-per-region 3 --regions 2

On this CPU container models run at smoke scale (--scale smoke); the
production mesh path is exercised by repro.launch.dryrun (lower+compile
only). `--framework` selects ECCO or a paper baseline so end-to-end
comparisons (paper Fig. 6/7) run from one entry point.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def build_controller(args, engine, streams):
    from repro.core.baselines import (EkyaController, NaiveController,
                                      RECLController)
    from repro.core.controller import ControllerConfig, ECCOController
    cc = ControllerConfig(
        window_micro=args.window_micro,
        seq_len=args.seq_len,
        sample_rate=args.sample_rate,
        shared_bandwidth=args.shared_bandwidth,
        drift_threshold=args.drift_threshold,
        micro_steps=args.micro_steps,
        train_batch=args.train_batch,
    )
    ctl_cls = {"ecco": ECCOController, "naive": NaiveController,
               "ekya": EkyaController, "recl": RECLController}[
                   args.framework]
    return ctl_cls(engine, streams, cc, seed=args.seed)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke",
                    help="smoke: reduced same-family config (CPU); "
                         "full: published dims (needs accelerators)")
    ap.add_argument("--framework", default="ecco",
                    choices=["ecco", "naive", "ekya", "recl"])
    ap.add_argument("--windows", type=int, default=10)
    ap.add_argument("--regions", type=int, default=2)
    ap.add_argument("--streams-per-region", type=int, default=3)
    ap.add_argument("--vocab", type=int, default=None,
                    help="synthetic stream vocab (defaults to model's)")
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--sample-rate", type=int, default=8)
    ap.add_argument("--window-micro", type=int, default=8)
    ap.add_argument("--micro-steps", type=int, default=4)
    ap.add_argument("--train-batch", type=int, default=8)
    ap.add_argument("--shared-bandwidth", type=float, default=64.0)
    ap.add_argument("--drift-threshold", type=float, default=0.25)
    ap.add_argument("--switch-time", type=float, default=10.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=4,
                    help="checkpoint job states every N windows")
    ap.add_argument("--fail-at-window", type=int, default=None,
                    help="simulate a failure: drop job state and restore "
                         "from the last checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import get_config, smoke_config
    from repro.core.trainer import SharedEngine
    from repro.data.streams import make_fleet

    cfg = (smoke_config(args.arch) if args.scale == "smoke"
           else get_config(args.arch))
    vocab = args.vocab or min(cfg.vocab_size, 64)
    if vocab != cfg.vocab_size:
        import dataclasses
        cfg = dataclasses.replace(cfg, vocab_size=vocab)
    engine = SharedEngine(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={engine.model.num_params():,}")

    _, streams = make_fleet(
        vocab=vocab, regions=args.regions,
        streams_per_region=args.streams_per_region,
        switch_times=(args.switch_time,), seed=args.seed)
    ctl = build_controller(args, engine, streams)

    ckpt = None
    if args.ckpt_dir:
        from repro.distributed.checkpoint import AsyncCheckpointer
        ckpt = AsyncCheckpointer(args.ckpt_dir)

    ctl.warmup()
    t0 = time.time()
    for w in range(args.windows):
        if args.fail_at_window is not None and w == args.fail_at_window \
                and ckpt is not None and ctl.jobs:
            # simulate losing the job's device state mid-run; the
            # restore writes through the JobBank residency cache and is
            # flushed to the device by the next fleet call
            from repro.distributed.checkpoint import latest_step, restore_job
            ckpt.wait()
            step = latest_step(args.ckpt_dir)
            if step is not None:
                j = ctl.jobs[0]
                extra = restore_job(args.ckpt_dir, step, j)
                print(f"[w{w}] recovered job {j.job_id} from "
                      f"checkpoint step {step} (window {extra.get('window')})")
        wm = ctl.run_window()
        accs = {k: round(v, 3) for k, v in wm.per_stream_acc.items()}
        print(f"[w{w}] t={wm.t:6.1f} groups={wm.groups} acc={accs}")
        if ckpt is not None and ctl.jobs and (w + 1) % args.ckpt_every == 0:
            ckpt.save_async(w, ctl.jobs[0].state, extra={"window": w})
    if ckpt is not None:
        ckpt.wait()

    elapsed = time.time() - t0
    final = ctl.mean_accuracy(last_k=2)
    print(f"done: {args.windows} windows in {elapsed:.1f}s  "
          f"final mean accuracy={final:.3f}")
    if args.json_out:
        hist = [{"t": wm.t, "acc": wm.per_stream_acc,
                 "groups": wm.groups} for wm in ctl.history]
        with open(args.json_out, "w") as f:
            json.dump({"framework": args.framework, "arch": cfg.name,
                       "final_acc": final, "history": hist}, f, indent=1)
    return final


if __name__ == "__main__":
    main()
