"""Continuous-serving launcher: batched requests against a (retrained)
group model using the slot-pool KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b \
        --requests 6 --prompt-len 24 --max-new 16

Serves the smoke-scale config on CPU; on TPU the same ServeLoop runs the
full config under the production mesh (decode shapes proven by
repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import dataclasses
    import jax
    from repro.configs import smoke_config
    from repro.models.model import build_model
    from repro.serve.kvcache import ServeLoop

    cfg = smoke_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step "
                         "(see DESIGN.md §Arch-applicability)")
    cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 256))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    loop = ServeLoop(model, params, num_slots=args.num_slots,
                     capacity=args.capacity, max_new=args.max_new)

    rng = np.random.default_rng(args.seed)
    pending = [(f"req{i}", rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len))
               for i in range(args.requests)]

    t0 = time.time()
    ticks = 0
    while pending or loop.mgr.active():
        # admit as many as fit
        while pending and loop.mgr.free_slots():
            rid, prompt = pending.pop(0)
            loop.submit(rid, prompt)
            print(f"admitted {rid} (util={loop.mgr.utilization():.2f})")
        loop.tick()
        ticks += 1
        if ticks > 10000:
            raise RuntimeError("serve loop did not drain")
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in loop.outputs.values())
    print(f"served {len(loop.outputs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s) over {ticks} ticks")
    for rid in sorted(loop.outputs):
        print(f"  {rid}: {loop.outputs[rid][:8]}...")
    return loop.outputs


if __name__ == "__main__":
    main()
