"""Continuous-serving launcher: batched requests against a (retrained)
group model using the slot-pool KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b \
        --requests 6 --prompt-len 24 --max-new 16

`--fleet` serves the same requests through the fleet serving plane
instead: two group models published through the EdgeSync-style swap
gate, queries decoded in shared vmapped ticks (one launch per tick for
any group mix), and a window report with qps / tick percentiles / gate
counters — the path `ControllerConfig.serve` drives inside
`ECCOController.run_window` (docs/serving_plane.md).

Serves the smoke-scale config on CPU; on TPU the same loop runs the
full config under the production mesh (decode shapes proven by
repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _run_single(args, cfg, model, params, pending):
    from repro.serve.kvcache import ServeLoop

    loop = ServeLoop(model, params, num_slots=args.num_slots,
                     capacity=args.capacity, max_new=args.max_new)
    t0 = time.time()
    ticks = 0
    done = {}
    while pending or loop.mgr.active():
        # admit as many as fit
        while pending and loop.mgr.free_slots():
            rid, prompt = pending.pop(0)
            loop.submit(rid, prompt)
            print(f"admitted {rid} (util={loop.mgr.utilization():.2f})")
        loop.tick()
        done.update(loop.drain())
        ticks += 1
        if ticks > 10000:
            raise RuntimeError("serve loop did not drain")
    done.update(loop.drain())
    return done, ticks, time.time() - t0


def _run_fleet(args, cfg, engine, pending):
    """Two-group fleet serving with the validated hot swap."""
    import jax
    from repro.serve.plane import FleetServePlane, ServeConfig

    plane = FleetServePlane(engine, ServeConfig(
        num_slots=args.num_slots, capacity=args.capacity,
        max_new=args.max_new, prompt_len=args.prompt_len))
    rng = np.random.default_rng(args.seed)
    sample = rng.integers(0, cfg.vocab_size, size=(4, 16))
    for g, seed in (("groupA", 0), ("groupB", 1)):
        d = plane.publish(g, engine.model.init(jax.random.PRNGKey(seed)),
                          sample)
        print(f"seeded {g}: acc={d.candidate_acc:.3f}")
    # a second publish rides the gate: accepted only if the candidate
    # holds up on the held-out sample (ties accept at margin 0.0)
    d = plane.publish("groupA",
                      engine.model.init(jax.random.PRNGKey(2)), sample)
    print(f"swap groupA: cand={d.candidate_acc:.3f} "
          f"inc={d.incumbent_acc:.3f} -> "
          f"{'accepted' if d.accepted else 'rejected'}")

    t0 = time.time()
    for i, (rid, prompt) in enumerate(pending):
        plane.enqueue(rid, ("groupA", "groupB")[i % 2], prompt)
    ticks = plane.pump()
    done = plane.drain()
    rep = plane.window_report()
    print(f"gate: seeded={rep['swap_seeded']} "
          f"accepted={rep['swap_accepted']} "
          f"rejected={rep['swap_rejected']}")
    print(f"qps={rep['qps']:.1f} p50_tick={rep['p50_tick_ms']:.1f}ms "
          f"p99_tick={rep['p99_tick_ms']:.1f}ms")
    return done, ticks, time.time() - t0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--num-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", action="store_true",
                    help="serve through the fleet plane (two group "
                         "models, swap gate, shared vmapped ticks)")
    args = ap.parse_args(argv)

    import dataclasses
    import jax
    from repro.configs import smoke_config
    from repro.models.model import build_model

    cfg = smoke_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step "
                         "(see DESIGN.md §Arch-applicability)")
    cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 256))

    rng = np.random.default_rng(args.seed)
    pending = [(f"req{i}", rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len))
               for i in range(args.requests)]

    if args.fleet:
        from repro.core.trainer import SharedEngine
        engine = SharedEngine(cfg)
        done, ticks, dt = _run_fleet(args, cfg, engine, pending)
    else:
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))
        done, ticks, dt = _run_single(args, cfg, model, params, pending)

    total_tokens = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s) over {ticks} ticks")
    for rid in sorted(done):
        print(f"  {rid}: {done[rid][:8]}...")
    return done


if __name__ == "__main__":
    main()
