"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; real deployments get devices from the TPU runtime.
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import AxisType

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-meshing)."""
    import jax
    from jax.sharding import AxisType
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))
