"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; real deployments get devices from the TPU runtime.

Version compatibility (mirrors kernels/_compat.py): jax 0.4.x has no
`jax.sharding.AxisType`, and early 0.4.x has no `jax.make_mesh` either.
`make_mesh` degrades through the newest API it finds — axis_types when
available, bare `jax.make_mesh`, finally a hand-built
`jax.sharding.Mesh` over `jax.devices()` — instead of raising
AttributeError, so the fleet planes and the elastic re-mesh path run on
every jax the container ships.
"""
from __future__ import annotations


def _mesh_compat(shape, axes, devices=None):
    import jax
    import numpy as np

    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto",
                        None)
    mk = getattr(jax, "make_mesh", None)
    if devices is None and mk is not None:
        if axis_type is not None:
            try:
                return mk(shape, axes, axis_types=(axis_type,) * len(axes))
            except TypeError:       # make_mesh predates axis_types kwarg
                pass
        return mk(shape, axes)
    n = int(np.prod(shape))
    devices = (jax.devices() if devices is None else list(devices))[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.sharding.Mesh(np.array(devices).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh_compat(shape, axes)


def make_mesh(shape, axes, *, devices=None):
    """Arbitrary mesh (tests, elastic re-meshing)."""
    return _mesh_compat(shape, axes, devices)


def make_fleet_mesh(n_devices=None, *, axis: str = "fleet", devices=None):
    """1-D mesh over the fleet row/job axis — what the batched decision
    planes (JobBank stack, fleet_drift, decide_many, pairwise_js) shard
    along. Defaults to every visible device; `n_devices` takes a
    prefix (elastic shrink uses this with the survivor list)."""
    import jax
    devices = list(jax.devices() if devices is None else devices)
    n = len(devices) if n_devices is None else int(n_devices)
    return _mesh_compat((n,), (axis,), devices[:n])
