import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count on first init). Everything else follows.

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, TrainConfig, cell_is_runnable, get_config
from repro.configs.base import MOE
from repro.distributed.sharding import mesh_rules
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model, input_specs
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import abstract_state, make_train_step

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e target)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (1 effective link assumed)

_COLL_RE = re.compile(
    r"(\w+[\d\.]*)\s*=\s*((?:\(|)[a-z0-9\[\],{}#: ()]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire-byte estimate per collective kind, parsed from the
    post-SPMD (local shapes) HLO. all-reduce counts 2x its result bytes
    (reduce-scatter + all-gather phases of a ring)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "total": 0}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^[%\w\.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute"):
            if re.match(rf"^[a-z0-9\[\],{{}}#:. ()]*{k}(-start)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        # result shape(s) precede the op name on the rhs
        shape_txt = rhs.split(kind)[0]
        b = _shape_bytes(shape_txt)
        if kind == "all-reduce":
            b *= 2
        out[kind] += b
        out["total"] += b
    return out


def roofline(flops, hbm_bytes, coll_bytes):
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll_bytes / LINK_BW,
    }


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               remat: str = "full", moe_impl: str = None,
               capacity_factor: float = 1.25, fsdp: bool = True,
               extra_rules: dict = None, policy: str = "tp"):
    """Lower + compile one (arch, shape, mesh) cell. Returns result dict.

    policy: "tp" (paper-faithful baseline) | "zero" (optimized; decode
    shapes fall back to tp — KV-cache sharding needs the model axis)."""
    cfg = get_config(arch)
    status = cell_is_runnable(cfg, shape_name)
    if status != "ok":
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": status}

    shape = SHAPES[shape_name]
    orig_policy = policy
    if policy == "zero" and shape.kind == "decode":
        policy = "tp"   # KV-cache sharding needs the model axis
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = mesh_rules(mesh, cfg, fsdp=fsdp, policy=policy)
    data_ways = mesh.shape.get("pod", 1) * mesh.shape["data"]
    if policy == "zero":
        # The model axis must carry real work. Pure DP (batch over every
        # axis) when the global batch divides the chip count. Otherwise:
        # SSM families get explicit sequence parallelism (shard_map —
        # GSPMD cannot shard the chunk recurrence); attention families
        # fall back to the tp policy, because GSPMD also cannot
        # spatially shard the blockwise-attention lax.scan (measured:
        # CP replicates q 16x — EXPERIMENTS.md §Perf H6).
        from repro.configs.base import SSM as _SSM_F
        all_axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.shape)
        n_chips = data_ways * mesh.shape["model"]
        if shape.global_batch % n_chips == 0:
            # vocab TP would reuse the model axis -> conflict; with one
            # sequence per device the full-vocab logits are small anyway
            rules = dict(rules, batch=all_axes, vocab=None)
        elif cfg.family == _SSM_F:
            rules = dict(rules, seq="model")
            if shape.global_batch % data_ways != 0:
                rules = dict(rules, batch=None)
        else:
            policy = "tp"
            rules = mesh_rules(mesh, cfg, fsdp=fsdp, policy="tp")
    # single-stream decode cannot shard batch
    if shape.global_batch < data_ways:
        rules = dict(rules, batch=None)
    if shape.kind == "decode":
        rules = dict(rules, seq=None)   # S=1 at decode
    if extra_rules:
        rules = dict(rules, **extra_rules)
    if moe_impl is None:
        moe_impl = "ep" if cfg.family == MOE else "dense"
    # SSM-family sequence dims cannot be GSPMD-sharded (the chunk
    # recurrence serializes into per-chunk state all-reduces); the zero
    # policy uses the explicit shard_map sequence-parallel path instead
    from repro.configs.base import SSM as _SSM
    ssm_impl = ("seqpar" if policy == "zero" and cfg.family == _SSM
                and rules.get("seq") == "model" else "gspmd")
    if ssm_impl == "seqpar":
        rules = dict(rules, seq=None)   # shard_map owns the seq axis

    model = build_model(cfg, ep=mesh.shape["model"],
                        tp=mesh.shape["model"] if rules.get("heads") else 1)
    tcfg = TrainConfig(remat=remat)
    specs = input_specs(cfg, shape_name, mesh, rules)

    t0 = time.time()
    if shape.kind == "train":
        step = make_train_step(model, tcfg, mesh=mesh, rules=rules,
                               moe_impl=moe_impl, ssm_impl=ssm_impl)
        state = abstract_state(model, mesh, rules, tcfg)
        with mesh:
            lowered = jax.jit(step, donate_argnums=0).lower(
                state, {"inputs": specs["inputs"], "labels": specs["labels"]})
    elif shape.kind == "prefill":
        cap = shape.seq_len + cfg.meta_tokens
        if cfg.causal:
            pf = make_prefill_step(model, cap, mesh=mesh, rules=rules,
                                   moe_impl=moe_impl, ssm_impl=ssm_impl)
        else:  # encoder-only: full-sequence encode, no cache
            from repro.serve.serve_step import make_encode_step
            pf = make_encode_step(model, mesh=mesh, rules=rules)
        # optimized profile serves bf16 weights (standard inference
        # practice): halves param gathers and HBM reads
        serve_dtype = jnp.bfloat16 if policy == "zero" else jnp.float32
        params = model.abstract_params(mesh, rules, serve_dtype)
        with mesh:
            lowered = jax.jit(pf).lower(params, specs["inputs"])
    else:  # decode
        dec = make_decode_step(model, mesh=mesh, rules=rules,
                               moe_impl=moe_impl)
        serve_dtype = (jnp.bfloat16 if orig_policy == "zero"
                       else jnp.float32)
        params = model.abstract_params(mesh, rules, serve_dtype)
        with mesh:
            lowered = jax.jit(dec, donate_argnums=2).lower(
                params, specs["token"], specs["cache"],
                jnp.array(shape.seq_len + cfg.meta_tokens - 1, jnp.int32))
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    # scan-body correction: add (count-1) x per-segment layer cost
    from repro.launch import roofline as RL
    # _cost_dict normalizes the list-of-dicts cost_analysis() newer jax
    # versions return for multi-program compiles
    base_cost = RL._cost_dict(compiled, collective_bytes)
    t0 = time.time()
    total_cost, per_layer = RL.corrected_cost(
        cfg, base_cost, mesh=mesh, rules=rules,
        batch=shape.global_batch, seq=shape.seq_len, kind=shape.kind,
        moe_impl=moe_impl, remat=remat, collective_fn=collective_bytes,
        capacity_factor=capacity_factor, ssm_impl=ssm_impl)
    t_layers = time.time() - t0
    flops = total_cost["flops"]
    bytes_accessed = total_cost["bytes"]
    coll = total_cost["coll"]
    terms = roofline(flops, bytes_accessed, coll["total"])

    n_chips = 512 if multi_pod else 256
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens
    model_flops_per_chip = model_flops / n_chips

    dominant = max(terms, key=terms.get)
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "moe_impl": moe_impl,
        "policy": orig_policy,
        "effective_policy": policy,
        "ssm_impl": ssm_impl,
        "remat": remat,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "t_layer_costs_s": round(t_layers, 1),
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll,
        "base_cost_uncorrected": base_cost,
        "per_layer_costs": per_layer,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
        },
        "roofline": terms,
        "dominant": dominant,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": (model_flops_per_chip / flops) if flops else 0.0,
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (model_flops_per_chip / PEAK_FLOPS)
            / max(terms.values()) if max(terms.values()) > 0 else 0.0,
    }
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--capacity-factor", type=float, default=1.25)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--policy", choices=["tp", "zero"], default="tp")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status", "").startswith(("ok", "skip"))}

    for arch in archs:
        for shape in shapes:
            for m in meshes:
                key = (arch, shape, m)
                if key in done:
                    continue
                print(f"=== {arch} x {shape} x {m} ===", flush=True)
                try:
                    r = lower_cell(arch, shape, multi_pod=(m == "multi"),
                                   remat=args.remat, moe_impl=args.moe_impl,
                                   capacity_factor=args.capacity_factor,
                                   fsdp=not args.no_fsdp,
                                   policy=args.policy)
                except Exception as e:
                    traceback.print_exc()
                    r = {"arch": arch, "shape": shape, "mesh": m,
                         "status": f"error: {type(e).__name__}: {str(e)[:300]}"}
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if r["status"] == "ok":
                    print(f"  compile={r['t_compile_s']}s "
                          f"flops/dev={r['flops_per_device']:.3e} "
                          f"dominant={r['dominant']} "
                          f"roofline_frac={r['roofline_fraction']:.3f}",
                          flush=True)
                else:
                    print(f"  {r['status']}", flush=True)


if __name__ == "__main__":
    main()
