"""Roofline accounting with scan-body correction.

XLA's HLO cost analysis counts while-loop bodies ONCE, so a scan-over-
layers model reports ~one layer of FLOPs. We correct compositionally:

    total_cost = cost(full model with scans)            # loop bodies x1
               + sum_seg (seg.count - 1) * cost(one segment layer)

The per-segment layer cost is obtained by compiling a standalone
fwd(+bwd, with jax.checkpoint to reproduce remat recompute) of one layer
under the same mesh/shardings. Inner scans are disabled for the layer
cost compile (q_chunk = full seq) so attention FLOPs are not undercounted
— the math is identical, only the schedule differs.

Known residual undercount: the sLSTM time-step scan body (xlstm) — its
per-step FLOPs are negligible vs the block's matmuls; noted in
EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import HYBRID, MOE, SSM, ModelConfig
from repro.models import transformer as T
from repro.models import param as P
from repro.models import xlstm as xlstm_lib
from repro.models.transformer import Segment, ShardCtx


def _layer_spec(cfg: ModelConfig, seg: Segment, ep: int, tp: int = 1):
    if seg.kind == "block":
        return T._block_spec(cfg, ep, tp)
    if seg.kind == "mlstm":
        return xlstm_lib.mlstm_block_spec(cfg)
    if seg.kind == "slstm":
        return xlstm_lib.slstm_block_spec(cfg)
    raise ValueError(seg.kind)


def _cost_dict(compiled, collective_fn):
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_fn(hlo)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll}


def _add(a, b, k=1):
    return {
        "flops": a["flops"] + k * b["flops"],
        "bytes": a["bytes"] + k * b["bytes"],
        "coll": {kk: a["coll"][kk] + k * b["coll"][kk] for kk in a["coll"]},
    }


def segment_layer_cost(cfg: ModelConfig, seg: Segment, *, mesh, rules,
                       batch: int, seq: int, kind: str, moe_impl: str,
                       remat: str, collective_fn, capacity_factor=1.25,
                       cache_slice=None, ssm_impl: str = "gspmd"):
    """Compile one layer of `seg` and return its cost dict.

    kind: "train" (fwd+bwd via vjp, checkpoint-wrapped) | "prefill" (fwd)
          | "decode" (single-token step against a cache slice).
    """
    from jax.sharding import NamedSharding

    ep = mesh.shape.get("model", 1)
    tp = ep if (rules or {}).get("heads") else 1
    spec = _layer_spec(cfg, seg, ep, tp)
    lp = P.abstract_params(spec, mesh, rules, jnp.float32)
    ctx = ShardCtx(mesh, rules)
    bspec = P.logical_to_pspec(("batch", None, None), rules)
    S_tot = seq + (cfg.meta_tokens if seg.kind == "block" else 0)
    x_s = jax.ShapeDtypeStruct((batch, S_tot, cfg.d_model), jnp.bfloat16,
                               sharding=NamedSharding(mesh, bspec))

    if kind == "decode":
        x1 = jax.ShapeDtypeStruct((batch, 1, cfg.d_model), jnp.bfloat16,
                                  sharding=NamedSharding(mesh, bspec))
        cache_abs = cache_slice

        def dec(lp, x, cache):
            pos = jnp.array(S_tot - 1, jnp.int32)
            if seg.kind == "block":
                return T._block_decode(cfg, lp, x, cache, pos, ctx,
                                       window=seg.window, moe_impl=moe_impl,
                                       mesh=mesh,
                                       capacity_factor=capacity_factor)
            if seg.kind == "mlstm":
                return xlstm_lib.apply_mlstm_block(cfg, lp, x, cache=cache)
            return xlstm_lib.apply_slstm_block(cfg, lp, x, cache=cache)

        with mesh:
            compiled = jax.jit(dec).lower(lp, x1, cache_abs).compile()
        return _cost_dict(compiled, collective_fn)

    positions = jnp.broadcast_to(jnp.arange(S_tot), (batch, S_tot))

    def fwd(lp, x):
        if seg.kind == "block":
            y, aux, _ = T._block_forward(
                cfg, lp, x, positions, ctx, window=seg.window,
                moe_impl=moe_impl, mesh=mesh,
                capacity_factor=capacity_factor, collect_cache=False,
                q_chunk=S_tot)
            return y
        if seg.kind == "mlstm":
            if ssm_impl == "seqpar":
                return xlstm_lib.apply_mlstm_block_seqpar(
                    cfg, lp, x, mesh, batch_axes=T._batch_axes(mesh))
            return xlstm_lib.apply_mlstm_block(cfg, lp, x)[0]
        return xlstm_lib.apply_slstm_block(cfg, lp, x)[0]

    if kind == "prefill":
        with mesh:
            compiled = jax.jit(fwd).lower(lp, x_s).compile()
        return _cost_dict(compiled, collective_fn)

    # train: fwd + bwd with remat-equivalent recompute
    f = jax.checkpoint(fwd) if remat != "none" else fwd

    def train_one(lp, x, ct):
        y, vjp = jax.vjp(f, lp, x)
        dlp, dx = vjp(ct)
        return y, dlp, dx

    with mesh:
        compiled = jax.jit(train_one).lower(lp, x_s, x_s).compile()
    return _cost_dict(compiled, collective_fn)


def corrected_cost(cfg: ModelConfig, base_cost: dict, *, mesh, rules,
                   batch: int, seq: int, kind: str, moe_impl: str,
                   remat: str, collective_fn, capacity_factor=1.25,
                   ssm_impl: str = "gspmd"):
    """base_cost: cost dict of the full scanned model (bodies counted x1).
    Adds (count-1) x per-layer cost for every segment. Returns
    (total_cost, per_layer_costs)."""
    from repro.models.param import Spec, tree_map_specs

    total = base_cost
    per_layer = []
    cache_spec_tree = None
    if kind == "decode":
        cap = seq + cfg.meta_tokens
        cache_spec_tree = T.cache_spec(cfg, batch, cap)
    for i, seg in enumerate(T.layer_plan(cfg)):
        cache_slice = None
        if kind == "decode":
            one = tree_map_specs(
                lambda s: Spec(s.shape[1:], s.axes[1:], s.init),
                cache_spec_tree["segments"][i])
            cache_slice = P.abstract_params(one, mesh, rules, jnp.bfloat16)
        lc = segment_layer_cost(
            cfg, seg, mesh=mesh, rules=rules, batch=batch, seq=seq,
            kind=kind, moe_impl=moe_impl, remat=remat,
            collective_fn=collective_fn, capacity_factor=capacity_factor,
            cache_slice=cache_slice, ssm_impl=ssm_impl)
        per_layer.append({"kind": seg.kind, "window": seg.window,
                          "count": seg.count, **lc})
        if seg.count > 1:
            total = _add(total, lc, seg.count - 1)
    return total, per_layer
