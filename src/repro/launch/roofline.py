"""Roofline accounting with scan-body correction.

XLA's HLO cost analysis counts while-loop bodies ONCE, so a scan-over-
layers model reports ~one layer of FLOPs. We correct compositionally:

    total_cost = cost(full model with scans)            # loop bodies x1
               + sum_seg (seg.count - 1) * cost(one segment layer)

The per-segment layer cost is obtained by compiling a standalone
fwd(+bwd, with jax.checkpoint to reproduce remat recompute) of one layer
under the same mesh/shardings. Inner scans are disabled for the layer
cost compile (q_chunk = full seq) so attention FLOPs are not undercounted
— the math is identical, only the schedule differs.

Known residual undercount: the sLSTM time-step scan body (xlstm) — its
per-step FLOPs are negligible vs the block's matmuls; noted in
docs/architecture.md (§Roofline accounting).

Beyond the per-cell dry-run accounting, this module also hosts the
fleet scheduling cost model (docs/scheduling.md):

  * `CostTable` — caches scan-corrected FLOP/byte costs per
    (model-config, batch, seq, precision, kind) and converts them to
    modeled device-seconds on a `DeviceSpec` roofline;
  * `WindowBudget` — one retraining window's metered budget ledger;
  * `RooflineMeter` — the controller/allocator-facing meter that prices
    duck-typed retraining jobs (train micro-windows, eval passes,
    serve-plane queries) against one fleet-wide budget.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HYBRID, MOE, SSM, ModelConfig, TrainConfig
from repro.models import transformer as T
from repro.models import param as P
from repro.models import xlstm as xlstm_lib
from repro.models.transformer import Segment, ShardCtx


def _layer_spec(cfg: ModelConfig, seg: Segment, ep: int, tp: int = 1):
    if seg.kind == "block":
        return T._block_spec(cfg, ep, tp)
    if seg.kind == "mlstm":
        return xlstm_lib.mlstm_block_spec(cfg)
    if seg.kind == "slstm":
        return xlstm_lib.slstm_block_spec(cfg)
    raise ValueError(seg.kind)


def _cost_dict(compiled, collective_fn):
    ca = compiled.cost_analysis() or {}
    # some jax versions return one properties dict per device program
    # instead of a plain dict; single-program compiles get a 1-list
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = collective_fn(hlo)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll}


def _add(a, b, k=1):
    return {
        "flops": a["flops"] + k * b["flops"],
        "bytes": a["bytes"] + k * b["bytes"],
        "coll": {kk: a["coll"][kk] + k * b["coll"][kk] for kk in a["coll"]},
    }


def segment_layer_cost(cfg: ModelConfig, seg: Segment, *, mesh, rules,
                       batch: int, seq: int, kind: str, moe_impl: str,
                       remat: str, collective_fn, capacity_factor=1.25,
                       cache_slice=None, ssm_impl: str = "gspmd",
                       compute_dtype=None):
    """Compile one layer of `seg` and return its cost dict.

    kind: "train" (fwd+bwd via vjp, checkpoint-wrapped) | "prefill" (fwd)
          | "decode" (single-token step against a cache slice).

    compute_dtype=None keeps the dry-run convention (fp32 weights,
    bf16 activations); the CostTable passes an explicit dtype so the
    layer compile matches the full-model compile it corrects.
    """
    from jax.sharding import NamedSharding

    x_dtype = compute_dtype or jnp.bfloat16
    p_dtype = compute_dtype or jnp.float32
    ep = mesh.shape.get("model", 1)
    tp = ep if (rules or {}).get("heads") else 1
    spec = _layer_spec(cfg, seg, ep, tp)
    lp = P.abstract_params(spec, mesh, rules, p_dtype)
    ctx = ShardCtx(mesh, rules)
    bspec = P.logical_to_pspec(("batch", None, None), rules)
    S_tot = seq + (cfg.meta_tokens if seg.kind == "block" else 0)
    x_s = jax.ShapeDtypeStruct((batch, S_tot, cfg.d_model), x_dtype,
                               sharding=NamedSharding(mesh, bspec))

    if kind == "decode":
        x1 = jax.ShapeDtypeStruct((batch, 1, cfg.d_model), x_dtype,
                                  sharding=NamedSharding(mesh, bspec))
        cache_abs = cache_slice

        def dec(lp, x, cache):
            pos = jnp.array(S_tot - 1, jnp.int32)
            if seg.kind == "block":
                return T._block_decode(cfg, lp, x, cache, pos, ctx,
                                       window=seg.window, moe_impl=moe_impl,
                                       mesh=mesh,
                                       capacity_factor=capacity_factor)
            if seg.kind == "mlstm":
                return xlstm_lib.apply_mlstm_block(cfg, lp, x, cache=cache)
            return xlstm_lib.apply_slstm_block(cfg, lp, x, cache=cache)

        with mesh:
            compiled = jax.jit(dec).lower(lp, x1, cache_abs).compile()
        return _cost_dict(compiled, collective_fn)

    positions = jnp.broadcast_to(jnp.arange(S_tot), (batch, S_tot))

    def fwd(lp, x):
        if seg.kind == "block":
            y, aux, _ = T._block_forward(
                cfg, lp, x, positions, ctx, window=seg.window,
                moe_impl=moe_impl, mesh=mesh,
                capacity_factor=capacity_factor, collect_cache=False,
                q_chunk=S_tot)
            return y
        if seg.kind == "mlstm":
            if ssm_impl == "seqpar":
                return xlstm_lib.apply_mlstm_block_seqpar(
                    cfg, lp, x, mesh, batch_axes=T._batch_axes(mesh))
            return xlstm_lib.apply_mlstm_block(cfg, lp, x)[0]
        return xlstm_lib.apply_slstm_block(cfg, lp, x)[0]

    if kind == "prefill":
        with mesh:
            compiled = jax.jit(fwd).lower(lp, x_s).compile()
        return _cost_dict(compiled, collective_fn)

    # train: fwd + bwd with remat-equivalent recompute
    f = jax.checkpoint(fwd) if remat != "none" else fwd

    def train_one(lp, x, ct):
        y, vjp = jax.vjp(f, lp, x)
        dlp, dx = vjp(ct)
        return y, dlp, dx

    with mesh:
        compiled = jax.jit(train_one).lower(lp, x_s, x_s).compile()
    return _cost_dict(compiled, collective_fn)


def corrected_cost(cfg: ModelConfig, base_cost: dict, *, mesh, rules,
                   batch: int, seq: int, kind: str, moe_impl: str,
                   remat: str, collective_fn, capacity_factor=1.25,
                   ssm_impl: str = "gspmd", compute_dtype=None):
    """base_cost: cost dict of the full scanned model (bodies counted x1).
    Adds (count-1) x per-layer cost for every segment. Returns
    (total_cost, per_layer_costs)."""
    from repro.models.param import Spec, tree_map_specs

    total = base_cost
    per_layer = []
    cache_spec_tree = None
    if kind == "decode":
        cap = seq + cfg.meta_tokens
        cache_spec_tree = T.cache_spec(cfg, batch, cap)
    for i, seg in enumerate(T.layer_plan(cfg)):
        cache_slice = None
        if kind == "decode":
            one = tree_map_specs(
                lambda s: Spec(s.shape[1:], s.axes[1:], s.init),
                cache_spec_tree["segments"][i])
            cache_slice = P.abstract_params(one, mesh, rules, jnp.bfloat16)
        lc = segment_layer_cost(
            cfg, seg, mesh=mesh, rules=rules, batch=batch, seq=seq,
            kind=kind, moe_impl=moe_impl, remat=remat,
            collective_fn=collective_fn, capacity_factor=capacity_factor,
            cache_slice=cache_slice, ssm_impl=ssm_impl,
            compute_dtype=compute_dtype)
        per_layer.append({"kind": seg.kind, "window": seg.window,
                          "count": seg.count, **lc})
        if seg.count > 1:
            total = _add(total, lc, seg.count - 1)
    return total, per_layer


# ---------------------------------------------------------------------------
# Fleet scheduling cost model (docs/scheduling.md)
# ---------------------------------------------------------------------------
PRECISIONS = ("fp32", "bf16")

_PRECISION_DTYPE = {"fp32": jnp.float32, "bf16": jnp.bfloat16}


def precision_dtype(precision: str):
    """jnp dtype for a job precision policy string."""
    try:
        return _PRECISION_DTYPE[precision]
    except KeyError:
        raise ValueError(
            f"unknown precision {precision!r}; known: {PRECISIONS}")


@dataclasses.dataclass(frozen=True)
class Cost:
    """Scan-corrected FLOP/byte cost of one pass (one train step, one
    eval forward, one prefill, or one decode step)."""
    flops: float
    bytes: float

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k)


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Per-precision roofline of one accelerator. Defaults match the
    TPU v5e numbers repro.launch.dryrun budgets against; fp32 runs at
    half the bf16 systolic peak, which is what makes a bf16 precision
    policy genuinely cheaper in the meter, not just a label."""
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12
    peak_flops_fp32: float = 98.5e12
    hbm_bw: float = 819e9

    def peak(self, precision: str) -> float:
        precision_dtype(precision)      # validate
        return (self.peak_flops_bf16 if precision == "bf16"
                else self.peak_flops_fp32)

    def seconds(self, cost: Cost, precision: str = "fp32") -> float:
        """Modeled device-seconds: max of the compute and HBM terms."""
        return max(cost.flops / self.peak(precision),
                   cost.bytes / self.hbm_bw)


class CostTable:
    """Cached scan-corrected costs per (model-config, batch, seq,
    precision, kind in {train, eval, prefill, decode}).

    Compiles are meshless (single-device abstract lowering — the fleet
    engines carry no mesh requirement) and happen once per key; every
    later lookup is a dict hit, so metering a window adds no compile
    work to the hot path. "eval" is a full forward with logits (the
    SharedEngine accuracy pass); "train" is one optimizer-free
    fwd+bwd step through the same loss the training plane uses.
    """

    def __init__(self, device: Optional[DeviceSpec] = None):
        self.device = device or DeviceSpec()
        self._cache: Dict[tuple, Cost] = {}
        self._models: Dict[ModelConfig, object] = {}
        self._mesh = None

    # -- compile plumbing ---------------------------------------------------
    def _model(self, cfg: ModelConfig):
        m = self._models.get(cfg)
        if m is None:
            from repro.models.model import build_model
            m = build_model(cfg)
            self._models[cfg] = m
        return m

    def _one_device_mesh(self):
        """1-device mesh for the per-layer correction compiles (the
        segment_layer_cost API shards; one device means replicated —
        identical math, zero placement effect)."""
        if self._mesh is None:
            import numpy as np
            from jax.sharding import Mesh
            self._mesh = Mesh(np.asarray(jax.devices()[:1]), ("model",))
        return self._mesh

    @staticmethod
    def _abstract(tree, dtype):
        return P.tree_map_specs(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree)

    def _base_compiled(self, cfg: ModelConfig, batch: int, seq: int,
                       kind: str, cd):
        model = self._model(cfg)
        params = self._abstract(model.spec, jnp.float32)   # master rows
        toks = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        if kind == "eval":
            def fwd(p, t):
                return model.apply(p, t, compute_dtype=cd)[0]
            return jax.jit(fwd).lower(params, toks).compile()
        if kind == "prefill":
            cap = seq + cfg.meta_tokens

            def pre(p, t):
                return model.prefill(p, t, cap, compute_dtype=cd)
            return jax.jit(pre).lower(params, toks).compile()
        if kind == "train":
            tcfg = TrainConfig(remat="none",
                               compute_dtype=str(jnp.dtype(cd)))
            from repro.train.train_step import make_loss_fn
            loss_fn = make_loss_fn(model, tcfg)

            def train_one(p, t):
                batch_d = {"inputs": t, "labels": t}
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(p, batch_d)
                return loss, grads
            return jax.jit(train_one).lower(params, toks).compile()
        if kind == "decode":
            cap = seq + cfg.meta_tokens
            cache = self._abstract(model.cache_spec(batch, cap),
                                   jnp.bfloat16)
            tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)

            def dec(p, t, c, q):
                return model.decode(p, t, c, q, compute_dtype=cd)
            return jax.jit(dec).lower(params, tok, cache, pos).compile()
        raise ValueError(
            f"unknown kind {kind!r}; expected train/eval/prefill/decode")

    # -- public API ---------------------------------------------------------
    def cost(self, cfg: ModelConfig, *, batch: int, seq: int, kind: str,
             precision: str = "fp32") -> Cost:
        """Scan-corrected FLOP/byte cost of one `kind` pass."""
        key = (cfg, int(batch), int(seq), kind, precision)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        cd = precision_dtype(precision)
        compiled = self._base_compiled(cfg, batch, seq, kind, cd)
        base = _cost_dict(compiled, lambda hlo: {})
        total, _ = corrected_cost(
            cfg, base, mesh=self._one_device_mesh(), rules={},
            batch=batch, seq=seq,
            kind=("prefill" if kind == "eval" else kind),
            moe_impl="dense", remat="none",
            collective_fn=lambda hlo: {}, compute_dtype=cd)
        out = Cost(flops=total["flops"], bytes=total["bytes"])
        self._cache[key] = out
        return out

    def seconds(self, cfg: ModelConfig, *, batch: int, seq: int, kind: str,
                precision: str = "fp32") -> float:
        """Modeled device-seconds of one `kind` pass on the roofline."""
        return self.device.seconds(
            self.cost(cfg, batch=batch, seq=seq, kind=kind,
                      precision=precision), precision)


@dataclasses.dataclass
class WindowBudget:
    """One retraining window's metered budget ledger (modeled
    device-seconds). Charges are tagged by kind so the window report
    shows where the budget went (train vs eval vs serve)."""
    total: float
    spent: float = 0.0
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def remaining(self) -> float:
        return self.total - self.spent

    def can_afford(self, seconds: float) -> bool:
        return self.spent + seconds <= self.total * (1 + 1e-9)

    def charge(self, seconds: float, kind: str = "train"):
        self.spent += seconds
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + seconds

    def report(self) -> Dict:
        return {"total": self.total, "spent": self.spent,
                "remaining": self.remaining, "by_kind": dict(self.by_kind)}


class RooflineMeter:
    """Prices duck-typed retraining jobs against one window budget.

    A job is priced from its own engine's ModelConfig, its own batch /
    micro_steps, and its own precision policy (`job.precision`,
    default fp32) — a heterogeneous fleet meters heterogeneously,
    which is what lets Alg. 1's gain/cost objective prefer a smaller
    backbone or a cheaper precision under budget pressure. Jobs
    without a real engine (scripted test fakes) fall back to
    `fallback_cost` seconds per micro-window so the allocator stays
    duck-typed.
    """

    def __init__(self, table: CostTable, budget_seconds: float, *,
                 seq_len: int = 32, eval_batch: int = 16,
                 fallback_cost: float = 1.0):
        self.table = table
        self.budget = WindowBudget(total=float(budget_seconds))
        self.seq_len = int(seq_len)
        self.eval_batch = int(eval_batch)
        self.fallback_cost = float(fallback_cost)

    # -- job pricing --------------------------------------------------------
    @staticmethod
    def job_precision(job) -> str:
        return getattr(job, "precision", "fp32") or "fp32"

    def _job_cfg(self, job) -> Optional[ModelConfig]:
        cfg = getattr(getattr(job, "engine", None), "cfg", None)
        return cfg if isinstance(cfg, ModelConfig) else None

    def train_cost(self, job) -> float:
        """One micro-window: `micro_steps` train steps at the job's
        train batch, engine config, and precision."""
        cfg = self._job_cfg(job)
        if cfg is None:
            return self.fallback_cost
        steps = int(getattr(job, "micro_steps", 1) or 1)
        return steps * self.table.seconds(
            cfg, batch=int(getattr(job, "batch", 8) or 8),
            seq=self.seq_len, kind="train",
            precision=self.job_precision(job))

    def eval_cost(self, job) -> float:
        """One allocator eval(): one accuracy pass per member at the
        controller eval batch."""
        cfg = self._job_cfg(job)
        if cfg is None:
            return 0.0
        members = max(1, int(getattr(job, "num_members", 1) or 1))
        return members * self.table.seconds(
            cfg, batch=self.eval_batch, seq=self.seq_len, kind="eval",
            precision=self.job_precision(job))

    def micro_cost(self, job) -> float:
        """One allocator micro-window: eval before, train, eval after
        (the measured AccGain bracket of Alg. 1)."""
        return self.train_cost(job) + 2 * self.eval_cost(job)

    def serve_cost(self, cfg: ModelConfig, *, queries: int,
                   prompt_len: int, gen_tokens: int,
                   batch: int = 1) -> float:
        """Serve-plane pricing: one prefill per query plus `gen_tokens`
        decode steps (gate evals are charged separately as evals)."""
        if queries <= 0:
            return 0.0
        pre = self.table.seconds(cfg, batch=batch, seq=prompt_len,
                                 kind="prefill", precision="fp32")
        dec = self.table.seconds(cfg, batch=batch, seq=prompt_len,
                                 kind="decode", precision="fp32")
        return queries * (pre + max(0, gen_tokens) * dec)

    # -- ledger passthrough -------------------------------------------------
    def can_afford(self, seconds: float) -> bool:
        return self.budget.can_afford(seconds)

    def charge(self, seconds: float, kind: str = "train"):
        self.budget.charge(seconds, kind)

    def report(self) -> Dict:
        return self.budget.report()
