"""Parameter spec trees.

A model is described by a nested dict of `Spec` leaves. From the spec tree
we can:
  * materialize real parameters (`init_params`) for CPU tests/examples;
  * produce `jax.ShapeDtypeStruct` stand-ins with `NamedSharding`
    (`abstract_params`) for the multi-pod dry-run — no allocation;
  * extract the sharding tree (`shardings`) for `jax.jit` in_shardings.

Logical axis names on each Spec dim are resolved to mesh axes through a
rules dict (see repro.distributed.sharding).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (or None)
    init: str = "normal"              # normal | zeros | ones | embed
    scale: float = 1.0                # fan-in style scale multiplier

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def tree_map_specs(fn, spec_tree):
    return jax.tree.map(fn, spec_tree, is_leaf=is_spec)


def _init_leaf(spec: Spec, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "neg_inf":
        return jnp.full(spec.shape, -jnp.inf, dtype)
    if spec.init == "normal":
        # truncated-normal, fan-in scaled on the last contracting dim
        fan_in = spec.shape[0] if len(spec.shape) == 1 else math.prod(spec.shape[:-1])
        std = spec.scale / max(1.0, math.sqrt(fan_in))
        return (jax.random.truncated_normal(key, -3.0, 3.0, spec.shape, jnp.float32)
                * std).astype(dtype)
    if spec.init == "embed":
        std = spec.scale * 0.02
        return (jax.random.truncated_normal(key, -3.0, 3.0, spec.shape, jnp.float32)
                * std).astype(dtype)
    raise ValueError(spec.init)


def init_params(spec_tree, key, dtype=jnp.float32):
    """Materialize real parameters. Deterministic given `key`."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def logical_to_pspec(axes: Sequence[Optional[str]], rules: dict):
    """Map logical axis names -> PartitionSpec entries via `rules`."""
    from jax.sharding import PartitionSpec as P
    entries = []
    for name in axes:
        if name is None:
            entries.append(None)
        else:
            entries.append(rules.get(name))
    return P(*entries)


def shardings(spec_tree, mesh, rules):
    """NamedSharding tree matching the spec tree."""
    from jax.sharding import NamedSharding
    return tree_map_specs(
        lambda s: NamedSharding(mesh, logical_to_pspec(s.axes, rules)),
        spec_tree)


def abstract_params(spec_tree, mesh, rules, dtype=jnp.float32):
    """ShapeDtypeStruct tree with shardings — dry-run stand-ins."""
    from jax.sharding import NamedSharding
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype,
            sharding=NamedSharding(mesh, logical_to_pspec(s.axes, rules))),
        spec_tree)


def param_count(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return int(sum(math.prod(s.shape) for s in leaves))


def param_bytes(spec_tree, bytes_per_el=4) -> int:
    return param_count(spec_tree) * bytes_per_el
