"""Model assembly: layer plans, scan-over-layers segments, forward /
prefill / decode for every architecture family.

A model is a sequence of *segments*: runs of homogeneous layers scanned
together (`jax.lax.scan` over stacked parameters), so HLO size is O(1) in
depth. Heterogeneous stacks (hymba's 3 global-attention layers, xlstm's
mLSTM/sLSTM alternation) become short segment lists.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (DENSE, ENCODER, HYBRID, MOE, SSM, VLM,
                                ModelConfig)
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.param import Spec, tree_map_specs


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str          # "block" | "mlstm" | "slstm"
    count: int
    window: int = 0    # 0 = full attention (block kind only)


def layer_plan(cfg: ModelConfig) -> List[Segment]:
    if cfg.family == SSM:
        e = cfg.ssm.slstm_every
        if e > 0 and cfg.num_layers % e == 0:
            # repeating unit: (e-1) mLSTM blocks then 1 sLSTM block
            unit = [Segment("mlstm", e - 1)] if e > 1 else []
            unit.append(Segment("slstm", 1))
            return unit * (cfg.num_layers // e)
        return [Segment("mlstm", cfg.num_layers)]
    # dense / moe / vlm / encoder / hybrid: group consecutive layers with
    # the same attention window
    windows = []
    for i in range(cfg.num_layers):
        if cfg.sliding_window and i not in cfg.global_attn_layers:
            windows.append(cfg.sliding_window)
        else:
            windows.append(0)
    segs: List[Segment] = []
    for w in windows:
        if segs and segs[-1].window == w:
            segs[-1] = Segment("block", segs[-1].count + 1, w)
        else:
            segs.append(Segment("block", 1, w))
    return segs


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def _stack_spec(spec_tree, count: int):
    return tree_map_specs(
        lambda s: Spec((count,) + s.shape, ("layers",) + s.axes,
                       s.init, s.scale),
        spec_tree)


def _block_spec(cfg: ModelConfig, ep: int, tp: int = 1):
    spec = {
        "ln1": L.norm_spec(cfg),
        "attn": L.attention_spec(cfg, tp),
        "ln2": L.norm_spec(cfg),
    }
    if cfg.family == MOE:
        spec["moe"] = moe_lib.moe_spec(cfg, ep)
    else:
        spec["mlp"] = L.mlp_spec(cfg)
    if cfg.family == HYBRID:
        spec["mamba"] = ssm_lib.mamba_spec(cfg)
        spec["mix_a"] = Spec((cfg.d_model,), (None,), "ones")
        spec["mix_s"] = Spec((cfg.d_model,), (None,), "ones")
    return spec


def build_spec(cfg: ModelConfig, *, ep: int = 1, tp: int = 1):
    """Full parameter spec tree for an architecture. `ep` pads MoE expert
    counts to the EP divisor; `tp` pads GQA head groups to the TP divisor
    (see layers.padded_heads)."""
    spec = {"embed": L.embedding_spec(cfg),
            "final_norm": L.norm_spec(cfg)}
    if cfg.meta_tokens:
        spec["meta"] = Spec((cfg.meta_tokens, cfg.d_model), (None, "fsdp"),
                            "embed")
    segs = []
    for seg in layer_plan(cfg):
        if seg.kind == "block":
            one = _block_spec(cfg, ep, tp)
        elif seg.kind == "mlstm":
            one = xlstm_lib.mlstm_block_spec(cfg)
        elif seg.kind == "slstm":
            one = xlstm_lib.slstm_block_spec(cfg)
        else:
            raise ValueError(seg.kind)
        segs.append(_stack_spec(one, seg.count))
    spec["segments"] = segs
    return spec


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------
def cache_spec(cfg: ModelConfig, batch: int, cap: int, dtype_name: str = "bfloat16"):
    """Spec tree for the decode cache at static capacity `cap` (the
    absolute position space includes meta tokens; `cap` should already
    include them for global layers)."""
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    meta = cfg.meta_tokens
    segs = []
    for seg in layer_plan(cfg):
        n = seg.count
        if seg.kind == "block":
            w = seg.window
            kv_cap = cap if w == 0 else min(w, cap)
            c = {"k": Spec((n, batch, kv_cap, K, hd),
                           ("layers", "batch", None, "kv_heads", None), "zeros"),
                 "v": Spec((n, batch, kv_cap, K, hd),
                           ("layers", "batch", None, "kv_heads", None), "zeros")}
            if w > 0 and meta:
                c["mk"] = Spec((n, batch, meta, K, hd),
                               ("layers", "batch", None, "kv_heads", None), "zeros")
                c["mv"] = Spec((n, batch, meta, K, hd),
                               ("layers", "batch", None, "kv_heads", None), "zeros")
            if cfg.family == HYBRID:
                di = cfg.ssm.expand * cfg.d_model
                Hs = max(1, di // 64)
                P = di // Hs
                c["mamba"] = {
                    "conv": Spec((n, batch, cfg.ssm.conv_width - 1, di),
                                 ("layers", "batch", None, "mlp"), "zeros"),
                    "state": Spec((n, batch, Hs, P, cfg.ssm.state_dim),
                                  ("layers", "batch", None, "mlp", None), "zeros"),
                }
            segs.append(c)
        elif seg.kind == "mlstm":
            di = cfg.ssm.expand * cfg.d_model
            H = cfg.num_heads
            P = di // H
            segs.append({
                "C": Spec((n, batch, H, P, P), ("layers", "batch", "heads", None, None), "zeros"),
                "n": Spec((n, batch, H, P), ("layers", "batch", "heads", None), "zeros"),
                "m": Spec((n, batch, H), ("layers", "batch", "heads"), "neg_inf"),
                "conv": Spec((n, batch, cfg.ssm.conv_width - 1, di),
                             ("layers", "batch", None, "mlp"), "zeros"),
            })
        elif seg.kind == "slstm":
            d = cfg.d_model
            H = cfg.num_heads
            P = d // H
            segs.append({
                "h": Spec((n, batch, H, P), ("layers", "batch", "heads", None), "zeros"),
                "c": Spec((n, batch, H, P), ("layers", "batch", "heads", None), "zeros"),
                "n": Spec((n, batch, H, P), ("layers", "batch", "heads", None), "zeros"),
                "m": Spec((n, batch, H, P), ("layers", "batch", "heads", None), "neg_inf"),
                "conv": Spec((n, batch, cfg.ssm.conv_width - 1, d),
                             ("layers", "batch", None, None), "zeros"),
            })
    return {"segments": segs}


# ---------------------------------------------------------------------------
# Sharding context
# ---------------------------------------------------------------------------
class ShardCtx:
    """Applies with_sharding_constraint from logical axis names; a None
    mesh makes it a no-op (single-device tests)."""

    def __init__(self, mesh=None, rules=None):
        self.mesh = mesh
        self.rules = rules or {}

    def __call__(self, x, *axes):
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding
        from repro.models.param import logical_to_pspec
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, logical_to_pspec(axes, self.rules)))


NULL_CTX = ShardCtx()


# ---------------------------------------------------------------------------
# Block forward / decode
# ---------------------------------------------------------------------------
def _block_forward(cfg: ModelConfig, p, x, positions, ctx, *, window: int,
                   moe_impl: str, mesh, capacity_factor: float,
                   collect_cache: bool, q_chunk: int = 1024):
    h = L.apply_norm(cfg, p["ln1"], x)
    if window > 0:
        attn_out, kv = L.attention_windowed(cfg, p["attn"], h, positions,
                                            window=window,
                                            meta=cfg.meta_tokens)
    else:
        attn_out, kv = L.attention_full(cfg, p["attn"], h, positions,
                                        causal=cfg.causal, q_chunk=q_chunk)
    attn_out = ctx(attn_out, "batch", None, None)

    mamba_cache = None
    if cfg.family == HYBRID:
        if collect_cache:
            ssm_out, mamba_cache = ssm_lib.apply_mamba(
                cfg, p["mamba"], h, return_cache=True)
        else:
            ssm_out = ssm_lib.apply_mamba(cfg, p["mamba"], h)
        na = _rms(attn_out) * p["mix_a"].astype(x.dtype)
        ns = _rms(ssm_out) * p["mix_s"].astype(x.dtype)
        x = x + 0.5 * (na + ns)
    else:
        x = x + attn_out

    h2 = L.apply_norm(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == MOE:
        if moe_impl == "ep" and mesh is not None:
            y, aux = moe_lib.apply_moe_ep(
                cfg, p["moe"], h2, mesh,
                capacity_factor=capacity_factor,
                batch_axes=_batch_axes(mesh),
                fsdp_axis="data" if "data" in mesh.shape else None)
        else:
            y, aux = moe_lib.apply_moe_dense(cfg, p["moe"], h2,
                                             capacity_factor=capacity_factor)
    else:
        y = L.apply_mlp(cfg, p["mlp"], h2)
    x = x + y
    x = ctx(x, "batch", None, None)

    cache = None
    if collect_cache:
        k, v = kv
        cache = {"k": k, "v": v}
        if mamba_cache is not None:
            cache["mamba"] = mamba_cache
    return x, aux, cache


def _rms(x, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf ** 2, -1, keepdims=True) + eps)
            ).astype(x.dtype)


def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _block_decode(cfg: ModelConfig, p, x, cache, pos, ctx, *, window: int,
                  moe_impl: str, mesh, capacity_factor: float):
    h = L.apply_norm(cfg, p["ln1"], x)
    attn_out, new_attn_cache = L.attention_decode(
        cfg, p["attn"], h, cache, pos, window=window, meta=cfg.meta_tokens)
    new_cache = new_attn_cache
    if cfg.family == HYBRID:
        ssm_out, new_mamba = ssm_lib.apply_mamba_step(cfg, p["mamba"], h,
                                                      cache["mamba"])
        na = _rms(attn_out) * p["mix_a"].astype(x.dtype)
        ns = _rms(ssm_out) * p["mix_s"].astype(x.dtype)
        x = x + 0.5 * (na + ns)
        new_cache = dict(new_cache)
        new_cache["mamba"] = new_mamba
    else:
        x = x + attn_out
    h2 = L.apply_norm(cfg, p["ln2"], x)
    if cfg.family == MOE:
        if moe_impl == "ep" and mesh is not None:
            y, _ = moe_lib.apply_moe_ep(
                cfg, p["moe"], h2, mesh, capacity_factor=capacity_factor,
                batch_axes=_batch_axes(mesh),
                fsdp_axis="data" if "data" in mesh.shape else None)
        else:
            y, _ = moe_lib.apply_moe_dense(cfg, p["moe"], h2,
                                           capacity_factor=capacity_factor)
    else:
        y = L.apply_mlp(cfg, p["mlp"], h2)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Segment runners
# ---------------------------------------------------------------------------
# When True, segment scans compile fully unrolled (every layer its own
# HLO) instead of as a while loop. Math-identical; only the schedule
# differs. The roofline cost model's parity test uses this to compare
# its scan-body-corrected totals against a direct cost_analysis of the
# unrolled graph (XLA counts loop bodies once, unrolled layers N times).
_SCAN_UNROLL = False


@contextlib.contextmanager
def unrolled_scans():
    """Compile segment layer scans unrolled within this context."""
    global _SCAN_UNROLL
    prev = _SCAN_UNROLL
    _SCAN_UNROLL = True
    try:
        yield
    finally:
        _SCAN_UNROLL = prev


def _scan_unroll():
    return True if _SCAN_UNROLL else 1


def _remat_wrap(body, remat: str):
    if remat == "none":
        return body
    if remat == "dots":
        return jax.checkpoint(body, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(body)


def _segment_forward(cfg, seg: Segment, params, x, positions, ctx, *,
                     moe_impl, mesh, capacity_factor, remat, collect_cache,
                     ssm_impl: str = "gspmd"):
    if seg.kind == "block":
        def body(carry, lp):
            xc, aux = carry
            xn, aux_l, cache_l = _block_forward(
                cfg, lp, xc, positions, ctx, window=seg.window,
                moe_impl=moe_impl, mesh=mesh,
                capacity_factor=capacity_factor, collect_cache=collect_cache)
            return (ctx(xn, "batch", "seq", None), aux + aux_l), cache_l
    elif seg.kind == "mlstm":
        if ssm_impl == "seqpar" and mesh is not None:
            def body(carry, lp):
                xc, aux = carry
                xn = xlstm_lib.apply_mlstm_block_seqpar(
                    cfg, lp, xc, mesh, batch_axes=_batch_axes(mesh))
                return (xn, aux), None
        else:
            def body(carry, lp):
                xc, aux = carry
                xn, _ = xlstm_lib.apply_mlstm_block(cfg, lp, xc)
                return (ctx(xn, "batch", "seq", None), aux), None
    elif seg.kind == "slstm":
        def body(carry, lp):
            xc, aux = carry
            xn, _ = xlstm_lib.apply_slstm_block(cfg, lp, xc)
            return (ctx(xn, "batch", "seq", None), aux), None
    else:
        raise ValueError(seg.kind)

    (x, aux), caches = jax.lax.scan(_remat_wrap(body, remat),
                                    (x, jnp.zeros((), jnp.float32)), params,
                                    unroll=_scan_unroll())
    return x, aux, caches


def _segment_decode(cfg, seg: Segment, params, caches, x, pos, ctx, *,
                    moe_impl, mesh, capacity_factor):
    if seg.kind == "block":
        def body(xc, pc):
            lp, cache_l = pc
            xn, new_c = _block_decode(cfg, lp, xc, cache_l, pos, ctx,
                                      window=seg.window, moe_impl=moe_impl,
                                      mesh=mesh,
                                      capacity_factor=capacity_factor)
            return xn, new_c
    elif seg.kind == "mlstm":
        def body(xc, pc):
            lp, cache_l = pc
            xn, new_c = xlstm_lib.apply_mlstm_block(cfg, lp, xc, cache=cache_l)
            return xn, new_c
    elif seg.kind == "slstm":
        def body(xc, pc):
            lp, cache_l = pc
            xn, new_c = xlstm_lib.apply_slstm_block(cfg, lp, xc, cache=cache_l)
            return xn, new_c
    else:
        raise ValueError(seg.kind)

    x, new_caches = jax.lax.scan(body, x, (params, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Public model functions
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, inputs, *, ctx: ShardCtx = NULL_CTX,
            moe_impl: str = "dense", mesh=None, capacity_factor: float = 1.25,
            remat: str = "none", compute_dtype=jnp.bfloat16,
            collect_cache: bool = False, ssm_impl: str = "gspmd"):
    """Full-sequence forward.

    inputs: int tokens (B,S) or float embeds (B,S,D) when
    cfg.embedding_frontend. Returns (logits (B,S,V), aux, caches|None).
    Meta tokens are prepended internally and stripped from logits.
    """
    if cfg.embedding_frontend:
        x = inputs.astype(compute_dtype)
    else:
        x = L.embed_tokens(params["embed"], inputs, compute_dtype)
    B = x.shape[0]
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"].astype(compute_dtype),
                                (B, cfg.meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta, x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = ctx(x, "batch", "seq", None)

    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for seg, segp in zip(layer_plan(cfg), params["segments"]):
        x, aux, cache_s = _segment_forward(
            cfg, seg, segp, x, positions, ctx, moe_impl=moe_impl, mesh=mesh,
            capacity_factor=capacity_factor, remat=remat,
            collect_cache=collect_cache, ssm_impl=ssm_impl)
        aux_total = aux_total + aux
        caches.append(cache_s)

    x = L.apply_norm(cfg, params["final_norm"], x)
    if cfg.meta_tokens:
        x = x[:, cfg.meta_tokens:]
    logits = L.unembed(cfg, params["embed"], x)
    logits = ctx(logits, "batch", None, "vocab")
    return logits, aux_total, (caches if collect_cache else None)


def prefill(cfg: ModelConfig, params, inputs, cap: int, *,
            ctx: ShardCtx = NULL_CTX, moe_impl: str = "dense", mesh=None,
            capacity_factor: float = 1.25, compute_dtype=jnp.bfloat16,
            cache_dtype=jnp.bfloat16, ssm_impl: str = "gspmd"):
    """Run the full prompt, build a decode cache with static capacity
    `cap` (absolute positions; includes meta tokens for global layers).
    Returns (last_logits (B,V), cache_tree, next_pos scalar)."""
    if cfg.family in (SSM,):
        return _prefill_recurrent(cfg, params, inputs, ctx=ctx,
                                  compute_dtype=compute_dtype, mesh=mesh,
                                  ssm_impl=ssm_impl)
    logits, _, kv_caches = forward(
        cfg, params, inputs, ctx=ctx, moe_impl=moe_impl, mesh=mesh,
        capacity_factor=capacity_factor, compute_dtype=compute_dtype,
        collect_cache=True)
    B = logits.shape[0]
    meta = cfg.meta_tokens
    S_in = inputs.shape[1]
    S_tot = S_in + meta
    segs = []
    for si, (seg, kv) in enumerate(zip(layer_plan(cfg), kv_caches)):
        k, v = kv["k"], kv["v"]             # (n, B, S_tot, K, hd)
        w = seg.window
        if w == 0:
            padlen = cap - S_tot
            k = jnp.pad(k, ((0, 0), (0, 0), (0, max(0, padlen)), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, max(0, padlen)), (0, 0), (0, 0)))
            c = {"k": k[:, :, :cap].astype(cache_dtype),
                 "v": v[:, :, :cap].astype(cache_dtype)}
        else:
            c = _ring_from_full(k, v, w, meta, S_tot, cache_dtype)
        if cfg.family == HYBRID:
            c["mamba"] = kv["mamba"]
        segs.append(c)
    return logits[:, -1], {"segments": segs}, S_tot


def _ring_from_full(k, v, w, meta, S_tot, cache_dtype):
    """Convert full (n,B,S,K,hd) kv into ring buffer of width w + meta
    cache, consistent with attention_decode's slot convention
    (slot = abs_pos % w)."""
    idx = jnp.arange(w)
    p_last = S_tot - 1
    # stored position for slot s: last value <= p_last congruent to s mod w
    stored = p_last - jnp.mod(p_last - idx, w)
    stored = jnp.clip(stored, 0, S_tot - 1)
    rk = jnp.take(k, stored, axis=2).astype(cache_dtype)
    rv = jnp.take(v, stored, axis=2).astype(cache_dtype)
    c = {"k": rk, "v": rv}
    if meta:
        c["mk"] = k[:, :, :meta].astype(cache_dtype)
        c["mv"] = v[:, :, :meta].astype(cache_dtype)
    return c


def _prefill_recurrent(cfg, params, inputs, *, ctx, compute_dtype,
                       mesh=None, ssm_impl: str = "gspmd"):
    """xLSTM prefill: run forward once per segment capturing final
    recurrent states. ssm_impl="seqpar" runs mLSTM segments sequence-
    parallel over the model axis (shard_map; see xlstm.py) — GSPMD
    cannot shard the chunk recurrence itself."""
    x = L.embed_tokens(params["embed"], inputs, compute_dtype)
    B, S, D = x.shape
    segs_cache = []
    seqpar = ssm_impl == "seqpar" and mesh is not None
    for seg, segp in zip(layer_plan(cfg), params["segments"]):
        if seg.kind == "mlstm":
            if seqpar:
                def body(xc, lp):
                    return xlstm_lib.apply_mlstm_block_seqpar(
                        cfg, lp, xc, mesh, batch_axes=_batch_axes(mesh),
                        want_state=True)
            else:
                def body(xc, lp):
                    from repro.models.xlstm import mlstm_block_states
                    xn, st = mlstm_block_states(cfg, lp, xc)
                    return xn, st
        else:
            def body(xc, lp):
                from repro.models.xlstm import slstm_block_states
                xn, st = slstm_block_states(cfg, lp, xc)
                return xn, st
        x, seg_states = jax.lax.scan(body, x, segp)
        segs_cache.append(seg_states)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], x)
    return logits[:, -1], {"segments": segs_cache}, S


def decode_step(cfg: ModelConfig, params, token, cache, pos, *,
                ctx: ShardCtx = NULL_CTX, moe_impl: str = "dense", mesh=None,
                capacity_factor: float = 1.25, compute_dtype=jnp.bfloat16):
    """One-token decode. token: (B,1) int (or (B,1,D) embeds); pos: scalar
    absolute position (incl. meta offset). Returns (logits (B,1,V),
    new_cache)."""
    if cfg.embedding_frontend:
        raise ValueError("encoder-only arch has no decode step")
    x = L.embed_tokens(params["embed"], token, compute_dtype)
    x = ctx(x, "batch", None, None)
    new_segs = []
    for seg, segp, segc in zip(layer_plan(cfg), params["segments"],
                               cache["segments"]):
        x, new_c = _segment_decode(cfg, seg, segp, segc, x, pos, ctx,
                                   moe_impl=moe_impl, mesh=mesh,
                                   capacity_factor=capacity_factor)
        new_segs.append(new_c)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], x)
    logits = ctx(logits, "batch", None, "vocab")
    return logits, {"segments": new_segs}
