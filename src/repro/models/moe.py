"""Mixture-of-Experts: top-k routing with capacity-based dispatch.

Two execution paths share the same parameters and routing math:

* `apply_moe_dense` — pure GSPMD: a (E, C, d) capacity-buffer einsum that
  XLA shards from parameter annotations. Simple, used as the
  paper-faithful baseline and for single-device tests.
* `apply_moe_ep`  — explicit GShard-style expert parallelism under
  `jax.shard_map`: per-device routing of a token slice, fixed-capacity
  all_to_all dispatch to expert shards, local expert einsum, all_to_all
  combine, all_gather over the model axis. This is the optimized path
  measured in EXPERIMENTS.md §Perf.

Experts are padded to a multiple of the EP shard count; padded experts
receive -inf router logits (never routed, zero weight).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import Spec

NEG_INF = -1e30


def padded_experts(cfg: ModelConfig, ep: int) -> int:
    e = cfg.moe.num_experts
    return ((e + ep - 1) // ep) * ep


def moe_spec(cfg: ModelConfig, ep: int):
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    E = padded_experts(cfg, ep)
    L = cfg.num_layers
    spec = {
        "router": Spec((d, E), (None, None)),
        "wg": Spec((E, d, f), ("experts", "fsdp", None)),
        "wu": Spec((E, d, f), ("experts", "fsdp", None)),
        "wd": Spec((E, f, d), ("experts", None, "fsdp"),
                   scale=1.0 / math.sqrt(2 * L)),
    }
    if m.num_shared_experts:
        fs = m.d_ff_shared
        spec.update({
            "shared_wg": Spec((d, fs), ("fsdp", "mlp")),
            "shared_wu": Spec((d, fs), ("fsdp", "mlp")),
            "shared_wd": Spec((fs, d), ("mlp", "fsdp"),
                              scale=1.0 / math.sqrt(2 * L)),
            "shared_gate": Spec((d, 1), (None, None)),
        })
    return spec


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------
def _route(cfg: ModelConfig, p, x2d):
    """x2d: (t, d) -> (weights (t,k), ids (t,k), aux_loss scalar)."""
    m = cfg.moe
    E = p["router"].shape[1]
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if E != m.num_experts:   # mask padded experts
        logits = jnp.where(jnp.arange(E) >= m.num_experts, NEG_INF, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)   # renormalize
    # Switch-style load-balance auxiliary loss over real experts.
    one_hot = jax.nn.one_hot(top_ids[:, 0], E, dtype=jnp.float32)
    frac_tokens = jnp.mean(one_hot, axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac_tokens * mean_probs)
    return top_w, top_ids, aux


def _dispatch_slots(ids, E: int, capacity: int):
    """Rank each (token, k) pair within its expert; drop beyond capacity.

    ids: (t, k) int. Returns (slot (t,k), keep (t,k) bool).
    """
    t, k = ids.shape
    flat = ids.reshape(-1)
    oneh = jax.nn.one_hot(flat, E, dtype=jnp.int32)          # (t*k, E)
    ranks = jnp.cumsum(oneh, axis=0) - oneh                  # exclusive
    slot = jnp.take_along_axis(ranks, flat[:, None], axis=1)[:, 0]
    keep = slot < capacity
    return slot.reshape(t, k), keep.reshape(t, k)


def _expert_ffn(cfg: ModelConfig, wg, wu, wd, xbuf):
    """xbuf: (E, C, d) -> (E, C, d). SwiGLU per expert."""
    dt = xbuf.dtype
    g = jnp.einsum("ecd,edf->ecf", xbuf, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xbuf, wu.astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))


def _shared_expert(cfg: ModelConfig, p, x2d):
    dt = x2d.dtype
    g = jnp.einsum("td,df->tf", x2d, p["shared_wg"].astype(dt))
    u = jnp.einsum("td,df->tf", x2d, p["shared_wu"].astype(dt))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tf,fd->td", h, p["shared_wd"].astype(dt))
    gate = jax.nn.sigmoid(
        jnp.einsum("td,dg->tg", x2d.astype(jnp.float32),
                   p["shared_gate"].astype(jnp.float32)))
    return y * gate.astype(dt)


# ---------------------------------------------------------------------------
# Dense (GSPMD-auto) path
# ---------------------------------------------------------------------------
def apply_moe_dense(cfg: ModelConfig, p, x, *, capacity_factor: float = 1.25):
    """x: (B,S,D) -> (y, aux_loss)."""
    B, S, D = x.shape
    m = cfg.moe
    E = p["router"].shape[1]
    x2d = x.reshape(-1, D)
    t = x2d.shape[0]
    top_w, top_ids, aux = _route(cfg, p, x2d)
    capacity = max(1, int(t * m.top_k / m.num_experts * capacity_factor))
    slot, keep = _dispatch_slots(top_ids, E, capacity)

    # scatter tokens into the (E, C, d) buffer
    xbuf = jnp.zeros((E, capacity, D), x.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], top_ids.shape)
    safe_slot = jnp.where(keep, slot, capacity - 1)
    upd = jnp.where(keep[..., None], x2d[tok_idx], 0).reshape(-1, D)
    xbuf = xbuf.at[top_ids.reshape(-1), safe_slot.reshape(-1)].add(
        upd, mode="drop")

    ybuf = _expert_ffn(cfg, p["wg"], p["wu"], p["wd"], xbuf)

    # gather back, weight, and sum over k
    y_pairs = ybuf[top_ids.reshape(-1), safe_slot.reshape(-1)].reshape(t, m.top_k, D)
    y_pairs = jnp.where(keep[..., None], y_pairs, 0)
    y = jnp.sum(y_pairs * top_w[..., None].astype(x.dtype), axis=1)
    if m.num_shared_experts:
        y = y + _shared_expert(cfg, p, x2d)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Explicit expert-parallel path (shard_map + all_to_all)
# ---------------------------------------------------------------------------
def apply_moe_ep(cfg: ModelConfig, p, x, mesh, *, capacity_factor: float = 1.25,
                 batch_axes=("data",), fsdp_axis: str = "data",
                 model_axis: str = "model"):
    """GShard-style EP. x: (B,S,D) sharded (batch over `batch_axes`,
    replicated over the model axis). Experts sharded over the model axis;
    expert weights additionally FSDP-sharded over `fsdp_axis` (gathered
    inside). Shared experts (qwen2) run outside the shard_map under plain
    GSPMD tensor parallelism.
    """
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, S, D = x.shape
    M = mesh.shape[model_axis]
    E = p["wg"].shape[0]
    E_loc = E // M

    def local_moe(x_loc, router_w, wg, wu, wd):
        # x_loc: (B_loc, S, D) replicated over the model axis
        midx = jax.lax.axis_index(model_axis)
        t_all = x_loc.shape[0] * x_loc.shape[1]
        x2d = x_loc.reshape(t_all, D)
        # pad the token axis so every model shard owns an equal slice
        t_m = max(1, -(-t_all // M))
        pad = t_m * M - t_all
        if pad:
            x2d = jnp.concatenate([x2d, jnp.zeros((pad, D), x2d.dtype)], 0)
        xm = jax.lax.dynamic_slice_in_dim(x2d, midx * t_m, t_m, 0)
        tok_valid = midx * t_m + jnp.arange(t_m) < t_all

        top_w, top_ids, aux = _route(cfg, {"router": router_w}, xm)
        # capacity per (expert, source shard)
        C = max(1, int(math.ceil(t_m * m.top_k / E * capacity_factor)))
        slot, keep = _dispatch_slots(top_ids, E, C)
        keep = keep & tok_valid[:, None]

        # build send buffer (E, C, D), grouped by destination shard
        sbuf = jnp.zeros((E, C, D), x_loc.dtype)
        safe_slot = jnp.where(keep, slot, C - 1)
        upd = jnp.where(keep[..., None], xm[jnp.broadcast_to(
            jnp.arange(t_m)[:, None], top_ids.shape)], 0).reshape(-1, D)
        sbuf = sbuf.at[top_ids.reshape(-1), safe_slot.reshape(-1)].add(
            upd, mode="drop")
        sbuf = sbuf.reshape(M, E_loc, C, D)
        rbuf = jax.lax.all_to_all(sbuf, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # rbuf: (M, E_loc, C, D) — rows destined to my local experts
        rbuf = rbuf.transpose(1, 0, 2, 3).reshape(E_loc, M * C, D)

        # FSDP gather of expert weights
        if fsdp_axis:
            wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)
        ybuf = _expert_ffn(cfg, wg, wu, wd, rbuf)

        ybuf = ybuf.reshape(E_loc, M, C, D).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(ybuf, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        back = back.reshape(E, C, D)

        y_pairs = back[top_ids.reshape(-1), safe_slot.reshape(-1)]
        y_pairs = jnp.where(keep.reshape(-1)[:, None], y_pairs, 0)
        y_pairs = y_pairs.reshape(t_m, m.top_k, D)
        ym = jnp.sum(y_pairs * top_w[..., None].astype(x_loc.dtype), axis=1)

        y = jax.lax.all_gather(ym, model_axis, axis=0, tiled=True)
        y = y[:t_all].reshape(x_loc.shape)
        aux = jax.lax.pmean(aux, model_axis)
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    batch_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
                   None, None)
    from repro.kernels._compat import shard_map
    fn = shard_map(
        local_moe, mesh=mesh,
        in_specs=(batch_spec,
                  P(None, None),                         # router replicated
                  P(model_axis, fsdp_axis, None),        # wg
                  P(model_axis, fsdp_axis, None),        # wu
                  P(model_axis, None, fsdp_axis)),       # wd
        out_specs=(batch_spec, P()))
    y, aux = fn(x, p["router"], p["wg"], p["wu"], p["wd"])

    if m.num_shared_experts:
        y = y + _shared_expert(cfg, p, x.reshape(-1, D)).reshape(B, S, D)
    return y, aux
