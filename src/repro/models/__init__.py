from repro.models.model import Model, build_model, input_specs  # noqa: F401
from repro.models.transformer import ShardCtx, NULL_CTX  # noqa: F401
