"""Mamba-2-style selective state-space (SSD) heads.

Used by hymba's parallel SSM path. Implements the chunkwise-parallel SSD
form (matmul-structured, TPU/MXU friendly) with a step function for
decode. `repro.kernels.ssd_scan` provides the Pallas version of the inner
chunk computation; `repro.kernels.ref` holds the sequential oracle.

Shapes: x (B, S, H, P) heads; B_mat/C_mat (B, S, N) shared across heads
(single group); dt (B, S, H); A (H,) negative scalars.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import Spec


def ssd_chunked(x, dt, A, Bm, Cm, D, *, chunk: int = 64,
                init_state=None, return_state: bool = False):
    """Chunkwise SSD scan.

    x: (B,S,H,P), dt: (B,S,H) (post-softplus), A: (H,) negative,
    Bm, Cm: (B,S,N), D: (H,) skip. Returns y (B,S,H,P) [, state (B,H,P,N)].
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        zx = jnp.zeros((Bsz, pad, H, P), x.dtype)
        x = jnp.concatenate([x, zx], 1)
        dt = jnp.concatenate([dt, jnp.zeros((Bsz, pad, H), dt.dtype)], 1)
        Bm = jnp.concatenate([Bm, jnp.zeros((Bsz, pad, N), Bm.dtype)], 1)
        Cm = jnp.concatenate([Cm, jnp.zeros((Bsz, pad, N), Cm.dtype)], 1)
    Sp = x.shape[1]
    n = Sp // Q

    f32 = jnp.float32
    xc = x.reshape(Bsz, n, Q, H, P)
    dtc = dt.reshape(Bsz, n, Q, H).astype(f32)
    Bc = Bm.reshape(Bsz, n, Q, N)
    Cc = Cm.reshape(Bsz, n, Q, N)

    dA = dtc * A.astype(f32)[None, None, None, :]          # (B,n,Q,H) log-decay
    cum = jnp.cumsum(dA, axis=2)                           # inclusive
    seg_end = cum[:, :, -1, :]                             # (B,n,H)

    # --- intra-chunk (quadratic within chunk) ---
    # L[i,j] = exp(cum_i - cum_j) * dt_j  for i >= j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,n,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    Lmat = Lmat * dtc[:, :, None, :, :]                    # decay * dt_j
    CB = jnp.einsum("bcis,bcjs->bcij",
                    Cc.astype(f32), Bc.astype(f32))        # (B,n,Q,Q)
    W = CB[..., None] * Lmat                               # (B,n,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xc.astype(f32))

    # --- chunk end-states ---
    # state_n = sum_j exp(seg_end - cum_j) dt_j * B_j (outer) x_j
    wj = jnp.exp(seg_end[:, :, None, :] - cum) * dtc       # (B,n,Q,H)
    states = jnp.einsum("bcjh,bcjs,bcjhp->bchps",
                        wj, Bc.astype(f32), xc.astype(f32))  # (B,n,H,P,N)

    # --- inter-chunk recurrence over n chunks ---
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), f32)
    else:
        init_state = init_state.astype(f32)

    def step(st, inp):
        seg_e, new_state = inp                             # (B,H), (B,H,P,N)
        out_prev = st                                      # state before chunk
        st = jnp.exp(seg_e)[:, :, None, None] * st + new_state
        return st, out_prev

    final_st, prev_states = jax.lax.scan(
        step, init_state,
        (seg_end.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,n,H,P,N)

    # --- inter-chunk contribution ---
    # y_inter_i = exp(cum_i) * C_i . prev_state
    y_inter = jnp.einsum("bcis,bchps->bcihp",
                         Cc.astype(f32), prev_states) * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(Bsz, Sp, H, P)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    y = y[:, :S].astype(x.dtype)
    if return_state:
        return y, final_st
    return y


def ssd_step(x, dt, A, Bm, Cm, D, state):
    """Single decode step. x: (B,H,P), dt: (B,H), Bm/Cm: (B,N),
    state: (B,H,P,N) -> (y (B,H,P), new_state)."""
    f32 = jnp.float32
    dA = (dt.astype(f32) * A.astype(f32)[None, :])         # (B,H)
    decay = jnp.exp(dA)[:, :, None, None]
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(f32), Bm.astype(f32),
                     x.astype(f32))
    new_state = decay * state.astype(f32) + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(f32), new_state)
    y = y + x.astype(f32) * D.astype(f32)[None, :, None]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba head-group layer (hymba SSM path)
# ---------------------------------------------------------------------------
def mamba_spec(cfg: ModelConfig):
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    H = max(1, di // 64)          # ssm heads of dim 64
    N = s.state_dim
    L = cfg.num_layers
    return {
        "w_in": Spec((d, 2 * di), ("fsdp", "mlp")),        # x path + gate
        "conv": Spec((s.conv_width, di), (None, "mlp"), "normal", 1.0),
        "w_bc": Spec((di, 2 * N), ("mlp", None)),
        "w_dt": Spec((di, H), ("mlp", None)),
        "dt_bias": Spec((H,), (None,), "zeros"),
        "A_log": Spec((H,), (None,), "zeros"),             # A = -exp(A_log)
        "D": Spec((H,), (None,), "ones"),
        "w_out": Spec((di, d), ("mlp", "fsdp"),
                      scale=1.0 / math.sqrt(2 * L)),
        "out_norm": Spec((di,), (None,), "ones"),
    }


def _causal_conv(x, w, cache=None):
    """x: (B,S,di); w: (W,di) depthwise. Returns (y, new_cache (B,W-1,di))."""
    W = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    new_cache = xp[:, -(W - 1):] if W > 1 else None
    # depthwise conv as W stacked shifts (W is tiny, e.g. 4)
    outs = 0
    S = x.shape[1]
    for i in range(W):
        outs = outs + xp[:, i:i + S, :] * w[i].astype(x.dtype)
    return outs, new_cache


def apply_mamba(cfg: ModelConfig, p, x, *, chunk: int = 64,
                return_cache: bool = False):
    """Full-sequence mamba head-group. x: (B,S,D) -> (B,S,D)
    [, decode cache {"conv","state"}]."""
    B, S, D = x.shape
    s = cfg.ssm
    di = s.expand * D
    dt_ = x.dtype
    u = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(dt_))
    xin_raw, z = jnp.split(u, 2, axis=-1)
    xin, _ = _causal_conv(xin_raw, p["conv"])
    xin = jax.nn.silu(xin)
    H = p["w_dt"].shape[1]
    P = di // H
    N = s.state_dim
    bc = jnp.einsum("bse,en->bsn", xin, p["w_bc"].astype(dt_))
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bse,eh->bsh", xin, p["w_dt"].astype(dt_)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(B, S, H, P)
    if return_cache:
        y, state = ssd_chunked(xh, dt, A, Bm, Cm, p["D"], chunk=chunk,
                               return_state=True)
    else:
        y = ssd_chunked(xh, dt, A, Bm, Cm, p["D"], chunk=chunk)
    y = y.reshape(B, S, di)
    # RMS out-norm then gate
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf ** 2, -1, keepdims=True) + 1e-6)
         * p["out_norm"].astype(jnp.float32)).astype(dt_)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_))
    if return_cache:
        W = s.conv_width
        cache = {"conv": xin_raw[:, -(W - 1):], "state": state}
        return out, cache
    return out


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = max(1, di // 64)
    P = di // H
    return {"conv": jnp.zeros((batch, s.conv_width - 1, di), dtype),
            "state": jnp.zeros((batch, H, P, s.state_dim), jnp.float32)}


def apply_mamba_step(cfg: ModelConfig, p, x, cache):
    """Decode step. x: (B,1,D) -> (y (B,1,D), new_cache)."""
    B, _, D = x.shape
    s = cfg.ssm
    di = s.expand * D
    dt_ = x.dtype
    u = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(dt_))
    xin, z = jnp.split(u, 2, axis=-1)
    xin, new_conv = _causal_conv(xin, p["conv"], cache=cache["conv"])
    xin = jax.nn.silu(xin)[:, 0]                            # (B,di)
    H = p["w_dt"].shape[1]
    P = di // H
    bc = jnp.einsum("be,en->bn", xin, p["w_bc"].astype(dt_))
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("be,eh->bh", xin, p["w_dt"].astype(dt_)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_state = ssd_step(xin.reshape(B, H, P), dt, A, Bm, Cm, p["D"],
                            cache["state"])
    y = y.reshape(B, 1, di)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf ** 2, -1, keepdims=True) + 1e-6)
         * p["out_norm"].astype(jnp.float32)).astype(dt_)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_))
    return out, {"conv": new_conv, "state": new_state}
