"""Core transformer layers: norms, RoPE, GQA attention (full / sliding
window with meta-token prefix / decode-against-cache), MLPs.

Conventions
-----------
* Activations: (batch, seq, d_model) or (batch, seq, heads, head_dim).
* Params are fp32; compute happens in `compute_dtype` (bf16 by default)
  with softmax/normalization in fp32.
* All functions are sharding-agnostic; the transformer applies
  with_sharding_constraint at block boundaries.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import Spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_spec(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": Spec((d,), (None,), "ones")}
    if cfg.norm == "layernorm":
        return {"scale": Spec((d,), (None,), "ones"),
                "bias": Spec((d,), (None,), "zeros")}
    if cfg.norm == "nonparam_ln":   # olmo: no learnable affine
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg: ModelConfig, params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm == "layernorm":
            y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps: float = 1e-6):
    """qk-norm: RMS-normalize over head_dim (chameleon / qwen3)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
MAX_HEAD_PAD_RATIO = 1.5


def padded_heads(cfg: ModelConfig, tp: int) -> int:
    """Query-head count padded *per KV group* so the head axis shards
    `tp`-ways while preserving the GQA head->kv mapping (head i uses kv
    head i // G_pad). Returns cfg.num_heads unchanged when no padding is
    needed or when padding would waste more than MAX_HEAD_PAD_RATIO
    (the sharding policy then replicates heads instead — see
    repro.distributed.sharding.mesh_rules)."""
    H, K = cfg.num_heads, cfg.num_kv_heads
    if tp <= 1 or H % tp == 0:
        return H
    g = H // K
    while (K * g) % tp:
        g += 1
    H_pad = K * g
    return H_pad if H_pad <= MAX_HEAD_PAD_RATIO * H else H


def head_mask(cfg: ModelConfig, H_pad: int, dtype):
    """(H_pad,) 1/0 mask of real vs padded q heads; None when unpadded."""
    if H_pad == cfg.num_heads:
        return None
    G_pad = H_pad // cfg.num_kv_heads
    G = cfg.num_heads // cfg.num_kv_heads
    return (jnp.arange(H_pad) % G_pad < G).astype(dtype)


def _mask_heads(cfg: ModelConfig, o):
    """Zero padded heads of o (..., H_pad, hd) so they contribute nothing
    to the output projection and receive no gradient."""
    m = head_mask(cfg, o.shape[-2], o.dtype)
    return o if m is None else o * m[..., :, None]


def attention_spec(cfg: ModelConfig, tp: int = 1):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = padded_heads(cfg, tp), cfg.num_kv_heads
    spec = {
        "wq": Spec((d, H, hd), ("fsdp", "heads", None)),
        "wk": Spec((d, K, hd), ("fsdp", "kv_heads", None)),
        "wv": Spec((d, K, hd), ("fsdp", "kv_heads", None)),
        "wo": Spec((H, hd, d), ("heads", None, "fsdp"), scale=1.0 / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qk_norm:
        spec["q_norm"] = Spec((hd,), (None,), "ones")
        spec["k_norm"] = Spec((hd,), (None,), "ones")
    return spec


def _qkv(cfg: ModelConfig, p, x, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if cfg.rope_theta and cfg.family != "encoder" and cfg.causal:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, H: int):
    """(B,T,K,hd) -> (B,T,H,hd) by repeating each KV head H//K times.
    Flat-head layout keeps the head axis cleanly shardable (a (K,G)
    reshape defeats GSPMD when K < the model-axis size)."""
    K = k.shape[2]
    if K == H:
        return k
    return jnp.repeat(k, H // K, axis=2)


def _gqa_scores(q, k):
    """q: (B,S,H,hd), k: (B,T,K,hd) -> scores (B,H,S,T) in fp32."""
    hd = q.shape[-1]
    kk = _repeat_kv(k, q.shape[2])
    s = jnp.einsum("bshd,bthd->bhst", q, kk).astype(jnp.float32)
    return s / math.sqrt(hd)


def _gqa_out(probs, v, out_dtype):
    """probs: (B,H,S,T) fp32; v: (B,T,K,hd) -> (B,S,H,hd)."""
    vv = _repeat_kv(v, probs.shape[1])
    o = jnp.einsum("bhst,bthd->bshd", probs.astype(vv.dtype), vv)
    return o.astype(out_dtype)


def attention_full(cfg: ModelConfig, p, x, positions, *, causal: bool,
                   q_chunk: int = 1024):
    """Full (possibly causal) attention, computed in sequential query
    chunks so peak memory is O(q_chunk * S) rather than O(S^2). Exact.
    x: (B,S,D).
    """
    q, k, v = _qkv(cfg, p, x, positions)
    B, S, H, hd = q.shape
    qc = min(q_chunk, S)
    pad = (-S) % qc          # pad queries only; keys stay length S, so
    if pad:                  # padded-query rows are garbage we slice off
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    n = Sp // qc
    scale = 1.0 / math.sqrt(hd)
    kk = _repeat_kv(k, H)
    vv = _repeat_kv(v, H)
    qr = q.reshape(B, n, qc, H, hd).transpose(1, 0, 2, 3, 4)
    t = jnp.arange(S)

    def body(_, xs):
        qi, ci = xs                                      # (B,qc,H,hd), scalar
        s = jnp.einsum("bahd,bthd->bhat", qi, kk).astype(jnp.float32) * scale
        if causal:
            q_abs = ci * qc + jnp.arange(qc)
            s = jnp.where((t[None, :] <= q_abs[:, None])[None, None],
                          s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhat,bthd->bahd", probs.astype(vv.dtype), vv)
        return None, o

    _, outs = jax.lax.scan(jax.checkpoint(body), None,
                           (qr, jnp.arange(n)))
    o = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, hd)[:, :S]
    o = o.astype(x.dtype)
    o = _mask_heads(cfg, o)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype)), (k, v)


def attention_windowed(cfg: ModelConfig, p, x, positions, *, window: int,
                       meta: int):
    """Exact sliding-window causal attention with an always-visible meta
    prefix, computed blockwise in O(S * (2*window + meta)).

    Visibility of key j from query i (i >= j):
      (i - j < window)  OR  (j < meta).
    """
    B, S, D = x.shape
    w = window
    q, k, v = _qkv(cfg, p, x, positions)
    H, hd = q.shape[2], q.shape[3]
    K = k.shape[2]
    G = H // K

    pad = (-S) % w
    Sp = S + pad
    n = Sp // w
    if pad:
        zq = jnp.zeros((B, pad, H, hd), q.dtype)
        zk = jnp.zeros((B, pad, K, hd), k.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zk], 1)
        v = jnp.concatenate([v, zk], 1)

    kf = _repeat_kv(k, H)                                # flat heads
    vf = _repeat_kv(v, H)
    qc = q.reshape(B, n, w, H, hd)
    kc = kf.reshape(B, n, w, H, hd)
    vc = vf.reshape(B, n, w, H, hd)
    # previous chunk (zero for chunk 0)
    kp = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], 1)
    vp = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], 1)
    kcat = jnp.concatenate([kp, kc], 2)                  # (B,n,2w,H,hd)
    vcat = jnp.concatenate([vp, vc], 2)

    scores = jnp.einsum("bnahd,bnchd->bnhac", qc, kcat).astype(jnp.float32)
    scores = scores / math.sqrt(hd)

    # mask: query abs pos = c*w + a; key abs pos = (c-1)*w + cidx
    a = jnp.arange(w)
    cidx = jnp.arange(2 * w)
    rel = a[:, None] + w - cidx[None, :]                 # i - j
    win_ok = (rel >= 0) & (rel < w)                      # (w, 2w)
    ci = jnp.arange(n)
    key_abs = (ci[:, None] - 1) * w + cidx[None, :]      # (n, 2w)
    valid_key = (key_abs >= 0) & (key_abs < S)           # excludes chunk-0 "prev"
    mask = win_ok[None] & valid_key[:, None, :]          # (n, w, 2w)
    scores = jnp.where(mask[None, :, None], scores, NEG_INF)

    if meta > 0:
        # meta block: keys [0, meta); visible from query abs i iff not
        # already covered by the windowed path: j <= i - w.
        km = kf[:, :meta]                                # (B,meta,H,hd)
        vm = vf[:, :meta]
        ms = jnp.einsum("bnahd,bmhd->bnham", qc, km).astype(jnp.float32)
        ms = ms / math.sqrt(hd)
        q_abs = ci[:, None] * w + a[None, :]             # (n, w)
        j = jnp.arange(meta)
        mmask = j[None, None, :] <= (q_abs[..., None] - w)
        ms = jnp.where(mmask[None, :, None], ms, NEG_INF)
        scores = jnp.concatenate([ms, scores], axis=-1)
        vcat = jnp.concatenate(
            [jnp.broadcast_to(vm[:, None], (B, n) + vm.shape[1:]), vcat], 2)

    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bnhac,bnchd->bnahd", probs.astype(vcat.dtype), vcat)
    o = o.reshape(B, Sp, H, hd)[:, :S].astype(x.dtype)
    o = _mask_heads(cfg, o)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype)), (k[:, :S], v[:, :S])


def attention_decode(cfg: ModelConfig, p, x, cache, pos, *, window: int,
                     meta: int):
    """Single-token decode. x: (B,1,D); pos: scalar absolute position of
    the new token. cache dict:
      full   : {"k","v": (B,cap,K,hd)}        — global layers
      sliding: {"k","v": (B,window,K,hd), "mk","mv": (B,meta,K,hd)}
    Returns (out (B,1,D), new_cache).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos)
    q, k, v = _qkv(cfg, p, x, positions)                 # k,v: (B,1,K,hd)
    new_cache = dict(cache)
    if window <= 0:
        cap = cache["k"].shape[1]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, 1)
        new_cache.update(k=ck, v=cv)
        t = jnp.arange(cap)
        key_mask = t <= pos
        kk, vv = ck, cv
    else:
        wcap = cache["k"].shape[1]
        slot = jnp.mod(pos, wcap)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
        new_cache.update(k=ck, v=cv)
        t = jnp.arange(wcap)
        # stored abs position in slot s: last value <= pos congruent to s
        stored = pos - jnp.mod(pos - t, wcap)
        key_mask = (stored >= meta) & (stored <= pos) & (stored > pos - wcap)
        kk, vv = ck, cv

    scores = _gqa_scores(q, kk)                          # (B,H,1,cap)
    scores = jnp.where(key_mask[None, None, None, :], scores, NEG_INF)
    if window > 0 and meta > 0:
        msc = _gqa_scores(q, cache["mk"])
        scores = jnp.concatenate([msc, scores], -1)
        vv = jnp.concatenate([cache["mv"], vv], 1)
    probs = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(probs, vv, x.dtype)
    o = _mask_heads(cfg, o)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype)), new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_spec(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {"w_gate": Spec((d, f), ("fsdp", "mlp")),
                "w_up": Spec((d, f), ("fsdp", "mlp")),
                "w_down": Spec((f, d), ("mlp", "fsdp"),
                               scale=1.0 / math.sqrt(2 * cfg.num_layers))}
    return {"w_in": Spec((d, f), ("fsdp", "mlp")),
            "w_down": Spec((f, d), ("mlp", "fsdp"),
                           scale=1.0 / math.sqrt(2 * cfg.num_layers))}


def apply_mlp(cfg: ModelConfig, p, x):
    dt = x.dtype
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(dt)))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def padded_vocab(cfg: ModelConfig) -> int:
    return ((cfg.vocab_size + 127) // 128) * 128


def embedding_spec(cfg: ModelConfig):
    V = padded_vocab(cfg)
    spec = {"table": Spec((V, cfg.d_model), ("vocab", "fsdp"), "embed")}
    if not cfg.tie_embeddings:
        spec["unembed"] = Spec((cfg.d_model, V), ("fsdp", "vocab"), "embed")
    return spec


def embed_tokens(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def unembed(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["table"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"].astype(x.dtype))
    V = padded_vocab(cfg)
    if V != cfg.vocab_size:   # mask padded vocab entries
        pad_mask = jnp.arange(V) >= cfg.vocab_size
        logits = jnp.where(pad_mask, NEG_INF, logits.astype(jnp.float32)).astype(logits.dtype)
    return logits
