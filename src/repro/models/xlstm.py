"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly recurrent) per arXiv:2405.04517.

The mLSTM chunkwise form is the TPU-efficient training path (matmul
structured); `repro.kernels.mlstm_scan` is its Pallas version and
`repro.kernels.ref.mlstm_recurrent` the sequential oracle.

Stabilization follows the paper: running log-max state m with
  m_t = max(logsig(f) + m_{t-1}, i_t)
  C_t = exp(logsig(f) + m_{t-1} - m_t) C_{t-1} + exp(i_t - m_t) v k^T
  h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import Spec

F32 = jnp.float32


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel
# ---------------------------------------------------------------------------
def mlstm_chunked(q, k, v, igate, fgate, *, chunk: int = 64,
                  init_state=None, return_state: bool = False):
    """q,k,v: (B,S,H,P); igate,fgate: (B,S,H) raw preactivations.
    Returns h (B,S,H,P) [, (C (B,H,P,P), n (B,H,P), m (B,H))].
    """
    B, S, H, P = q.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        z = jnp.zeros((B, pad, H, P), q.dtype)
        q = jnp.concatenate([q, z], 1)
        k = jnp.concatenate([k, z], 1)
        v = jnp.concatenate([v, z], 1)
        igate = jnp.concatenate(
            [igate, jnp.full((B, pad, H), -1e30, igate.dtype)], 1)
        fgate = jnp.concatenate(
            [fgate, jnp.zeros((B, pad, H), fgate.dtype)], 1)
    Sp = q.shape[1]
    n_ch = Sp // Q
    scale = 1.0 / math.sqrt(P)

    qc = (q * scale).reshape(B, n_ch, Q, H, P).astype(F32)
    kc = k.reshape(B, n_ch, Q, H, P).astype(F32)
    vc = v.reshape(B, n_ch, Q, H, P).astype(F32)
    ig = igate.reshape(B, n_ch, Q, H).astype(F32)
    lf = jax.nn.log_sigmoid(fgate.reshape(B, n_ch, Q, H).astype(F32))

    b = jnp.cumsum(lf, axis=2)                       # inclusive in-chunk decay
    b_last = b[:, :, -1, :]                          # (B,n,H)

    # ---- inter-chunk recurrence (sequential over chunks) ----
    # carry: C (B,H,P,P), n (B,H,P), m (B,H)
    if init_state is None:
        C0 = jnp.zeros((B, H, P, P), F32)
        n0 = jnp.zeros((B, H, P), F32)
        m0 = jnp.full((B, H), -jnp.inf, F32)
    else:
        C0, n0, m0 = (s.astype(F32) for s in init_state)

    # per-chunk summaries: log-weights of each in-chunk step toward the
    # chunk end: a_j = i_j + (b_last - b_j)
    a = ig + (b_last[:, :, None, :] - b)             # (B,n,Q,H)
    a_max = jnp.max(a, axis=2)                       # (B,n,H)

    def chunk_step(carry, xs):
        C, nvec, m = carry
        a_c, amax_c, blast_c, k_c, v_c = xs
        m_new = jnp.maximum(blast_c + m, amax_c)     # (B,H)
        w_old = jnp.exp(blast_c + m - m_new)         # decay of old state
        w_in = jnp.exp(a_c - m_new[:, None, :])      # (B,Q,H)
        C_new = w_old[:, :, None, None] * C + jnp.einsum(
            "bqh,bqhp,bqhr->bhpr", w_in, v_c, k_c)
        n_new = w_old[:, :, None] * nvec + jnp.einsum(
            "bqh,bqhp->bhp", w_in, k_c)
        return (C_new, n_new, m_new), (C, nvec, m)

    xs = (a.transpose(1, 0, 2, 3), a_max.transpose(1, 0, 2),
          b_last.transpose(1, 0, 2), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4))
    (Cf, nf, mf), (Cprev, nprev, mprev) = jax.lax.scan(
        chunk_step, (C0, n0, m0), xs)
    # per-chunk initial states, shape (n, B, ...) -> (B, n, ...)
    Cprev = Cprev.transpose(1, 0, 2, 3, 4)
    nprev = nprev.transpose(1, 0, 2, 3)
    mprev = mprev.transpose(1, 0, 2)

    # ---- intra-chunk + cross term ----
    # total log-weight for (i >= j): b_i - b_j + i_j; inter weight: b_i + m_prev
    d_intra = b[:, :, :, None, :] - b[:, :, None, :, :] + ig[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    d_intra = jnp.where(mask[None, None, :, :, None], d_intra, -jnp.inf)
    d_inter = b + mprev[:, :, None, :]               # (B,n,Q,H)
    m_loc = jnp.maximum(jnp.max(d_intra, axis=3), d_inter)  # (B,n,Q,H)
    m_loc = jnp.maximum(m_loc, -1e30)                # avoid -inf - -inf

    w_intra = jnp.exp(d_intra - m_loc[:, :, :, None, :])    # (B,n,Q,Q,H)
    w_inter = jnp.exp(d_inter - m_loc)                       # (B,n,Q,H)

    qk = jnp.einsum("bnihp,bnjhp->bnijh", qc, kc)            # (B,n,Q,Q,H)
    h_intra = jnp.einsum("bnijh,bnijh,bnjhp->bnihp", qk, w_intra, vc)
    h_inter = jnp.einsum("bnihr,bnhpr->bnihp", qc, Cprev) \
        * w_inter[..., None]
    h_num = h_intra + h_inter
    # denominator: n_t . q_t with the same stabilization
    nq_intra = jnp.einsum("bnijh,bnijh->bnih", qk, w_intra)
    nq_inter = jnp.einsum("bnihp,bnhp,bnih->bnih", qc, nprev, w_inter)
    nq = nq_intra + nq_inter
    denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m_loc))
    h = h_num / denom[..., None]

    h = h.reshape(B, Sp, H, P)[:, :S].astype(q.dtype)
    if return_state:
        return h, (Cf, nf, mf)
    return h


def mlstm_state_summary(k, v, igate, fgate, *, chunk: int = 64):
    """State-only pass: the (C, n, m) state a zero-initialized mLSTM
    reaches after consuming the sequence, plus the total log-decay
    b_total. This is the per-shard *summary* of the sequence-parallel
    formulation (half the math of mlstm_chunked: no intra-chunk output).

    k, v: (B, S, H, P); gates: (B, S, H). Returns ((C, n, m), b_total).
    """
    B, S, H, P = k.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        z = jnp.zeros((B, pad, H, P), k.dtype)
        k = jnp.concatenate([k, z], 1)
        v = jnp.concatenate([v, z], 1)
        igate = jnp.concatenate(
            [igate, jnp.full((B, pad, H), -1e30, igate.dtype)], 1)
        fgate = jnp.concatenate(
            [fgate, jnp.zeros((B, pad, H), fgate.dtype)], 1)
    Sp = k.shape[1]
    n_ch = Sp // Q
    kc = k.reshape(B, n_ch, Q, H, P).astype(F32)
    vc = v.reshape(B, n_ch, Q, H, P).astype(F32)
    ig = igate.reshape(B, n_ch, Q, H).astype(F32)
    lf = jax.nn.log_sigmoid(fgate.reshape(B, n_ch, Q, H).astype(F32))
    b = jnp.cumsum(lf, axis=2)
    b_last = b[:, :, -1, :]
    a = ig + (b_last[:, :, None, :] - b)
    a_max = jnp.max(a, axis=2)

    def chunk_step(carry, xs):
        C, nvec, m = carry
        a_c, amax_c, blast_c, k_c, v_c = xs
        m_new = jnp.maximum(blast_c + m, amax_c)
        w_old = jnp.exp(blast_c + m - m_new)
        w_in = jnp.exp(a_c - m_new[:, None, :])
        C_new = w_old[:, :, None, None] * C + jnp.einsum(
            "bqh,bqhp,bqhr->bhpr", w_in, v_c, k_c)
        n_new = w_old[:, :, None] * nvec + jnp.einsum(
            "bqh,bqhp->bhp", w_in, k_c)
        return (C_new, n_new, m_new), None

    init = (jnp.zeros((B, H, P, P), F32), jnp.zeros((B, H, P), F32),
            jnp.full((B, H), -jnp.inf, F32))
    xs = (a.transpose(1, 0, 2, 3), a_max.transpose(1, 0, 2),
          b_last.transpose(1, 0, 2), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4))
    (C, n, m), _ = jax.lax.scan(chunk_step, init, xs)
    return (C, n, m), jnp.sum(lf, axis=(1, 2))


def combine_mlstm_states(s1, b2, s2):
    """Sequential combine: state s1, then a segment with total decay
    b2 whose zero-init state is s2. All in the paper's log-max frame."""
    C1, n1, m1 = s1
    C2, n2, m2 = s2
    m_new = jnp.maximum(b2 + m1, m2)
    m_new = jnp.maximum(m_new, -1e30)            # both -inf: stay finite
    w1 = jnp.exp(b2 + m1 - m_new)
    w2 = jnp.exp(m2 - m_new)
    C = w1[..., None, None] * C1 + w2[..., None, None] * C2
    n = w1[..., None] * n1 + w2[..., None] * n2
    return (C, n, m_new)


def mlstm_step(q, k, v, igate, fgate, state):
    """Decode step. q,k,v: (B,H,P); gates (B,H); state (C,n,m)."""
    C, nvec, m = state
    scale = 1.0 / math.sqrt(q.shape[-1])
    qf = q.astype(F32) * scale
    kf, vf = k.astype(F32), v.astype(F32)
    lf = jax.nn.log_sigmoid(fgate.astype(F32))
    ig = igate.astype(F32)
    m_new = jnp.maximum(lf + m, ig)
    w_old = jnp.exp(lf + m - m_new)
    w_in = jnp.exp(ig - m_new)
    C_new = w_old[..., None, None] * C + w_in[..., None, None] * \
        jnp.einsum("bhp,bhr->bhpr", vf, kf)
    n_new = w_old[..., None] * nvec + w_in[..., None] * kf
    num = jnp.einsum("bhpr,bhr->bhp", C_new, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n_new, qf)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q.dtype)
    return h, (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM cell — strictly recurrent (block-diagonal per head)
# ---------------------------------------------------------------------------
def slstm_scan(x_gates, r_weights, H: int, init_state=None):
    """x_gates: (B,S,4,H,P) input-driven gate preactivations (i,f,z,o);
    r_weights: (4,H,P,P) recurrent block-diagonal weights.
    Returns h (B,S,H,P) [, state]."""
    B, S, _, Hh, P = x_gates.shape

    if init_state is None:
        h0 = jnp.zeros((B, Hh, P), F32)
        c0 = jnp.zeros((B, Hh, P), F32)
        n0 = jnp.zeros((B, Hh, P), F32)
        m0 = jnp.full((B, Hh, P), -jnp.inf, F32)
    else:
        h0, c0, n0, m0 = (s.astype(F32) for s in init_state)

    rw = r_weights.astype(F32)

    def step(carry, g):
        h, c, n, m = carry
        rec = jnp.einsum("bhp,ghpr->bghr", h, rw)     # (B,4,H,P)
        gi = g.astype(F32) + rec
        it, ft, zt, ot = gi[:, 0], gi[:, 1], gi[:, 2], gi[:, 3]
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(lf + m - m_new)
        c_new = f_p * c + i_p * jnp.tanh(zt)
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new, m_new), h_new

    (hf, cf, nf, mf), hs = jax.lax.scan(step, (h0, c0, n0, m0),
                                        x_gates.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3), (hf, cf, nf, mf)


# ---------------------------------------------------------------------------
# Sequence-parallel mLSTM block (shard_map over the model axis)
# ---------------------------------------------------------------------------
def apply_mlstm_block_seqpar(cfg: ModelConfig, p, x, mesh, *,
                             seq_axis: str = "model",
                             batch_axes=("data",), chunk: int = 64,
                             want_state: bool = False):
    """TPU-native sequence parallelism for the mLSTM block.

    GSPMD cannot shard the chunkwise scan's sequence dimension (it
    serializes the inter-chunk recurrence into per-chunk state
    all-reduces — measured 1 TB/device on prefill_32k, EXPERIMENTS.md
    §Perf). The explicit formulation: every device runs the block on its
    LOCAL sequence shard (projections/conv/gates are token-local; the
    causal conv takes a (W-1)-token halo from the left neighbour via
    ppermute), computes its (C, n, m, b_total) state summary, all-gathers
    the summaries (B x H x P x P — megabytes, once per layer), locally
    prefix-combines the shards before it, and finishes with the
    intra-chunk pass seeded by that prefix state.

    x: (B, S, D) sharded (batch over batch_axes, seq over seq_axis).
    Returns (out, final_state|None) — final_state on the LAST shard is
    the true full-sequence state (used by prefill).
    """
    from jax.sharding import PartitionSpec as PS
    from repro.models.layers import apply_norm

    M = mesh.shape[seq_axis]
    W = cfg.ssm.conv_width
    dt_ = x.dtype
    D = x.shape[-1]
    di = cfg.ssm.expand * D
    H = cfg.num_heads
    P_dim = di // H

    def local_block(x, p):
        midx = jax.lax.axis_index(seq_axis)
        xin = apply_norm(cfg, p["norm"], x)
        u = jnp.einsum("bsd,de->bse", xin, p["w_up"].astype(dt_))
        ux_raw, z = jnp.split(u, 2, axis=-1)
        # causal-conv halo: last W-1 tokens of the LEFT neighbour
        halo = jax.lax.ppermute(
            ux_raw[:, -(W - 1):],
            seq_axis, [(i, (i + 1) % M) for i in range(M)])
        halo = jnp.where(midx == 0, jnp.zeros_like(halo), halo)
        xp = jnp.concatenate([halo.astype(ux_raw.dtype), ux_raw], axis=1)
        S_loc = ux_raw.shape[1]
        conv = 0
        for i in range(W):
            conv = conv + xp[:, i:i + S_loc, :] * p["conv"][i].astype(dt_)
        ux = jax.nn.silu(conv)
        q = jnp.einsum("bse,ehp->bshp", ux, p["wq"].astype(dt_))
        k = jnp.einsum("bse,ehp->bshp", ux, p["wk"].astype(dt_))
        v = jnp.einsum("bse,ehp->bshp", ux, p["wv"].astype(dt_))
        gates = jnp.einsum("bse,egh->bsgh", ux, p["w_if"].astype(dt_)) \
            + p["b_if"].astype(dt_)
        ig, fg = gates[:, :, 0], gates[:, :, 1]

        # shard state summary -> all-gather -> local prefix combine
        (C, n, m), btot = mlstm_state_summary(k, v, ig, fg, chunk=chunk)
        Cs = jax.lax.all_gather(C, seq_axis)          # (M, B, H, P, P)
        ns = jax.lax.all_gather(n, seq_axis)
        ms = jax.lax.all_gather(m, seq_axis)
        bs = jax.lax.all_gather(btot, seq_axis)       # (M, B, H)

        B = x.shape[0]
        init = (jnp.zeros((B, H, P_dim, P_dim), F32),
                jnp.zeros((B, H, P_dim), F32),
                jnp.full((B, H), -jnp.inf, F32))

        def comb(carry, xs):
            idx, (C2, n2, m2, b2) = xs
            new = combine_mlstm_states(carry, b2, (C2, n2, m2))
            keep = idx < midx                          # strict prefix
            out = jax.tree.map(
                lambda a, b: jnp.where(keep, b, a), carry, new)
            return out, None

        prefix, _ = jax.lax.scan(
            comb, init, (jnp.arange(M), (Cs, ns, ms, bs)))

        h = mlstm_chunked(q, k, v, ig, fg, chunk=chunk,
                          init_state=prefix)
        h = h.reshape(B, S_loc, di)
        hf = h.astype(F32)
        h = (hf * jax.lax.rsqrt(jnp.mean(hf ** 2, -1, keepdims=True)
                                + 1e-6)
             * p["gn"].astype(F32)).astype(dt_)
        h = h * jax.nn.silu(z)
        out = x + jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(dt_))
        if not want_state:
            return out
        # full-sequence final state = prefix ++ my shard; only the last
        # shard's value is the true one — broadcast it with psum-mask
        # (C, n finite; m via pmax to respect a legitimate -inf)
        mine = combine_mlstm_states(prefix, btot, (C, n, m))
        is_last = (midx == M - 1).astype(F32)
        C_fin = jax.lax.psum(mine[0] * is_last, seq_axis)
        n_fin = jax.lax.psum(mine[1] * is_last, seq_axis)
        m_fin = jax.lax.pmax(
            jnp.where(midx == M - 1, mine[2], -jnp.inf), seq_axis)
        cache = {"C": C_fin, "n": n_fin, "m": m_fin,
                 "conv": jax.lax.all_gather(  # true last W-1 raw tokens
                     ux_raw[:, -(W - 1):], seq_axis)[-1]}
        return out, cache

    bspec = (batch_axes if len(batch_axes) > 1
             else (batch_axes[0] if batch_axes else None))
    x_spec = PS(bspec, seq_axis, None)
    p_specs = jax.tree.map(lambda _: PS(), p)
    out_specs = ((x_spec, PS(bspec)) if want_state else x_spec)
    if want_state:
        out_specs = (x_spec, {"C": PS(bspec), "n": PS(bspec),
                              "m": PS(bspec), "conv": PS(bspec)})
    from repro.kernels._compat import shard_map
    fn = shard_map(local_block, mesh=mesh,
                   in_specs=(x_spec, p_specs),
                   out_specs=out_specs)
    return fn(x, p)


# ---------------------------------------------------------------------------
# Block specs and applications
# ---------------------------------------------------------------------------
def mlstm_block_spec(cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    H = cfg.num_heads
    P = di // H
    L = cfg.num_layers
    return {
        "norm": {"scale": Spec((d,), (None,), "ones"),
                 "bias": Spec((d,), (None,), "zeros")},
        "w_up": Spec((d, 2 * di), ("fsdp", "mlp")),
        "conv": Spec((cfg.ssm.conv_width, di), (None, "mlp")),
        "wq": Spec((di, H, P), ("mlp", "heads", None)),
        "wk": Spec((di, H, P), ("mlp", "heads", None)),
        "wv": Spec((di, H, P), ("mlp", "heads", None)),
        "w_if": Spec((di, 2, H), ("mlp", None, None)),
        "b_if": Spec((2, H), (None, None), "zeros"),
        "gn": Spec((di,), (None,), "ones"),
        "w_down": Spec((di, d), ("mlp", "fsdp"), scale=1.0 / math.sqrt(2 * L)),
    }


def apply_mlstm_block(cfg: ModelConfig, p, x, *, chunk: int = 64,
                      cache=None):
    """Pre-LN mLSTM block. x: (B,S,D). cache: (C,n,m,conv) for decode."""
    from repro.models.layers import apply_norm
    from repro.models.ssm import _causal_conv

    B, S, D = x.shape
    dt = x.dtype
    di = cfg.ssm.expand * D
    H = cfg.num_heads
    P = di // H
    lncfg = cfg  # layernorm params live in p["norm"]
    xin = apply_norm(cfg, p["norm"], x)
    u = jnp.einsum("bsd,de->bse", xin, p["w_up"].astype(dt))
    ux, z = jnp.split(u, 2, axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    ux, new_conv = _causal_conv(ux, p["conv"], cache=conv_cache)
    ux = jax.nn.silu(ux)
    q = jnp.einsum("bse,ehp->bshp", ux, p["wq"].astype(dt))
    k = jnp.einsum("bse,ehp->bshp", ux, p["wk"].astype(dt))
    v = jnp.einsum("bse,ehp->bshp", ux, p["wv"].astype(dt))
    gates = jnp.einsum("bse,egh->bsgh", ux, p["w_if"].astype(dt)) \
        + p["b_if"].astype(dt)
    ig, fg = gates[:, :, 0], gates[:, :, 1]

    if cache is None:
        h = mlstm_chunked(q, k, v, ig, fg, chunk=chunk)
        new_state = None
    else:
        state = (cache["C"], cache["n"], cache["m"])
        h, new_state = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                  ig[:, 0], fg[:, 0], state)
        h = h[:, None]
    h = h.reshape(B, S, di)
    hf = h.astype(F32)
    h = (hf * jax.lax.rsqrt(jnp.mean(hf ** 2, -1, keepdims=True) + 1e-6)
         * p["gn"].astype(F32)).astype(dt)
    h = h * jax.nn.silu(z)
    out = x + jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(dt))
    if cache is None:
        return out, None
    return out, {"C": new_state[0], "n": new_state[1], "m": new_state[2],
                 "conv": new_conv}


def mlstm_block_states(cfg: ModelConfig, p, x, *, chunk: int = 64):
    """Full-sequence mLSTM block that also returns the decode cache."""
    from repro.models.layers import apply_norm
    from repro.models.ssm import _causal_conv

    B, S, D = x.shape
    dt = x.dtype
    di = cfg.ssm.expand * D
    H = cfg.num_heads
    xin = apply_norm(cfg, p["norm"], x)
    u = jnp.einsum("bsd,de->bse", xin, p["w_up"].astype(dt))
    ux_raw, z = jnp.split(u, 2, axis=-1)
    ux, _ = _causal_conv(ux_raw, p["conv"])
    ux = jax.nn.silu(ux)
    q = jnp.einsum("bse,ehp->bshp", ux, p["wq"].astype(dt))
    k = jnp.einsum("bse,ehp->bshp", ux, p["wk"].astype(dt))
    v = jnp.einsum("bse,ehp->bshp", ux, p["wv"].astype(dt))
    gates = jnp.einsum("bse,egh->bsgh", ux, p["w_if"].astype(dt)) \
        + p["b_if"].astype(dt)
    ig, fg = gates[:, :, 0], gates[:, :, 1]
    h, (Cf, nf, mf) = mlstm_chunked(q, k, v, ig, fg, chunk=chunk,
                                    return_state=True)
    h = h.reshape(B, S, di)
    hf = h.astype(F32)
    h = (hf * jax.lax.rsqrt(jnp.mean(hf ** 2, -1, keepdims=True) + 1e-6)
         * p["gn"].astype(F32)).astype(dt)
    h = h * jax.nn.silu(z)
    out = x + jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(dt))
    W = cfg.ssm.conv_width
    cache = {"C": Cf, "n": nf, "m": mf, "conv": ux_raw[:, -(W - 1):]}
    return out, cache


def slstm_block_states(cfg: ModelConfig, p, x):
    """Full-sequence sLSTM block that also returns the decode cache."""
    from repro.models.layers import apply_norm
    from repro.models.ssm import _causal_conv

    B, S, D = x.shape
    dt = x.dtype
    H = cfg.num_heads
    xin = apply_norm(cfg, p["norm"], x)
    xc_raw = xin
    xc, _ = _causal_conv(xc_raw, p["conv"])
    xc = jax.nn.silu(xc)
    g_if = jnp.einsum("bsd,dghp->bsghp", xc, p["w_gates"][:, :2].astype(dt))
    g_zo = jnp.einsum("bsd,dghp->bsghp", xin, p["w_gates"][:, 2:].astype(dt))
    gates = jnp.concatenate([g_if, g_zo], axis=2) + p["b_gates"].astype(dt)
    hs, (hf_, cf, nf, mf) = slstm_scan(gates, p["r_gates"], H)
    h = hs.reshape(B, S, D).astype(dt)
    hff = h.astype(F32)
    h = (hff * jax.lax.rsqrt(jnp.mean(hff ** 2, -1, keepdims=True) + 1e-6)
         * p["gn"].astype(F32)).astype(dt)
    x = x + h
    xin2 = apply_norm(cfg, p["norm"], x)
    g = jnp.einsum("bsd,df->bsf", xin2, p["ffn"]["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", xin2, p["ffn"]["w_up"].astype(dt))
    hh = jax.nn.silu(g) * u
    x = x + jnp.einsum("bsf,fd->bsd", hh, p["ffn"]["w_down"].astype(dt))
    W = cfg.ssm.conv_width
    cache = {"h": hf_, "c": cf, "n": nf, "m": mf,
             "conv": xc_raw[:, -(W - 1):]}
    return x, cache


def mlstm_init_cache(cfg: ModelConfig, batch: int, dtype):
    di = cfg.ssm.expand * cfg.d_model
    H = cfg.num_heads
    P = di // H
    return {"C": jnp.zeros((batch, H, P, P), F32),
            "n": jnp.zeros((batch, H, P), F32),
            "m": jnp.full((batch, H), -jnp.inf, F32),
            "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, di), dtype)}


def slstm_block_spec(cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    L = cfg.num_layers
    ff = int(4 * d * 2 / 3)
    ff = ((ff + 63) // 64) * 64
    return {
        "norm": {"scale": Spec((d,), (None,), "ones"),
                 "bias": Spec((d,), (None,), "zeros")},
        "conv": Spec((cfg.ssm.conv_width, d), (None, None)),
        "w_gates": Spec((d, 4, H, P), (None, None, "heads", None)),
        "r_gates": Spec((4, H, P, P), (None, "heads", None, None),
                        scale=0.5),
        "b_gates": Spec((4, H, P), (None, "heads", None), "zeros"),
        "gn": Spec((d,), (None,), "ones"),
        "ffn": {"w_gate": Spec((d, ff), ("fsdp", "mlp")),
                "w_up": Spec((d, ff), ("fsdp", "mlp")),
                "w_down": Spec((ff, d), ("mlp", "fsdp"),
                               scale=1.0 / math.sqrt(2 * L))},
    }


def apply_slstm_block(cfg: ModelConfig, p, x, *, cache=None):
    """Pre-LN sLSTM block + gated FFN. x: (B,S,D)."""
    from repro.models.layers import apply_norm
    from repro.models.ssm import _causal_conv

    B, S, D = x.shape
    dt = x.dtype
    H = cfg.num_heads
    P = D // H
    xin = apply_norm(cfg, p["norm"], x)
    conv_cache = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xin, p["conv"], cache=conv_cache)
    xc = jax.nn.silu(xc)
    # conv feeds i/f gates; raw input feeds z/o (per paper Fig. 10)
    g_if = jnp.einsum("bsd,dghp->bsghp", xc,
                      p["w_gates"][:, :2].astype(dt))
    g_zo = jnp.einsum("bsd,dghp->bsghp", xin, p["w_gates"][:, 2:].astype(dt))
    gates = jnp.concatenate([g_if, g_zo], axis=2) + p["b_gates"].astype(dt)

    if cache is None:
        h, _ = slstm_scan(gates, p["r_gates"], H)
        new_state = None
    else:
        state = (cache["h"], cache["c"], cache["n"], cache["m"])
        hs, new_state = slstm_scan(gates, p["r_gates"], H, init_state=state)
        h = hs
    h = h.reshape(B, S, D).astype(dt)
    hf = h.astype(F32)
    h = (hf * jax.lax.rsqrt(jnp.mean(hf ** 2, -1, keepdims=True) + 1e-6)
         * p["gn"].astype(F32)).astype(dt)
    x = x + h
    # gated FFN
    xin2 = apply_norm(cfg, p["norm"], x)
    g = jnp.einsum("bsd,df->bsf", xin2, p["ffn"]["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", xin2, p["ffn"]["w_up"].astype(dt))
    hh = jax.nn.silu(g) * u
    x = x + jnp.einsum("bsf,fd->bsd", hh, p["ffn"]["w_down"].astype(dt))
    if cache is None:
        return x, None
    return x, {"h": new_state[0], "c": new_state[1], "n": new_state[2],
               "m": new_state[3], "conv": new_conv}


def slstm_init_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    return {"h": jnp.zeros((batch, H, P), F32),
            "c": jnp.zeros((batch, H, P), F32),
            "n": jnp.zeros((batch, H, P), F32),
            "m": jnp.full((batch, H, P), -jnp.inf, F32),
            "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, d), dtype)}
