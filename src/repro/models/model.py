"""Public model API: one entry point per architecture family.

    model = build_model(cfg)
    params = model.init(key)
    logits, aux = model.apply(params, tokens)
    last, cache, pos = model.prefill(params, tokens, cap)
    logits, cache = model.decode(params, token, cache, pos)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES
from repro.models import transformer as T
from repro.models import param as P
from repro.models.transformer import NULL_CTX, ShardCtx


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    spec: Any
    ep: int = 1
    tp: int = 1

    # ---- parameters -------------------------------------------------------
    def init(self, key, dtype=jnp.float32):
        return P.init_params(self.spec, key, dtype)

    def abstract_params(self, mesh, rules, dtype=jnp.float32):
        return P.abstract_params(self.spec, mesh, rules, dtype)

    def param_shardings(self, mesh, rules):
        return P.shardings(self.spec, mesh, rules)

    def num_params(self) -> int:
        return P.param_count(self.spec)

    # ---- compute ----------------------------------------------------------
    def apply(self, params, inputs, *, ctx: ShardCtx = NULL_CTX, mesh=None,
              moe_impl: str = "dense", remat: str = "none",
              compute_dtype=jnp.bfloat16, capacity_factor: float = 1.25,
              ssm_impl: str = "gspmd"):
        logits, aux, _ = T.forward(
            self.cfg, params, inputs, ctx=ctx, mesh=mesh, moe_impl=moe_impl,
            remat=remat, compute_dtype=compute_dtype,
            capacity_factor=capacity_factor, ssm_impl=ssm_impl)
        return logits, aux

    def prefill(self, params, inputs, cap: int, *, ctx: ShardCtx = NULL_CTX,
                mesh=None, moe_impl: str = "dense",
                compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16,
                capacity_factor: float = 1.25, ssm_impl: str = "gspmd"):
        return T.prefill(self.cfg, params, inputs, cap, ctx=ctx, mesh=mesh,
                         moe_impl=moe_impl, compute_dtype=compute_dtype,
                         cache_dtype=cache_dtype,
                         capacity_factor=capacity_factor,
                         ssm_impl=ssm_impl)

    def decode(self, params, token, cache, pos, *, ctx: ShardCtx = NULL_CTX,
               mesh=None, moe_impl: str = "dense",
               compute_dtype=jnp.bfloat16, capacity_factor: float = 1.25):
        return T.decode_step(self.cfg, params, token, cache, pos, ctx=ctx,
                             mesh=mesh, moe_impl=moe_impl,
                             compute_dtype=compute_dtype,
                             capacity_factor=capacity_factor)

    # ---- cache ------------------------------------------------------------
    def cache_spec(self, batch: int, cap: int):
        return T.cache_spec(self.cfg, batch, cap)

    def init_cache(self, batch: int, cap: int, dtype=jnp.bfloat16):
        spec = self.cache_spec(batch, cap)
        return P.tree_map_specs(
            lambda s: (jnp.full(s.shape, -jnp.inf, jnp.float32)
                       if s.init == "neg_inf" else
                       jnp.zeros(s.shape, jnp.float32 if s.init == "neg_inf"
                                 else dtype)), spec)

    def abstract_cache(self, batch: int, cap: int, mesh, rules,
                       dtype=jnp.bfloat16):
        return P.abstract_params(self.cache_spec(batch, cap), mesh, rules,
                                 dtype)


def build_model(cfg: ModelConfig, *, ep: int = 1, tp: int = 1) -> Model:
    return Model(cfg=cfg, spec=T.build_spec(cfg, ep=ep, tp=tp), ep=ep, tp=tp)


# ---------------------------------------------------------------------------
# Input specs per (arch, shape) — ShapeDtypeStruct stand-ins for the dry-run
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape_name: str, mesh=None, rules=None):
    """Abstract inputs for a cell. For decode shapes this includes the
    cache tree. With mesh/rules, ShapeDtypeStructs carry NamedShardings."""
    from jax.sharding import NamedSharding
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len

    def struct(shp, dtype, axes):
        if mesh is None:
            return jax.ShapeDtypeStruct(shp, dtype)
        return jax.ShapeDtypeStruct(
            shp, dtype,
            sharding=NamedSharding(mesh, P.logical_to_pspec(axes, rules)))

    if shape.kind in ("train", "prefill"):
        if cfg.embedding_frontend:
            toks = struct((B, S, cfg.d_model), jnp.bfloat16,
                          ("batch", None, None))
        else:
            toks = struct((B, S), jnp.int32, ("batch", None))
        if shape.kind == "train":
            return {"inputs": toks,
                    "labels": struct((B, S), jnp.int32, ("batch", None))}
        return {"inputs": toks}
    # decode: one new token with a cache of S (absolute space incl. meta)
    cap = S + cfg.meta_tokens
    model = build_model(cfg, ep=(mesh.shape.get("model", 1) if mesh else 1))
    cache = (model.abstract_cache(B, cap, mesh, rules) if mesh is not None
             else P.tree_map_specs(
                 lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
                 model.cache_spec(B, cap)))
    return {"token": struct((B, 1), jnp.int32, ("batch", None)),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
