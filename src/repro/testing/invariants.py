"""Window-level fleet invariants — what must hold on EVERY window of
EVERY scenario, benign or hostile, under every framework.

The golden traces pin one fixed-seed trajectory per framework; this
module pins the *laws* those trajectories (and every other run) must
obey, so an adversarial scenario that wanders off the golden set still
cannot silently violate the planes' contracts:

  * transmission — each flow's delivered tokens fit its realized
    bandwidth (`delivered <= bw * W / bytes_per_token`), realized
    bandwidth respects the per-camera uplink caps, and the fleet total
    respects the shared bottleneck (up to GAIMD's additive-increase
    overshoot bound, see `_check_bandwidth`).
  * allocation — GPU shares are a distribution (sum to 1, each in
    [0, 1]) and reproduce Alg. 1 Line 15 exactly: proportional to the
    previous window's final positive gains with the estimate_shares
    new-job fill rule (uniform for the patched no-coordination
    baselines).
  * grouping — no stream sits in two groups, memberships match the
    live jobs list, and every membership change is explained by this
    window's join/new/evict events (frameworks that patch the grouper
    must instead keep memberships frozen).
  * residency — detector / signature-index / transmission-plane rows
    never outlive their stream; JobBank slots match live jobs (after
    draining the deferred-free queue); ServingStore rows match live
    groups.

`InvariantChecker` is stateful per run: `before_window` snapshots the
previous window's gains/groups (what the laws are relative to),
`after_window` asserts. The trace runner (repro.testing.trace.
run_scenario) drives it on every window by default; benchmarks opt out
via `invariants=False`.

Adding a new invariant: write a `_check_*(self, ctl, wm, events)`
method that calls `self._fail(msg)` on violation, and append it to
`_CHECKS` — docs/scenarios.md ("Hostile scenarios") documents the
catalogue.
"""
from __future__ import annotations

import gc
from typing import Dict, Iterable, List, Optional, Set


class InvariantViolation(AssertionError):
    """A window broke a fleet-plane contract (see module docstring)."""


def _patched(obj, name: str) -> bool:
    """True when `name` is instance-patched (the baseline controllers
    overwrite grouper/allocator methods with lambdas per window)."""
    return name in getattr(obj, "__dict__", {})


def expected_shares(job_ids: List[str], prev_gains: Dict[str, float],
                    *, uniform: bool) -> Dict[str, float]:
    """The p_j distribution Alg. 1 Line 15 must have produced for
    `job_ids` given the previous window's final gains — the
    ECCOAllocator.estimate_shares contract re-derived independently
    (new jobs fill at the mean positive known gain; an all-nonpositive
    fleet falls to uniform). `uniform=True` is the patched-baseline
    contract (equal shares regardless of gains)."""
    n = len(job_ids)
    if n == 0:
        return {}
    if uniform:
        return {j: 1.0 / n for j in job_ids}
    known = {j: prev_gains[j] for j in job_ids if j in prev_gains}
    pos_known = [v for v in known.values() if v > 0]
    if pos_known:
        fill = sum(pos_known) / len(pos_known)
        gains = {j: known.get(j, fill) for j in job_ids}
    else:
        gains = {j: 0.0 for j in job_ids}
    pos = {j: max(g, 0.0) for j, g in gains.items()}
    tot = sum(pos.values())
    if tot <= 0:
        return {j: 1.0 / n for j in job_ids}
    return {j: v / tot for j, v in pos.items()}


class InvariantChecker:
    """Asserts the window-level fleet invariants around each
    `run_window` call.

    `bank_exact`: when the controller's engines are exclusive to this
    run, JobBank live-slot counts must EQUAL the live job count after
    draining the deferred-free queue. A shared engine (golden fixture,
    benchmark loops) may carry slots of a previous run's still-
    referenced jobs, so the check relaxes to "the stranger-slot count
    never grows during this run".
    """

    def __init__(self, *, bank_exact: bool = True, label: str = ""):
        self.bank_exact = bank_exact
        self.label = label
        self.windows_checked = 0
        self._prev_gains: Dict[str, float] = {}
        self._prev_groups: Dict[str, str] = {}
        self._churned: Set[str] = set()
        self._bank_extra: Dict[int, int] = {}

    # -- driver hooks --------------------------------------------------
    def before_window(self, ctl, churned_ids: Iterable[str] = ()):
        """Snapshot the pre-window state the laws are relative to. Call
        AFTER applying churn/bandwidth events, BEFORE run_window."""
        self._prev_gains = dict(getattr(ctl.allocator, "last_gains",
                                        None) or {})
        self._prev_groups = {m.stream_id: j.job_id
                             for j in ctl.jobs for m in j.members}
        self._churned = set(churned_ids)

    def after_window(self, ctl, wm, events: Optional[List[dict]] = None):
        """Assert every invariant against the window's outcome.
        `events` is the slice of `ctl.grouper.events` appended during
        this window (None skips event-correspondence)."""
        self._wm = wm
        for check in self._CHECKS:
            check(self, ctl, wm, events)
        self.windows_checked += 1

    def _fail(self, msg: str):
        where = f"{self.label}: " if self.label else ""
        raise InvariantViolation(
            f"{where}window {self.windows_checked} "
            f"(t={getattr(self._wm, 't', '?')}): {msg}")

    # -- transmission (§3.2 / GAIMD) -----------------------------------
    def _check_bandwidth(self, ctl, wm, events):
        cc = ctl.cc
        w, bpt = cc.window_seconds, cc.bytes_per_token
        caps = cc.local_caps or {}
        tol = 1e-6
        extra = set(wm.delivered) - set(wm.bandwidth)
        if extra:
            self._fail(f"delivered tokens for flows with no bandwidth "
                       f"allocation: {sorted(extra)}")
        for sid, bw in wm.bandwidth.items():
            if bw < -tol:
                self._fail(f"negative bandwidth {bw} for {sid}")
            cap = caps.get(sid)
            if cap is not None and bw > cap * (1 + tol) + tol:
                self._fail(f"flow {sid} bandwidth {bw} exceeds local "
                           f"cap {cap}")
            d = wm.delivered.get(sid, 0)
            if d > bw * w / bpt + tol:
                self._fail(f"flow {sid} delivered {d} tokens > "
                           f"bw*W/T = {bw * w / bpt}")
        if wm.bandwidth:
            # the AIMD sawtooth's recorded rates can transiently exceed
            # the bottleneck by at most the fleet's summed additive
            # increase before the multiplicative decrease bites: the
            # recorded per-step sum never exceeds max(C, sum(alpha))
            # (fixpoint of s -> max(C, beta*(s + sum_alpha)), beta=0.5),
            # so the window's time-averaged sum is bounded by it too.
            # ecco mode: alpha_i = p_j/n_j, summing to sum_j p_j <= 1;
            # equal mode: alpha_i = 1 per flow.
            sum_alpha = (len(wm.bandwidth)
                         if ctl.bandwidth_mode == "equal"
                         else sum(wm.shares.values()))
            bound = max(cc.shared_bandwidth, sum_alpha)
            total = sum(wm.bandwidth.values())
            if total > bound * (1 + tol) + tol:
                self._fail(f"fleet bandwidth {total} exceeds shared "
                           f"bound {bound} "
                           f"(C={cc.shared_bandwidth}, "
                           f"sum_alpha={sum_alpha})")

    # -- GPU shares (Alg. 1 Line 15) -----------------------------------
    def _check_shares(self, ctl, wm, events):
        if not wm.shares:
            return
        tol = 1e-6
        total = sum(wm.shares.values())
        if abs(total - 1.0) > tol:
            self._fail(f"GPU shares sum to {total}, not 1")
        for jid, p in wm.shares.items():
            if p < -tol or p > 1 + tol:
                self._fail(f"share {p} for {jid} outside [0, 1]")
        want = expected_shares(
            list(wm.shares), self._prev_gains,
            uniform=_patched(ctl.allocator, "estimate_shares"))
        for jid, p in wm.shares.items():
            if abs(p - want[jid]) > 1e-8:
                self._fail(
                    f"share for {jid} is {p}, expected {want[jid]} "
                    f"from last window's final gains "
                    f"(gain-proportionality, Alg. 1 Line 15)")

    # -- grouping (Alg. 2) ---------------------------------------------
    def _check_groups(self, ctl, wm, events):
        live = {s.stream_id for s in ctl.streams}
        cur: Dict[str, str] = {}
        for jid, members in wm.groups.items():
            for sid in members:
                if sid in cur:
                    self._fail(f"stream {sid} is a member of both "
                               f"{cur[sid]} and {jid}")
                cur[sid] = jid
        stale = set(cur) - live
        if stale:
            self._fail(f"grouped streams not in the fleet: "
                       f"{sorted(stale)}")
        jobs_now = {j.job_id: [m.stream_id for m in j.members]
                    for j in ctl.jobs}
        if jobs_now != wm.groups:
            self._fail(f"wm.groups disagrees with live jobs: "
                       f"{wm.groups} vs {jobs_now}")
        # a previously grouped stream that survived the window must
        # still be grouped somewhere — eviction requeues and regroups
        # in the same update_grouping pass, it never orphans
        dropped = set(self._prev_groups) - set(cur) - self._churned
        if dropped & live:
            self._fail(f"grouped streams lost their group with no "
                       f"churn: {sorted(dropped & live)}")
        if events is None:
            return
        if _patched(ctl.grouper, "group_request") \
                or _patched(ctl.grouper, "update_grouping"):
            # no-grouping baselines: memberships are frozen (their
            # patched update_grouping is a no-op), so any change short
            # of churn is a violation
            for sid, jid in self._prev_groups.items():
                if sid in cur and cur[sid] != jid:
                    self._fail(f"baseline regrouped {sid}: "
                               f"{jid} -> {cur[sid]}")
            return
        joins = {}
        evicts = []
        for e in events:
            if e["kind"] in ("join", "new"):
                joins[e["stream"]] = e["job"]
            elif e["kind"] == "evict":
                evicts.append((e["stream"], e["job"]))
        for sid, jid in cur.items():
            if sid in joins:
                if joins[sid] != jid:
                    self._fail(f"{sid} last joined {joins[sid]} but "
                               f"ended the window in {jid}")
            elif self._prev_groups.get(sid) != jid:
                self._fail(f"{sid} moved "
                           f"{self._prev_groups.get(sid)} -> {jid} "
                           f"with no join/new event")
        for sid, jid in evicts:
            if sid not in live:
                continue
            if cur.get(sid) is None:
                self._fail(f"evicted stream {sid} was not regrouped")
            if cur.get(sid) == jid:
                self._fail(f"{sid} evicted from {jid} yet still a "
                           f"member (Alg. 2 excludes the evicting "
                           f"job from the requeue)")

    # -- plane row residency -------------------------------------------
    def _check_plane_rows(self, ctl, wm, events):
        live = {s.stream_id for s in ctl.streams}
        det = set(ctl.fleet.stream_ids)
        if det != live:
            self._fail(f"drift-detector rows {sorted(det)} != live "
                       f"fleet {sorted(live)}")
        tx = set(ctl.tx_plane.flow_ids)
        if not tx <= live:
            self._fail(f"transmission rows outlive their streams: "
                       f"{sorted(tx - live)}")
        sig = set(ctl.sig_index.state_dict()["row"])
        if not sig <= live:
            self._fail(f"signature-index rows outlive their streams: "
                       f"{sorted(sig - live)}")
        pending = set(ctl.request_time)
        if not pending <= live:
            self._fail(f"pending-request clocks outlive their "
                       f"streams: {sorted(pending - live)}")

    # -- bank / serving-store residency --------------------------------
    def _check_bank(self, ctl, wm, events):
        banks: Dict[int, object] = {}
        jobs_on: Dict[int, int] = {}
        for eng in [ctl.engine] + [getattr(j, "engine", ctl.engine)
                                   for j in ctl.jobs]:
            bank = getattr(eng, "bank", None)
            if bank is not None:
                banks[id(bank)] = bank
                jobs_on.setdefault(id(bank), 0)
        for j in ctl.jobs:
            bank = getattr(getattr(j, "engine", ctl.engine), "bank",
                           None)
            if bank is not None:
                jobs_on[id(bank)] += 1
        # dead jobs queue their slot frees from GC finalizers (cyclic
        # garbage needs a collect) and the bank frees lazily at the
        # next safe point — drain both before counting
        gc.collect()
        for key, bank in banks.items():
            bank.compact()
            extra = len(bank) - jobs_on[key]
            if extra < 0:
                self._fail(f"JobBank holds {len(bank)} live slots for "
                           f"{jobs_on[key]} live jobs")
            if self.bank_exact and extra:
                self._fail(f"JobBank leaked {extra} slots beyond the "
                           f"{jobs_on[key]} live jobs")
            seen = self._bank_extra.setdefault(key, extra)
            if extra > seen:
                self._fail(f"JobBank stranger-slot count grew "
                           f"{seen} -> {extra} during the run "
                           f"(slot leak)")
            self._bank_extra[key] = min(seen, extra)

    def _check_serving(self, ctl, wm, events):
        sp = getattr(ctl, "serve_plane", None)
        if sp is None:
            return
        store = set(sp.store.group_ids)
        live = {j.job_id for j in ctl.jobs}
        if not store <= live:
            self._fail(f"ServingStore rows for dead groups: "
                       f"{sorted(store - live)}")

    _CHECKS = (_check_bandwidth, _check_shares, _check_groups,
               _check_plane_rows, _check_bank, _check_serving)
