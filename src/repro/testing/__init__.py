"""Testing utilities: scenario runners and golden-trace regression
harness (repro.testing.trace)."""
