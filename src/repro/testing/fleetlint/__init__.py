"""fleetlint: contract-enforcing static analysis + runtime sanitizer
for the five planes (docs/static_analysis.md).

    python -m repro.testing.fleetlint src benchmarks examples
"""
from repro.testing.fleetlint.engine import (Finding, Module, Pragma, Rule,
                                            check_module, load_module,
                                            module_from_source, run)
from repro.testing.fleetlint.rules import default_rules

__all__ = ["Finding", "Module", "Pragma", "Rule", "check_module",
           "load_module", "module_from_source", "run", "default_rules"]
