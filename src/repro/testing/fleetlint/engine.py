"""fleetlint rule engine: per-file AST walks with pragma suppression.

The five planes are held to contracts that used to exist only as prose
(docs/training_plane.md, docs/transmission_plane.md, ROADMAP.md
conventions).  fleetlint turns each contract into a `Rule` that walks a
module's AST and yields `Finding`s; the engine handles file discovery,
pragma parsing, suppression, and JSON/human reporting, so rules stay
pure functions of the parsed module.

Pragma syntax (one per comment)::

    x = bank.params_stack()  # fleetlint: disable=borrowed-stack -- reason
    # fleetlint: disable=host-sync -- reason      (applies to next line)
    # fleetlint: disable-file=determinism -- reason (whole file)

The justification text after ``--`` (or an em dash) is REQUIRED — a
pragma without one is itself a finding (the `pragma-reason` meta rule),
so every suppression in the tree documents which side of the contract
makes it legal.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str
    path: str          # posix-style path as given on the command line
    line: int          # 1-based
    col: int           # 0-based (ast convention)
    message: str

    def human(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}"

    def as_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Pragma:
    """One parsed `# fleetlint:` comment."""
    line: int                  # line the comment sits on
    target: int                # line the suppression applies to
    rules: tuple               # rule names it disables ("*" = all)
    file_level: bool           # disable-file= form
    reason: str                # justification text ("" = missing)


_PRAGMA_RE = re.compile(
    r"#\s*fleetlint:\s*(disable(?:-file)?)\s*=\s*"
    r"([A-Za-z0-9_*,\- ]+?)\s*(?:(?:--|—|–)\s*(.*))?$")


def parse_pragmas(source: str) -> List[Pragma]:
    """All fleetlint pragmas in `source`.

    A pragma trailing a code line suppresses that line; a standalone
    comment pragma suppresses the next CODE line (blank lines and the
    justification's continuation comments may sit in between)."""
    lines = source.splitlines()

    def target_of(comment_line: int) -> int:
        before = lines[comment_line - 1].split("#", 1)[0]
        if before.strip():
            return comment_line            # trails code: its own line
        for i in range(comment_line, len(lines)):
            s = lines[i].strip()
            if s and not s.startswith("#"):
                return i + 1               # next code line (1-based)
        return comment_line

    out: List[Pragma] = []
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _PRAGMA_RE.search(tok.string)
            if not m:
                continue
            rules = tuple(r.strip() for r in m.group(2).split(",")
                          if r.strip())
            out.append(Pragma(line=tok.start[0],
                              target=target_of(tok.start[0]),
                              rules=rules,
                              file_level=m.group(1) == "disable-file",
                              reason=(m.group(3) or "").strip()))
    except tokenize.TokenError:
        pass
    return out


@dataclasses.dataclass
class Module:
    """Everything a rule gets to look at for one file."""
    path: str                  # as reported in findings
    rel: str                   # posix path relative to the scan root
    source: str
    tree: ast.Module
    pragmas: List[Pragma]

    @property
    def lines(self) -> List[str]:
        return self.source.splitlines()


class Rule:
    """Protocol for a lint rule.

    Subclasses set `name` (the pragma token) and `contract` (one line:
    which plane contract this encodes, with the doc that states it) and
    implement `check(module) -> Iterator[Finding]`.  Rules must not
    mutate the module.
    """
    name: str = ""
    contract: str = ""

    def check(self, module: Module) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, module: Module, node: ast.AST, message: str) -> Finding:
        return Finding(rule=self.name, path=module.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), message=message)


def load_module(path: Path, report_path: Optional[str] = None,
                rel: Optional[str] = None) -> Optional[Module]:
    """Parse one file; returns None for files that do not parse (the
    tier-1 suite owns syntax errors — a linter crash would mask them)."""
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    return Module(path=report_path or str(path),
                  rel=rel if rel is not None else path.as_posix(),
                  source=source, tree=tree, pragmas=parse_pragmas(source))


def module_from_source(source: str, rel: str) -> Module:
    """A Module for an in-memory snippet (the fixture tests)."""
    return Module(path=rel, rel=rel, source=source,
                  tree=ast.parse(source), pragmas=parse_pragmas(source))


def _suppressed(finding: Finding, pragmas: Sequence[Pragma]) -> bool:
    for p in pragmas:
        if finding.rule not in p.rules and "*" not in p.rules:
            continue
        if p.file_level:
            return True
        # trailing comment: its own line; standalone: the next code line
        if finding.line in (p.line, p.target):
            return True
    return False


def check_module(module: Module, rules: Sequence[Rule]) -> List[Finding]:
    """All unsuppressed findings for one module, source order."""
    out: List[Finding] = []
    for rule in rules:
        for f in rule.check(module):
            if not _suppressed(f, module.pragmas):
                out.append(f)
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    return out


def iter_python_files(paths: Iterable[str]) -> Iterator[Path]:
    for p in paths:
        root = Path(p)
        if root.is_file() and root.suffix == ".py":
            yield root
        elif root.is_dir():
            yield from sorted(root.rglob("*.py"))


def run(paths: Sequence[str], rules: Sequence[Rule]) -> List[Finding]:
    """Lint every .py file under `paths` with `rules`."""
    findings: List[Finding] = []
    for path in iter_python_files(paths):
        mod = load_module(path, report_path=path.as_posix(),
                          rel=path.as_posix())
        if mod is None:
            continue
        findings.extend(check_module(mod, rules))
    return findings


def report_human(findings: Sequence[Finding], rules: Sequence[Rule],
                 n_files: int) -> str:
    lines = [f.human() for f in findings]
    lines.append(f"fleetlint: {len(findings)} finding(s) in {n_files} "
                 f"file(s), {len(rules)} rule(s) active")
    return "\n".join(lines)


def report_json(findings: Sequence[Finding], rules: Sequence[Rule],
                n_files: int) -> str:
    return json.dumps({
        "findings": [f.as_json() for f in findings],
        "rules": [{"name": r.name, "contract": r.contract} for r in rules],
        "files_checked": n_files,
        "clean": not findings,
    }, indent=1)
