"""CLI: `python -m repro.testing.fleetlint [--check] [--json FILE] PATHS`.

Exit codes: 0 clean, 1 findings, 2 usage error.  `--check` is the CI
spelling (identical semantics, named for intent); `--json FILE` writes
the machine-readable report the CI lint job uploads as an artifact.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.testing.fleetlint.engine import (check_module, iter_python_files,
                                            load_module, report_human,
                                            report_json)
from repro.testing.fleetlint.rules import default_rules


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.testing.fleetlint",
        description="contract-enforcing static analysis for the five "
                    "planes (docs/static_analysis.md)")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to lint")
    ap.add_argument("--check", action="store_true",
                    help="CI mode (same semantics; exit 1 on findings)")
    ap.add_argument("--json", metavar="FILE",
                    help="write the JSON report to FILE ('-' = stdout)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    rules = default_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.name}: {r.contract}")
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        print("fleetlint: no paths given", file=sys.stderr)
        return 2

    findings, n_files = [], 0
    for path in iter_python_files(args.paths):
        mod = load_module(path, report_path=path.as_posix(),
                          rel=path.as_posix())
        if mod is None:
            continue
        n_files += 1
        findings.extend(check_module(mod, rules))

    if args.json:
        payload = report_json(findings, rules, n_files)
        if args.json == "-":
            print(payload)
        else:
            Path(args.json).write_text(payload + "\n")
    print(report_human(findings, rules, n_files))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
