"""The fleetlint rule set: one rule per plane contract.

Each rule encodes a convention the plane docs state in prose (the
`contract` attribute names the doc).  Rules are deliberately
approximate in the direction of FEW false positives: a miss costs a
review comment, a false positive costs a pragma — so every heuristic
here errs toward silence and the runtime sanitizer
(repro.testing.fleetlint.runtime) backstops the static gaps.

Path scoping uses substring/endswith matches on the scanned path so the
rules work both on the real tree (``src/repro/core/trainer.py``) and on
the fixture snippets the tests feed in under synthetic paths.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.testing.fleetlint.engine import Finding, Module, Rule

# -- small AST helpers -------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _mentions(node: ast.AST, names: Set[str]) -> bool:
    return any(isinstance(n, ast.Name) and n.id in names
               for n in ast.walk(node))


def _functions(tree: ast.Module) -> Iterator[Tuple[ast.AST, Optional[str]]]:
    """(function node, enclosing class name) for every def in the file."""
    def visit(node: ast.AST, cls: Optional[str]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from visit(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield child, cls
                yield from visit(child, cls)
            else:
                yield from visit(child, cls)
    yield from visit(tree, None)


_LOOPS = (ast.For, ast.ListComp, ast.SetComp, ast.GeneratorExp, ast.DictComp)


# -- rule 1: borrowed-stack --------------------------------------------------

class BorrowedStackRule(Rule):
    """`params_stack()` / `params_stack_compute()` results are BORROWED:
    valid only until the next bank write/scatter/compaction (the
    resident buffers are donated to the update kernels), so they may
    not be stored on an attribute or escape the function that captured
    them.  `snapshot_params` / `gather` / `row_device` return committed
    copies and are the escape hatch."""

    name = "borrowed-stack"
    contract = "docs/training_plane.md: params_stack() is borrowed; " \
               "capture right before the fleet call, never cache"

    _BORROW = {"params_stack", "params_stack_compute"}

    def _is_borrow_call(self, node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in self._BORROW)

    def check(self, module: Module) -> Iterator[Finding]:
        for fn, _cls in _functions(module.tree):
            if fn.name.startswith("params_stack"):
                continue        # the borrow SOURCE returns by design
            borrowed: Set[str] = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) \
                        and self._is_borrow_call(node.value):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            borrowed.add(tgt.id)
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    escapes = (self._is_borrow_call(node.value)
                               or (isinstance(node.value, ast.Name)
                                   and node.value.id in borrowed))
                    if escapes and any(isinstance(t, ast.Attribute)
                                       for t in node.targets):
                        yield self.finding(
                            module, node,
                            "borrowed params_stack() result stored on an "
                            "attribute; it dies at the next bank "
                            "write/compaction — use snapshot_params/"
                            "gather for a committed copy")
                elif isinstance(node, (ast.Return, ast.Yield)):
                    val = node.value
                    if val is not None and (
                            self._is_borrow_call(val)
                            or (isinstance(val, ast.Name)
                                and val.id in borrowed)):
                        yield self.finding(
                            module, node,
                            "borrowed params_stack() result escapes the "
                            "capturing function — the caller cannot see "
                            "the bank mutations that invalidate it")


# -- rule 2: sync-before-capture ---------------------------------------------

class SyncBeforeCaptureRule(Rule):
    """A function that captures ANOTHER job's bank slot index
    (`job._slot.idx`) must run the compaction entry point first,
    unconditionally (top-of-body, not behind a branch): queued-dead
    slots compact at entry points, so an index captured before
    `compact()` can silently point at a moved row.  Reading a handle's
    OWN index (`self._slot.idx`) is exempt — it is re-read fresh on
    every call."""

    name = "sync-before-capture"
    contract = "docs/training_plane.md: batched entry points compact + " \
               "flush BEFORE capturing slot indices"

    _IMPL_CLASSES = {"JobBank", "_Slot"}

    def _captures(self, node: ast.AST) -> Iterator[ast.Attribute]:
        for n in ast.walk(node):
            if (isinstance(n, ast.Attribute) and n.attr == "idx"
                    and isinstance(n.value, ast.Attribute)
                    and n.value.attr == "_slot"
                    and not (isinstance(n.value.value, ast.Name)
                             and n.value.value.id == "self")):
                yield n

    @staticmethod
    def _has_compact(node: ast.AST) -> bool:
        return any(isinstance(n, ast.Call)
                   and isinstance(n.func, ast.Attribute)
                   and n.func.attr == "compact"
                   for n in ast.walk(node))

    def check(self, module: Module) -> Iterator[Finding]:
        for fn, cls in _functions(module.tree):
            if cls in self._IMPL_CLASSES:
                continue
            synced = False
            for stmt in fn.body:
                # an unconditional compact() call dominates everything
                # after it; one inside if/for/try does NOT count — the
                # contract is "on every path"
                if self._has_compact(stmt) and not any(
                        isinstance(n, (ast.If, ast.For, ast.While, ast.Try))
                        for n in ast.walk(stmt)):
                    synced = True
                    continue
                if synced:
                    continue
                for cap in self._captures(stmt):
                    yield self.finding(
                        module, cap,
                        "slot index captured before an unconditional "
                        "bank.compact() in this function — a queued-dead "
                        "slot may move this row after capture")


# -- rule 3: per-member-loop -------------------------------------------------

class PerMemberLoopRule(Rule):
    """Per-member/per-flow Python loops around the scalar decision
    calls (`decide` / `eval_on` / `best`) in plane code must go through
    the batched APIs (`decide_many` / `eval_pairs` / `eval_jobs` /
    `best_many`) — the batched paths are bit-identical and turn O(fleet)
    device launches into O(1)."""

    name = "per-member-loop"
    contract = "docs/transmission_plane.md + docs/training_plane.md: " \
               "no per-member scalar loops in plane code"

    _SCALAR = {"decide", "eval_on", "best"}
    _SCOPE = ("repro/core/", "benchmarks/", "examples/")

    def check(self, module: Module) -> Iterator[Finding]:
        if not any(s in module.rel for s in self._SCOPE):
            return
        flagged: Dict[int, ast.AST] = {}
        stack: List[ast.AST] = []

        def visit(node: ast.AST):
            is_loop = isinstance(node, _LOOPS)
            if is_loop:
                stack.append(node)
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in self._SCALAR and stack):
                loop = stack[-1]          # innermost enclosing loop
                flagged.setdefault(id(loop), loop)
            for child in ast.iter_child_nodes(node):
                visit(child)
            if is_loop:
                stack.pop()

        visit(module.tree)
        for loop in sorted(flagged.values(), key=lambda n: n.lineno):
            yield self.finding(
                module, loop,
                "per-member loop around a scalar decision call "
                "(decide/eval_on/best) — use the batched plane API "
                "(decide_many / eval_pairs / eval_jobs / best_many)")


# -- rule 4: rows-discipline -------------------------------------------------

class RowsDisciplineRule(Rule):
    """Growable per-row state must ride a RowRegistry (core/rows.py):
    hand-rolled `self.x = np.concatenate([self.x, ...])` growth forgets
    amortized doubling, swap-compaction, and mesh alignment.  Growth
    sized against a registry (`.capacity` / `.reserve()`) in the same
    function is exempt — that IS the discipline."""

    name = "rows-discipline"
    contract = "ROADMAP conventions: RowRegistry owns churn; owners " \
               "size arrays against .capacity"

    _CONCAT = {"np.concatenate", "numpy.concatenate",
               "jnp.concatenate", "jax.numpy.concatenate"}

    def _is_self_concat(self, node: ast.Assign) -> bool:
        tgt = node.targets[0] if len(node.targets) == 1 else None
        if not isinstance(tgt, ast.Attribute):
            return False
        call = node.value
        if not (isinstance(call, ast.Call)
                and _dotted(call.func) in self._CONCAT and call.args):
            return False
        first = call.args[0]
        parts = first.elts if isinstance(first, (ast.List, ast.Tuple)) \
            else [first]
        return any(isinstance(p, ast.Attribute) and p.attr == tgt.attr
                   for p in parts)

    @staticmethod
    def _registry_sized(fn: ast.AST) -> bool:
        for n in ast.walk(fn):
            if isinstance(n, ast.Attribute) and n.attr == "capacity":
                return True
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                    and n.func.attr == "reserve":
                return True
        return False

    def check(self, module: Module) -> Iterator[Finding]:
        if module.rel.endswith("repro/core/rows.py"):
            return            # the sanctioned implementation
        for fn, _cls in _functions(module.tree):
            if self._registry_sized(fn):
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) \
                        and self._is_self_concat(node):
                    yield self.finding(
                        module, node,
                        "hand-rolled concatenate growth on an instance "
                        "attribute — use a RowRegistry (core/rows.py) "
                        "or size against its .capacity")


# -- rule 5: host-sync -------------------------------------------------------

class HostSyncRule(Rule):
    """Decision-plane modules must not force host<->device syncs in
    hot paths: `.item()`, `jax.device_get`, and `float()/int()/bool()/
    np.asarray()` applied to jax-valued expressions each block on the
    device.  Legitimate mirror-side syncs (the lazy d2h of the
    residency protocol, scalar decision APIs documented to return host
    floats) carry pragmas citing the residency rule."""

    name = "host-sync"
    contract = "docs/training_plane.md residency: zero per-member host " \
               "transfer in batched decision paths"

    _MODULES = ("repro/core/trainer.py", "repro/core/transmission.py",
                "repro/core/batching.py", "repro/core/gaimd.py",
                "repro/core/drift.py")
    _CASTS = {"float", "int", "bool"}
    _JAX = {"jax", "jnp"}

    def check(self, module: Module) -> Iterator[Finding]:
        if not module.rel.endswith(self._MODULES):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                yield self.finding(
                    module, node,
                    ".item() forces a device->host sync in a "
                    "decision-plane module")
                continue
            dotted = _dotted(node.func)
            if dotted == "jax.device_get":
                yield self.finding(
                    module, node,
                    "jax.device_get in a decision-plane module — only "
                    "the residency protocol's lazy mirror sync may "
                    "cross here (pragma it with the rule citation)")
                continue
            is_cast = (isinstance(node.func, ast.Name)
                       and node.func.id in self._CASTS)
            is_asarray = dotted in ("np.asarray", "numpy.asarray")
            if (is_cast or is_asarray) and node.args \
                    and _mentions(node.args[0], self._JAX):
                kind = node.func.id if is_cast else "np.asarray"
                yield self.finding(
                    module, node,
                    f"{kind}() on a jax-valued expression blocks on the "
                    f"device in a decision-plane module — keep the value "
                    f"device-side or pragma the documented sync point")


# -- rule 6: determinism -----------------------------------------------------

class DeterminismRule(Rule):
    """Decision code in core/ and serve/ must be replayable: no
    wall-clock reads (`time.time`), no unseeded module-level
    `np.random.*` draws (use `np.random.default_rng(seed)`), and no
    iteration over `set(...)` feeding decision outputs (set order is
    hash-seed dependent)."""

    name = "determinism"
    contract = "ROADMAP bit-identity bar: decisions replay exactly; " \
               "golden traces pin them"

    _SCOPE = ("repro/core/", "repro/serve/")
    _SEEDED = {"default_rng", "Generator", "SeedSequence", "PCG64",
               "Philox"}

    def check(self, module: Module) -> Iterator[Finding]:
        if not any(s in module.rel for s in self._SCOPE):
            return
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                dotted = _dotted(node.func)
                if dotted == "time.time":
                    yield self.finding(
                        module, node,
                        "time.time() in decision code — inject a clock "
                        "(time.monotonic default + test fake) instead")
                elif dotted and dotted.startswith(("np.random.",
                                                   "numpy.random.")):
                    leaf = dotted.rsplit(".", 1)[1]
                    if leaf not in self._SEEDED:
                        yield self.finding(
                            module, node,
                            f"unseeded np.random.{leaf}() — draw from "
                            f"np.random.default_rng(seed) so runs replay")
            elif isinstance(node, ast.For):
                it = node.iter
                unordered = (isinstance(it, (ast.Set, ast.SetComp))
                             or (isinstance(it, ast.Call)
                                 and isinstance(it.func, ast.Name)
                                 and it.func.id in ("set", "frozenset")))
                if unordered:
                    yield self.finding(
                        module, node,
                        "iteration over a set feeds decision code — "
                        "sort it (sorted(...)) for a replayable order")


# -- rule 7: profile-resolution ----------------------------------------------

class ProfileResolutionRule(Rule):
    """ProfileTable literals must be uniform-resolution: every
    `configs` entry's resolution (second element) equals the stream's
    seq_len.  The controller enforces resolution == seq_len at
    construction; statically, a profile literal mixing resolutions is
    always wrong."""

    name = "profile-resolution"
    contract = "docs/transmission_plane.md: resolution == seq_len on " \
               "every ProfileTable row"

    def check(self, module: Module) -> Iterator[Finding]:
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Dict):
                continue
            for key, val in zip(node.keys, node.values):
                if not (isinstance(key, ast.Constant)
                        and key.value == "configs"):
                    continue
                resolutions: Set[object] = set()
                entries: List[ast.AST] = []
                if isinstance(val, ast.List):
                    entries = val.elts
                elif isinstance(val, ast.ListComp):
                    entries = [val.elt]
                for e in entries:
                    if isinstance(e, (ast.List, ast.Tuple)) \
                            and len(e.elts) >= 2 \
                            and isinstance(e.elts[1], ast.Constant):
                        resolutions.add(e.elts[1].value)
                if len(resolutions) > 1:
                    yield self.finding(
                        module, val,
                        f"profile literal mixes resolutions "
                        f"{sorted(resolutions)} — resolution must equal "
                        f"seq_len on every configs row")


# -- rule 8: mesh-compat -----------------------------------------------------

class MeshCompatRule(Rule):
    """`shard_map` and the pallas TPU CompilerParams API moved between
    jax releases (jax.experimental.shard_map/check_rep on 0.4.x vs
    jax.shard_map/check_vma; TPUCompilerParams vs CompilerParams).
    Only `kernels/_compat.py` may touch them directly — everything
    else imports the version-resolved shims."""

    name = "mesh-compat"
    contract = "kernels/_compat.py: the one sanctioned spelling of " \
               "version-moved jax APIs"

    _BANNED_ATTRS = {"jax.shard_map",
                     "jax.experimental.shard_map.shard_map",
                     "pltpu.CompilerParams", "pltpu.TPUCompilerParams"}
    _BANNED_MODULES = {"jax.experimental.shard_map",
                       "jax.experimental.pallas.tpu"}
    _BANNED_NAMES = {"shard_map", "CompilerParams", "TPUCompilerParams"}

    def check(self, module: Module) -> Iterator[Finding]:
        if module.rel.endswith("kernels/_compat.py"):
            return
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Attribute):
                dotted = _dotted(node)
                if dotted in self._BANNED_ATTRS:
                    yield self.finding(
                        module, node,
                        f"direct {dotted} use — import the shim from "
                        f"repro.kernels._compat (spelling moved across "
                        f"jax releases)")
            elif isinstance(node, ast.ImportFrom):
                if node.module in self._BANNED_MODULES and any(
                        a.name in self._BANNED_NAMES for a in node.names):
                    yield self.finding(
                        module, node,
                        f"direct import from {node.module} — import the "
                        f"shim from repro.kernels._compat instead")


# -- rule 9: pragma-reason ---------------------------------------------------

class PragmaReasonRule(Rule):
    """Every `# fleetlint: disable=` pragma must carry a justification
    (`-- why this side of the contract makes it legal`) and must name a
    real rule — a typo'd rule name silently disables nothing."""

    name = "pragma-reason"
    contract = "docs/static_analysis.md pragma policy: suppressions " \
               "document their contract citation"

    def __init__(self, known_rules: Sequence[str] = ()):
        self.known = set(known_rules) | {"*", self.name}

    def check(self, module: Module) -> Iterator[Finding]:
        for p in module.pragmas:
            if not p.reason:
                yield Finding(self.name, module.path, p.line, 0,
                              "pragma without a justification — add "
                              "'-- <why the contract allows this>'")
            unknown = [r for r in p.rules if r not in self.known]
            if unknown and self.known - {"*", self.name}:
                yield Finding(self.name, module.path, p.line, 0,
                              f"pragma names unknown rule(s) "
                              f"{unknown} — typo'd suppressions disable "
                              f"nothing")


def default_rules() -> List[Rule]:
    """The shipped rule set (>= 8 contract rules + the pragma meta
    rule)."""
    rules: List[Rule] = [
        BorrowedStackRule(),
        SyncBeforeCaptureRule(),
        PerMemberLoopRule(),
        RowsDisciplineRule(),
        HostSyncRule(),
        DeterminismRule(),
        ProfileResolutionRule(),
        MeshCompatRule(),
    ]
    rules.append(PragmaReasonRule([r.name for r in rules]))
    return rules
