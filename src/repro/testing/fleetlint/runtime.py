"""fleetlint runtime sanitizer: dynamic checks of the residency
contracts static analysis can only approximate.

Two instruments, installed by monkeypatching the real classes (no
subclass opt-in — the point is to catch call sites that DIDN'T opt in):

* **Borrow fingerprinting** — every `JobBank.params_stack()` /
  `params_stack_compute()` call records a checksum of the borrowed
  leaves plus the bank's `_version`.  At the next entry-point sync
  (`compact()` / `sync_to_device()`), if the version is unchanged — no
  legitimate write invalidated the borrow — the leaves are re-hashed:
  a mismatch means someone mutated the borrowed buffers in place,
  bypassing the dirty-bit write protocol (host mode) or aliasing
  donated device buffers.  A version bump simply retires the record:
  that is the borrow expiring legally.

* **Transfer guard** — the batched decision entry points
  (`eval_pairs`, `eval_jobs`, `train_micro_many`, `batched_accuracy`)
  promise zero host<->device crossings of bank state once the fleet is
  resident (docs/training_plane.md).  The guard pre-flushes (compact +
  sync, both idempotent and exactly what the entry point would do
  first anyway), then hard-fails any `TransferStats.h2d/d2h` fired
  inside the guarded call on a resident bank.

Enable with `FLEETLINT_RUNTIME=1` (tests/conftest.py installs the
hooks in pytest_configure).  Both instruments change failure modes
only, never values: the tier-1 suite runs green under them.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np


class FleetlintRuntimeError(RuntimeError):
    """A residency-contract violation caught at runtime."""


_ORIGINALS: Dict[str, object] = {}    # qualified name -> unpatched fn


def _fingerprint(tree) -> List[Tuple[int, int]]:
    """(id, crc32) per leaf of a borrowed stack.  The crc is computed
    over host bytes (device leaves pay one debug-only d2h — the
    sanitizer is a test mode, not a production path)."""
    out = []
    for leaf in jax.tree.leaves(tree):
        try:
            buf = np.ascontiguousarray(np.asarray(leaf))
        except Exception as e:        # deleted (donated) buffer
            raise FleetlintRuntimeError(
                "borrowed params_stack() leaf was donated/deleted while "
                "still referenced — the borrow outlived a bank update"
            ) from e
        out.append((id(leaf), zlib.crc32(buf.tobytes())))
    return out


def _record_borrow(bank, stack) -> None:
    if stack is None:
        return
    bank._fleetlint_borrow = {
        "version": bank._version,
        "prints": _fingerprint(stack),
        "tree": stack,
    }


def _verify_borrow(bank) -> None:
    rec = getattr(bank, "_fleetlint_borrow", None)
    if rec is None:
        return
    bank._fleetlint_borrow = None
    if rec["version"] != bank._version:
        return    # a legitimate write/compaction retired the borrow
    for (lid, crc), leaf in zip(rec["prints"],
                                jax.tree.leaves(rec["tree"])):
        buf = np.ascontiguousarray(np.asarray(leaf))
        if zlib.crc32(buf.tobytes()) != crc:
            raise FleetlintRuntimeError(
                "borrowed params_stack() buffers were mutated in place "
                "with no bank version bump — a write bypassed the "
                "dirty-bit protocol (docs/training_plane.md residency "
                "rule: go through bank.write / scatter / "
                "write_row_device)")


class _GuardStats:
    """TransferStats stand-in that hard-fails on any crossing.  All
    other reads/writes forward to the real stats object (TransferStats
    is __slots__-only, so the guard swaps `bank.stats` wholesale for
    the duration of the guarded call)."""

    def __init__(self, inner):
        object.__setattr__(self, "_inner", inner)

    def h2d(self, nbytes: int):
        raise FleetlintRuntimeError(
            f"h2d transfer ({nbytes} bytes) of bank state inside a "
            f"batched decision call on a resident bank — the residency "
            f"contract promises zero per-call host crossings "
            f"(docs/training_plane.md)")

    def d2h(self, nbytes: int):
        raise FleetlintRuntimeError(
            f"d2h transfer ({nbytes} bytes) of bank state inside a "
            f"batched decision call on a resident bank — the residency "
            f"contract promises zero per-call host crossings "
            f"(docs/training_plane.md)")

    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "_inner"), name)

    def __setattr__(self, name, value):
        setattr(object.__getattribute__(self, "_inner"), name, value)


def _guard_transfers(engine):
    """Context manager: hard-fail any TransferStats crossing fired
    inside a batched decision call on a RESIDENT bank."""
    class _Guard:
        def __enter__(self):
            bank = engine.bank
            self.bank = bank
            self.depth = getattr(bank, "_fleetlint_guard_depth", 0)
            bank._fleetlint_guard_depth = self.depth + 1
            self.armed = not (self.depth or not bank.resident
                              or bank._host is None)
            if self.armed:
                # the entry point's own first moves, hoisted:
                # idempotent, and any crossing they need happens
                # BEFORE the guard arms
                bank.compact()
                bank.sync_to_device()
                bank.stats = _GuardStats(bank.stats)
            return self

        def __exit__(self, *exc):
            self.bank._fleetlint_guard_depth = self.depth
            if self.armed and isinstance(self.bank.stats, _GuardStats):
                self.bank.stats = object.__getattribute__(
                    self.bank.stats, "_inner")
            return False
    return _Guard()


def install() -> None:
    """Monkeypatch JobBank + SharedEngine with the sanitizer hooks.
    Idempotent; `uninstall()` restores the originals."""
    if _ORIGINALS:
        return
    from repro.core.trainer import JobBank, SharedEngine

    _ORIGINALS["JobBank.params_stack"] = JobBank.params_stack
    _ORIGINALS["JobBank.compact"] = JobBank.compact
    _ORIGINALS["JobBank.sync_to_device"] = JobBank.sync_to_device
    _ORIGINALS["SharedEngine.eval_pairs"] = SharedEngine.eval_pairs
    _ORIGINALS["SharedEngine.train_micro_many"] = \
        SharedEngine.train_micro_many
    _ORIGINALS["SharedEngine.batched_accuracy"] = \
        SharedEngine.batched_accuracy

    orig_stack = JobBank.params_stack
    orig_compact = JobBank.compact
    orig_sync = JobBank.sync_to_device

    def params_stack(self):
        stack = orig_stack(self)
        _record_borrow(self, stack)
        return stack

    def compact(self):
        _verify_borrow(self)
        return orig_compact(self)

    def sync_to_device(self):
        _verify_borrow(self)
        return orig_sync(self)

    JobBank.params_stack = params_stack
    JobBank.compact = compact
    JobBank.sync_to_device = sync_to_device

    for name in ("eval_pairs", "train_micro_many", "batched_accuracy"):
        orig = _ORIGINALS[f"SharedEngine.{name}"]

        def wrapped(self, *args, _orig=orig, **kwargs):
            with _guard_transfers(self):
                return _orig(self, *args, **kwargs)
        wrapped.__name__ = name
        setattr(SharedEngine, name, wrapped)


def uninstall() -> None:
    """Restore the unpatched JobBank/SharedEngine methods."""
    if not _ORIGINALS:
        return
    from repro.core.trainer import JobBank, SharedEngine
    for qual, fn in _ORIGINALS.items():
        cls_name, meth = qual.split(".")
        cls = {"JobBank": JobBank, "SharedEngine": SharedEngine}[cls_name]
        setattr(cls, meth, fn)
    _ORIGINALS.clear()


def installed() -> bool:
    return bool(_ORIGINALS)
