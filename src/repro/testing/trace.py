"""Golden-trace harness: run a scenario, serialize a compact behavior
trace, compare against checked-in goldens.

A trace captures, per window: every stream's drift score, the group
memberships, the GPU shares, the realized bandwidth, the per-stream
accuracy, and the grouping events (join/new/evict) — the full observable
decision surface of the controller. Golden JSON files under
tests/golden/ pin this surface for one fixed-seed scenario per
framework, so silent behavior drift in grouping / allocation /
transmission fails tier-1 instead of shipping.

Job ids are canonicalized ("g0", "g1", ... in order of first
appearance): `RetrainJob` draws ids from a process-global counter, so
raw ids depend on what ran before in the process.

Comparison policy (`compare`): structure — window count, stream sets,
group memberships, grouping events — must match EXACTLY; float fields
(drift scores, shares, bandwidth, accuracy) match within per-field
tolerances, because model-training floats wobble across jax/XLA builds
while the decisions they drive are pinned by the structural fields.

Regenerate after an intentional behavior change:

    PYTHONPATH=src python -m repro.testing.trace --regen tests/golden
"""
from __future__ import annotations

import copy
import dataclasses
import json
import math
import os
from typing import Dict, List, Optional

from repro.configs import smoke_config
from repro.core.baselines import FRAMEWORKS
from repro.core.controller import ControllerConfig
from repro.core.trainer import SharedEngine
from repro.core.transmission import ProfileTable
from repro.data.scenarios import FleetScenario, build_scenario


def make_engine_for(scenario: FleetScenario, arch: str = "olmo-1b"
                    ) -> SharedEngine:
    cfg = dataclasses.replace(smoke_config(arch),
                              vocab_size=scenario.bank.vocab)
    return SharedEngine(cfg)


def run_scenario(framework: str, scenario: FleetScenario, *,
                 engine: Optional[SharedEngine] = None,
                 windows: Optional[int] = None, seed: int = 0,
                 trace: Optional[dict] = None, **cc_overrides):
    """Run `framework` over `scenario` (churn events applied at window
    boundaries). Pass `trace={}` to also fill it with the golden-trace
    record. Returns the controller.

    The scenario is deep-copied first (streams carry live rng state
    and churn events carry Stream objects the controller consumes), so
    one built scenario can be run repeatedly — under several
    frameworks, say — and every run sees the identical fleet."""
    engine = engine or make_engine_for(scenario)
    scenario = copy.deepcopy(scenario)      # bank is shared via memo
    windows = scenario.windows if windows is None else windows
    cc_kw = dict(window_seconds=scenario.window_seconds,
                 shared_bandwidth=scenario.shared_bandwidth,
                 local_caps=scenario.local_caps)
    if getattr(scenario, "profile", None):
        cc_kw["profile_table"] = ProfileTable.from_spec(scenario.profile)
    cc_kw.update(cc_overrides)
    cc = ControllerConfig(**cc_kw)
    ctl = FRAMEWORKS[framework](engine, list(scenario.streams), cc,
                                seed=seed)
    ctl.warmup()
    if trace is not None:
        trace.update({"meta": {"scenario": scenario.name,
                               "scenario_seed": scenario.seed,
                               "framework": framework, "seed": seed,
                               "windows": windows},
                      "windows": []})
    jobname: Dict[str, str] = {}
    for w in range(windows):
        for ev in scenario.events_at(w):
            if ev.kind == "join" and ev.stream is not None:
                ctl.add_stream(ev.stream)
            elif ev.kind == "leave":
                ctl.remove_stream(ev.stream_id)
        n_events = len(ctl.grouper.events)
        wm = ctl.run_window()
        if trace is not None:
            trace["windows"].append(_window_record(
                ctl, wm, ctl.grouper.events[n_events:], jobname))
    return ctl


# -- trace records -----------------------------------------------------------
def _canon(jobname: Dict[str, str], job_id: str) -> str:
    if job_id not in jobname:
        jobname[job_id] = f"g{len(jobname)}"
    return jobname[job_id]


def _round(x, nd: int):
    v = float(x)
    return None if math.isnan(v) else round(v, nd)


def _window_record(ctl, wm, events, jobname: Dict[str, str]) -> dict:
    drift = {sid: _round(ctl.fleet.score(sid), 6)
             for sid in sorted(ctl.fleet.stream_ids)}
    groups = {_canon(jobname, jid): sorted(members)
              for jid, members in wm.groups.items()}
    shares = {_canon(jobname, jid): _round(v, 6)
              for jid, v in wm.shares.items()}
    bw = {sid: _round(v, 4) for sid, v in sorted(wm.bandwidth.items())}
    acc = {sid: _round(v, 4) for sid, v in sorted(wm.per_stream_acc.items())}
    evs = [{"kind": e["kind"], "stream": e["stream"],
            "job": _canon(jobname, e["job"])} for e in events]
    return {"t": wm.t, "drift": drift, "groups": groups, "shares": shares,
            "bandwidth": bw, "acc": acc, "events": evs}


def save_trace(trace: dict, path: str):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(trace, f, indent=1, sort_keys=True)
        f.write("\n")


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


# -- comparison --------------------------------------------------------------
def _cmp_floats(diffs, where, a: dict, b: dict, atol: float,
                rtol: float = 0.0):
    if set(a) != set(b):
        diffs.append(f"{where}: key sets differ {sorted(a)} vs {sorted(b)}")
        return
    for k in a:
        x, y = a[k], b[k]
        if (x is None) != (y is None):
            diffs.append(f"{where}[{k}]: {x} vs {y}")
        elif x is not None and abs(x - y) > atol + rtol * abs(y):
            diffs.append(f"{where}[{k}]: {x} vs {y}")


def compare(got: dict, want: dict, *, drift_atol: float = 1e-4,
            share_atol: float = 5e-3, bw_rtol: float = 5e-3,
            acc_atol: float = 0.08) -> List[str]:
    """Diff two traces. Returns [] when `got` matches `want`; otherwise
    human-readable difference lines. Structure is exact; floats are
    toleranced (see module docstring)."""
    diffs: List[str] = []
    if got.get("meta") != want.get("meta"):
        diffs.append(f"meta: {got.get('meta')} vs {want.get('meta')}")
    gw, ww = got.get("windows", []), want.get("windows", [])
    if len(gw) != len(ww):
        diffs.append(f"window count: {len(gw)} vs {len(ww)}")
    for i, (g, w) in enumerate(zip(gw, ww)):
        at = f"window[{i}]"
        if g["t"] != w["t"]:
            diffs.append(f"{at}.t: {g['t']} vs {w['t']}")
        if g["groups"] != w["groups"]:
            diffs.append(f"{at}.groups: {g['groups']} vs {w['groups']}")
        if g["events"] != w["events"]:
            diffs.append(f"{at}.events: {g['events']} vs {w['events']}")
        _cmp_floats(diffs, f"{at}.drift", g["drift"], w["drift"],
                    drift_atol)
        _cmp_floats(diffs, f"{at}.shares", g["shares"], w["shares"],
                    share_atol)
        _cmp_floats(diffs, f"{at}.bandwidth", g["bandwidth"],
                    w["bandwidth"], 1e-6, bw_rtol)
        _cmp_floats(diffs, f"{at}.acc", g["acc"], w["acc"], acc_atol)
    return diffs


# -- golden registry ---------------------------------------------------------
# One fixed-seed scenario run per framework. Sized for tier-1: a tiny
# drift_wave fleet (2 regions x 2 streams), 3 windows, reduced training.
GOLDEN_SCENARIO = dict(name="drift_wave", seed=0, regions=2,
                       streams_per_region=2, wave_start=5.0,
                       wave_step=10.0, windows=3)
GOLDEN_CONTROLLER = dict(window_micro=4, micro_steps=2, train_batch=8,
                         sample_rate=8, p_drop=0.5, shared_bandwidth=96.0)
GOLDEN_FRAMEWORKS = ("ecco", "naive", "ekya", "recl")


def golden_scenario() -> FleetScenario:
    kw = dict(GOLDEN_SCENARIO)
    return build_scenario(kw.pop("name"), **kw)


def golden_trace(framework: str, engine: Optional[SharedEngine] = None
                 ) -> dict:
    scenario = golden_scenario()
    trace: dict = {}
    run_scenario(framework, scenario, engine=engine, seed=0, trace=trace,
                 **GOLDEN_CONTROLLER)
    return trace


def golden_path(dirpath: str, framework: str) -> str:
    return os.path.join(dirpath, f"trace_{framework}.json")


def regenerate(dirpath: str, frameworks=GOLDEN_FRAMEWORKS) -> List[str]:
    scenario = golden_scenario()
    engine = make_engine_for(scenario)
    paths = []
    for fw in frameworks:
        tr = golden_trace(fw, engine=engine)
        p = golden_path(dirpath, fw)
        save_trace(tr, p)
        paths.append(p)
    return paths


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--regen", metavar="DIR",
                    help="regenerate golden traces into DIR")
    ap.add_argument("--check", metavar="DIR",
                    help="re-run and diff against goldens in DIR")
    args = ap.parse_args(argv)
    if args.regen:
        for p in regenerate(args.regen):
            print(f"wrote {p}")
    if args.check:
        bad = 0
        for fw in GOLDEN_FRAMEWORKS:
            diffs = compare(golden_trace(fw),
                            load_trace(golden_path(args.check, fw)))
            status = "ok" if not diffs else f"{len(diffs)} diffs"
            print(f"{fw}: {status}")
            for d in diffs:
                print(f"  {d}")
            bad += bool(diffs)
        raise SystemExit(1 if bad else 0)


if __name__ == "__main__":
    main()
