"""Golden-trace harness: run a scenario, serialize a compact behavior
trace, compare against checked-in goldens.

A trace captures, per window: every stream's drift score, the group
memberships, the GPU shares, the realized bandwidth, the per-stream
accuracy, and the grouping events (join/new/evict) — the full observable
decision surface of the controller. Golden JSON files under
tests/golden/ pin this surface for one fixed-seed scenario per
framework, so silent behavior drift in grouping / allocation /
transmission fails tier-1 instead of shipping.

Job ids are canonicalized ("g0", "g1", ... in order of first
appearance): `RetrainJob` draws ids from a process-global counter, so
raw ids depend on what ran before in the process.

Comparison policy (`compare`): structure — window count, stream sets,
group memberships, grouping events — must match EXACTLY; float fields
(drift scores, shares, bandwidth, accuracy) match within per-field
tolerances, because model-training floats wobble across jax/XLA builds
while the decisions they drive are pinned by the structural fields.

Besides the benign drift_wave goldens (one per framework), the four
HOSTILE scenarios (data.scenarios.HOSTILE_SCENARIOS) are golden-pinned
at smoke scale under `trace_<scenario>_<framework>.json` — same
--regen/--check flow, same comparator.

`run_scenario` also drives `repro.testing.invariants.InvariantChecker`
on every window by default (window-level laws: bandwidth caps, share
proportionality, grouping/event consistency, plane-row and
bank/serving-store residency). Benchmarks opt out with
`invariants=False`.

Regenerate after an intentional behavior change:

    PYTHONPATH=src python -m repro.testing.trace --regen tests/golden
"""
from __future__ import annotations

import copy
import dataclasses
import json
import math
import os
from typing import Dict, List, Optional

from repro.configs import smoke_config
from repro.core.baselines import FRAMEWORKS
from repro.core.controller import ControllerConfig
from repro.core.trainer import SharedEngine
from repro.core.transmission import ProfileTable
from repro.data.scenarios import (HOSTILE_SCENARIOS, FleetScenario,
                                  build_scenario)
from repro.testing.invariants import InvariantChecker


def make_engine_for(scenario: FleetScenario, arch: str = "olmo-1b"
                    ) -> SharedEngine:
    cfg = dataclasses.replace(smoke_config(arch),
                              vocab_size=scenario.bank.vocab)
    return SharedEngine(cfg)


def run_scenario(framework: str, scenario: FleetScenario, *,
                 engine: Optional[SharedEngine] = None,
                 windows: Optional[int] = None, seed: int = 0,
                 trace: Optional[dict] = None, invariants: bool = True,
                 **cc_overrides):
    """Run `framework` over `scenario` (churn and bandwidth events
    applied at window boundaries). Pass `trace={}` to also fill it
    with the golden-trace record. Returns the controller.

    The scenario is deep-copied first (streams carry live rng state
    and churn events carry Stream objects the controller consumes), so
    one built scenario can be run repeatedly — under several
    frameworks, say — and every run sees the identical fleet.

    `invariants`: check the window-level fleet laws
    (repro.testing.invariants) around every window; an
    InvariantViolation names the window and the broken contract.
    Benchmarks chasing wall-clock pass False (the bank check drains
    the GC per window)."""
    own_engine = engine is None
    engine = engine or make_engine_for(scenario)
    scenario = copy.deepcopy(scenario)      # bank is shared via memo
    windows = scenario.windows if windows is None else windows
    cc_kw = dict(window_seconds=scenario.window_seconds,
                 shared_bandwidth=scenario.shared_bandwidth,
                 local_caps=scenario.local_caps)
    if getattr(scenario, "profile", None):
        cc_kw["profile_table"] = ProfileTable.from_spec(scenario.profile)
    cc_kw.update(cc_overrides)
    cc = ControllerConfig(**cc_kw)
    ctl = FRAMEWORKS[framework](engine, list(scenario.streams), cc,
                                seed=seed)
    ctl.warmup()
    checker = (InvariantChecker(bank_exact=own_engine,
                                label=f"{scenario.name}/{framework}")
               if invariants else None)
    if trace is not None:
        trace.update({"meta": {"scenario": scenario.name,
                               "scenario_seed": scenario.seed,
                               "framework": framework, "seed": seed,
                               "windows": windows},
                      "windows": []})
    jobname: Dict[str, str] = {}
    for w in range(windows):
        churned = set()
        for ev in scenario.events_at(w):
            if ev.kind == "join" and ev.stream is not None:
                live = {s.stream_id for s in ctl.streams}
                if ev.stream_id in live:
                    # a silent re-add would overwrite the stream's
                    # detector/transmission rows and leak its old job
                    # membership; hostile generators minting duplicate
                    # ids must fail loudly (ISSUE 9 satellite)
                    raise ValueError(
                        f"scenario {scenario.name!r}: ChurnEvent joins "
                        f"stream {ev.stream_id!r} at window {w} but it "
                        f"is already live")
                ctl.add_stream(ev.stream)
                churned.add(ev.stream_id)
            elif ev.kind == "leave":
                ctl.remove_stream(ev.stream_id)
                churned.add(ev.stream_id)
        for be in scenario.bandwidth_events_at(w):
            if be.shared_bandwidth is not None:
                ctl.cc.shared_bandwidth = float(be.shared_bandwidth)
            if be.local_caps is not None:
                ctl.cc.local_caps = dict(be.local_caps)
        if checker is not None:
            checker.before_window(ctl, churned)
        n_events = len(ctl.grouper.events)
        wm = ctl.run_window()
        events = ctl.grouper.events[n_events:]
        if checker is not None:
            checker.after_window(ctl, wm, events)
        if trace is not None:
            trace["windows"].append(_window_record(
                ctl, wm, events, jobname))
    if checker is not None:
        # benches record this to prove the hostile rows ran checked
        ctl.invariant_windows = checker.windows_checked
    return ctl


# -- trace records -----------------------------------------------------------
def _canon(jobname: Dict[str, str], job_id: str) -> str:
    if job_id not in jobname:
        jobname[job_id] = f"g{len(jobname)}"
    return jobname[job_id]


def _round(x, nd: int):
    v = float(x)
    return None if math.isnan(v) else round(v, nd)


def _window_record(ctl, wm, events, jobname: Dict[str, str]) -> dict:
    drift = {sid: _round(ctl.fleet.score(sid), 6)
             for sid in sorted(ctl.fleet.stream_ids)}
    groups = {_canon(jobname, jid): sorted(members)
              for jid, members in wm.groups.items()}
    shares = {_canon(jobname, jid): _round(v, 6)
              for jid, v in wm.shares.items()}
    bw = {sid: _round(v, 4) for sid, v in sorted(wm.bandwidth.items())}
    acc = {sid: _round(v, 4) for sid, v in sorted(wm.per_stream_acc.items())}
    evs = [{"kind": e["kind"], "stream": e["stream"],
            "job": _canon(jobname, e["job"])} for e in events]
    return {"t": wm.t, "drift": drift, "groups": groups, "shares": shares,
            "bandwidth": bw, "acc": acc, "events": evs}


def save_trace(trace: dict, path: str):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(trace, f, indent=1, sort_keys=True)
        f.write("\n")


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


# -- comparison --------------------------------------------------------------
def _cmp_floats(diffs, where, a: dict, b: dict, atol: float,
                rtol: float = 0.0):
    if set(a) != set(b):
        diffs.append(f"{where}: key sets differ {sorted(a)} vs {sorted(b)}")
        return
    for k in a:
        x, y = a[k], b[k]
        if (x is None) != (y is None):
            diffs.append(f"{where}[{k}]: {x} vs {y}")
        elif x is not None and abs(x - y) > atol + rtol * abs(y):
            diffs.append(f"{where}[{k}]: {x} vs {y}")


def compare(got: dict, want: dict, *, drift_atol: float = 1e-4,
            share_atol: float = 5e-3, bw_rtol: float = 5e-3,
            acc_atol: float = 0.08) -> List[str]:
    """Diff two traces. Returns [] when `got` matches `want`; otherwise
    human-readable difference lines. Structure is exact; floats are
    toleranced (see module docstring)."""
    diffs: List[str] = []
    if got.get("meta") != want.get("meta"):
        diffs.append(f"meta: {got.get('meta')} vs {want.get('meta')}")
    gw, ww = got.get("windows", []), want.get("windows", [])
    if len(gw) != len(ww):
        diffs.append(f"window count: {len(gw)} vs {len(ww)}")
    for i, (g, w) in enumerate(zip(gw, ww)):
        at = f"window[{i}]"
        if g["t"] != w["t"]:
            diffs.append(f"{at}.t: {g['t']} vs {w['t']}")
        if g["groups"] != w["groups"]:
            diffs.append(f"{at}.groups: {g['groups']} vs {w['groups']}")
        if g["events"] != w["events"]:
            diffs.append(f"{at}.events: {g['events']} vs {w['events']}")
        _cmp_floats(diffs, f"{at}.drift", g["drift"], w["drift"],
                    drift_atol)
        _cmp_floats(diffs, f"{at}.shares", g["shares"], w["shares"],
                    share_atol)
        _cmp_floats(diffs, f"{at}.bandwidth", g["bandwidth"],
                    w["bandwidth"], 1e-6, bw_rtol)
        _cmp_floats(diffs, f"{at}.acc", g["acc"], w["acc"], acc_atol)
    return diffs


# -- golden registry ---------------------------------------------------------
# One fixed-seed scenario run per framework. Sized for tier-1: a tiny
# drift_wave fleet (2 regions x 2 streams), 3 windows, reduced training.
GOLDEN_SCENARIO = dict(name="drift_wave", seed=0, regions=2,
                       streams_per_region=2, wave_start=5.0,
                       wave_step=10.0, windows=3)
GOLDEN_CONTROLLER = dict(window_micro=4, micro_steps=2, train_batch=8,
                         sample_rate=8, p_drop=0.5, shared_bandwidth=96.0)
GOLDEN_FRAMEWORKS = ("ecco", "naive", "ekya", "recl")


def golden_scenario() -> FleetScenario:
    kw = dict(GOLDEN_SCENARIO)
    return build_scenario(kw.pop("name"), **kw)


def golden_trace(framework: str, engine: Optional[SharedEngine] = None
                 ) -> dict:
    scenario = golden_scenario()
    trace: dict = {}
    run_scenario(framework, scenario, engine=engine, seed=0, trace=trace,
                 **GOLDEN_CONTROLLER)
    return trace


# Hostile-scenario goldens (ROADMAP item 3): each of the four
# adversarial workloads pinned per framework at smoke scale — small
# fleets, short horizons (tier-1 runs all of these), but the same
# failure boundaries: a cohort join storm, a correlated region
# blackout, per-window drift flips, a ~100x bandwidth collapse.
# Files land as trace_<scenario>_<framework>.json.
HOSTILE_GOLDEN: Dict[str, dict] = {
    "flash_crowd_10k": dict(
        scenario=dict(seed=0, joiners=6, base_regions=1,
                      streams_per_region=2, join_window=1, windows=4),
        # shortlist caps the grouper's eval fan-out exactly where the
        # full-scale crowd needs it
        controller=dict(shortlist_k=2)),
    "sensor_blackout": dict(
        scenario=dict(seed=0, regions=2, streams_per_region=2,
                      switch_time=5.0, blackout_window=2, windows=4)),
    "oscillating_drift": dict(
        scenario=dict(seed=0, regions=2, streams_per_region=2,
                      windows=4)),
    "bandwidth_collapse": dict(
        scenario=dict(seed=0, regions=2, streams_per_region=2,
                      collapse_window=2, windows=4),
        # the scenario owns the caps (collapse events rewrite them
        # mid-run) — don't let GOLDEN_CONTROLLER's bottleneck win
        controller=dict(shared_bandwidth=None)),
}
assert set(HOSTILE_GOLDEN) == set(HOSTILE_SCENARIOS)


def hostile_scenario(name: str) -> FleetScenario:
    return build_scenario(name, **HOSTILE_GOLDEN[name]["scenario"])


def hostile_controller_kwargs(name: str) -> dict:
    kw = dict(GOLDEN_CONTROLLER)
    kw.update(HOSTILE_GOLDEN[name].get("controller", {}))
    return {k: v for k, v in kw.items() if v is not None}


def hostile_trace(name: str, framework: str,
                  engine: Optional[SharedEngine] = None) -> dict:
    """One hostile scenario run (invariants ON) -> its trace record."""
    trace: dict = {}
    run_scenario(framework, hostile_scenario(name), engine=engine,
                 seed=0, trace=trace, **hostile_controller_kwargs(name))
    return trace


def golden_path(dirpath: str, framework: str,
                scenario: Optional[str] = None) -> str:
    """Golden file path; `scenario=None` is the benign drift_wave
    golden (seed layout), a name is one of the hostile goldens."""
    stem = (f"trace_{framework}" if scenario is None
            else f"trace_{scenario}_{framework}")
    return os.path.join(dirpath, f"{stem}.json")


def regenerate(dirpath: str, frameworks=GOLDEN_FRAMEWORKS) -> List[str]:
    scenario = golden_scenario()
    engine = make_engine_for(scenario)
    paths = []
    for fw in frameworks:
        tr = golden_trace(fw, engine=engine)
        p = golden_path(dirpath, fw)
        save_trace(tr, p)
        paths.append(p)
    for name in HOSTILE_SCENARIOS:
        for fw in frameworks:
            tr = hostile_trace(name, fw, engine=engine)
            p = golden_path(dirpath, fw, scenario=name)
            save_trace(tr, p)
            paths.append(p)
    return paths


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--regen", metavar="DIR",
                    help="regenerate golden traces into DIR")
    ap.add_argument("--check", metavar="DIR",
                    help="re-run and diff against goldens in DIR")
    args = ap.parse_args(argv)
    if args.regen:
        for p in regenerate(args.regen):
            print(f"wrote {p}")
    if args.check:
        bad = 0
        runs = [(None, fw) for fw in GOLDEN_FRAMEWORKS] + \
            [(name, fw) for name in HOSTILE_SCENARIOS
             for fw in GOLDEN_FRAMEWORKS]
        for name, fw in runs:
            got = (golden_trace(fw) if name is None
                   else hostile_trace(name, fw))
            diffs = compare(got, load_trace(
                golden_path(args.check, fw, scenario=name)))
            label = fw if name is None else f"{name}/{fw}"
            status = "ok" if not diffs else f"{len(diffs)} diffs"
            print(f"{label}: {status}")
            for d in diffs:
                print(f"  {d}")
            bad += bool(diffs)
        raise SystemExit(1 if bad else 0)


if __name__ == "__main__":
    main()
