"""Teacher annotation — the server-side "high-accuracy model" that labels
retraining frames (paper Fig. 1: YOLO11x annotating sampled frames).

Two teachers are provided:
  * OracleTeacher — the DomainBank's true next-token distribution
    (a perfect teacher; isolates control-plane effects in benchmarks).
  * ModelTeacher  — a larger same-family student (e.g. 2x depth/width)
    producing soft logits via a jitted forward; this is what the paper's
    setup maps to (teacher FLOPs >> student FLOPs, run server-side only
    on *sampled* frames).

Both return per-token soft label distributions that the train step
consumes through `distill_weight` (repro.train.train_step.make_loss_fn).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import build_model


class OracleTeacher:
    """Wraps a DomainBank; emits exact next-token distributions."""

    def __init__(self, bank):
        self.bank = bank

    def annotate(self, domain: int, tokens: np.ndarray) -> np.ndarray:
        """tokens (B,S) -> soft targets (B,S,V) (probability space)."""
        return self.bank.soft_labels(domain, tokens)


def scale_config(cfg: ModelConfig, *, depth_mult: float = 2.0,
                 width_mult: float = 1.0) -> ModelConfig:
    """A same-family, larger teacher config (the YOLO11n -> YOLO11x
    analogue)."""
    d_model = int(cfg.d_model * width_mult)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-teacher",
        num_layers=max(1, int(cfg.num_layers * depth_mult)),
        d_model=d_model,
        d_ff=int(cfg.d_ff * width_mult) if cfg.d_ff else cfg.d_ff,
        num_heads=max(1, int(cfg.num_heads * width_mult)),
        num_kv_heads=max(1, int(cfg.num_kv_heads * width_mult)),
    )


class ModelTeacher:
    """A larger same-family model annotating sampled sequences with
    logits. Kept fp32 on the server; never shipped to devices."""

    def __init__(self, student_cfg: ModelConfig, *, depth_mult: float = 2.0,
                 width_mult: float = 1.0, seed: int = 0):
        self.cfg = scale_config(student_cfg, depth_mult=depth_mult,
                                width_mult=width_mult)
        self.model = build_model(self.cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))

        def fwd(params, toks):
            logits, _ = self.model.apply(params, toks,
                                         compute_dtype=jnp.float32)
            return logits

        self._fwd = jax.jit(fwd)

    def annotate(self, tokens: np.ndarray) -> np.ndarray:
        """tokens (B,S) -> teacher logits (B,S,V) as float32."""
        return np.asarray(self._fwd(self.params, jnp.asarray(tokens)))

    def fit(self, batches, *, steps: int = 50, lr: float = 3e-3,
            tcfg=None):
        """Optionally adapt the teacher itself on pooled fleet data (the
        paper pre-trains teachers offline; exposed for examples)."""
        from repro.configs.base import TrainConfig
        from repro.train.train_step import init_state, make_train_step
        tcfg = tcfg or TrainConfig(learning_rate=lr, warmup_steps=5,
                                   total_steps=max(steps, 10), remat="none")
        step = jax.jit(make_train_step(self.model, tcfg))
        state = init_state(self.model, jax.random.PRNGKey(1), tcfg)
        state = {"params": self.params, "opt": state["opt"]}
        it = 0
        while it < steps:
            for b in batches:
                state, _ = step(state, {k: jnp.asarray(v)
                                        for k, v in b.items()})
                it += 1
                if it >= steps:
                    break
        self.params = state["params"]
        return self
