"""Synthetic drifting token streams with controllable cross-stream
correlation — the CARLA substitute.

Each *region* owns a latent domain trajectory (a sequence of domain
switches over time). A stream belongs to a region and follows the
region's trajectory with a per-stream lag and noise, so streams in the
same region experience *correlated drift* (the paper's premise), while
streams in different regions drift independently.

A *domain* d is a seeded random bigram language: next ~ Cat(P_d[prev]).
P_d = softmax(E_d E_d^T / tau) over a shared vocab, so a student model
genuinely has to adapt its predictions when the domain switches, and a
"teacher" with access to P_d provides ground-truth soft labels
(the paper's high-accuracy teacher annotating frames).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class DomainBank:
    """Shared collection of bigram domains over one vocab."""

    def __init__(self, vocab: int, num_domains: int, *, dim: int = 8,
                 tau: float = 0.15, seed: int = 0):
        self.vocab = vocab
        self.num_domains = num_domains
        rng = np.random.default_rng(seed)
        self.P = np.zeros((num_domains, vocab, vocab), np.float64)
        for d in range(num_domains):
            E = rng.normal(size=(vocab, dim))
            logits = E @ E.T / (tau * np.sqrt(dim))
            # kill self-transitions: the raw Gram diagonal (|E_i|^2) would
            # make chains collapse into constant runs, turning the task
            # into trivial copying and starving the drift detector of
            # distributional signal
            np.fill_diagonal(logits, -np.inf)
            logits -= logits.max(axis=1, keepdims=True)
            p = np.exp(logits)
            self.P[d] = p / p.sum(axis=1, keepdims=True)

    def sample(self, domain: int, rng: np.random.Generator, batch: int,
               seq_len: int, mix_with: Optional[int] = None,
               mix_frac: float = 0.0) -> np.ndarray:
        """Sample (batch, seq_len) token sequences from a domain (optionally
        a mixture during gradual drift)."""
        P = self.P[domain]
        if mix_with is not None and mix_frac > 0:
            P = (1 - mix_frac) * P + mix_frac * self.P[mix_with]
        out = np.empty((batch, seq_len), np.int64)
        tok = rng.integers(0, self.vocab, size=batch)
        cum = np.cumsum(P, axis=1)
        for s in range(seq_len):
            out[:, s] = tok
            u = rng.random(batch)
            # vectorized per-row searchsorted: left insertion point ==
            # count of cum-cells strictly below the draw (data
            # generation dominates fleet benchmarks at 10k streams; the
            # per-row Python np.searchsorted loop was the hot spot)
            tok = (cum[tok] < u[:, None]).sum(axis=1)
            tok = np.minimum(tok, self.vocab - 1)
        return out

    def soft_labels(self, domain: int, tokens: np.ndarray) -> np.ndarray:
        """Ground-truth next-token distribution (the perfect teacher).
        tokens: (B,S) -> (B,S,V)."""
        return self.P[domain][tokens]


@dataclasses.dataclass
class Region:
    """Latent domain trajectory shared by co-located streams."""
    region_id: str
    schedule: List[Tuple[float, int]]     # (switch_time, domain) sorted

    def domain_at(self, t: float) -> int:
        d = self.schedule[0][1]
        for ts, dom in self.schedule:
            if t >= ts:
                d = dom
            else:
                break
        return d


class Stream:
    """One camera-equivalent: emits token batches from its region's
    current domain (with lag/noise), carries spatial metadata."""

    def __init__(self, stream_id: str, bank: DomainBank, region: Region,
                 loc: Sequence[float], *, lag: float = 0.0,
                 noise_domain_prob: float = 0.0, seed: int = 0):
        self.stream_id = stream_id
        self.bank = bank
        self.region = region
        self.loc = tuple(loc)
        self.lag = lag
        self.noise_domain_prob = noise_domain_prob
        self.rng = np.random.default_rng(seed)

    def domain_at(self, t: float) -> int:
        d = self.region.domain_at(t - self.lag)
        if self.noise_domain_prob and self.rng.random() < self.noise_domain_prob:
            d = int(self.rng.integers(0, self.bank.num_domains))
        return d

    def sample(self, t: float, batch: int, seq_len: int) -> np.ndarray:
        return self.bank.sample(self.domain_at(t), self.rng, batch, seq_len)

    def sample_labeled(self, t: float, batch: int, seq_len: int):
        toks = self.sample(t, batch, seq_len)
        soft = self.bank.soft_labels(self.domain_at(t), toks)
        return toks, soft


def make_fleet(*, vocab: int = 64, num_domains: int = 6, dim: int = 4,
               regions: int = 2, streams_per_region: int = 3,
               region_spread: float = 10.0, region_distance: float = 1000.0,
               switch_times: Sequence[float] = (100.0,),
               seed: int = 0) -> Tuple[DomainBank, List[Stream]]:
    """Build a fleet with correlated drift inside regions. Each region
    switches domains at `switch_times` (staggered by region).

    vocab=64/dim=8 calibrates domain difficulty so a smoke-scale student
    approaches the Bayes ceiling within ~1 retraining window — matching
    the paper's lightweight-model regime (fast adaptation possible, drift
    costly if unhandled)."""
    bank = DomainBank(vocab, num_domains, dim=dim, seed=seed)
    rng = np.random.default_rng(seed + 1)
    streams: List[Stream] = []
    for r in range(regions):
        doms = rng.permutation(num_domains)
        sched = [(0.0, int(doms[0]))]
        for i, ts in enumerate(switch_times):
            sched.append((ts + 5.0 * r, int(doms[(i + 1) % num_domains])))
        region = Region(f"region{r}", sched)
        cx, cy = r * region_distance, 0.0
        for s in range(streams_per_region):
            loc = (cx + rng.uniform(-region_spread, region_spread),
                   cy + rng.uniform(-region_spread, region_spread))
            streams.append(Stream(
                f"cam{r}_{s}", bank, region, loc,
                lag=rng.uniform(0.0, 2.0), seed=seed + 10 * r + s))
    return bank, streams
