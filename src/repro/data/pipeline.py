"""Rate-limited group batcher — the data plane between streams and jobs.

Implements the paper's transmission-to-training handoff at system level:
each stream's delivered tokens (bounded by its realized GAIMD bandwidth,
repro.core.gaimd) land in a per-group ring buffer; `group_batch()` then
draws a training batch that is *balanced across members* (the paper's
f*/n_j scaling), optionally attaching teacher soft labels.

Pure host-side Python/NumPy by design: this layer feeds the device,
it never runs on it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class StreamBuffer:
    """Per-stream ring buffer of delivered (tokens [, soft-label]) rows."""
    seq_len: int
    capacity: int = 512
    tokens: Optional[np.ndarray] = None      # (n, S)
    soft: Optional[np.ndarray] = None        # (n, S, V) teacher labels
    delivered_total: int = 0
    dropped_total: int = 0

    def push(self, toks: np.ndarray, soft: Optional[np.ndarray] = None):
        toks = np.asarray(toks).reshape(-1, self.seq_len)
        self.delivered_total += toks.shape[0]
        if self.tokens is None:
            self.tokens = toks
            self.soft = soft
        else:
            self.tokens = np.concatenate([self.tokens, toks])
            if soft is not None and self.soft is not None:
                self.soft = np.concatenate([self.soft, soft])
        if self.tokens.shape[0] > self.capacity:
            cut = self.tokens.shape[0] - self.capacity
            self.dropped_total += cut
            self.tokens = self.tokens[cut:]
            if self.soft is not None:
                self.soft = self.soft[cut:]

    def __len__(self) -> int:
        return 0 if self.tokens is None else self.tokens.shape[0]


class GroupPipeline:
    """Aggregates member buffers of one retraining job and serves
    member-balanced batches."""

    def __init__(self, seq_len: int, *, capacity_per_stream: int = 512,
                 seed: int = 0):
        self.seq_len = seq_len
        self.capacity = capacity_per_stream
        self.buffers: Dict[str, StreamBuffer] = {}
        self.rng = np.random.default_rng(seed)

    def ensure(self, stream_id: str) -> StreamBuffer:
        if stream_id not in self.buffers:
            self.buffers[stream_id] = StreamBuffer(
                self.seq_len, self.capacity)
        return self.buffers[stream_id]

    def deliver(self, stream_id: str, toks: np.ndarray,
                *, bandwidth_tokens: Optional[int] = None,
                soft: Optional[np.ndarray] = None):
        """Push a window of sampled sequences, truncated to the stream's
        bandwidth budget (tokens deliverable this window)."""
        toks = np.asarray(toks).reshape(-1, self.seq_len)
        if bandwidth_tokens is not None:
            n = max(0, bandwidth_tokens // self.seq_len)
            if soft is not None:
                soft = soft[:n]
            toks = toks[:n]
        if toks.shape[0]:
            self.ensure(stream_id).push(toks, soft)

    def drop_stream(self, stream_id: str):
        self.buffers.pop(stream_id, None)

    def total_rows(self) -> int:
        return sum(len(b) for b in self.buffers.values())

    def group_batch(self, batch: int, *, with_soft: bool = False
                    ) -> Optional[dict]:
        """Member-balanced sample of `batch` sequences. Returns
        {"inputs","labels"[,"teacher_logits"]} or None when empty."""
        live = {k: b for k, b in self.buffers.items() if len(b)}
        if not live:
            return None
        per = max(1, batch // len(live))
        rows, softs = [], []
        for b in live.values():
            idx = self.rng.integers(0, len(b), size=min(per, len(b)))
            rows.append(b.tokens[idx])
            if with_soft and b.soft is not None:
                softs.append(b.soft[idx])
        toks = np.concatenate(rows)
        if toks.shape[0] < batch:
            # top up from the pooled rows so short buffers don't shrink
            # the batch (with replacement; the pool is small by design)
            pool = np.concatenate([b.tokens for b in live.values()])
            extra = self.rng.integers(0, pool.shape[0],
                                      size=batch - toks.shape[0])
            toks = np.concatenate([toks, pool[extra]])
        toks = toks[:batch]
        out = {"inputs": toks, "labels": toks}
        if with_soft and softs:
            out["teacher_logits"] = np.concatenate(softs)[:batch]
        return out

    def stats(self) -> dict:
        return {k: {"rows": len(b), "delivered": b.delivered_total,
                    "dropped": b.dropped_total}
                for k, b in self.buffers.items()}
