"""Deterministic fleet scenario library — the workload zoo.

Ekya/RECL-class systems are evaluated across diverse drift patterns,
not one synthetic fleet shape. Each generator here builds a seeded,
fully deterministic `FleetScenario` on top of `DomainBank`/`Region`
(same substrate as `make_fleet`), so every benchmark, golden trace, and
regression test can name a workload and get the identical fleet back:

  * drift_wave            — a domain switch sweeps region by region
                            across space (rolling front, staggered in
                            time like a weather system).
  * diurnal               — day/night domain recurrence; every region
                            oscillates between two domains with a fixed
                            period (drift that *repeats*).
  * camera_churn          — streams join and leave mid-run (`churn`
                            events applied by the scenario runner at
                            window boundaries).
  * flash_crowd           — at one instant every region snaps to the
                            SAME domain (a city-wide event): maximal
                            cross-camera correlation.
  * bandwidth_contention  — one drift event under a tight shared
                            bottleneck, heterogeneous per-camera
                            uplink caps, and a profiled §3.2
                            sampling-config table (`profile` spec).

Hostile scenarios (ROADMAP item 3) push the same planes to their
failure boundaries — the regimes Ekya/RECL report as worst-case and
the benign five never enter (see docs/scenarios.md "Hostile
scenarios"):

  * flash_crowd_10k       — a huge camera cohort joins in ONE window
                            (default 10k; override `joiners` for
                            smoke), then drifts together one window
                            later: RowRegistry/JobBank growth and
                            grouper shortlisting under a request storm.
  * sensor_blackout       — an entire region's streams fail together
                            mid-run (correlated leave events); compose
                            with FleetElastic device loss in
                            benchmarks/bench_faults.py.
  * oscillating_drift     — every region's domain flips EVERY window,
                            tuned to thrash join/evict regrouping.
  * bandwidth_collapse    — shared + local caps drop ~100x mid-retrain
                            (`BandwidthEvent`), exercising GAIMD decay
                            and the zero-bandwidth delivery path.

A scenario is `make_fleet`-compatible: `.bank`/`.streams` slot in
anywhere `make_fleet`'s return does, and `shared_bandwidth` /
`local_caps` / `churn` carry the scenario's resource shape to the
controller (see repro.testing.trace.run_scenario and
benchmarks/bench_scalability.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.data.streams import DomainBank, Region, Stream


@dataclasses.dataclass
class ChurnEvent:
    """Fleet membership change applied BEFORE running window `window`."""
    window: int
    kind: str                      # "join" | "leave"
    stream_id: str
    stream: Optional[Stream] = None    # populated for joins


@dataclasses.dataclass
class BandwidthEvent:
    """Network-resource change applied BEFORE running window `window`:
    the scenario runner overwrites the controller's shared bottleneck
    and/or per-camera uplink caps (None fields keep the current
    value). Models backhaul degradation/recovery mid-run — the caps a
    live fleet sees are not a constant of the deployment."""
    window: int
    shared_bandwidth: Optional[float] = None
    local_caps: Optional[Dict[str, float]] = None


@dataclasses.dataclass
class FleetScenario:
    name: str
    bank: DomainBank
    streams: List[Stream]          # fleet at t=0
    windows: int                   # suggested run length
    seed: int
    window_seconds: float = 10.0
    shared_bandwidth: float = 1e9
    local_caps: Optional[Dict[str, float]] = None
    churn: List[ChurnEvent] = dataclasses.field(default_factory=list)
    # §3.2 profiled sampling-config table as PLAIN DATA (data/ cannot
    # import core/): {"configs": [[rate, resolution], ...],
    # "acc": [[budget_level, cfg_idx, acc], ...]}. The scenario runner
    # materializes it via transmission.ProfileTable.from_spec. None =
    # the controller's fixed-sampling default.
    profile: Optional[dict] = None
    # mid-run network-resource changes (see BandwidthEvent), applied by
    # the scenario runner at window boundaries like `churn`
    bandwidth: List[BandwidthEvent] = dataclasses.field(
        default_factory=list)

    def events_at(self, window: int) -> List[ChurnEvent]:
        return [e for e in self.churn if e.window == window]

    def bandwidth_events_at(self, window: int) -> List[BandwidthEvent]:
        return [e for e in self.bandwidth if e.window == window]


def _place_streams(bank: DomainBank, region: Region, center,
                   n: int, rng: np.random.Generator, *, prefix: str,
                   spread: float = 10.0, seed: int = 0) -> List[Stream]:
    out = []
    for s in range(n):
        loc = (center[0] + rng.uniform(-spread, spread),
               center[1] + rng.uniform(-spread, spread))
        out.append(Stream(f"{prefix}_{s}", bank, region, loc,
                          lag=rng.uniform(0.0, 2.0), seed=seed + s))
    return out


def _mk(bank_seed: int, vocab: int, num_domains: int, dim: int
        ) -> Tuple[DomainBank, np.random.Generator]:
    bank = DomainBank(vocab, num_domains, dim=dim, seed=bank_seed)
    return bank, np.random.default_rng(bank_seed + 1)


def drift_wave(*, regions: int = 4, streams_per_region: int = 2,
               vocab: int = 64, num_domains: int = 6, dim: int = 4,
               wave_start: float = 5.0, wave_step: float = 10.0,
               windows: int = 8, seed: int = 0) -> FleetScenario:
    """A drift front sweeps across regions in spatial order: region r
    switches domain at wave_start + r * wave_step. Nearby regions drift
    at nearby times — the cross-camera-correlation premise with a
    *temporal* gradient (grouping must track the moving front)."""
    bank, rng = _mk(seed, vocab, num_domains, dim)
    streams: List[Stream] = []
    for r in range(regions):
        doms = rng.permutation(num_domains)
        sched = [(0.0, int(doms[0])),
                 (wave_start + r * wave_step, int(doms[1]))]
        region = Region(f"region{r}", sched)
        streams += _place_streams(bank, region, (r * 1000.0, 0.0),
                                  streams_per_region, rng,
                                  prefix=f"cam{r}", seed=seed + 10 * r)
    return FleetScenario("drift_wave", bank, streams, windows, seed)


def diurnal(*, regions: int = 2, streams_per_region: int = 3,
            vocab: int = 64, num_domains: int = 6, dim: int = 4,
            period: float = 40.0, windows: int = 10,
            seed: int = 0) -> FleetScenario:
    """Day/night recurrence: each region alternates between two domains
    every period/2 for the whole horizon. Drift the fleet has seen
    before — the regime where model reuse and stable grouping pay."""
    bank, rng = _mk(seed, vocab, num_domains, dim)
    horizon = windows * 10.0 + period
    streams: List[Stream] = []
    for r in range(regions):
        doms = rng.permutation(num_domains)
        day, night = int(doms[0]), int(doms[1])
        sched = [(0.0, day)]
        t, cur = period / 2.0, night
        while t < horizon:
            sched.append((t, cur))
            cur = night if cur == day else day
            t += period / 2.0
        region = Region(f"region{r}", sched)
        streams += _place_streams(bank, region, (r * 1000.0, 0.0),
                                  streams_per_region, rng,
                                  prefix=f"cam{r}", seed=seed + 10 * r)
    return FleetScenario("diurnal", bank, streams, windows, seed)


def camera_churn(*, regions: int = 2, streams_per_region: int = 2,
                 vocab: int = 64, num_domains: int = 6, dim: int = 4,
                 switch_time: float = 10.0, join_window: int = 2,
                 leave_window: int = 5, windows: int = 8,
                 seed: int = 0) -> FleetScenario:
    """Streams join and leave mid-run: one extra camera per region
    comes online at `join_window`, and the first camera of region 0
    goes dark at `leave_window`. Exercises detector-row / index /
    job-membership churn paths end to end."""
    bank, rng = _mk(seed, vocab, num_domains, dim)
    streams: List[Stream] = []
    churn: List[ChurnEvent] = []
    for r in range(regions):
        doms = rng.permutation(num_domains)
        sched = [(0.0, int(doms[0])),
                 (switch_time + 5.0 * r, int(doms[1]))]
        region = Region(f"region{r}", sched)
        streams += _place_streams(bank, region, (r * 1000.0, 0.0),
                                  streams_per_region, rng,
                                  prefix=f"cam{r}", seed=seed + 10 * r)
        late = _place_streams(bank, region, (r * 1000.0, 0.0), 1, rng,
                              prefix=f"late{r}", seed=seed + 500 + r)[0]
        churn.append(ChurnEvent(window=join_window, kind="join",
                                stream_id=late.stream_id, stream=late))
    churn.append(ChurnEvent(window=leave_window, kind="leave",
                            stream_id=streams[0].stream_id))
    return FleetScenario("camera_churn", bank, streams, windows, seed,
                         churn=churn)


def flash_crowd(*, regions: int = 3, streams_per_region: int = 2,
                vocab: int = 64, num_domains: int = 6, dim: int = 4,
                flash_time: float = 15.0, windows: int = 8,
                seed: int = 0) -> FleetScenario:
    """At `flash_time` every region snaps to one shared event domain
    (city-wide incident). All cameras drift simultaneously and
    identically — the best case for group retraining, the worst case
    for per-stream budgets."""
    bank, rng = _mk(seed, vocab, num_domains, dim)
    event_dom = int(rng.integers(0, num_domains))
    streams: List[Stream] = []
    for r in range(regions):
        base = int((event_dom + 1 + r) % num_domains)
        region = Region(f"region{r}", [(0.0, base),
                                       (flash_time, event_dom)])
        streams += _place_streams(bank, region, (r * 1000.0, 0.0),
                                  streams_per_region, rng,
                                  prefix=f"cam{r}", seed=seed + 10 * r)
    return FleetScenario("flash_crowd", bank, streams, windows, seed)


def bandwidth_contention(*, regions: int = 2, streams_per_region: int = 4,
                         vocab: int = 64, num_domains: int = 6,
                         dim: int = 4, switch_time: float = 10.0,
                         shared_bandwidth: float = 48.0,
                         cap_range: Tuple[float, float] = (4.0, 24.0),
                         windows: int = 8, seed: int = 0) -> FleetScenario:
    """One drift event under a tight shared bottleneck plus seeded
    heterogeneous per-camera uplink caps — the regime where GAIMD's
    GPU-share-proportional bandwidth (vs equal share) matters."""
    bank, rng = _mk(seed, vocab, num_domains, dim)
    streams: List[Stream] = []
    for r in range(regions):
        doms = rng.permutation(num_domains)
        sched = [(0.0, int(doms[0])),
                 (switch_time + 5.0 * r, int(doms[1]))]
        region = Region(f"region{r}", sched)
        streams += _place_streams(bank, region, (r * 1000.0, 0.0),
                                  streams_per_region, rng,
                                  prefix=f"cam{r}", seed=seed + 10 * r)
    caps = {s.stream_id: float(rng.uniform(*cap_range)) for s in streams}
    # a profiled §3.2 sampling-config table (rates at the streams'
    # native 32-token resolution — the controller's ring pool holds
    # fixed-width rows): higher budget levels profile best at higher
    # sampling rates, with seeded jitter so the argmax isn't degenerate
    rates = (2, 4, 8)
    acc = [[lvl, i,
            round(0.35 + 0.10 * lvl * (i + 1) / len(rates)
                  + float(rng.uniform(0.0, 0.02)), 6)]
           for lvl in range(4) for i in range(len(rates))]
    profile = {"configs": [[r, 32] for r in rates], "acc": acc}
    return FleetScenario("bandwidth_contention", bank, streams, windows,
                         seed, shared_bandwidth=shared_bandwidth,
                         local_caps=caps, profile=profile)


# ---------------------------------------------------------------------------
# hostile scenarios (ROADMAP item 3): the failure-boundary regimes.
# Same substrate and determinism contract as the benign five; sized by
# parameters so goldens/smoke can run them tiny while benchmarks run
# them at full hostility.
# ---------------------------------------------------------------------------
def flash_crowd_10k(*, joiners: int = 10_000, base_regions: int = 2,
                    streams_per_region: int = 2, vocab: int = 64,
                    num_domains: int = 6, dim: int = 4,
                    join_window: int = 1, windows: int = 5,
                    window_seconds: float = 10.0,
                    seed: int = 0) -> FleetScenario:
    """A `joiners`-camera cohort joins the fleet in ONE window, then the
    whole cohort drifts to a shared event domain one window later: a
    registry/bank growth spike followed by a correlated request storm
    through grouping. The default 10k matches the paper's fleet-scale
    claim; goldens/smoke override `joiners` down."""
    bank, rng = _mk(seed, vocab, num_domains, dim)
    streams: List[Stream] = []
    for r in range(base_regions):
        doms = rng.permutation(num_domains)
        region = Region(f"region{r}", [(0.0, int(doms[0]))])
        streams += _place_streams(bank, region, (r * 1000.0, 0.0),
                                  streams_per_region, rng,
                                  prefix=f"cam{r}", seed=seed + 10 * r)
    calm = int(rng.integers(0, num_domains))
    event_dom = int((calm + 1) % num_domains)
    # the cohort shares one region that flips ONE window after the
    # join, so every joiner's deployment-time drift reference (set at
    # join) is invalidated simultaneously
    crowd = Region("crowd", [(0.0, calm),
                             ((join_window + 1) * window_seconds,
                              event_dom)])
    late = _place_streams(bank, crowd, (5000.0, 5000.0), joiners, rng,
                          prefix="crowd", spread=50.0, seed=seed + 900)
    churn = [ChurnEvent(window=join_window, kind="join",
                        stream_id=s.stream_id, stream=s) for s in late]
    return FleetScenario("flash_crowd_10k", bank, streams, windows, seed,
                         window_seconds=window_seconds, churn=churn)


def sensor_blackout(*, regions: int = 3, streams_per_region: int = 2,
                    vocab: int = 64, num_domains: int = 6, dim: int = 4,
                    switch_time: float = 5.0, blackout_window: int = 2,
                    blackout_region: int = 0, windows: int = 5,
                    seed: int = 0) -> FleetScenario:
    """Correlated failure: every stream of one region goes dark in the
    same window, AFTER that region drifted and grouped — its group must
    die cleanly (members, pooled data, detector/index/tx rows) while
    the rest of the fleet keeps retraining. Compose with FleetElastic
    device loss for the full drill (benchmarks/bench_faults.py)."""
    bank, rng = _mk(seed, vocab, num_domains, dim)
    streams: List[Stream] = []
    for r in range(regions):
        doms = rng.permutation(num_domains)
        sched = [(0.0, int(doms[0])),
                 (switch_time + 5.0 * r, int(doms[1]))]
        region = Region(f"region{r}", sched)
        streams += _place_streams(bank, region, (r * 1000.0, 0.0),
                                  streams_per_region, rng,
                                  prefix=f"cam{r}", seed=seed + 10 * r)
    doomed = [s for s in streams
              if s.region.region_id == f"region{blackout_region}"]
    churn = [ChurnEvent(window=blackout_window, kind="leave",
                        stream_id=s.stream_id) for s in doomed]
    return FleetScenario("sensor_blackout", bank, streams, windows, seed,
                         churn=churn)


def oscillating_drift(*, regions: int = 2, streams_per_region: int = 2,
                      vocab: int = 64, num_domains: int = 6, dim: int = 4,
                      flip_every: float = 10.0, windows: int = 6,
                      seed: int = 0) -> FleetScenario:
    """Every region's domain flips EVERY `flip_every` seconds (default:
    once per window) between two alternatives for the whole horizon —
    each window's data contradicts the distribution the group just
    retrained on, thrashing Alg. 2's evict/requeue/regroup loop at its
    maximum rate."""
    bank, rng = _mk(seed, vocab, num_domains, dim)
    horizon = windows * 10.0 + flip_every
    streams: List[Stream] = []
    for r in range(regions):
        doms = rng.permutation(num_domains)
        a, b = int(doms[0]), int(doms[1])
        sched = [(0.0, a)]
        t, cur = flip_every, b
        while t < horizon:
            sched.append((t, cur))
            cur = b if cur == a else a
            t += flip_every
        region = Region(f"region{r}", sched)
        streams += _place_streams(bank, region, (r * 1000.0, 0.0),
                                  streams_per_region, rng,
                                  prefix=f"cam{r}", seed=seed + 10 * r)
    return FleetScenario("oscillating_drift", bank, streams, windows,
                         seed)


def bandwidth_collapse(*, regions: int = 2, streams_per_region: int = 3,
                       vocab: int = 64, num_domains: int = 6, dim: int = 4,
                       switch_time: float = 5.0,
                       shared_bandwidth: float = 48.0,
                       cap_range: Tuple[float, float] = (4.0, 24.0),
                       collapse_window: int = 2,
                       collapse_factor: float = 100.0,
                       recover_window: Optional[int] = None,
                       windows: int = 6, seed: int = 0) -> FleetScenario:
    """bandwidth_contention's fleet, but the backhaul collapses ~100x
    (shared bottleneck AND per-camera caps) mid-retrain: GAIMD must
    decay every flow to the starved regime and §3.2 compression must
    take the zero/near-zero-delivery path instead of forcing tokens
    through. `recover_window` (optional) restores the original caps to
    exercise the additive-increase ramp back up."""
    base = bandwidth_contention(
        regions=regions, streams_per_region=streams_per_region,
        vocab=vocab, num_domains=num_domains, dim=dim,
        switch_time=switch_time, shared_bandwidth=shared_bandwidth,
        cap_range=cap_range, windows=windows, seed=seed)
    f = float(collapse_factor)
    events = [BandwidthEvent(
        window=collapse_window, shared_bandwidth=shared_bandwidth / f,
        local_caps={k: v / f for k, v in base.local_caps.items()})]
    if recover_window is not None:
        events.append(BandwidthEvent(
            window=recover_window, shared_bandwidth=shared_bandwidth,
            local_caps=dict(base.local_caps)))
    return dataclasses.replace(base, name="bandwidth_collapse",
                               bandwidth=events)


SCENARIOS: Dict[str, Callable[..., FleetScenario]] = {
    "drift_wave": drift_wave,
    "diurnal": diurnal,
    "camera_churn": camera_churn,
    "flash_crowd": flash_crowd,
    "bandwidth_contention": bandwidth_contention,
    "flash_crowd_10k": flash_crowd_10k,
    "sensor_blackout": sensor_blackout,
    "oscillating_drift": oscillating_drift,
    "bandwidth_collapse": bandwidth_collapse,
}

#: the adversarial subset (ROADMAP item 3) — what the invariant
#: harness golden-pins and CI's adversarial-smoke job sweeps
HOSTILE_SCENARIOS = ("flash_crowd_10k", "sensor_blackout",
                     "oscillating_drift", "bandwidth_collapse")


def build_scenario(name: str, *, seed: int = 0, **kw) -> FleetScenario:
    """Build a named scenario (see SCENARIOS) with overrides."""
    try:
        gen = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}") from None
    return gen(seed=seed, **kw)
