"""Pallas TPU kernels for the substrate hot spots.

flash_attention — blockwise online-softmax attention (GQA-aware index
    maps, causal + sliding-window), grid (B, H, nq, nk) with VMEM
    accumulator carry on the sequential kv dim.
mlstm_scan — chunkwise-parallel mLSTM with the (C, n, m) matrix-memory
    state carried in VMEM scratch across the sequential chunk dim.
ssd_scan — Mamba-2 SSD chunk scan, (P x N) state in VMEM scratch.

ops.py dispatches pallas/interpret/xla/ref; ref.py holds the pure-jnp
sequential oracles every kernel is swept against (tests/test_kernels.py).
The paper itself has no kernel-level contribution — these optimize the
training/serving substrate its control plane drives (DESIGN.md §6).
"""
