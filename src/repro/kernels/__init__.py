"""Pallas TPU kernels for the substrate hot spots.

flash_attention — blockwise online-softmax attention (GQA-aware index
    maps, causal + sliding-window), grid (B, H, nq, nk) with VMEM
    accumulator carry on the sequential kv dim.
mlstm_scan — chunkwise-parallel mLSTM with the (C, n, m) matrix-memory
    state carried in VMEM scratch across the sequential chunk dim.
ssd_scan — Mamba-2 SSD chunk scan, (P x N) state in VMEM scratch.
pairwise_js — batched (N, M) Jensen-Shannon divergence between stream
    drift-signature histograms: grid (nN, nM), a (TN, TM) output tile
    per cell from the (TN, TM, B) broadcast of m = (p+q)/2, all fp32.
    This is the similarity engine behind fleet-scale dynamic grouping
    (core.signature_index.SignatureIndex shortlists the jobs that pay
    the expensive eval_on model check in Alg. 2).

ops.py dispatches every op across four impls:
    "pallas"    — compiled Pallas kernel (TPU only)
    "interpret" — the same kernel in interpret mode (CPU correctness)
    "xla"       — pure-jnp blockwise/chunked form (fast everywhere; for
                  pairwise_js a lax.map over q blocks bounding peak
                  memory at (N, block, B))
    "ref"       — materialize-everything oracle in ref.py (tests only)
    "auto"      — pallas on TPU, xla elsewhere
ref.py holds the pure-jnp oracles every kernel is swept against
(tests/test_kernels.py); _compat.py smooths Pallas API renames across
jax versions. The paper itself has no kernel-level contribution — these
optimize the substrate its control plane drives (DESIGN.md §6).
"""
